"""End-to-end driver: train a language model for a few hundred steps with
checkpoint/restart, on whatever devices exist.

Default preset is CPU-sized; `--preset 100m` trains the real xlstm-125m
config (use on a TPU host).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

ap = argparse.ArgumentParser()
ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train",
       "--steps", str(args.steps), "--ckpt-every", "100"]
if args.preset == "tiny":
    cmd += ["--arch", "qwen3-0.6b", "--smoke", "--seq", "128",
            "--batch", "8"]
else:
    cmd += ["--arch", "xlstm-125m", "--seq", "1024", "--batch", "16"]

env = {"PYTHONPATH": str(ROOT / "src")}
import os
env = {**os.environ, **env}
raise SystemExit(subprocess.run(cmd, env=env).returncode)
