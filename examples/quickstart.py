"""Quickstart: build a circuit with the DSL, compile it with the static-BSP
compiler, and simulate it on the lockstep engine — all public API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.netlist import Circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.compile import compile_circuit
from repro.core.bsp import Machine

# --- 1. describe hardware: a 24-bit counter driving a blinking LED pattern
c = Circuit("blinky")
cnt = c.reg(24, init=0, name="cnt")
c.set_next(cnt, cnt + 1)
led = c.reg(8, init=1, name="led")
rot = (led << 1) | (led >> 7)               # rotate
c.set_next(led, c.mux(cnt[3:0].eq(0), rot, led))
c.output("led", led)
c.finish_when(cnt.eq(1000), eid=1)          # $finish after 1000 cycles

# --- 2. reference simulation (the oracle)
sim = NetlistSim(c)
cycles, _ = sim.run(2000)
print(f"oracle finished at cycle {cycles}, led={sim.reg_value('led'):#04x}")

# --- 3. compile for a Manticore grid (static BSP: split -> merge -> LUT
#        fusion -> list schedule -> collision-free NoC routes)
prog = compile_circuit(c, HardwareConfig(grid_width=4, grid_height=4))
print(f"compiled: {prog.used_cores} cores, VCPL={prog.vcpl} "
      f"(machine cycles per simulated RTL cycle)")
print(f"predicted hardware rate at 475 MHz: {475e6 / prog.vcpl / 1e3:.0f} kHz")

# --- 4. execute on the vectorized lockstep engine (JAX)
m = Machine(prog)
st = m.run(m.init_state(), 2000)
assert m.perf(st)["vcycles"] == cycles
assert m.read_reg(st, "led") == sim.reg_value("led")
print(f"engine matches oracle: led={m.read_reg(st, 'led'):#04x}, "
      f"exceptions={m.exceptions(st)}")
