"""Quickstart: build a circuit with the DSL, then compile *and* simulate it
through the unified ``repro.sim`` front door — one facade call per step,
with the netlist oracle, the lockstep engine and the persistent Program
artifact all behind the same API (see ``docs/api.md``).

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import repro.sim as sim
from repro.core import Circuit, HardwareConfig

# --- 1. describe hardware: a 24-bit counter driving a blinking LED pattern
c = Circuit("blinky")
cnt = c.reg(24, init=0, name="cnt")
c.set_next(cnt, cnt + 1)
led = c.reg(8, init=1, name="led")
rot = (led << 1) | (led >> 7)               # rotate
c.set_next(led, c.mux(cnt[3:0].eq(0), rot, led))
c.output("led", led)
c.finish_when(cnt.eq(1000), eid=1)          # $finish after 1000 cycles

# --- 2. compile for a Manticore grid (static BSP: lower -> opt -> split ->
#        merge -> LUT fusion -> list schedule -> collision-free NoC routes)
s = sim.compile(c, HardwareConfig(grid_width=4, grid_height=4))
prog = s.program
print(f"compiled: {prog.used_cores} cores, VCPL={prog.vcpl} "
      f"(machine cycles per simulated RTL cycle)")
print(f"predicted hardware rate at 475 MHz: {475e6 / prog.vcpl / 1e3:.0f} kHz")

# --- 3. reference simulation (the netlist oracle, same Engine protocol)
ref = s.run(2000, engine="oracle")
print(f"oracle finished at cycle {ref.cycles}, led={ref.registers['led']:#04x}")

# --- 4. execute on the vectorized lockstep engine (JAX) — same RunResult
res = s.run(2000)
assert res.cycles == ref.cycles
assert res.registers["led"] == ref.registers["led"]
print(f"engine matches oracle: led={res.registers['led']:#04x}, "
      f"exceptions={res.exceptions}")

# --- 5. the compiled Program is a persistent artifact: save, reload, rerun
with tempfile.TemporaryDirectory() as td:
    path = Path(td) / "blinky.npz"
    s.save(path)
    res2 = sim.load(path).run(2000)
    assert res2.registers == res.registers
    print(f"artifact round-trip OK ({path.stat().st_size} bytes on disk)")
