"""Scenario: simulate the rv32r benchmark (a ring of 16 tiny processors) on
the full static-BSP stack via the ``repro.sim`` facade, with an elastic
mid-run grid migration — the fault-tolerance path a long simulation would
take if its machine allocation changed. Both grids compile through an
on-disk compile cache (scoped to this run; point ``cache=`` at a fixed
directory — or pass ``cache=True`` for ``~/.cache/repro-sim`` — to skip
the middle-end across runs too), and the final recompile demonstrates the
warm path: a pure artifact load, ``Simulation.cache_hit``.

    PYTHONPATH=src python examples/simulate_accelerator.py
"""
import tempfile

import repro.sim as sim
from repro.core import HardwareConfig
from repro.runtime import elastic

bench_name = "rv32r"
_cache_tmp = tempfile.TemporaryDirectory(prefix="repro-sim-cache-")
cache_dir = _cache_tmp.name

# compile for a small grid, run half way
sa = sim.compile(bench_name, HardwareConfig(grid_width=5, grid_height=5),
                 cache=cache_dir)
print(f"benchmark: rv32r ring, finishes at cycle {sa.n_cycles}")
print(f"5x5 grid: {sa.program.used_cores} cores used, "
      f"VCPL={sa.program.vcpl} (cache_hit={sa.cache_hit})")
ea = sa.engine()
half = sa.n_cycles // 2
ra = ea.run(half)
print(f"ran {ra.cycles} cycles on the 5x5 grid")

# "the job got a bigger allocation": recompile for 15x15 and migrate the
# architectural state (registers + memories) by name
sb = sim.compile(bench_name, HardwareConfig(grid_width=15, grid_height=15),
                 cache=cache_dir)
print(f"15x15 grid: {sb.program.used_cores} cores used, "
      f"VCPL={sb.program.vcpl} "
      f"({sa.program.vcpl / sb.program.vcpl:.2f}x fewer machine cycles "
      f"per Vcycle)")
eb = sb.engine()
eb.state = elastic.migrate(sa.program, ea.state, sb.program, eb.m)
rb = eb.run(sb.n_cycles)
total = rb.cycles + half
assert rb.finished, rb.exceptions
print(f"migrated run finished cleanly at cycle {total} "
      f"(expected {sb.n_cycles}) — state carried over exactly")

# a second compile of either grid is a pure cache hit (middle-end skipped)
sc = sim.compile(bench_name, HardwareConfig(grid_width=15, grid_height=15),
                 cache=cache_dir)
assert sc.cache_hit
print(f"warm recompile: cache_hit={sc.cache_hit}")
