"""Scenario: simulate the rv32r benchmark (a ring of 16 tiny processors) on
the full static-BSP stack, with an elastic mid-run grid migration — the
fault-tolerance path a long simulation would take if its machine allocation
changed.

    PYTHONPATH=src python examples/simulate_accelerator.py
"""
import numpy as np

from repro.circuits import build, FINISH
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig
from repro.runtime import elastic

bench = build("rv32r", "full")
print(f"benchmark: rv32r ring, finishes at cycle {bench.n_cycles}")

# compile for a small grid, run half way
hw_small = HardwareConfig(grid_width=5, grid_height=5)
prog_a = compile_circuit(bench.circuit, hw_small)
print(f"5x5 grid: {prog_a.used_cores} cores used, VCPL={prog_a.vcpl}")
ma = Machine(prog_a)
half = bench.n_cycles // 2
st = ma.run(ma.init_state(), half)
print(f"ran {ma.perf(st)['vcycles']} cycles on the 5x5 grid")

# "the job got a bigger allocation": recompile for 15x15 and migrate the
# architectural state (registers + memories) by name
hw_big = HardwareConfig(grid_width=15, grid_height=15)
prog_b = compile_circuit(bench.circuit, hw_big)
print(f"15x15 grid: {prog_b.used_cores} cores used, VCPL={prog_b.vcpl} "
      f"({prog_a.vcpl / prog_b.vcpl:.2f}x fewer machine cycles per Vcycle)")
mb = Machine(prog_b)
st_b = elastic.migrate(prog_a, st, prog_b, mb)
st_b = mb.run(st_b, bench.n_cycles)
total = int(np.asarray(st_b.counters)[0]) + half
assert set(mb.exceptions(st_b).values()) == {FINISH}
print(f"migrated run finished cleanly at cycle {total} "
      f"(expected {bench.n_cycles}) — state carried over exactly")
