"""Roofline: measured engine throughput vs the static-BSP machine model.

A compiled Program fixes everything the machine will do: ``vcpl`` machine
cycles per simulated RTL cycle — the steady-state initiation interval when
cross-Vcycle modulo pipelining shipped, the barrier VCPL otherwise. The
hardware roofline for a circuit is ``MANTICORE_CLOCK_HZ / vcpl`` simulated
Vcycles/sec (paper Table 2 prototype clock; pipelining raises the ceiling
exactly where the II beat the VCPL), and the schedule's accounting says how
much of the machine each Vcycle actually uses (``useful_fraction`` — mean
non-NOP slots per used core over the Vcycle) and where the ceiling comes
from (``bottleneck``: ``epilogue`` when the SEND-drain tail dominates,
``compute`` otherwise).

Per circuit this bench compiles through the ``repro.sim`` facade (both the
5x5 bench grid it measures on and the paper's 15x15 evaluation grid for the
model-side numbers), measures the specialized jnp engine's Vcycles/sec, and
reports the fraction of the respective roofline the interpreter reaches —
the honest gap a real accelerator backend has to close (ROADMAP: "as fast
as the hardware allows").

Emits ``results/bench/roofline.json`` via the shared driver.

  PYTHONPATH=src python -m benchmarks.roofline            # all nine
  PYTHONPATH=src python -m benchmarks.roofline bc --smoke # CI smoke
"""
from __future__ import annotations

import sys

import jax

from benchmarks.common import MANTICORE_CLOCK_HZ, best_time, row_csv, \
    run_rows
import repro.sim as sim
from repro.circuits import CIRCUITS
from repro.core import HardwareConfig

HW_RUN = HardwareConfig(grid_width=5, grid_height=5)      # measured grid
HW_PAPER = HardwareConfig(grid_width=15, grid_height=15)  # model grid
REPS = 3
EPILOGUE_BOUND = 0.25    # epilogue share above which the NoC tail dominates


def _model(prog) -> dict:
    """Machine-model terms for one compiled Program.

    ``prog.vcpl`` is the *shipped* machine-cycles-per-Vcycle: the
    steady-state initiation interval when cross-Vcycle pipelining won the
    best-of-two, the barrier VCPL otherwise — so the roofline is the
    pipelined machine's bound whenever pipelining is on. The unpipelined
    span is reported alongside for the delta."""
    st = prog.stats
    vcpl = max(prog.vcpl, 1)
    return {
        "vcpl": int(prog.vcpl),
        "vcpl_unpipelined": int(st.get("vcpl_unpipelined", prog.vcpl)),
        "pipeline_pick": str(st.get("pipeline_pick", "off")),
        "t_compute": int(prog.t_compute),
        "model_vcycles_per_s": MANTICORE_CLOCK_HZ / vcpl,
        "useful_fraction": float(st["core_load_mean"]) / vcpl,
        "epilogue_share": float(st["epilogue_share"]),
        "bottleneck": ("epilogue"
                       if st["epilogue_share"] > EPILOGUE_BOUND
                       else "compute"),
    }


def bench_circuit(nm: str, scale: str, reps: int) -> dict:
    s_run = sim.compile(nm, HW_RUN, scale=scale)
    s_model = sim.compile(nm, HW_PAPER, scale=scale)
    row = {"circuit": nm, "scale": scale,
           "grid_run": [HW_RUN.grid_width, HW_RUN.grid_height],
           "grid_model": [HW_PAPER.grid_width, HW_PAPER.grid_height],
           "run": _model(s_run.program),
           "model": _model(s_model.program)}
    n = min(max(8, (s_run.n_cycles or 16) - 2), 128)
    eng = s_run.engine("jnp")
    m = eng.m

    def once():
        jax.block_until_ready(m.run(m.init_state(), n).regs)
    rate = n / best_time(once, reps)
    row["vcycles"] = n
    row["jnp_vcycles_per_s"] = rate
    row["roofline_fraction"] = rate / row["run"]["model_vcycles_per_s"]
    row_csv(f"roofline/{nm}", 1e6 / rate,
            f"{row['model']['bottleneck']} "
            f"useful {row['model']['useful_fraction']:.2f} "
            f"frac {row['roofline_fraction']:.4f}")
    return row


def run(names=None, smoke: bool = False):
    scale = "small" if smoke else "full"
    reps = 1 if smoke else REPS
    run_rows([nm for nm in sorted(CIRCUITS) if not names or nm in names],
             lambda nm: bench_circuit(nm, scale, reps),
             "roofline", smoke,
             lambda rows: "interpreter reaches %.4f of the hw roofline at "
             "best; %d/%d circuits epilogue-bound on the paper grid" % (
                 max((r["roofline_fraction"] for r in rows), default=0.0),
                 sum(r["model"]["bottleneck"] == "epilogue" for r in rows),
                 len(rows)))


if __name__ == "__main__":
    argv = sys.argv[1:]
    run([a for a in argv if not a.startswith("-")] or None,
        smoke="--smoke" in argv)
