"""Roofline report: reads results/dryrun/*.json, emits the per-cell table
(markdown to stdout + results/bench/roofline.json)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit, row_csv

DRYRUN = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "16x16", tag: str = ""):
    rows = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}{tag}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            rows.append(rec)
            continue
        rows.append(rec)
    return rows


def table(rows):
    out = ["| arch | shape | bottleneck | t_comp (s) | t_mem (s) | "
           "t_coll (s) | useful/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['bottleneck']} | "
            f"{rf['t_compute']:.2e} | {rf['t_memory']:.2e} | "
            f"{rf['t_collective']:.2e} | {rf['useful_fraction']:.2f} | "
            f"{rf['roofline_fraction']:.2f} |")
    return "\n".join(out)


def run():
    rows = load("16x16")
    print(table(rows))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        row_csv("roofline/cells", float(len(ok)),
                f"worst={worst['arch']}/{worst['shape']}"
                f"@{worst['roofline']['roofline_fraction']:.2f}")
    emit("roofline", rows)
    return rows
