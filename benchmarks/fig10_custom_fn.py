"""Fig 10: custom-function (LUT) synthesis ablation — VCPL and non-NOp
instruction reduction with custom instructions on/off.

Both arms run on the *optimized* IR (``optimize=True``, explicit since
PR 3): the ablation isolates LUT fusion, not the middle-end — and the
post-opt IR is where copy propagation exposes the larger fanout-free
logic cones the cut enumeration feeds on.
"""
from __future__ import annotations

from repro.circuits import build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

from .common import emit, row_csv

NAMES = ["bc", "mc", "cgra", "mm", "rv32r", "jpeg", "noc", "blur", "vta"]


def run():
    rows = []
    hw = HardwareConfig(grid_width=15, grid_height=15)
    for nm in NAMES:
        b = build(nm, "full")
        on = compile_circuit(b.circuit, hw, use_luts=True, optimize=True)
        off = compile_circuit(b.circuit, hw, use_luts=False, optimize=True)
        rows.append({
            "bench": nm,
            "opt_baseline": True,
            "instrs_post_opt": on.stats["instrs_opt"],
            "vcpl_on": on.vcpl, "vcpl_off": off.vcpl,
            "vcpl_ratio": on.vcpl / off.vcpl,
            "instrs_on": on.stats["instrs"], "instrs_off": off.stats["instrs"],
            "instr_reduction_pct":
                100.0 * (off.stats["instrs"] - on.stats["instrs"]) /
                max(off.stats["instrs"], 1),
            "lut_instrs": on.stats["lut_instrs"],
            "lut_tables": on.stats["lut_tables"],
        })
        row_csv(f"fig10/{nm}", 0.0,
                f"instr -{rows[-1]['instr_reduction_pct']:.1f}% "
                f"vcpl x{rows[-1]['vcpl_ratio']:.2f}")
    emit("fig10_custom_fn", rows)
    return rows
