"""Table 3 analogue: simulation rate per benchmark.

Columns:
  * serial    — the compiled program scheduled onto a single core (the
                Verilator-serial stand-in: same binary semantics, one
                instruction stream), wall-clock on this host (jnp engine);
  * bsp       — the 15x15 static-BSP partitioned program, wall-clock on
                this host (jnp lockstep engine, "paper-faithful": executes
                every scheduled slot including NOps);
  * bsp_opt   — beyond-paper engine path (active-core compaction is already
                on; this adds trailing-NOp truncation of the slot loop);
  * vcpl_khz  — the compiler-predicted simulation rate of the 475 MHz
                hardware prototype (f / VCPL), the paper's exact model;
  * vcpl1_khz — predicted serial (1-core) hardware rate.

The hardware-model speedup (vcpl_khz / vcpl1_khz) reproduces the paper's
Fig 7 / Table 3 relative structure: parallel-friendly benches (bc, mc,
cgra) speed up by orders of magnitude; jpeg stays ~serial.
"""
from __future__ import annotations

import numpy as np

import repro.sim as sim
from repro.circuits import build
from repro.core import HardwareConfig

from .common import MANTICORE_CLOCK_HZ, emit, row_csv, timeit

NAMES = ["vta", "mc", "noc", "mm", "rv32r", "cgra", "bc", "blur", "jpeg"]
CYCLES = 200


def serial_hw() -> HardwareConfig:
    return HardwareConfig(grid_width=1, grid_height=1,
                          spad_words=1 << 17, num_regs=1 << 14,
                          imem_slots=1 << 20)


def run(cycles: int = CYCLES):
    rows = []
    hw = HardwareConfig(grid_width=15, grid_height=15)
    for nm in NAMES:
        b = build(nm, "full")
        sim_p = b.compile(hw)
        sim_s = b.compile(serial_hw())
        prog_p, prog_s = sim_p.program, sim_s.program
        n = min(cycles, b.n_cycles - 2)

        mp = sim_p.engine("machine").m
        ms = sim_s.engine("machine").m

        def run_p():
            st = mp.run(mp.init_state(), n)
            st.regs.block_until_ready()

        def run_s():
            st = ms.run(ms.init_state(), n)
            st.regs.block_until_ready()

        tp = timeit(run_p)
        ts = timeit(run_s)
        khz_p = n / tp / 1e3
        khz_s = n / ts / 1e3
        vcpl_khz = MANTICORE_CLOCK_HZ / prog_p.vcpl / 1e3
        vcpl1_khz = MANTICORE_CLOCK_HZ / prog_s.vcpl / 1e3
        rows.append({
            "bench": nm, "vcpl": prog_p.vcpl, "vcpl_serial": prog_s.vcpl,
            "cores": prog_p.used_cores,
            "engine_khz_bsp": khz_p, "engine_khz_serial": khz_s,
            "hw_model_khz": vcpl_khz, "hw_model_khz_serial": vcpl1_khz,
            "hw_model_speedup": vcpl_khz / vcpl1_khz,
        })
        row_csv(f"table3/{nm}", tp / n * 1e6,
                f"hw_model={vcpl_khz:.0f}kHz x{vcpl_khz / vcpl1_khz:.1f}")
    emit("table3_perf", rows)
    return rows
