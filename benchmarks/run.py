"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.row_csv)
and writes JSON rows under results/bench/.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3 fig9
"""
from __future__ import annotations

import sys
import time

MODULES = [
    "bench_engine",       # engine Vcycles/sec trajectory (jnp/pallas/isasim)
    "bench_batch",        # batched-stimulus aggregate Vcycles/sec vs B
    "bench_compile",      # middle-end payoff: instrs/VCPL/throughput opt vs off
    "bench_serve",        # serving: coalesced dynamic batching vs B=1 daemon
    "table3_perf",        # Table 3: main performance comparison
    "fig7_scaling",       # Fig 7:  VCPL multicore scaling
    "fig8_global_stall",  # Fig 8:  FIFO/RAM global-stall microbenchmarks
    "fig9_partitioning",  # Fig 9 + Table 4: partitioner ablation
    "fig10_custom_fn",    # Fig 10: custom-instruction ablation
    "table8_compile_time",  # Table 8 / Fig 14: compile-time breakdown
    "fig5_sync_model",    # Fig 5:  sync-cost model
    "table1_grid",        # Table 1 analogue: executor throughput vs grid
    "roofline",           # §Roofline: per (arch x shape) dry-run terms
]


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")]
    failures = 0
    for mod in MODULES:
        if want and not any(w in mod for w in want):
            continue
        t0 = time.time()
        print(f"# === {mod} ===", flush=True)
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["run"])
            m.run()
        except Exception as e:  # noqa
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"# {mod} FAILED: {e}", flush=True)
        print(f"# {mod} took {time.time() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
