"""Serving throughput: coalesced dynamic batching vs sequential B=1.

The PR 9 headline: a long-lived daemon (``repro.serve``) that keeps hot
compiled Simulations resident and coalesces concurrent same-fingerprint
requests into one batched launch should beat the same daemon forced to
``max_batch=1`` (one launch per request — the "no dynamic batching"
deployment) on aggregate requests/sec at batch-64-scale concurrency.

Traffic is *mixed*: N requests per circuit for two circuits (mc + bc —
structure-seed-invariant builders, so per-request results are provably
bit-exact against independent ``sim.compile(name, seeds=[s]).run()``
runs, which this bench spot-checks and records). Each mode gets an
unmeasured warmup wave at the same concurrency (compiles through the
shared on-disk cache + XLA traces are one-time serving costs), then a
measured wave on fresh seeds (per-seed init-plane building and host →
device image stacking stay inside the measured region — they are real
per-request serving work).

A third ``hardened`` arm runs the coalesced policy with the full
fault-tolerance machinery attached (an all-zero ``FaultPlan``, circuit
breakers, the retry/bisection path) but no fault ever firing — the
recovery layer must cost <10% rps on the happy path.

Emits ``results/bench/BENCH_serve.json`` and a root-level copy
(``BENCH_serve.json``): one row per mode (rps, p50/p95 latency, observed
batch sizes) plus a summary row with the rps speedup and the
hardened/coalesced rps ratio. Exits non-zero if coalescing does not beat
B=1, the hardened arm loses >10% rps, or any sampled result is not
bit-exact.

  PYTHONPATH=src python -m benchmarks.bench_serve           # N=64/circuit
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # N=8, CI
"""
from __future__ import annotations

import asyncio
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

import repro.sim as sim
from benchmarks.common import emit, row_csv
from repro.core import HardwareConfig
from repro.serve import (BatchPolicy, FaultPlan, RetryPolicy,
                         SessionManager, SimRequest, SimServer)

HWD = {"grid_width": 5, "grid_height": 5}
HW = HardwareConfig(**HWD)
NAMES = ["mc", "bc"]
SCALE = "small"
N_PER_CIRCUIT = 64
N_SMOKE = 8
MAX_WAIT_S = 0.03
EXACT_SAMPLES = 3          # per circuit, vs individual compile+run


def _policy(mode: str) -> BatchPolicy:
    if mode in ("coalesced", "hardened"):
        return BatchPolicy(max_batch=64, max_wait_s=MAX_WAIT_S,
                           max_queue=4096)
    return BatchPolicy(max_batch=1, max_wait_s=0.0, max_queue=4096)


def _reqs(names: List[str], scale: str, n: int, seed0: int
          ) -> List[SimRequest]:
    """n requests per circuit, interleaved — the mixed-traffic shape."""
    return [SimRequest(nm, scale=scale, seed=seed0 + i, hw=HWD)
            for i in range(n) for nm in names]


async def _wave(server: SimServer, reqs: List[SimRequest]):
    """Fire every request concurrently; per-request latency + wall time."""
    lat: Dict[str, float] = {}

    async def one(r: SimRequest):
        t0 = time.perf_counter()
        resp = await server.submit(r)
        lat[r.rid] = time.perf_counter() - t0
        return resp

    t0 = time.perf_counter()
    resps = await asyncio.gather(*(one(r) for r in reqs))
    wall = time.perf_counter() - t0
    return resps, wall, [lat[r.rid] for r in reqs]


async def _bench_mode(mode: str, names: List[str], scale: str, n: int,
                      cache_dir: str) -> dict:
    # "hardened" = coalesced policy + the full fault-tolerance machinery
    # attached (an all-zero FaultPlan, breaker bookkeeping, retry/bisect
    # paths armed) with no fault ever firing — measures the overhead of
    # the recovery layer on the happy path
    faults = FaultPlan(seed=0) if mode == "hardened" else None
    server = SimServer(
        sessions=SessionManager(cache=cache_dir, max_sessions=8,
                                faults=faults),
        policy=_policy(mode), faults=faults,
        retry=RetryPolicy() if mode == "hardened" else None)
    try:
        # warmup wave: compiles (warm via the shared cache after the first
        # mode) and the XLA trace for this mode's steady-state batch shape
        warm, _, _ = await _wave(server, _reqs(names, scale, n, 10_000))
        bad = [r for r in warm if not r.ok]
        if bad:
            raise RuntimeError(f"warmup failed: {bad[0].error}")
        stats0 = dict(server.batcher.stats)

        resps, wall, lats = await _wave(server, _reqs(names, scale, n, 1))
        bad = [r for r in resps if not (r.ok and r.result.finished)]
        if bad:
            raise RuntimeError(
                f"{len(bad)} requests failed in measured wave "
                f"(first: {bad[0].status} {bad[0].error})")

        stats = server.batcher.stats
        launches = stats["launches"] - stats0["launches"]
        launched = stats["launched_requests"] - stats0["launched_requests"]
        row = {
            "mode": mode,
            "scale": scale,
            "circuits": list(names),
            "n_requests": len(resps),
            "wall_s": wall,
            "rps": len(resps) / wall,
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p95_ms": float(np.percentile(lats, 95) * 1e3),
            "launches": launches,
            "mean_batch": launched / max(launches, 1),
            "max_seen_batch": stats["max_seen_batch"],
            "mean_run_s": float(np.mean([r.run_s for r in resps])),
            "engine_kinds": sorted({r.engine_kind for r in resps}),
            "sessions_resident": len(server.sessions.resident()),
        }
        # spot-check bit-exactness of served results against independent
        # single-stimulus compiles of the same (circuit, seed)
        exact = True
        checked = 0
        for q, r in zip(_reqs(names, scale, n, 1), resps):
            if q.seed - 1 >= EXACT_SAMPLES:
                continue
            ref = sim.compile(q.circuit, HW, scale=scale, seeds=[q.seed],
                              cache=cache_dir).run()
            exact = exact and ref.finished and (
                r.result.cycles == ref.cycles
                and r.result.registers == ref.registers
                and r.result.outputs == ref.outputs
                and r.result.exceptions == ref.exceptions)
            checked += 1
        row["bit_exact_samples"] = checked
        row["bit_exact_vs_individual"] = bool(exact)
        return row
    finally:
        await server.close()


async def _run_async(names: List[str], scale: str, n: int,
                     cache_dir: str) -> List[dict]:
    rows = []
    for mode in ("coalesced", "b1", "hardened"):
        row = await _bench_mode(mode, names, scale, n, cache_dir)
        row_csv(f"serve/{mode}", 1e6 / row["rps"],
                f"p95={row['p95_ms']:.0f}ms_meanB={row['mean_batch']:.1f}")
        rows.append(row)
    coal, b1, hard = rows[0], rows[1], rows[2]
    rows.append({
        "mode": "summary",
        "scale": scale,
        "n_requests": coal["n_requests"],
        "speedup_rps": coal["rps"] / b1["rps"],
        "p50_ratio": coal["p50_ms"] / b1["p50_ms"],
        "p95_ratio": coal["p95_ms"] / b1["p95_ms"],
        # the fault-tolerance layer with zero faults armed should be
        # ~free: hardened rps within a few % of plain coalesced
        "hardened_rps_ratio": hard["rps"] / coal["rps"],
    })
    return rows


def run(names=None, smoke: bool = False) -> None:
    names = names or NAMES
    n = N_SMOKE if smoke else N_PER_CIRCUIT
    # a private compile cache shared by both modes: the coalesced mode's
    # warmup pays the cold compiles, b1 warm-starts from disk — neither
    # measured wave ever compiles
    with tempfile.TemporaryDirectory(prefix="bench_serve_cache_") as cd:
        rows = asyncio.run(_run_async(list(names), SCALE, n, cd))
    emit("BENCH_serve" + ("_smoke" if smoke else ""), rows,
         root=not smoke)
    summary = rows[-1]
    coal = rows[0]
    print(f"# serve: coalesced {coal['rps']:.1f} rps "
          f"(mean batch {coal['mean_batch']:.1f}) vs b1 "
          f"{rows[1]['rps']:.1f} rps -> "
          f"{summary['speedup_rps']:.2f}x aggregate rps; "
          f"hardened/coalesced rps ratio "
          f"{summary['hardened_rps_ratio']:.3f}")
    if summary["speedup_rps"] <= 1.0:
        raise SystemExit("bench_serve: coalescing did not beat the B=1 "
                         f"baseline ({summary['speedup_rps']:.2f}x)")
    if summary["hardened_rps_ratio"] < 0.90:
        raise SystemExit(
            "bench_serve: fault-tolerance machinery cost >10% rps with "
            f"no faults armed (ratio {summary['hardened_rps_ratio']:.3f})")
    if not all(r.get("bit_exact_vs_individual", True) for r in rows):
        raise SystemExit("bench_serve: served results diverged from "
                         "individual compile+run references")


if __name__ == "__main__":
    argv = sys.argv[1:]
    names = [a for a in argv if not a.startswith("-")] or None
    run(names, smoke="--smoke" in argv)
