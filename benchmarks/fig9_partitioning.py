"""Fig 9 + Table 4: communication-aware balanced partitioning (B) vs
longest-processing-time-first (L): VCPL (normalized to L) and Send counts.

Both arms run on the *optimized* IR (``optimize=True``, explicit since
PR 3): the partitioner ablation isolates the merge strategy, not the
middle-end, so Table 4 numbers stay comparable across PRs as passes land.
"""
from __future__ import annotations

from repro.circuits import build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

from .common import emit, row_csv

NAMES = ["mm", "mc", "vta", "noc", "cgra", "rv32r", "bc", "blur", "jpeg"]


def run():
    rows = []
    hw = HardwareConfig(grid_width=15, grid_height=15)
    for nm in NAMES:
        b = build(nm, "full")
        pb = compile_circuit(b.circuit, hw, strategy="balanced",
                             optimize=True)
        pl = compile_circuit(b.circuit, hw, strategy="lpt", optimize=True)
        rows.append({
            "bench": nm,
            "opt_baseline": True,
            "instrs_post_opt": pb.stats["instrs_opt"],
            "vcpl_B": pb.vcpl, "vcpl_L": pl.vcpl,
            "vcpl_ratio": pb.vcpl / pl.vcpl,
            "sends_B": pb.stats["sends"], "sends_L": pl.stats["sends"],
            "sends_delta_pct":
                100.0 * (pb.stats["sends"] - pl.stats["sends"]) /
                max(pl.stats["sends"], 1),
            "cores_B": pb.used_cores, "cores_L": pl.used_cores,
            "nops_B": pb.stats["nops"], "nops_L": pl.stats["nops"],
        })
        row_csv(f"fig9/{nm}", 0.0,
                f"vcpl B/L={rows[-1]['vcpl_ratio']:.2f} "
                f"sends {rows[-1]['sends_delta_pct']:+.0f}%")
    emit("fig9_partitioning", rows)
    return rows
