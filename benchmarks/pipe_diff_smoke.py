"""CI smoke: cross-Vcycle pipelined programs stay bit-exact vs the oracle.

Every benchmark circuit is compiled with the default
``pipeline="modulo"`` (schedule validator on — cross-iteration RAW
distances, modulo resource claims and commit-order safety are re-checked)
and executed to its self-checking FINISH on two independent executors:

  * the vectorized numpy ISA simulator (rotated prologue dispatch), and
  * the specialized jnp engine (``core.bsp.Machine``);

both must finish at the oracle's cycle with the oracle's exception set and
bit-identical architectural registers. The smoke also asserts the
best-of-two pick actually ships a pipelined schedule on at least one
circuit *with a non-empty retimed prologue* — otherwise the rotated
dispatch paths would silently stop being covered.

  PYTHONPATH=src python -m benchmarks.pipe_diff_smoke
"""
from __future__ import annotations

from repro.circuits import CIRCUITS, FINISH, build
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim

HW = HardwareConfig(grid_width=5, grid_height=5)


def run() -> None:
    picks, prologues = [], []
    # all nine at the cheap small scale, plus full-scale bc — the circuit
    # whose shipped schedule carries a retimed prologue on this grid, so
    # the rotated prologue dispatch is exercised end to end
    jobs = [(nm, "small") for nm in sorted(CIRCUITS)] + [("bc", "full")]
    for nm, scale in jobs:
        b = build(nm, scale)
        prog = compile_circuit(b.circuit, HW, pipeline="modulo", check=True)
        picks.append(prog.stats["pipeline_pick"])
        prologues.append(prog.pipe_prologue)
        assert prog.vcpl <= prog.stats["vcpl_unpipelined"], \
            f"{nm}: shipped II {prog.vcpl} exceeds the unpipelined vcpl"
        ref = NetlistSim(b.circuit)
        ref.run(b.n_cycles + 10)

        sim = IsaSim(prog)
        assert sim.run(b.n_cycles + 10) == b.n_cycles, nm
        assert set(sim.exceptions().values()) == {FINISH}, nm
        for rname in prog.state_regs:
            assert sim.read_reg(rname) == ref.reg_value(rname), \
                f"{nm}: isasim register {rname} differs from oracle"

        m = Machine(prog)
        st = m.run(m.init_state(), b.n_cycles + 10)
        assert m.perf(st)["vcycles"] == b.n_cycles, nm
        assert set(m.exceptions(st).values()) == {FINISH}, nm
        for rname in prog.state_regs:
            assert m.read_reg(st, rname) == ref.reg_value(rname), \
                f"{nm}: jnp engine register {rname} differs from oracle"
        print(f"# {nm}/{scale}: pick={prog.stats['pipeline_pick']} "
              f"ii={prog.vcpl} vcpl={prog.stats['vcpl_unpipelined']} "
              f"prologue={prog.pipe_prologue} bit-exact")
    assert "modulo" in picks, "no circuit shipped a pipelined schedule"
    assert any(p > 0 for p in prologues), \
        "no circuit shipped a retimed prologue — rotated dispatch uncovered"
    print(f"# pipe_diff_smoke OK: {len(picks)} circuits, "
          f"{picks.count('modulo')} pipelined, "
          f"max prologue {max(prologues)}")


if __name__ == "__main__":
    run()
