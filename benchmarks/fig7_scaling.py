"""Fig 7 analogue: compiler-predicted VCPL scaling vs core count.

The paper's own methodology: "speedup numbers are predicted by Manticore's
compiler, since the compiler can accurately count cycles"."""
from __future__ import annotations

from repro.circuits import build
from repro.core import HardwareConfig

from .common import emit, row_csv

GRIDS = [(1, 1), (2, 2), (4, 4), (8, 8), (15, 15), (18, 18)]
NAMES = ["bc", "mc", "cgra", "rv32r", "jpeg", "noc"]


def run():
    rows = []
    for nm in NAMES:
        b = build(nm, "full")
        base = None
        for (w, h) in GRIDS:
            hw = HardwareConfig(grid_width=w, grid_height=h,
                                spad_words=1 << 17 if w == 1 else 16384,
                                num_regs=1 << 14 if w == 1 else 2048,
                                imem_slots=1 << 20 if w == 1 else 4096)
            prog = b.compile(hw).program
            if base is None:
                base = prog.vcpl
            rows.append({"bench": nm, "cores": w * h, "vcpl": prog.vcpl,
                         "used_cores": prog.used_cores,
                         "speedup": base / prog.vcpl})
        row_csv(f"fig7/{nm}", 0.0,
                f"speedup@{GRIDS[-1][0]*GRIDS[-1][1]}={base / prog.vcpl:.1f}")
    emit("fig7_scaling", rows)
    return rows
