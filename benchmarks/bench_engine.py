"""Engine throughput: simulated Vcycles/second per circuit and backend.

First entry in the repo's perf trajectory (PR 1): measures the partially-
evaluated fast path (``Machine(specialize=True)`` — opcode-set-specialized
slots, compact SEND capture, chunked K-Vcycle dispatch) against the seed
engine (``specialize=False`` — compute-all-select, full [T, C] trace,
per-Vcycle while_loop), plus the Pallas chunk kernel in interpret mode and
the vectorized numpy ISA simulator.

Emits ``results/bench/BENCH_engine.json`` and a copy at the repo root
(``BENCH_engine.json``) so the trajectory is easy to diff across PRs. Rows
are written incrementally and one circuit's failure cannot blank the whole
artifact (PR 2 fix: the committed artifact had been ``[]``).

  PYTHONPATH=src python -m benchmarks.bench_engine            # all circuits
  PYTHONPATH=src python -m benchmarks.bench_engine bc mm      # a subset
  PYTHONPATH=src python -m benchmarks.bench_engine bc --smoke # CI smoke
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import best_time, row_csv, run_rows
import repro.sim as sim
from repro.circuits import CIRCUITS
from repro.core import HardwareConfig

HW = HardwareConfig(grid_width=5, grid_height=5)
REPS = 3


def _rate_machine(m, n: int, reps: int = REPS) -> float:
    """Vcycles/sec of a raw core.bsp.Machine (timed without the facade's
    RunResult probe sweep, keeping rows comparable across PRs)."""
    def once():
        jax.block_until_ready(m.run(m.init_state(), n).regs)
    return n / best_time(once, reps)


def _rate_isasim(prog, n: int, reps: int = REPS) -> float:
    from repro.core.isasim import IsaSim
    best = float("inf")
    for _ in range(reps):
        sim = IsaSim(prog)
        t0 = time.perf_counter()
        sim.run(n)
        best = min(best, time.perf_counter() - t0)
    return n / best


def bench_circuit(nm: str, scale: str = "full", reps: int = REPS) -> dict:
    # LUT-free compile: the specialization headline the paper-style
    # engines target (no 16-pattern loop anywhere in the schedule)
    s = sim.compile(nm, HW, scale=scale, use_luts=False)
    b, prog = s.bench, s.program
    # stay below the FINISH cycle; cap the cycle count so the slow seed
    # arm keeps the whole sweep in seconds
    n = min(max(8, b.n_cycles - 2), 128)

    row = {
        "circuit": nm,
        "scale": scale,
        "t_compute": prog.t_compute,
        "used_cores": prog.used_cores,
        "n_sends": prog.n_sends,
        "n_ops": len(prog.op_set()),
        "lut_free": True,
        "vcycles": n,
    }
    new = s.engine("machine").m
    row["jnp_vcycles_per_s"] = _rate_machine(new, n, reps)
    seed = s.engine("seed").m
    row["seed_vcycles_per_s"] = _rate_machine(seed, n, reps)
    row["speedup_vs_seed"] = (row["jnp_vcycles_per_s"]
                              / row["seed_vcycles_per_s"])
    row["isasim_vcycles_per_s"] = _rate_isasim(prog, n, reps)
    if not prog.has_global:
        pal = s.engine("pallas", interpret=True).m
        row["pallas_interpret_vcycles_per_s"] = _rate_machine(pal, n, reps)
    else:
        row["pallas_interpret_vcycles_per_s"] = None

    # bit-exactness of the fast path against the seed engine
    st_new = new.run(new.init_state(), b.n_cycles + 10)
    st_seed = seed.run(seed.init_state(), b.n_cycles + 10)
    row["bit_exact_vs_seed"] = bool(
        np.array_equal(np.asarray(st_new.regs), np.asarray(st_seed.regs))
        and np.array_equal(np.asarray(st_new.spads),
                           np.asarray(st_seed.spads))
        and np.array_equal(np.asarray(st_new.flags),
                           np.asarray(st_seed.flags)))
    row_csv(f"engine/{nm}", 1e6 / row["jnp_vcycles_per_s"],
            f"{row['speedup_vs_seed']:.2f}x_vs_seed")
    return row


def run(names=None, smoke: bool = False) -> None:
    scale = "small" if smoke else "full"
    reps = 1 if smoke else REPS
    run_rows([nm for nm in sorted(CIRCUITS) if not names or nm in names],
             lambda nm: bench_circuit(nm, scale, reps),
             "BENCH_engine", smoke,
             lambda rows: "best jnp speedup vs seed engine: %.2fx"
             % max((r["speedup_vs_seed"] for r in rows), default=0.0))


if __name__ == "__main__":
    argv = sys.argv[1:]
    run([a for a in argv if not a.startswith("-")] or None,
        smoke="--smoke" in argv)
