"""Engine throughput: simulated Vcycles/second per circuit and backend.

First entry in the repo's perf trajectory (PR 1): measures the partially-
evaluated fast path (``Machine(specialize=True)`` — opcode-set-specialized
slots, compact SEND capture, chunked K-Vcycle dispatch) against the seed
engine (``specialize=False`` — compute-all-select, full [T, C] trace,
per-Vcycle while_loop), plus the Pallas chunk kernel in interpret mode and
the vectorized numpy ISA simulator.

Emits ``results/bench/BENCH_engine.json`` and a copy at the repo root
(``BENCH_engine.json``) so the trajectory is easy to diff across PRs.

  PYTHONPATH=src python -m benchmarks.bench_engine            # all circuits
  PYTHONPATH=src python -m benchmarks.bench_engine bc mm      # a subset
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import RESULTS, emit, row_csv
from repro.circuits import CIRCUITS, build
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim

HW = HardwareConfig(grid_width=5, grid_height=5)
REPS = 3


def _rate_machine(m: Machine, n: int) -> float:
    st = m.init_state()
    st = m.run(st, n)                      # compile + warm
    jax.block_until_ready(st.regs)
    best = float("inf")
    for _ in range(REPS):
        st = m.init_state()
        t0 = time.perf_counter()
        st = m.run(st, n)
        jax.block_until_ready(st.regs)
        best = min(best, time.perf_counter() - t0)
    return n / best


def _rate_isasim(prog, n: int) -> float:
    best = float("inf")
    for _ in range(REPS):
        sim = IsaSim(prog)
        t0 = time.perf_counter()
        sim.run(n)
        best = min(best, time.perf_counter() - t0)
    return n / best


def run(names=None) -> None:
    rows = []
    for nm in sorted(CIRCUITS):
        if names and nm not in names:
            continue
        b = build(nm, "full")
        # LUT-free compile: the specialization headline the paper-style
        # engines target (no 16-pattern loop anywhere in the schedule)
        prog = compile_circuit(b.circuit, HW, use_luts=False)
        # stay below the FINISH cycle; cap the cycle count so the slow seed
        # arm keeps the whole sweep in seconds
        n = min(max(8, b.n_cycles - 2), 128)

        row = {
            "circuit": nm,
            "t_compute": prog.t_compute,
            "used_cores": prog.used_cores,
            "n_sends": prog.n_sends,
            "n_ops": len(prog.op_set()),
            "lut_free": True,
            "vcycles": n,
        }
        new = Machine(prog)
        row["jnp_vcycles_per_s"] = _rate_machine(new, n)
        seed = Machine(prog, specialize=False)
        row["seed_vcycles_per_s"] = _rate_machine(seed, n)
        row["speedup_vs_seed"] = (row["jnp_vcycles_per_s"]
                                  / row["seed_vcycles_per_s"])
        row["isasim_vcycles_per_s"] = _rate_isasim(prog, n)
        if not prog.has_global:
            pal = Machine(prog, backend="pallas", interpret=True)
            row["pallas_interpret_vcycles_per_s"] = _rate_machine(pal, n)
        else:
            row["pallas_interpret_vcycles_per_s"] = None

        # bit-exactness of the fast path against the seed engine
        st_new = new.run(new.init_state(), b.n_cycles + 10)
        st_seed = seed.run(seed.init_state(), b.n_cycles + 10)
        row["bit_exact_vs_seed"] = bool(
            np.array_equal(np.asarray(st_new.regs), np.asarray(st_seed.regs))
            and np.array_equal(np.asarray(st_new.spads),
                               np.asarray(st_seed.spads))
            and np.array_equal(np.asarray(st_new.flags),
                               np.asarray(st_seed.flags)))

        rows.append(row)
        row_csv(f"engine/{nm}", 1e6 / row["jnp_vcycles_per_s"],
                f"{row['speedup_vs_seed']:.2f}x_vs_seed")

    emit("BENCH_engine", rows)
    # root-level copy: the cross-PR perf trajectory marker
    root = Path(__file__).resolve().parents[1] / "BENCH_engine.json"
    root.write_text(json.dumps(rows, indent=1))
    best = max((r["speedup_vs_seed"] for r in rows), default=0.0)
    print(f"# best jnp speedup vs seed engine: {best:.2f}x")


if __name__ == "__main__":
    run([a for a in sys.argv[1:] if not a.startswith("-")] or None)
