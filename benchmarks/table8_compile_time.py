"""Table 8 / Fig 14: compile times with per-pass breakdown.

Since PR 3 the breakdown includes the optimizing middle-end: the aggregate
``pass_opt`` wall time plus, from ``Program.stats["opt_passes"]``, the
per-optimization-pass time and instruction delta (``opt_<pass>_s`` /
``opt_<pass>_removed``, summed over pipeline rounds). ``instrs_lowered``
vs ``instrs_post_opt`` is the middle-end's input/output — note that
optimization usually *reduces* total compile time: the partitioner,
scheduler and register allocator chew on the smaller IR.

Since PR 6 the breakdown also includes the partition-aware
rematerialization pass (``pass_remat``) and the slack scheduler
(``pass_schedule`` now covers two priority passes); ``remat_sends`` counts
the NoC messages the pass converted into local recompute.
"""
from __future__ import annotations

import time

from repro.circuits import build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

from .common import emit, row_csv

NAMES = ["vta", "mc", "noc", "mm", "rv32r", "cgra", "bc", "blur", "jpeg"]


def run():
    rows = []
    hw = HardwareConfig(grid_width=15, grid_height=15)
    for nm in NAMES:
        b = build(nm, "full")
        tm = {}
        t0 = time.perf_counter()
        prog = compile_circuit(b.circuit, hw, timings=tm)
        total = time.perf_counter() - t0
        opt_cols = {}
        for r in prog.stats["opt_passes"]:
            opt_cols[f"opt_{r['pass']}_s"] = (
                opt_cols.get(f"opt_{r['pass']}_s", 0.0) + r["seconds"])
            opt_cols[f"opt_{r['pass']}_removed"] = (
                opt_cols.get(f"opt_{r['pass']}_removed", 0)
                + r["instrs_before"] - r["instrs_after"])
        rows.append({"bench": nm, "total_s": total,
                     "nodes": len(b.circuit.nodes),
                     "instrs": prog.stats["instrs"],
                     "instrs_lowered": prog.stats["instrs_lowered"],
                     "instrs_post_opt": prog.stats["instrs_opt"],
                     "split_procs": prog.stats["split_procs"],
                     "vcpl": prog.vcpl,
                     "remat_sends": prog.stats["remat_sends"],
                     **{f"pass_{k}": v for k, v in tm.items()},
                     **opt_cols})
        worst = max(tm, key=tm.get)
        removed = prog.stats["instrs_lowered"] - prog.stats["instrs_opt"]
        row_csv(f"table8/{nm}", total * 1e6,
                f"dominant_pass={worst}({tm[worst]:.2f}s) "
                f"opt-{removed}instrs({tm.get('opt', 0.0):.2f}s)")
    emit("table8_compile_time", rows)
    return rows
