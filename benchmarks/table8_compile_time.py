"""Table 8 / Fig 14: compile times with per-pass breakdown."""
from __future__ import annotations

import time

from repro.circuits import build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

from .common import emit, row_csv

NAMES = ["vta", "mc", "noc", "mm", "rv32r", "cgra", "bc", "blur", "jpeg"]


def run():
    rows = []
    hw = HardwareConfig(grid_width=15, grid_height=15)
    for nm in NAMES:
        b = build(nm, "full")
        tm = {}
        t0 = time.perf_counter()
        prog = compile_circuit(b.circuit, hw, timings=tm)
        total = time.perf_counter() - t0
        rows.append({"bench": nm, "total_s": total,
                     "nodes": len(b.circuit.nodes),
                     "instrs": prog.stats["instrs"],
                     "split_procs": prog.stats["split_procs"],
                     **{f"pass_{k}": v for k, v in tm.items()}})
        worst = max(tm, key=tm.get)
        row_csv(f"table8/{nm}", total * 1e6,
                f"dominant_pass={worst}({tm[worst]:.2f}s)")
    emit("table8_compile_time", rows)
    return rows
