"""Table 1 analogue: FPGA clock-vs-grid-size is physical design, which has
no CPU analogue; the engine-side equivalent is executor throughput
(slots/sec across all lanes) as the simulated grid grows."""
from __future__ import annotations

from repro.circuits import build
from repro.core import HardwareConfig

from .common import emit, row_csv, timeit

GRIDS = [(4, 4), (8, 8), (15, 15)]


def run():
    rows = []
    b = build("cgra", "full")
    for (w, h) in GRIDS:
        s = b.compile(HardwareConfig(grid_width=w, grid_height=h))
        prog = s.program
        m = s.engine("machine").m
        n = 64

        def go():
            st = m.run(m.init_state(), n)
            st.regs.block_until_ready()

        t = timeit(go)
        slots = n * prog.t_compute * prog.used_cores
        rows.append({"grid": f"{w}x{h}", "used_cores": prog.used_cores,
                     "vcpl": prog.vcpl,
                     "engine_slots_per_s": slots / t,
                     "engine_khz": n / t / 1e3})
        row_csv(f"table1/{w}x{h}", t / n * 1e6,
                f"{slots / t / 1e6:.1f}M slots/s")
    emit("table1_grid", rows)
    return rows
