"""Batched-stimulus throughput: aggregate simulated Vcycles/sec vs. B.

The PR 2 headline: the static BSP schedule is compiled once per *design*,
so B independent testbench stimuli (different reg/spad/gmem init planes,
identical code) can share one device launch (``core.bsp.BatchedMachine``).
This bench measures aggregate throughput (B * vcycles / wall-time) for
B ∈ {1, 8, 64} against the honest baseline — B *sequential* runs of the
PR 1 specialized single-stimulus engine — and records per-element
bit-exactness of the batched run against those baselines.

Emits ``results/bench/BENCH_batch.json`` and a root-level copy
(``BENCH_batch.json``).

  PYTHONPATH=src python -m benchmarks.bench_batch             # bc mc cgra
  PYTHONPATH=src python -m benchmarks.bench_batch bc --smoke  # CI smoke
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from benchmarks.common import best_time, row_csv, run_rows
import repro.sim as sim
from repro.core import HardwareConfig
from repro.core.bsp import BatchedMachine, Machine

HW = HardwareConfig(grid_width=5, grid_height=5)
# full-scale LUT-free circuits spanning the utilization range: dense
# (bc, cgra), sparse (mc), serial (jpeg) and network (rv32r) schedules
NAMES = ["bc", "mc", "cgra", "jpeg", "rv32r"]
BATCHES = [1, 8, 64]
REPS = 3


def _time_batched(bm: BatchedMachine, n: int, reps: int) -> float:
    """Wall time for one batched launch of a raw core.bsp.BatchedMachine
    (the facade's RunResult probe sweep stays out of the timed region so
    rows stay comparable across PRs)."""
    def once():
        jax.block_until_ready(bm.run(bm.init_state(), n).regs)
    return best_time(once, reps)


def _time_sequential(m: Machine, images, n: int, reps: int) -> float:
    def once():
        for img in images:
            st = m.run(m.init_state(images=img), n)
        jax.block_until_ready(st.regs)
    return best_time(once, reps)


def bench_circuit(nm: str, scale: str, batches, reps: int) -> dict:
    bmax = max(batches)
    s = sim.compile(nm, HW, scale=scale,
                    seeds=[1000 + i for i in range(bmax)], use_luts=False)
    bench, prog = s.bench, s.program
    images = s.images()
    n = min(max(8, bench.n_cycles - 2), 128)

    single = s.engine("machine", images=None).m   # PR 1 specialized engine
    row = {
        "circuit": nm,
        "scale": scale,
        "t_compute": prog.t_compute,
        "used_cores": prog.used_cores,
        "lut_free": True,
        "vcycles": n,
        "points": [],
    }
    for B in batches:
        imgs = images[:B]
        bm = s.engine("batched", images=imgs).m
        t_b = _time_batched(bm, n, reps)
        t_seq = _time_sequential(single, imgs, n, reps)
        agg_b = B * n / t_b
        agg_seq = B * n / t_seq
        row["points"].append({
            "B": B,
            "batched_agg_vcycles_per_s": agg_b,
            "sequential_agg_vcycles_per_s": agg_seq,
            "speedup_vs_sequential": agg_b / agg_seq,
        })
        row_csv(f"batch/{nm}/B{B}", 1e6 * t_b / (B * n),
                f"{agg_b / agg_seq:.2f}x_vs_seq")

    # per-element bit-exactness at the largest batch, against independent
    # single-stimulus runs of the same stimuli
    bm = s.engine("batched").m
    st = bm.run(bm.init_state(), bench.n_cycles + 10)
    exact = True
    for i, img in enumerate(images):
        s1 = single.run(single.init_state(images=img), bench.n_cycles + 10)
        exact = exact and (
            np.array_equal(np.asarray(st.regs[i]), np.asarray(s1.regs))
            and np.array_equal(np.asarray(st.spads[i]),
                               np.asarray(s1.spads))
            and np.array_equal(np.asarray(st.flags[i]),
                               np.asarray(s1.flags)))
    row["bit_exact_vs_single"] = bool(exact)
    row["all_finish"] = bool(all(
        set(e.values()) == {1} for e in bm.exceptions(st)))
    return row


def run(names=None, smoke: bool = False) -> None:
    scale = "small" if smoke else "full"
    batches = [1, 4] if smoke else BATCHES
    reps = 1 if smoke else REPS
    run_rows(names or NAMES,
             lambda nm: bench_circuit(nm, scale, batches, reps),
             "BENCH_batch", smoke,
             lambda rows: "best batched speedup vs sequential "
             "single-stimulus: %.2fx"
             % max((p["speedup_vs_sequential"]
                    for r in rows for p in r["points"]), default=0.0))


if __name__ == "__main__":
    argv = sys.argv[1:]
    run([a for a in argv if not a.startswith("-")] or None,
        smoke="--smoke" in argv)
