"""Batched-stimulus throughput: aggregate simulated Vcycles/sec vs. B.

The PR 2 headline: the static BSP schedule is compiled once per *design*,
so B independent testbench stimuli (different reg/spad/gmem init planes,
identical code) can share one device launch (``core.bsp.BatchedMachine``).
This bench measures aggregate throughput (B * vcycles / wall-time) for
B ∈ {1, 8, 64} against the honest baseline — B *sequential* runs of the
PR 1 specialized single-stimulus engine — and records per-element
bit-exactness of the batched run against those baselines.

PR 5 adds **sharded points** when more than one device is visible
(``core.bsp.ShardedBatchedMachine``: the batch axis split ``[D, B/D]``
over the mesh): per-B ``sharded_points`` entries record D, B/D, aggregate
and per-device Vcycles/sec and the speedup over the *unsharded* batched
path at equal B — the existing ``points`` schema is unchanged for
cross-PR comparability. Refresh the artifact on forced host devices::

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.bench_batch

``--exact`` runs the sharded bit-exactness sweep instead of timing: B=64
stimuli of every benchmark circuit, each element compared against an
independent single-stimulus specialized run (``BENCH_sharded_exact``).

Emits ``results/bench/BENCH_batch.json`` and a root-level copy
(``BENCH_batch.json``).

  PYTHONPATH=src python -m benchmarks.bench_batch             # bc mc cgra
  PYTHONPATH=src python -m benchmarks.bench_batch bc --smoke  # CI smoke
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from benchmarks.common import best_time, row_csv, run_rows
import repro.sim as sim
from repro.circuits import CIRCUITS
from repro.core import HardwareConfig
from repro.core.bsp import BatchedMachine, Machine, ShardedBatchedMachine

HW = HardwareConfig(grid_width=5, grid_height=5)
# full-scale LUT-free circuits spanning the utilization range: dense
# (bc, cgra), sparse (mc), serial (jpeg) and network (rv32r) schedules
NAMES = ["bc", "mc", "cgra", "jpeg", "rv32r"]
BATCHES = [1, 8, 64]
# sharded sweep: B values (each with its own same-B unsharded reference)
# x device counts. B/D is the per-device batch: on CPU the per-op dispatch
# overhead of the specialized graph amortizes over it, so small shards of
# overhead-bound circuits lose what the mesh parallelism gains — the sweep
# records the crossover instead of a single cherry-picked point.
SHARD_BATCHES = [64, 512]
SHARD_DEVICES = [2, 4, 8]
REPS = 3
EXACT_B = 64


def _time_batched(bm: BatchedMachine, n: int, reps: int) -> float:
    """Wall time for one batched launch of a raw core.bsp.BatchedMachine
    (the facade's RunResult probe sweep stays out of the timed region so
    rows stay comparable across PRs)."""
    def once():
        jax.block_until_ready(bm.run(bm.init_state(), n).regs)
    return best_time(once, reps)


def _time_sequential(m: Machine, images, n: int, reps: int) -> float:
    def once():
        for img in images:
            st = m.run(m.init_state(images=img), n)
        jax.block_until_ready(st.regs)
    return best_time(once, reps)


def bench_circuit(nm: str, scale: str, batches, shard_batches,
                  reps: int) -> dict:
    bmax = max(batches + shard_batches) if shard_batches else max(batches)
    s = sim.compile(nm, HW, scale=scale,
                    seeds=[1000 + i for i in range(bmax)], use_luts=False)
    bench, prog = s.bench, s.program
    stacked = s.images_stacked()       # host-parallel, batched layout

    def img(i):
        return tuple(a[i] for a in stacked)

    n = min(max(8, bench.n_cycles - 2), 128)

    single = Machine(prog)                        # PR 1 specialized engine
    row = {
        "circuit": nm,
        "scale": scale,
        "t_compute": prog.t_compute,
        "used_cores": prog.used_cores,
        "lut_free": True,
        "vcycles": n,
        "points": [],
    }
    for B in batches:
        bm = BatchedMachine(prog, images=tuple(a[:B] for a in stacked))
        t_b = _time_batched(bm, n, reps)
        t_seq = _time_sequential(single, [img(i) for i in range(B)], n,
                                 reps)
        agg_b = B * n / t_b
        agg_seq = B * n / t_seq
        row["points"].append({
            "B": B,
            "batched_agg_vcycles_per_s": agg_b,
            "sequential_agg_vcycles_per_s": agg_seq,
            "speedup_vs_sequential": agg_b / agg_seq,
        })
        row_csv(f"batch/{nm}/B{B}", 1e6 * t_b / (B * n),
                f"{agg_b / agg_seq:.2f}x_vs_seq")

    # sharded points: the same batch, split [D, B/D] over the device mesh
    # (a parallel list — the ``points`` schema above is frozen for
    # cross-PR comparability). Each B carries its own same-B unsharded
    # reference so speedup_vs_unsharded is self-contained.
    D_avail = len(jax.devices())
    if D_avail > 1 and shard_batches:
        row["sharded_points"] = []
        for B in shard_batches:
            imgs = tuple(a[:B] for a in stacked)
            bm = BatchedMachine(prog, images=imgs)
            t_u = _time_batched(bm, n, reps)
            agg_u = B * n / t_u
            for D in sorted({d for d in SHARD_DEVICES if d <= D_avail}
                            | {D_avail}):
                sm = ShardedBatchedMachine(prog, images=imgs,
                                           devices=jax.devices()[:D])
                t_s = _time_batched(sm, n, reps)
                agg_s = B * n / t_s
                row["sharded_points"].append({
                    "B": B,
                    "D": D,
                    "B_per_device": sm.Bp // D,
                    "unsharded_agg_vcycles_per_s": agg_u,
                    "sharded_agg_vcycles_per_s": agg_s,
                    "per_device_vcycles_per_s": agg_s / D,
                    "speedup_vs_unsharded": agg_s / agg_u,
                })
                row_csv(f"batch/{nm}/B{B}/D{D}", 1e6 * t_s / (B * n),
                        f"{agg_s / agg_u:.2f}x_vs_unsharded")

    # per-element bit-exactness at the largest *timing* batch, against
    # independent single-stimulus runs of the same stimuli (the full
    # sharded bit-exactness sweep lives in --exact / BENCH_sharded_exact)
    Bx = max(batches)
    bm = BatchedMachine(prog, images=tuple(a[:Bx] for a in stacked))
    st = bm.run(bm.init_state(), bench.n_cycles + 10)
    exact = True
    for i in range(Bx):
        s1 = single.run(single.init_state(images=img(i)),
                        bench.n_cycles + 10)
        exact = exact and (
            np.array_equal(np.asarray(st.regs[i]), np.asarray(s1.regs))
            and np.array_equal(np.asarray(st.spads[i]),
                               np.asarray(s1.spads))
            and np.array_equal(np.asarray(st.flags[i]),
                               np.asarray(s1.flags)))
    row["bit_exact_vs_single"] = bool(exact)
    row["all_finish"] = bool(all(
        set(e.values()) == {1}
        for e in bm.exceptions(st)))
    return row


def exact_circuit(nm: str, B: int = EXACT_B) -> dict:
    """Sharded bit-exactness sweep row: run B stimuli of ``nm`` (full
    scale, default compile options) sharded over every visible device and
    compare each element against an independent single-stimulus
    specialized run — registers, scratchpads, flags and counters."""
    s = sim.compile(nm, HW, seeds=[1000 + i for i in range(B)])
    prog, bench = s.program, s.bench
    stacked = s.images_stacked()
    sm = ShardedBatchedMachine(prog, images=stacked)
    st = sm.run(sm.init_state(), bench.n_cycles + 10)
    single = Machine(prog)
    exact = True
    for i in range(B):
        img = tuple(a[i] for a in stacked)
        s1 = single.run(single.init_state(images=img), bench.n_cycles + 10)
        exact = exact and all(
            np.array_equal(np.asarray(a[i]), np.asarray(b))
            for a, b in ((st.regs, s1.regs), (st.spads, s1.spads),
                         (st.flags, s1.flags), (st.counters, s1.counters)))
    # a divergence is recorded in the row (never asserted here): the
    # artifact keeps the failing circuit visible and run_exact turns any
    # false field into a non-zero exit
    return {
        "circuit": nm,
        "B": B,
        "D": sm.D,
        "B_per_device": sm.Bp // sm.D,
        "bit_exact_vs_single": bool(exact),
        "all_finish": bool(all(set(e.values()) == {1}
                               for e in sm.exceptions(st))),
    }


def run(names=None, smoke: bool = False) -> None:
    scale = "small" if smoke else "full"
    batches = [1, 4] if smoke else BATCHES
    # the sharded sweep is the only consumer of B > max(batches): don't
    # build (or stack) the extra stimuli on a single-device host where
    # the whole sweep is skipped
    shard_batches = [] if len(jax.devices()) < 2 else \
        ([4] if smoke else SHARD_BATCHES)
    reps = 1 if smoke else REPS
    run_rows(names or NAMES,
             lambda nm: bench_circuit(nm, scale, batches, shard_batches,
                                      reps),
             "BENCH_batch", smoke,
             lambda rows: "best batched speedup vs sequential "
             "single-stimulus: %.2fx; best sharded vs unsharded: %.2fx"
             % (max((p["speedup_vs_sequential"]
                     for r in rows for p in r["points"]), default=0.0),
                max((p["speedup_vs_unsharded"] for r in rows
                     for p in r.get("sharded_points", [])), default=0.0)))


def run_exact(names=None, smoke: bool = False) -> None:
    import json

    from benchmarks.common import RESULTS

    B = 8 if smoke else EXACT_B
    run_rows(names or list(CIRCUITS),
             lambda nm: exact_circuit(nm, B),
             "BENCH_sharded_exact", smoke,
             lambda rows: "sharded bit-exact on %d/%d circuits at B=%d"
             % (sum(r["bit_exact_vs_single"] for r in rows), len(rows), B))
    artifact = "BENCH_sharded_exact" + ("_smoke" if smoke else "")
    rows = json.loads((RESULTS / f"{artifact}.json").read_text())
    bad = [r["circuit"] for r in rows if not r["bit_exact_vs_single"]]
    if bad:
        raise SystemExit(
            f"sharded runs diverged from single-stimulus runs on: "
            f"{', '.join(bad)}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    names = [a for a in argv if not a.startswith("-")] or None
    if "--exact" in argv:
        run_exact(names, smoke="--smoke" in argv)
    else:
        run(names, smoke="--smoke" in argv)
