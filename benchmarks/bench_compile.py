"""Middle-end payoff: instruction count, VCPL and throughput, opt on vs off.

Third entry in the repo's perf trajectory (PR 3): the optimizing middle-end
(``core.opt`` — constant folding, copy propagation, strength reduction,
CSE, DCE over the lowered SSA IR) runs between lower and partition, so the
partitioner, LUT synthesizer and scheduler all see fewer, simpler
instructions. Per circuit this bench records post-lower vs post-opt
instruction counts, scheduled VCPL (with the schedule's critical-path
lower bound, to tell "improved" from "already provably minimal"), compile
time, and measured simulated-Vcycles/sec of the specialized jnp engine on
the optimized vs legacy program.

Compile-model metrics (instrs, VCPL, sends) are reported on the paper's
15x15 evaluation grid — the same grid as ``fig9_partitioning`` /
``table8_compile_time``, so Table 4/8 stay comparable; engine throughput
is measured on the 5x5 bench grid the other trajectory benches use.
(Small grids can show VCPL *regressions* on dense circuits: with fewer
instructions the communication-aware merge goes further, trading Sends
for per-core serialization — e.g. cgra on 5x5. That is the partitioner's
cost model ignoring the critical path, the ROADMAP's next lever, not the
middle-end; ``vcpl_small_*`` columns keep it visible.)

Since the slack-driven scheduler landed (PR 6), each circuit also records
the **scheduler strategy comparison** — the same optimized IR scheduled by
the frozen ``"greedy"`` baseline vs the default ``"slack"`` strategy
(ASAP/ALAP mobility priorities, earliest-slot SEND reservation,
partition-aware rematerialization): ``vcpl_sched_{greedy,slack}``,
``vcpl_over_lb_*`` (distance from the critical-path lower bound),
``remat_sends`` / ``remat_instrs``, scheduler wall-time, and the shipped
schedule's per-core utilization (``util_*``: NOp-density histogram,
max/mean core load, epilogue share).

Since communication-aware placement landed (``core.place``), each circuit
also records the **communication profile of the shipped program** —
``n_sends``, ``total_hops`` (dimension-ordered route hops summed over the
exchange table), ``mean_hops_per_send`` — plus the per-placement-strategy
VCPL (``vcpl_place_anneal`` vs ``vcpl_place_identity``, same slack
scheduler) and which geometry the best-of-two pick shipped
(``place_pick``).

Since cross-Vcycle modulo pipelining landed, each circuit also records
the **steady-state initiation interval** of the shipped program
(``vcpl_ii`` — equals the unpipelined VCPL when the best-of-two pick
ships the baseline), its distance from the critical-path lower bound
(``ii_over_lb``), which arm shipped (``pipeline_pick``) and the
prologue/retiming footprint (``pipe_prologue_len``/``pipe_hoisted``).
The greedy scheduler arm is pinned ``pipeline="off"`` — it is the frozen
differential baseline and must stay byte-stable.

Since the ``repro.sim`` facade landed, each circuit also records
**cold-vs-warm compile time** through the on-disk compile cache
(``compile_s_cold`` / ``compile_s_warm`` / ``cache_speedup`` /
``artifact_bytes``): the warm pass loads the persistent Program artifact
and skips the entire middle-end.

Emits ``results/bench/BENCH_compile.json`` (root copy via
``benchmarks.common.emit``, the single artifact writer).

  PYTHONPATH=src python -m benchmarks.bench_compile             # all nine
  PYTHONPATH=src python -m benchmarks.bench_compile bc --smoke  # CI smoke
"""
from __future__ import annotations

import sys
import tempfile
import time

import jax

from benchmarks.common import best_time, row_csv, run_rows
import repro.sim as sim
from repro.circuits import CIRCUITS, build
from repro.core import HardwareConfig

HW_RUN = HardwareConfig(grid_width=5, grid_height=5)     # throughput grid
HW_PAPER = HardwareConfig(grid_width=15, grid_height=15)  # compile metrics
REPS = 3


def _program_hops(p) -> int:
    """Total dimension-ordered route hops of the shipped exchange table."""
    return sum(p.hw.route_hops(int(s), int(d))
               for s, d in zip(p.xchg_src_core, p.xchg_dst_core))


def _rate(prog, n: int, reps: int) -> float:
    m = sim.MachineEngine(prog).m

    def once():
        jax.block_until_ready(m.run(m.init_state(), n).regs)
    return n / best_time(once, reps)


def _cache_timings(b, row: dict) -> None:
    """Cold-vs-warm compile through the repro.sim on-disk cache: the cold
    pass pays lower/opt/partition/schedule/regalloc plus the artifact
    store; the warm pass is a pure artifact load (the whole middle-end is
    skipped — ``Simulation.cache_hit``)."""
    with tempfile.TemporaryDirectory(prefix="repro-bench-cache-") as td:
        t0 = time.perf_counter()
        cold = sim.compile(b, HW_PAPER, cache=td)
        row["compile_s_cold"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = sim.compile(b, HW_PAPER, cache=td)
        row["compile_s_warm"] = time.perf_counter() - t0
        assert not cold.cache_hit and warm.cache_hit
        row["cache_hit_warm"] = warm.cache_hit
        row["cache_speedup"] = (row["compile_s_cold"]
                                / max(row["compile_s_warm"], 1e-9))
        row["artifact_bytes"] = (
            sim.CompileCache(td).path(warm.meta["cache_key"])
            .stat().st_size)


def bench_circuit(nm: str, scale: str, reps: int) -> dict:
    b = build(nm, scale)
    row = {"circuit": nm, "scale": scale,
           "grid_compile": [HW_PAPER.grid_width, HW_PAPER.grid_height],
           "grid_run": [HW_RUN.grid_width, HW_RUN.grid_height]}
    progs = {}
    for key, opt in (("opt", True), ("off", False)):
        t0 = time.perf_counter()
        p = sim.compile(b, HW_PAPER, optimize=opt).program
        row[f"compile_s_{key}"] = time.perf_counter() - t0
        progs[key] = p
        row[f"instrs_{key}"] = p.stats["instrs"]        # scheduled (+Sends)
        row[f"vcpl_{key}"] = p.vcpl
        row[f"sends_{key}"] = p.stats["sends"]
        row[f"used_cores_{key}"] = p.used_cores
    _cache_timings(b, row)
    run_progs = {key: sim.compile(b, HW_RUN, optimize=opt).program
                 for key, opt in (("opt", True), ("off", False))}
    row["vcpl_small_opt"] = run_progs["opt"].vcpl
    row["vcpl_small_off"] = run_progs["off"].vcpl
    po = progs["opt"]
    # scheduler strategy comparison (PR 6): same middle-end output through
    # the frozen greedy scheduler vs the slack-driven default (ASAP/ALAP
    # mobility + earliest-slot SEND reservation + rematerialization)
    pg = sim.compile(b, HW_PAPER, sched_strategy="greedy",
                     placement="identity", pipeline="off").program
    row["vcpl_sched_greedy"] = pg.vcpl
    row["vcpl_sched_slack"] = po.stats["vcpl_unpipelined"]
    row["vcpl_sched_delta"] = row["vcpl_sched_slack"] - pg.vcpl
    row["vcpl_over_lb_greedy"] = pg.stats["vcpl_over_lb"]
    row["vcpl_over_lb_slack"] = po.stats["vcpl_over_lb"]
    # cross-Vcycle modulo pipelining: steady-state initiation interval vs
    # the unpipelined VCPL (best-of-two — "off" means the pipelined arm
    # could not beat the barrier machine and the baseline shipped)
    row["vcpl_ii"] = po.stats["vcpl_ii"]
    lb = po.stats["crit_path_lb"]
    row["ii_over_lb"] = round(po.stats["vcpl_ii"] / lb, 4) if lb else 0.0
    row["pipeline_pick"] = po.stats["pipeline_pick"]
    row["pipe_prologue_len"] = po.stats["pipe_prologue_len"]
    row["pipe_hoisted"] = po.stats["pipe_hoisted"]
    row["sched_seconds_greedy"] = pg.stats["sched_seconds"]
    row["sched_seconds_slack"] = po.stats["sched_seconds"]
    row["sched_prio"] = po.stats["sched_prio"]
    row["remat_sends"] = po.stats["remat_sends"]
    row["remat_instrs"] = po.stats["remat_instrs"]
    # communication profile + placement strategy comparison (core.place):
    # the default compile ships the better of {anneal, identity}; the
    # identity arm is recompiled explicitly for the side-by-side
    pp = sim.compile(b, HW_PAPER, placement="identity").program
    row["n_sends"] = po.n_sends
    row["total_hops"] = _program_hops(po)
    row["total_hops_identity"] = _program_hops(pp)
    row["mean_hops_per_send"] = row["total_hops"] / max(po.n_sends, 1)
    row["vcpl_place_anneal"] = po.vcpl
    row["vcpl_place_identity"] = pp.vcpl
    row["place_pick"] = po.stats["place_pick"]
    row["place_seconds"] = po.stats["place_seconds"]
    # per-core utilization of the shipped (slack) schedule
    for k in ("cores_used", "core_load_max", "core_load_mean",
              "nop_density_hist", "epilogue_share"):
        row[f"util_{k}"] = po.stats[k]
    row["instrs_lowered"] = po.stats["instrs_lowered"]
    row["instrs_post_opt"] = po.stats["instrs_opt"]
    row["instr_reduction_pct"] = 100.0 * (
        1 - po.stats["instrs_opt"] / max(po.stats["instrs_lowered"], 1))
    row["vcpl_ratio"] = row["vcpl_opt"] / max(row["vcpl_off"], 1)
    row["crit_path_lb"] = po.stats["crit_path_lb"]
    row["sched_minimal"] = bool(po.stats["sched_minimal"])
    # per-pass breakdown (aggregated over pipeline rounds)
    passes = {}
    for r in po.stats["opt_passes"]:
        agg = passes.setdefault(r["pass"], {"seconds": 0.0, "removed": 0})
        agg["seconds"] += r["seconds"]
        agg["removed"] += r["instrs_before"] - r["instrs_after"]
    row["opt_pass_breakdown"] = passes
    n = min(max(8, b.n_cycles - 2), 128)
    row["vcycles"] = n
    row["jnp_vcycles_per_s_opt"] = _rate(run_progs["opt"], n, reps)
    row["jnp_vcycles_per_s_off"] = _rate(run_progs["off"], n, reps)
    row["speedup_vs_off"] = (row["jnp_vcycles_per_s_opt"]
                             / row["jnp_vcycles_per_s_off"])
    row_csv(f"compile/{nm}", 1e6 / row["jnp_vcycles_per_s_opt"],
            f"instr -{row['instr_reduction_pct']:.1f}% "
            f"vcpl {row['vcpl_off']}->{row['vcpl_opt']} "
            f"sched {row['vcpl_sched_greedy']}->{row['vcpl_sched_slack']} "
            f"{row['speedup_vs_off']:.2f}x_vs_off")
    return row


def run(names=None, smoke: bool = False) -> None:
    scale = "small" if smoke else "full"
    reps = 1 if smoke else REPS
    run_rows([nm for nm in sorted(CIRCUITS) if not names or nm in names],
             lambda nm: bench_circuit(nm, scale, reps),
             "BENCH_compile", smoke,
             lambda rows: "mean instr reduction %.1f%%, slack vcpl wins "
             "%d/%d (regressions %d), pipelined II wins %d/%d, best engine "
             "speedup %.2fx, best warm-cache compile speedup %.0fx" % (
                 sum(r["instr_reduction_pct"] for r in rows) / max(len(rows), 1),
                 sum(r["vcpl_sched_delta"] < 0 for r in rows), len(rows),
                 sum(r["vcpl_sched_delta"] > 0 for r in rows),
                 sum(r["pipeline_pick"] == "modulo" for r in rows), len(rows),
                 max((r["speedup_vs_off"] for r in rows), default=0.0),
                 max((r["cache_speedup"] for r in rows), default=0.0)))


if __name__ == "__main__":
    argv = sys.argv[1:]
    run([a for a in argv if not a.startswith("-")] or None,
        smoke="--smoke" in argv)
