"""Fig 8: FIFO vs RAM microbenchmarks at 1/64/512 KiB — global-stall cost.

Reports machine cycles normalized to the 1 KiB (scratchpad) configuration
and the cache hit rate, from the engine's hardware counters."""
from __future__ import annotations

import numpy as np

from repro.circuits.fig8 import build_membench
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

from .common import emit, row_csv

SIZES = [1, 64, 512]
N = 2048


def run():
    rows = []
    hw = HardwareConfig(grid_width=1, grid_height=1, spad_words=1 << 14,
                        num_regs=4096, imem_slots=1 << 16)
    for kind in ("fifo", "ram"):
        base = None
        for kib in SIZES:
            b = build_membench(kind, kib, n_cycles=N)
            prog = compile_circuit(b.circuit, hw)
            m = Machine(prog)
            st = m.run(m.init_state(), N)
            perf = m.perf(st)
            cyc = perf["machine_cycles"]
            if base is None:
                base = cyc
            acc = perf["ghits"] + perf["gmisses"]
            rows.append({
                "kind": kind, "kib": kib,
                "machine_cycles": cyc, "normalized": cyc / base,
                "hit_rate": perf["ghits"] / acc if acc else 1.0,
                "stall_cycles": perf["stall_cycles"],
                "global": prog.has_global,
            })
            row_csv(f"fig8/{kind}_{kib}k", 0.0,
                    f"norm={cyc / base:.2f} hit={rows[-1]['hit_rate']:.2f}")
    emit("fig8_global_stall", rows)
    return rows
