"""CI guard: the slack scheduler's VCPL may not regress.

The slack-driven scheduler (PR 6) closed most of the gap between the
scheduled VCPL and its critical-path lower bound on the paper's 15x15
grid. This guard keeps that win locked in: every full-scale bench circuit
is compiled with the default ``sched_strategy="slack"`` (schedule validator
on) and its VCPL compared against the committed expectations in
``results/expectations/vcpl.json``.

Five failure modes trip it:

  * a circuit's slack VCPL exceeds its committed value by more than
    ``TOLERANCE`` slots — a scheduler / rematerialization / placement
    regression;
  * slack VCPL exceeds the *greedy* VCPL recorded alongside it — the new
    strategy must never lose to the baseline it replaced;
  * the default ``placement="anneal"`` loses to ``placement="identity"``
    on any circuit — the annealer ships the better of the two scheduled
    geometries (``core.place``), so losing means the best-of-two pick
    broke;
  * the shipped steady-state initiation interval (``vcpl_ii``, from the
    default ``pipeline="modulo"`` arm) exceeds its committed value — a
    cross-Vcycle pipeliner regression;
  * the shipped II exceeds the unpipelined VCPL on any circuit — the
    pipeline best-of-two ship rule broke (II may never be worse than the
    barrier machine it replaces).

Improvements do not fail the guard; they print a hint to refresh the
expectations. Regenerate deliberately with:

  PYTHONPATH=src python -m benchmarks.vcpl_guard --update

CI runs the ``--smoke`` variant (a two-circuit subset) next to
``opt_diff_smoke``; the full sweep is a couple of minutes of pure
compilation.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.circuits import CIRCUITS, build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

HW = HardwareConfig(grid_width=15, grid_height=15)
EXPECT = (Path(__file__).resolve().parents[1] / "results" / "expectations"
          / "vcpl.json")
TOLERANCE = 0        # slots of allowed slack-VCPL growth per circuit
SMOKE_CIRCUITS = ("bc", "vta")


def measure(names) -> dict:
    out = {}
    for nm in names:
        c = build(nm, "full").circuit
        # vcpl_slack is the shipping default: slack scheduler + annealed
        # placement (best-of-two vs identity inside compile_circuit)
        ps = compile_circuit(c, HW, sched_strategy="slack",
                             placement="anneal", check=True)
        pi = compile_circuit(c, HW, sched_strategy="slack",
                             placement="identity", pipeline="off",
                             check=True)
        pg = compile_circuit(c, HW, sched_strategy="greedy",
                             placement="identity", pipeline="off",
                             check=True)
        out[nm] = {
            "vcpl_slack": int(ps.stats["vcpl_unpipelined"]),
            "vcpl_identity": int(pi.vcpl),
            "vcpl_greedy": int(pg.vcpl),
            # steady-state initiation interval of the shipped (default,
            # pipeline="modulo") program: equals vcpl_slack whenever the
            # best-of-two pick ships the unpipelined baseline
            "vcpl_ii": int(ps.vcpl),
            "pipeline_pick": str(ps.stats["pipeline_pick"]),
            "pipe_prologue": int(ps.stats["pipe_prologue_len"]),
            "crit_path_lb": int(ps.stats["crit_path_lb"]),
            "remat_sends": int(ps.stats["remat_sends"]),
            "total_hops": int(ps.stats["total_hops"]),
            "place_pick": str(ps.stats["place_pick"]),
        }
    return out


def run(update: bool = False, smoke: bool = False) -> None:
    names = sorted(SMOKE_CIRCUITS if smoke else CIRCUITS)
    got = measure(names)
    if update:
        EXPECT.parent.mkdir(parents=True, exist_ok=True)
        EXPECT.write_text(json.dumps(measure(sorted(CIRCUITS)), indent=1,
                                     sort_keys=True) + "\n")
        print(f"# wrote {EXPECT}")
        return
    want = json.loads(EXPECT.read_text())
    errors, better = [], []
    for nm in names:
        w, g = want[nm], got[nm]
        if g["vcpl_slack"] > w["vcpl_slack"] + TOLERANCE:
            errors.append(
                f"{nm}: slack vcpl {g['vcpl_slack']} > committed "
                f"{w['vcpl_slack']} (+{TOLERANCE} tolerance)")
        if g["vcpl_slack"] > g["vcpl_greedy"]:
            errors.append(
                f"{nm}: slack vcpl {g['vcpl_slack']} worse than greedy "
                f"{g['vcpl_greedy']}")
        if g["vcpl_slack"] > g["vcpl_identity"]:
            errors.append(
                f"{nm}: anneal placement vcpl {g['vcpl_slack']} worse than "
                f"identity {g['vcpl_identity']} — best-of-two pick broke")
        if g["vcpl_ii"] > w.get("vcpl_ii", w["vcpl_slack"]) + TOLERANCE:
            errors.append(
                f"{nm}: pipelined II {g['vcpl_ii']} > committed "
                f"{w.get('vcpl_ii', w['vcpl_slack'])} (+{TOLERANCE} "
                f"tolerance)")
        if g["vcpl_ii"] > g["vcpl_slack"]:
            errors.append(
                f"{nm}: shipped II {g['vcpl_ii']} worse than unpipelined "
                f"vcpl {g['vcpl_slack']} — best-of-two pipeline pick broke")
        if g["vcpl_slack"] < w["vcpl_slack"]:
            better.append(f"{nm} {w['vcpl_slack']}->{g['vcpl_slack']}")
        elif g["vcpl_ii"] < w.get("vcpl_ii", w["vcpl_slack"]):
            better.append(f"{nm} ii {w.get('vcpl_ii')}->{g['vcpl_ii']}")
    if errors:
        raise SystemExit("vcpl_guard FAILED:\n  " + "\n  ".join(errors))
    if better:
        print("# vcpl improved (" + ", ".join(better) +
              ") — refresh with --update to lock it in")
    wins = sum(got[nm]["vcpl_slack"] < got[nm]["vcpl_greedy"]
               for nm in names)
    pwins = sum(got[nm]["vcpl_slack"] < got[nm]["vcpl_identity"]
                for nm in names)
    iwins = sum(got[nm]["vcpl_ii"] < got[nm]["vcpl_slack"]
                for nm in names)
    print(f"# vcpl_guard OK: {len(names)} circuits, slack beats greedy on "
          f"{wins}, anneal placement beats identity on {pwins}, pipelined "
          f"II below vcpl on {iwins}, regressions 0")


if __name__ == "__main__":
    argv = sys.argv[1:]
    run(update="--update" in argv, smoke="--smoke" in argv)
