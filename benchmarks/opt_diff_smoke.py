"""CI smoke: the legacy compile path is frozen; the optimized path matches it.

Two guarantees, cheap enough for every CI run:

  1. **Legacy freeze** — ``compile_circuit(optimize=False,
     sched_strategy="greedy")`` on one full-scale circuit must stay
     *bit-identical* to the committed expectations (``results/expectations/optoff_<circuit>.json``: binary
     image digests, VCPL, exchange tables, and the IsaSim end state). The
     legacy path is the fixed cross-PR baseline — if this trips, a change
     leaked into the pre-middle-end compiler.
  2. **Differential** — the same circuit compiled with ``optimize=True``
     must finish at the same cycle with the same exceptions and identical
     final register values.

Regenerate expectations only when a PR deliberately changes the legacy
path:  PYTHONPATH=src python -m benchmarks.opt_diff_smoke --update
"""
from __future__ import annotations

import hashlib
import json
import sys
from pathlib import Path

from repro.circuits import FINISH, build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim

CIRCUIT = "mc"
HW = HardwareConfig(grid_width=5, grid_height=5)
EXPECT = (Path(__file__).resolve().parents[1] / "results" / "expectations"
          / f"optoff_{CIRCUIT}.json")


def _digest(prog, sim: IsaSim, n_cycles: int) -> dict:
    h = hashlib.sha256()
    for arr in (prog.code, prog.luts, prog.reg_init, prog.spad_init,
                prog.gmem_init, prog.xchg_src_core, prog.xchg_src_slot,
                prog.xchg_dst_core, prog.xchg_dst_reg):
        h.update(arr.tobytes())
    cycles = sim.run(n_cycles + 10)
    return {
        "circuit": CIRCUIT,
        "grid": [HW.grid_width, HW.grid_height],
        "binary_sha256": h.hexdigest(),
        "vcpl": int(prog.vcpl),
        "t_compute": int(prog.t_compute),
        "used_cores": int(prog.used_cores),
        "n_sends": int(prog.n_sends),
        "cycles": int(cycles),
        "exceptions": {str(c): int(e) for c, e in sim.exceptions().items()},
        "regs": {name: int(sim.read_reg(name))
                 for name in sorted(prog.state_regs)},
    }


def run(update: bool = False) -> None:
    b = build(CIRCUIT, "full")
    # both compiles pin the frozen greedy scheduler, identity placement
    # and pipeline="off": this smoke guards the legacy pre-middle-end
    # path, not the slack scheduler, the placement annealer or the
    # cross-Vcycle pipeliner (vcpl_guard does)
    p_off = compile_circuit(b.circuit, HW, optimize=False,
                            sched_strategy="greedy", placement="identity",
                            pipeline="off")
    got = _digest(p_off, IsaSim(p_off), b.n_cycles)
    if update:
        EXPECT.parent.mkdir(parents=True, exist_ok=True)
        EXPECT.write_text(json.dumps(got, indent=1))
        print(f"# wrote {EXPECT}")
    else:
        want = json.loads(EXPECT.read_text())
        diff = {k: (want.get(k), got.get(k))
                for k in set(want) | set(got) if want.get(k) != got.get(k)}
        if diff:
            raise SystemExit(
                f"optimize=False path diverged from committed expectations "
                f"({EXPECT.name}): {diff}")
    # differential: the optimized program reaches the same end state
    p_opt = compile_circuit(b.circuit, HW, optimize=True,
                            sched_strategy="greedy", placement="identity",
                            pipeline="off")
    sim = IsaSim(p_opt)
    assert sim.run(b.n_cycles + 10) == got["cycles"], "finish cycle differs"
    assert {str(c): int(e) for c, e in sim.exceptions().items()} \
        == got["exceptions"] == {"0": FINISH}
    for name, val in got["regs"].items():
        assert sim.read_reg(name) == val, f"register {name} differs"
    assert p_opt.stats["instrs_opt"] < p_opt.stats["instrs_lowered"]
    print(f"# opt_diff_smoke OK: {CIRCUIT} legacy frozen "
          f"(vcpl={got['vcpl']}), optimized bit-exact "
          f"(instrs {p_opt.stats['instrs_lowered']}"
          f"->{p_opt.stats['instrs_opt']}, vcpl={p_opt.vcpl})")


if __name__ == "__main__":
    run(update="--update" in sys.argv[1:])
