"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"
RESULTS.mkdir(parents=True, exist_ok=True)

# Manticore prototype model constants (paper Table 2 / §7.2)
MANTICORE_CLOCK_HZ = 475e6
X86_SERIAL_GHZ = 4.75e9


def timeit(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, rows: List[Dict]) -> None:
    (RESULTS / f"{name}.json").write_text(json.dumps(rows, indent=1))


def row_csv(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
