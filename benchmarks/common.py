"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
import traceback
from pathlib import Path
from typing import Callable, Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "bench"
RESULTS.mkdir(parents=True, exist_ok=True)

# Manticore prototype model constants (paper Table 2 / §7.2)
MANTICORE_CLOCK_HZ = 475e6
X86_SERIAL_GHZ = 4.75e9


def timeit(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, rows: List[Dict], root: bool = False) -> None:
    """Single writer for benchmark artifacts. ``results/bench/<name>.json``
    is canonical; ``root=True`` additionally refreshes the repo-root copy
    (``<name>.json``) used for the cross-PR perf trajectory. No bench
    module writes either location itself."""
    text = json.dumps(rows, indent=1)
    (RESULTS / f"{name}.json").write_text(text)
    if root:
        (RESULTS.parents[1] / f"{name}.json").write_text(text)


def row_csv(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def best_time(fn: Callable[[], None], reps: int) -> float:
    """Warm once (compile/trace), then best-of-``reps`` wall time."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_rows(circuits, bench_one: Callable[[str], Dict], artifact: str,
             smoke: bool, summary: Callable[[List[Dict]], str]) -> None:
    """Shared bench driver (bench_engine / bench_batch): per-circuit
    failure isolation, incremental emit after every row (one circuit's
    crash can never blank the artifact), a root-level copy of the real
    (non-smoke) artifact for the cross-PR perf trajectory, and a non-zero
    exit when anything failed or nothing was measured."""
    rows: List[Dict] = []
    failures = 0
    for nm in circuits:
        try:
            rows.append(bench_one(nm))
        except Exception:
            traceback.print_exc()
            failures += 1
            continue
        emit(artifact + ("_smoke" if smoke else ""), rows)
    if not smoke and rows:
        emit(artifact, rows, root=True)
    print(f"# {summary(rows)}")
    if failures or not rows:
        raise SystemExit(f"{artifact}: {failures} circuit(s) failed, "
                         f"{len(rows)} row(s) written")
