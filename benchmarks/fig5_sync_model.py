"""Fig 5 / Listing 1: the parallel-simulation sync-cost model.

Model 1: rate(P) = 1 / (N/(P*ips) + 2*t_barrier(P)); t_barrier measured with
threading.Barrier on this host (caveat: this container exposes one core, so
the measured barrier cost is an upper bound — the *shape* of the curves is
the point). Model 2 adds the i-cache pressure factor of the paper (serial
throughput derated when the per-thread footprint exceeds L1i)."""
from __future__ import annotations

import threading
import time

from .common import emit, row_csv

SIZES = [3_000, 43_000, 169_000, 1_000_000]   # instructions per RTL cycle
THREADS = [1, 2, 4, 8, 16]
IPS = 4.75e9 * 2.0          # instr/s per core (freq x IPC)
ICACHE_INSTR = 64_000       # L1i footprint in instructions
ICACHE_DERATE = 2.5


def measure_barrier(p: int, iters: int = 200) -> float:
    if p == 1:
        return 0.0
    bar = threading.Barrier(p)
    times = []

    def worker():
        for _ in range(iters):
            bar.wait()

    ts = [threading.Thread(target=worker) for _ in range(p - 1)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for _ in range(iters):
        bar.wait()
    for t in ts:
        t.join()
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    barrier = {p: measure_barrier(p) for p in THREADS}
    for n in SIZES:
        for p in THREADS:
            t_compute = n / p / IPS
            r1 = 1.0 / (t_compute + 2 * barrier[p])
            foot = n / p
            derate = ICACHE_DERATE if foot > ICACHE_INSTR else 1.0
            r2 = 1.0 / (t_compute * derate + 2 * barrier[p])
            rows.append({"instr_per_cycle": n, "threads": p,
                         "barrier_s": barrier[p],
                         "model1_khz": r1 / 1e3, "model2_khz": r2 / 1e3})
        best = max(r["model2_khz"] for r in rows
                   if r["instr_per_cycle"] == n)
        row_csv(f"fig5/{n}", 0.0, f"peak_model2={best:.0f}kHz")
    emit("fig5_sync_model", rows)
    return rows
