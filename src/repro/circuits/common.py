"""Shared helpers for the benchmark circuits.

Every benchmark is *self-checking* (paper §7.5: "wrapped in simple,
assertion-based Verilog test drivers"): the builder computes golden values in
plain Python while constructing the netlist, embeds them as constants, and
the circuit EXPECTs equality when its cycle counter reaches ``n_cycles``
(exception id FINISH fires on success; MISMATCH on a wrong value).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List

from ..core.netlist import Circuit, Sig

FINISH = 1        # clean end-of-simulation
MISMATCH = 2      # golden check failed
M32 = (1 << 32) - 1
M16 = (1 << 16) - 1


@dataclass
class Bench:
    circuit: Circuit
    n_cycles: int            # cycle at which FINISH fires (== cycles to run)
    meta: Dict = field(default_factory=dict)


def rng(seed: int) -> random.Random:
    return random.Random(seed)


def rotl32(c: Circuit, x: Sig, k: int) -> Sig:
    k %= 32
    if k == 0:
        return x
    return (x << k) | (x >> (32 - k))


def rotr32(c: Circuit, x: Sig, k: int) -> Sig:
    return rotl32(c, x, 32 - (k % 32))


def py_rotl32(x: int, k: int) -> int:
    k %= 32
    return ((x << k) | (x >> (32 - k))) & M32


def xorshift32_py(x: int) -> int:
    x ^= (x << 13) & M32
    x ^= x >> 17
    x ^= (x << 5) & M32
    return x & M32


def xorshift32_sig(c: Circuit, x: Sig) -> Sig:
    x = x ^ (x << 13)
    x = x ^ (x >> 17)
    x = x ^ (x << 5)
    return x


def rom16(c: Circuit, values: List[int], idx: Sig, width: int = 16) -> Sig:
    """Small ROM as a mux tree (keeps cones parallelizable, unlike a
    scratchpad memory which would serialize every reader into one core)."""
    sigs = [c.const(v, width) for v in values]
    n = max(1, (len(values) - 1).bit_length())
    return c.onehot_mux(idx[n - 1:0] if idx.width > n else idx, sigs)


def make_counter(c: Circuit, width: int, name: str = "ctr") -> Sig:
    ctr = c.reg(width, init=0, name=name)
    c.set_next(ctr, ctr + 1)
    return ctr


def finish_and_check(c: Circuit, ctr: Sig, n_cycles: int,
                     checks: List) -> int:
    """Arm golden checks at ``ctr == n_cycles`` and FINISH one cycle later,
    so a MISMATCH always freezes the machine before the clean finish.

    Returns the total cycle count at which FINISH fires (what the driver
    should expect from a correct run)."""
    at_check = ctr.eq(n_cycles)
    for actual, golden in checks:
        g = c.const(golden, actual.width)
        # only differs from golden while the check is armed
        val = c.mux(at_check, actual, g)
        c.expect_eq(val, g, MISMATCH)
    c.finish_when(ctr.eq(n_cycles + 1), FINISH)
    return n_cycles + 2
