"""Shared helpers for the benchmark circuits.

Every benchmark is *self-checking* (paper §7.5: "wrapped in simple,
assertion-based Verilog test drivers"): the builder computes golden values in
plain Python while constructing the netlist, embeds them as constants, and
the circuit EXPECTs equality when its cycle counter reaches ``n_cycles``
(exception id FINISH fires on success; MISMATCH on a wrong value).

Batched stimuli (PR 2): a builder called with ``seeds=[s0, s1, ...]``
constructs **one** structural netlist (wires, registers, memories and code
are those of ``s0``) plus *per-seed init planes* — for every seed-dependent
value the builder routes the value through :class:`Planes` instead of a
``c.const``/plain ``c.reg``, so the value lands in *initial state* (register
file / scratchpad / global-memory images) rather than in instruction
immediates. All seeds then share the same compiled ``code``/``luts`` and a
``BatchedMachine`` can simulate every stimulus in a single device launch.
Golden check values are seed-dependent too, so in batched mode they become
self-holding registers (``Planes.hold``) initialized per seed.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..core.netlist import Circuit, Memory, Sig

FINISH = 1        # clean end-of-simulation
MISMATCH = 2      # golden check failed
M32 = (1 << 32) - 1
M16 = (1 << 16) - 1


class Planes:
    """Per-seed init planes collected while building one structural netlist.

    ``live=False`` (a legacy single-seed build) degrades every helper to the
    plain constructs the pre-batching builders used — ``hold`` becomes
    ``c.const``, ``reg``/``mem`` plain construction — so existing callers
    get bit-identical netlists. ``live=True`` records, for each seed, the
    name → init value (registers) and name → 16-bit-word image (memories)
    overlays that :meth:`repro.core.compile.Program.init_images` turns into
    per-stimulus ``reg_init``/``spad_init``/``gmem_init`` arrays.
    """

    def __init__(self, c: Circuit, n_seeds: int, live: bool):
        self.c = c
        self.n = n_seeds
        self.live = live
        self.regs: List[Dict[str, int]] = [dict() for _ in range(n_seeds)]
        self.mems: List[Dict[str, List[int]]] = [dict() for _ in range(n_seeds)]

    def reg(self, width: int, inits: Sequence[int], name: str) -> Sig:
        """A register whose *initial value* varies per seed."""
        assert len(inits) == self.n, (name, len(inits), self.n)
        m = (1 << width) - 1
        r = self.c.reg(width, init=inits[0] & m, name=name)
        if self.live:
            for b in range(self.n):
                self.regs[b][name] = inits[b] & m
        return r

    def hold(self, values: Sequence[int], width: int, name: str) -> Sig:
        """A per-seed 'constant': a self-holding register in batched mode
        (value lives in the init plane, not in an immediate), a plain
        shared constant otherwise."""
        if not self.live:
            return self.c.const(values[0], width)
        r = self.reg(width, values, name)
        self.c.set_next(r, r)
        return r

    def mem(self, name: str, depth: int, width: int,
            inits: Sequence[Sequence[int]],
            is_global: bool = False) -> Memory:
        """A memory whose init image varies per seed (recorded flattened to
        the 16-bit words the scratchpad/global images use)."""
        assert len(inits) == self.n, (name, len(inits), self.n)
        m = self.c.mem(name, depth, width, init=list(inits[0]),
                       is_global=is_global)
        if self.live:
            stride = (width + 15) // 16
            emask = (1 << width) - 1
            for b in range(self.n):
                words: List[int] = []
                for v in inits[b]:
                    v &= emask
                    for w in range(stride):
                        words.append((v >> (16 * w)) & M16)
                self.mems[b][name] = words
        return m


def seed_list(seed: int, seeds: Optional[Sequence[int]]) -> List[int]:
    """Normalize the (legacy ``seed``, batched ``seeds``) pair."""
    return [seed] if seeds is None else list(seeds)


def make_planes(c: Circuit, seed: int,
                seeds: Optional[Sequence[int]]) -> "Planes":
    sl = seed_list(seed, seeds)
    return Planes(c, len(sl), live=seeds is not None)


@dataclass
class Bench:
    circuit: Circuit
    n_cycles: int            # cycle at which FINISH fires (== cycles to run)
    meta: Dict = field(default_factory=dict)
    # batched-stimulus metadata (None for legacy single-seed builds):
    seeds: Optional[List[int]] = None
    reg_planes: Optional[List[Dict[str, int]]] = None
    mem_planes: Optional[List[Dict[str, List[int]]]] = None

    @property
    def batch(self) -> int:
        return len(self.reg_planes) if self.reg_planes is not None else 1

    def attach(self, planes: Planes, seeds: Sequence[int]) -> "Bench":
        """Record a live build's planes on this bench (no-op when legacy)."""
        if planes.live:
            self.seeds = list(seeds)
            self.reg_planes = planes.regs
            self.mem_planes = planes.mems
        return self

    def images(self, program) -> List:
        """Per-stimulus (reg_init, spad_init, gmem_init) images for a
        Program compiled from this bench's circuit."""
        assert self.reg_planes is not None, "bench was not built with seeds"
        return [program.init_images(r, m)
                for r, m in zip(self.reg_planes, self.mem_planes)]

    def images_batch(self, program, workers: Optional[int] = None):
        """Stacked ``([B, C, R], [B, C, S], [B, G])`` init images,
        generated host-parallel (:meth:`Program.init_images_batch`) — the
        layout the batched/sharded engines consume directly."""
        assert self.reg_planes is not None, "bench was not built with seeds"
        return program.init_images_batch(self.reg_planes, self.mem_planes,
                                         workers=workers)

    def compile(self, hw=None, **options) -> "Simulation":  # noqa: F821
        """Compile this bench through the :mod:`repro.sim` facade — the
        returned Simulation knows the cycle budget and the seed planes, so
        ``bench.compile(hw).run()`` is the whole simulate-and-check flow.
        Options (``optimize=``, ``use_luts=``, ``cache=``, ...) are those
        of :func:`repro.sim.compile`."""
        from ..sim import facade
        return facade.compile(self, hw, **options)


def rng(seed: int) -> random.Random:
    return random.Random(seed)


def rotl32(c: Circuit, x: Sig, k: int) -> Sig:
    k %= 32
    if k == 0:
        return x
    return (x << k) | (x >> (32 - k))


def rotr32(c: Circuit, x: Sig, k: int) -> Sig:
    return rotl32(c, x, 32 - (k % 32))


def py_rotl32(x: int, k: int) -> int:
    k %= 32
    return ((x << k) | (x >> (32 - k))) & M32


def xorshift32_py(x: int) -> int:
    x ^= (x << 13) & M32
    x ^= x >> 17
    x ^= (x << 5) & M32
    return x & M32


def xorshift32_sig(c: Circuit, x: Sig) -> Sig:
    x = x ^ (x << 13)
    x = x ^ (x >> 17)
    x = x ^ (x << 5)
    return x


def rom16(c: Circuit, values: List[int], idx: Sig, width: int = 16) -> Sig:
    """Small ROM as a mux tree (keeps cones parallelizable, unlike a
    scratchpad memory which would serialize every reader into one core)."""
    sigs = [c.const(v, width) for v in values]
    n = max(1, (len(values) - 1).bit_length())
    return c.onehot_mux(idx[n - 1:0] if idx.width > n else idx, sigs)


def make_counter(c: Circuit, width: int, name: str = "ctr") -> Sig:
    ctr = c.reg(width, init=0, name=name)
    c.set_next(ctr, ctr + 1)
    return ctr


def finish_and_check(c: Circuit, ctr: Sig, n_cycles: int,
                     checks: List, planes: Optional[Planes] = None) -> int:
    """Arm golden checks at ``ctr == n_cycles`` and FINISH one cycle later,
    so a MISMATCH always freezes the machine before the clean finish.

    A check is ``(actual, golden)`` where ``golden`` is an int (shared by
    every stimulus) or a per-seed sequence (batched builds; the golden
    becomes a hold-register so it lands in the init planes, keeping the
    code stream identical across seeds).

    Returns the total cycle count at which FINISH fires (what the driver
    should expect from a correct run)."""
    if planes is None:
        planes = Planes(c, 1, live=False)
    at_check = ctr.eq(n_cycles)
    for k, (actual, golden) in enumerate(checks):
        golds = [golden] * planes.n if isinstance(golden, int) \
            else list(golden)
        g = planes.hold(golds, actual.width, f"gold{k}")
        # only differs from golden while the check is armed
        val = c.mux(at_check, actual, g)
        c.expect_eq(val, g, MISMATCH)
    c.finish_when(ctr.eq(n_cycles + 1), FINISH)
    return n_cycles + 2
