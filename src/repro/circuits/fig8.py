"""Fig. 8 microbenchmarks: FIFO vs RAM at 1 KiB / 64 KiB / 512 KiB.

1 KiB fits the scratchpad (no global stalls); 64 KiB goes through the
privileged core's 128 KiB cache (all hits after warmup); 512 KiB spills to
DRAM (misses => long stalls). One load + one store per Vcycle, like the
paper."""
from __future__ import annotations

from ..core.netlist import Circuit
from .common import Bench, FINISH, make_counter, make_planes, rng, seed_list


def build_membench(kind: str, kib: int, n_cycles: int = 4096,
                   seed: int = 0, seeds=None) -> Bench:
    assert kind in ("fifo", "ram")
    words = kib * 1024 // 2
    c = Circuit(f"{kind}_{kib}k")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    m = c.mem("m", words, 16, is_global=(kib * 1024 > 32768))
    ctr = make_counter(c, 32)

    if kind == "fifo":
        addr = ctr  # sequential
    else:
        x0s = [0x1234567] if not planes.live else \
            [rng(s).getrandbits(32) | 1 for s in sl]
        x = planes.reg(32, x0s, "rng")
        # xorshift-style address scramble (paper: XOR-shift-128; 32 here)
        nx = x ^ (x << 13)
        nx = nx ^ (nx >> 17)
        nx = nx ^ (nx << 5)
        c.set_next(x, nx)
        addr = x
    a16 = addr[15:0]
    a_hi = addr[31:16]
    idx = a_hi.cat(a16) if words > 65536 else addr
    rd = c.mem_read(m, idx.trunc(32) if idx.width > 32 else idx.zext(32)
                    if idx.width < 32 else idx)
    acc = c.reg(16, init=0, name="acc")
    c.set_next(acc, acc + rd)
    c.mem_write(m, idx.trunc(32) if idx.width > 32 else idx.zext(32)
                if idx.width < 32 else idx, rd ^ 0x5A5A, c.const(1, 1))
    c.finish_when(ctr.eq(n_cycles), FINISH)
    return Bench(c, n_cycles + 1,
                 meta={"kind": kind, "kib": kib}).attach(planes, sl)
