"""Network benchmarks: noc (2-D deflection torus) and rv32r (ring of tiny
processors). Paper §7.5.

Batched builds (``seeds=[...]``): the router pipeline / per-core program
structure is shared; the per-seed stimulus is the initial network state
(in-flight flits, sink totals, accumulators, ring tokens). The golden
mirrors run from the same per-seed state.
"""
from __future__ import annotations

from typing import List

from ..core.netlist import Circuit, Sig
from .common import (Bench, M16, M32, finish_and_check, make_counter,
                     make_planes, rng, seed_list)

# flit encoding: [12]=valid, [11:10]=dest.y, [9:8]=dest.x, [7:0]=payload
_V = 1 << 12


def build_noc(rows: int = 4, cols: int = 4, n_cycles: int = 200,
              seed: int = 29, seeds=None) -> Bench:
    """Uni-directional 2-D torus with dimension-ordered (X then Y) routing
    and Hoplite-style deflection: through-traffic in the Y plane has
    priority, turning flits deflect around their row ring."""
    c = Circuit("noc")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    n = rows * cols
    ctr = make_counter(c, 16)

    # per-seed initial network state: random in-flight flits and sink
    # totals (all-zero for the legacy single-seed build, as before)
    if planes.live:
        x0s, y0s, s0s = [], [], []
        for s in sl:
            r = rng(s)
            x0s.append([r.getrandbits(13) for _ in range(n)])
            y0s.append([r.getrandbits(13) for _ in range(n)])
            s0s.append([r.getrandbits(32) for _ in range(n)])
    else:
        x0s = y0s = [[0] * n]
        s0s = [[0] * n]
    xreg = [planes.reg(13, [x0s[b][i] for b in range(len(sl))], f"x{i}")
            for i in range(n)]
    yreg = [planes.reg(13, [y0s[b][i] for b in range(len(sl))], f"y{i}")
            for i in range(n)]
    sink = [planes.reg(32, [s0s[b][i] for b in range(len(sl))], f"s{i}")
            for i in range(n)]

    def fxy(i):
        return i % cols, i // cols

    for i in range(n):
        x, y = fxy(i)
        west = xreg[(y * cols + (x - 1) % cols)]
        north = yreg[((y - 1) % rows) * cols + x]

        xv = west[12]
        xdx = west[9:8]
        xdy = west[11:10]
        x_here = xdx.eq(x)
        x_cons = xv & x_here & xdy.eq(y)           # consume from X plane
        x_turn = xv & x_here & ~xdy.eq(y)          # wants the Y plane

        yv = north[12]
        ydy = north[11:10]
        y_cons = yv & ydy.eq(y)                    # consume from Y plane
        y_pass = yv & ~ydy.eq(y)                   # through-traffic

        # Y register: through traffic wins; otherwise a turning flit enters
        zero = c.const(0, 13)
        c.set_next(yreg[i], c.mux(y_pass, north, c.mux(x_turn & ~y_pass,
                                                       west, zero)))
        # X register: flit continues if not at its column, or deflects when
        # blocked from turning; else this router may inject
        x_fwd = xv & (~x_here | (x_turn & y_pass))
        inj_turn = ctr[2:0].eq(i & 7)              # injection cadence
        pay = (ctr[7:0] ^ c.const(i * 29 & 0xFF, 8))
        dest = ((ctr + 3 * i)[3:0])                # roaming destination
        flit = c.const(1, 1).cat(dest).cat(pay)    # valid|dest|payload
        c.set_next(xreg[i], c.mux(x_fwd, west, c.mux(inj_turn, flit, zero)))
        consumed = (c.mux(x_cons, west[7:0], c.const(0, 8)).zext(32) +
                    c.mux(y_cons, north[7:0], c.const(0, 8)).zext(32))
        c.set_next(sink[i], sink[i] + consumed)

    # ---- python golden (exact mirror), per seed ----
    finals: List[List[int]] = []
    for b in range(len(sl)):
        xp, yp, sp = list(x0s[b]), list(y0s[b]), list(s0s[b])
        for t in range(n_cycles):
            nx, ny, ns = [0] * n, [0] * n, list(sp)
            for i in range(n):
                x, y = fxy(i)
                west = xp[y * cols + (x - 1) % cols]
                north = yp[((y - 1) % rows) * cols + x]
                xv, xdx, xdy = west >> 12, (west >> 8) & 3, (west >> 10) & 3
                x_here = int(xdx == x)
                x_cons = xv & x_here & int(xdy == y)
                x_turn = xv & x_here & (1 - int(xdy == y))
                yv, ydy = north >> 12, (north >> 10) & 3
                y_cons = yv & int(ydy == y)
                y_pass = yv & (1 - int(ydy == y))
                ny[i] = north if y_pass else (west if (x_turn and not y_pass)
                                              else 0)
                x_fwd = xv & ((1 - x_here) | (x_turn & y_pass))
                inj_turn = int((t & 7) == (i & 7))
                pay = ((t & 0xFF) ^ (i * 29 & 0xFF))
                dest = (t + 3 * i) & 0xF
                flit = _V | (dest << 8) | pay
                nx[i] = west if x_fwd else (flit if inj_turn else 0)
                consumed = (west & 0xFF if x_cons else 0) + \
                           (north & 0xFF if y_cons else 0)
                ns[i] = (sp[i] + consumed) & M32
            xp, yp, sp = nx, ny, ns
        finals.append(sp)

    checks = [(sink[i], [finals[b][i] for b in range(len(sl))])
              for i in range(n)]
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta={"sink0": finals[0][0]}).attach(planes, sl)


def build_rv32r(n_cores: int = 16, n_cycles: int = 128,
                seed: int = 31, seeds=None) -> Bench:
    """Ring of tiny in-order processors: each runs an 8-instruction loop
    (mux-tree "decoder" over its PC) and exchanges a 16-bit token with its
    ring neighbour every cycle (the paper's riscv-mini ring, miniaturized).
    The instruction immediates are structure (``seeds[0]``); per-seed
    stimulus is the initial accumulator / ring-token state."""
    c = Circuit("rv32r")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    r = rng(sl[0])
    ctr = make_counter(c, 16)
    imm = [r.getrandbits(16) for _ in range(n_cores)]
    if planes.live:
        a0s, r0s = [], []
        for s in sl:
            rr = rng(s * 7 + 1)
            a0s.append([rr.getrandbits(32) for _ in range(n_cores)])
            r0s.append([rr.getrandbits(16) for _ in range(n_cores)])
    else:
        a0s = [[i * 0x1234567 & M32 for i in range(n_cores)]]
        r0s = [list(imm)]
    acc = [planes.reg(32, [a0s[b][i] for b in range(len(sl))], f"acc{i}")
           for i in range(n_cores)]
    ring = [planes.reg(16, [r0s[b][i] for b in range(len(sl))], f"ring{i}")
            for i in range(n_cores)]
    pc = [c.reg(3, init=i & 7, name=f"pc{i}") for i in range(n_cores)]

    for i in range(n_cores):
        rin = ring[(i - 1) % n_cores]
        a = acc[i]
        ops: List[Sig] = [
            a + imm[i],                      # addi
            a ^ rin.zext(32),                # xor ring
            (a << 1) | (a >> 31),            # rotl 1
            a + rin.zext(32),                # add ring
            a - imm[i],                      # subi
            a & (rin.zext(32) | 0xFFFF0000), # and
            (a >> 3) + imm[i],               # srli+add
            a * 5,                           # mul small
        ]
        c.set_next(acc[i], c.onehot_mux(pc[i], ops))
        c.set_next(pc[i], pc[i] + 1)
        c.set_next(ring[i], a[15:0] ^ a[31:16])

    # golden, per seed
    finals = []
    for b in range(len(sl)):
        ap = list(a0s[b])
        rp = list(r0s[b])
        pp = [i & 7 for i in range(n_cores)]
        for _ in range(n_cycles):
            na, nr, np_ = [0] * n_cores, [0] * n_cores, [0] * n_cores
            for i in range(n_cores):
                rin = rp[(i - 1) % n_cores]
                a = ap[i]
                ops_p = [
                    (a + imm[i]) & M32,
                    a ^ rin,
                    ((a << 1) | (a >> 31)) & M32,
                    (a + rin) & M32,
                    (a - imm[i]) & M32,
                    a & (rin | 0xFFFF0000),
                    ((a >> 3) + imm[i]) & M32,
                    (a * 5) & M32,
                ]
                na[i] = ops_p[pp[i]]
                np_[i] = (pp[i] + 1) & 7
                nr[i] = ((a & M16) ^ (a >> 16)) & M16
            ap, rp, pp = na, nr, np_
        finals.append(ap)
    checks = [(acc[i], [finals[b][i] for b in range(len(sl))])
              for i in range(n_cores)]
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta={"acc0": finals[0][0]}).attach(planes, sl)
