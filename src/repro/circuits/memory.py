"""Memory-centric benchmarks: vta (GEMM accelerator core), blur (stencil
line buffers), jpeg (serial variable-length decoder). Paper §7.5.

These stress the scratchpad path: instructions touching one memory must
colocate on its owner core (paper §6.1), so these designs parallelize poorly
by construction — exactly the behaviour Table 3 shows for vta/jpeg.

Batched builds (``seeds=[...]``): the seed-dependent data here is mostly
*memory images* (vta's weight/input buffers, jpeg's Huffman table), which
already live in init state — they become per-seed scratchpad planes via
``Planes.mem`` with no structural change at all.
"""
from __future__ import annotations

from ..core.netlist import Circuit
from .common import (Bench, M16, M32, finish_and_check, make_counter,
                     make_planes, rng, seed_list, xorshift32_py,
                     xorshift32_sig)


def build_vta(n_cycles: int = 256, depth: int = 256, acc_depth: int = 64,
              lanes: int = 4, seed: int = 13, seeds=None) -> Bench:
    """GEMM core: ``lanes`` parallel MAC lanes, each with its own wgt/inp
    buffers and accumulator scratchpad (paper's vta, 4-lane spatial config,
    buffers divided to fit scratchpads)."""
    c = Circuit("vta")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    ctr = make_counter(c, 16)
    lg_acc = (acc_depth - 1).bit_length()
    i = ctr[7:0].zext(16)
    j = ctr[lg_acc - 1:0].zext(16)
    checks = []
    csums = {}
    for ln in range(lanes):
        rs = [rng(s + 101 * ln) for s in sl]
        wgt_vs = [[r.getrandbits(16) for _ in range(depth)] for r in rs]
        inp_vs = [[r.getrandbits(16) for _ in range(depth)] for r in rs]
        wgt = planes.mem(f"wgt{ln}", depth, 16, wgt_vs)
        inp = planes.mem(f"inp{ln}", depth, 16, inp_vs)
        accm = c.mem(f"acc{ln}", acc_depth, 32)
        w = c.mem_read(wgt, i)
        x = c.mem_read(inp, ((i + j) & 0xFF))
        prod = w.zext(32) * x.zext(32)
        a_old = c.mem_read(accm, j)
        c.mem_write(accm, j, a_old + prod, c.const(1, 1))
        csum = c.reg(32, init=0, name=f"csum{ln}")
        c.set_next(csum, csum + prod)
        # probe through a register so the EXPECT cone reads register state,
        # not the scratchpad (a direct mem read would pull every lane's
        # memory into the privileged process)
        probe = c.reg(32, init=0, name=f"probe{ln}")
        c.set_next(probe, c.mem_read(accm, c.const(0, 16)))

        csumps, probes = [], []
        for wgt_v, inp_v in zip(wgt_vs, inp_vs):
            accp = [0] * acc_depth
            csump, probe_g = 0, 0
            for t in range(n_cycles):
                if t == n_cycles - 1:
                    probe_g = accp[0]   # the probe register lags one cycle
                ip, jp = t & 0xFF, t & (acc_depth - 1)
                pr = (wgt_v[ip] * inp_v[(ip + jp) & 0xFF]) & M32
                accp[jp] = (accp[jp] + pr) & M32
                csump = (csump + pr) & M32
            csumps.append(csump)
            probes.append(probe_g)
        checks += [(csum, csumps), (probe, probes)]
        csums[f"csum{ln}"] = csumps[0]
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta=csums).attach(planes, sl)


def build_blur(n_cycles: int = 256, width: int = 32, seed: int = 17,
               seeds=None) -> Bench:
    """3x3 Gaussian stencil with two line buffers over a streamed image
    (paper's blur: non-uniform partitioned reuse buffers)."""
    c = Circuit("blur")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    seed_vs = [rng(s).getrandbits(32) | 1 for s in sl]
    lb1 = c.mem("lb1", width, 16)
    lb2 = c.mem("lb2", width, 16)
    ctr = make_counter(c, 16)
    col = (ctr & (width - 1))[15:0]

    x = planes.reg(32, seed_vs, "pixgen")
    c.set_next(x, xorshift32_sig(c, x))
    pix = x[15:0]

    row1 = c.mem_read(lb1, col)
    row2 = c.mem_read(lb2, col)
    c.mem_write(lb2, col, row1, c.const(1, 1))
    c.mem_write(lb1, col, pix, c.const(1, 1))

    # 3x3 window registers (shift in the three row taps)
    taps = {}
    for rname, src in (("r0", row2), ("r1", row1), ("r2", pix)):
        t0 = c.reg(16, init=0, name=f"{rname}a")
        t1 = c.reg(16, init=0, name=f"{rname}b")
        c.set_next(t1, t0)
        c.set_next(t0, src)
        taps[rname] = (src, t0, t1)

    def w32(s):
        return s.zext(32)

    (p02, p01, p00) = taps["r0"]
    (p12, p11, p10) = taps["r1"]
    (p22, p21, p20) = taps["r2"]
    out = (w32(p00) + (w32(p01) << 1) + w32(p02) +
           (w32(p10) << 1) + (w32(p11) << 2) + (w32(p12) << 1) +
           w32(p20) + (w32(p21) << 1) + w32(p22)) >> 4
    csum = c.reg(32, init=0, name="csum")
    c.set_next(csum, (csum ^ out) + 1)

    # golden, per seed
    golds = []
    for seed_v in seed_vs:
        lb1p, lb2p = [0] * width, [0] * width
        t0p = {k: 0 for k in ("r0", "r1", "r2")}
        t1p = {k: 0 for k in ("r0", "r1", "r2")}
        xp, csump = seed_v, 0
        for t in range(n_cycles):
            colp = t & (width - 1)
            pixp = xp & M16
            r1p, r2p = lb1p[colp], lb2p[colp]
            srcs = {"r0": r2p, "r1": r1p, "r2": pixp}
            outp = (t1p["r0"] + 2 * t0p["r0"] + srcs["r0"] +
                    2 * t1p["r1"] + 4 * t0p["r1"] + 2 * srcs["r1"] +
                    t1p["r2"] + 2 * t0p["r2"] + srcs["r2"]) >> 4
            csump = ((csump ^ outp) + 1) & M32
            lb2p[colp] = r1p
            lb1p[colp] = pixp
            for k in srcs:
                t1p[k] = t0p[k]
                t0p[k] = srcs[k]
            xp = xorshift32_py(xp)
        golds.append(csump)
    total = finish_and_check(c, ctr, n_cycles, [(csum, golds)], planes)
    return Bench(c, total, meta={"csum": golds[0]}).attach(planes, sl)


def build_jpeg(n_cycles: int = 512, seed: int = 23, seeds=None) -> Bench:
    """Serial variable-length decoder: a leading-ones length chain, a
    barrel-shifted bit reservoir and a Huffman table lookup form one long
    sequential dependence per cycle (the paper's jpeg: Huffman is the
    bottleneck and parallelism is ~nil)."""
    c = Circuit("jpeg")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    rs = [rng(s) for s in sl]
    huff_vs = [[r.getrandbits(16) for _ in range(64)] for r in rs]
    huff = planes.mem("huff", 64, 16, huff_vs)
    seed_vs = [r.getrandbits(32) | 1 for r in rs]

    ctr = make_counter(c, 16)
    buf = planes.reg(32, seed_vs, "buf")
    c.set_next(buf, xorshift32_sig(c, buf))

    # leading-ones count of the top 8 bits (serial chain)
    ones = c.const(0, 4)
    run = c.const(1, 1)
    for k in range(8):
        bit = buf[31 - k]
        run = run & bit
        ones = ones + run.zext(4)
    # barrel shift by the decoded length (serial mux chain)
    shifted = c.shr_dyn(buf, ones)
    sym = (shifted & 0x3F)[5:0]
    entry = c.mem_read(huff, sym.zext(16))
    val = c.reg(32, init=0, name="val")
    nxt = ((val << 1) | (val >> 31)) + entry.zext(32) + ones.zext(32)
    c.set_next(val, nxt)

    # golden, per seed
    golds = []
    for huff_v, seed_v in zip(huff_vs, seed_vs):
        bufp, valp = seed_v, 0
        for _ in range(n_cycles):
            onesp, runp = 0, 1
            for k in range(8):
                runp &= (bufp >> (31 - k)) & 1
                onesp += runp
            shiftedp = bufp >> onesp
            symp = shiftedp & 0x3F
            valp = (((valp << 1) | (valp >> 31)) + huff_v[symp] + onesp) & M32
            bufp = xorshift32_py(bufp)
        golds.append(valp)
    total = finish_and_check(c, ctr, n_cycles, [(val, golds)], planes)
    return Bench(c, total, meta={"val": golds[0]}).attach(planes, sl)
