"""The paper's nine evaluation benchmarks (§7.5) as circuit generators.

Each builder returns a self-checking :class:`~repro.circuits.common.Bench`:
the circuit raises exception id 1 (FINISH) at ``bench.n_cycles`` when every
golden check passed, and id 2 (MISMATCH) one cycle earlier otherwise.

``full`` builds the evaluation-scale versions; ``small`` builds reduced
variants for oracle-vs-engine differential tests.
"""
from __future__ import annotations

from typing import Callable, Dict

from .common import Bench, FINISH, MISMATCH
from .compute import build_bc, build_cgra, build_mc, build_mm
from .memory import build_blur, build_jpeg, build_vta
from .network import build_noc, build_rv32r

CIRCUITS: Dict[str, Callable[..., Bench]] = {
    "bc": build_bc,
    "mm": build_mm,
    "mc": build_mc,
    "cgra": build_cgra,
    "vta": build_vta,
    "blur": build_blur,
    "jpeg": build_jpeg,
    "noc": build_noc,
    "rv32r": build_rv32r,
}

# evaluation-scale parameters (compile times stay in seconds; the paper's
# exact RTL is not reproducible without its Verilog sources, so sizes are
# chosen to preserve each benchmark's *character*: relative step size,
# parallelism profile and memory behaviour)
FULL_PARAMS: Dict[str, Dict] = {
    "bc": dict(n_cycles=64, n_pipes=4),
    "mm": dict(n=16),
    "mc": dict(n_walkers=32, n_cycles=128),
    "cgra": dict(rows=8, cols=8, n_cycles=96),
    "vta": dict(n_cycles=256, depth=256, acc_depth=64, lanes=4),
    "blur": dict(n_cycles=256, width=32),
    "jpeg": dict(n_cycles=512),
    "noc": dict(rows=4, cols=4, n_cycles=200),
    "rv32r": dict(n_cores=16, n_cycles=128),
}

SMALL_PARAMS: Dict[str, Dict] = {
    "bc": dict(n_cycles=24, n_pipes=1),
    "mm": dict(n=4),
    "mc": dict(n_walkers=4, n_cycles=32),
    "cgra": dict(rows=2, cols=2, n_cycles=24),
    "vta": dict(n_cycles=48, depth=64, acc_depth=16, lanes=2),
    "blur": dict(n_cycles=48, width=8),
    "jpeg": dict(n_cycles=48),
    "noc": dict(rows=2, cols=2, n_cycles=32),
    "rv32r": dict(n_cores=4, n_cycles=32),
}


SCALES = ("full", "small")


def build(name: str, scale: str = "full", seeds=None, **overrides) -> Bench:
    """Build one benchmark. ``seeds=[s0, s1, ...]`` requests a *batched*
    bench: one structural netlist (that of ``s0``) plus per-seed init
    planes (``bench.reg_planes``/``bench.mem_planes``) so a single compiled
    Program can simulate every stimulus at once (``core.bsp.BatchedMachine``
    — or, one level up, ``repro.sim.compile(name, seeds=[...])``).
    """
    if name not in CIRCUITS:
        raise KeyError(
            f"unknown circuit {name!r}: available circuits are "
            f"{', '.join(sorted(CIRCUITS))} (scales: {', '.join(SCALES)})")
    if scale not in SCALES:
        raise KeyError(
            f"unknown scale {scale!r} for circuit {name!r}: valid scales "
            f"are {', '.join(SCALES)}")
    params = dict(FULL_PARAMS[name] if scale == "full"
                  else SMALL_PARAMS[name])
    params.update(overrides)
    if seeds is not None:
        params["seeds"] = list(seeds)
    return CIRCUITS[name](**params)
