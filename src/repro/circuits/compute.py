"""Compute-heavy benchmarks: bc (bitcoin/SHA-round), mm (matmul),
mc (Monte-Carlo), cgra (PE grid). Paper §7.5.

Every builder accepts ``seeds=[...]`` for batched-stimulus builds: one
structural netlist (built from ``seeds[0]``) whose seed-dependent values —
register resets and golden check constants — live in per-seed init planes
(see ``common.Planes``). Structural constants (mm's ROM matrices, cgra's
weights) stay those of ``seeds[0]``; the *stimulus* axis is the initial
register state.
"""
from __future__ import annotations

from typing import List

from ..core.netlist import Circuit, Sig
from .common import (Bench, M16, M32, finish_and_check, make_counter,
                     make_planes, rng, rom16, rotr32, py_rotl32, seed_list,
                     xorshift32_py, xorshift32_sig)

_K = [0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5,
      0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5]
_IV = [0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A,
       0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19]


def build_bc(n_cycles: int = 64, n_pipes: int = 2, seed: int = 7,
             seeds=None) -> Bench:
    """SHA-256-style round pipelines fed by an xorshift message schedule.
    ``n_pipes`` independent pipelines model the miner's unrolled cores."""
    c = Circuit("bc")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    ctr = make_counter(c, 16)
    checks = []
    golden_meta = {}
    for pipe in range(n_pipes):
        w0s = [rng(s + pipe).getrandbits(32) for s in sl]
        st = [c.reg(32, init=_IV[i] ^ pipe, name=f"h{pipe}_{i}")
              for i in range(8)]
        w = planes.reg(32, w0s, f"w{pipe}")
        c.set_next(w, xorshift32_sig(c, w))
        a, b_, cc, d, e, f, g, h = st
        s1 = rotr32(c, e, 6) ^ rotr32(c, e, 11) ^ rotr32(c, e, 25)
        ch = (e & f) ^ (~e & g)
        kc = c.const(_K[pipe % 8], 32)
        t1 = h + s1 + ch + kc + w
        s0 = rotr32(c, a, 2) ^ rotr32(c, a, 13) ^ rotr32(c, a, 22)
        maj = (a & b_) ^ (a & cc) ^ (b_ & cc)
        t2 = s0 + maj
        c.set_next(h, g); c.set_next(g, f); c.set_next(f, e)
        c.set_next(e, d + t1)
        c.set_next(d, cc); c.set_next(cc, b_); c.set_next(b_, a)
        c.set_next(a, t1 + t2)

        # python golden, per seed
        golds_a, golds_e = [], []
        for w0 in w0s:
            sp = [(_IV[i] ^ pipe) & M32 for i in range(8)]
            wp = w0
            for _ in range(n_cycles):
                pa, pb, pc_, pd, pe, pf, pg, ph = sp
                ps1 = py_rotl32(pe, 32 - 6) ^ py_rotl32(pe, 32 - 11) ^ \
                    py_rotl32(pe, 32 - 25)
                pch = (pe & pf) ^ (~pe & pg & M32)
                pt1 = (ph + ps1 + pch + _K[pipe % 8] + wp) & M32
                ps0 = py_rotl32(pa, 32 - 2) ^ py_rotl32(pa, 32 - 13) ^ \
                    py_rotl32(pa, 32 - 22)
                pmaj = (pa & pb) ^ (pa & pc_) ^ (pb & pc_)
                pt2 = (ps0 + pmaj) & M32
                sp = [(pt1 + pt2) & M32, pa, pb, pc_, (pd + pt1) & M32,
                      pe, pf, pg]
                wp = xorshift32_py(wp)
            golds_a.append(sp[0])
            golds_e.append(sp[4])
        checks.append((a, golds_a))
        checks.append((e, golds_e))
        golden_meta[f"digest{pipe}"] = golds_a[0]
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta=golden_meta).attach(planes, sl)


def build_mm(n: int = 8, seed: int = 11, seeds=None) -> Bench:
    """n x n int16 matrix multiply on n row-PEs; PE i streams A[i,k]*B[k,j]
    over time (one (j,k) pair per cycle) and checks each C[i,j].

    The A/B matrices are ROM constants (structure), so the batched stimulus
    axis is a per-seed random *initial accumulator*: block j=0 then sums
    ``acc0 + Σ A[i,k]B[k,0]`` and the golden compare subtracts the
    (init-plane-held) ``acc0`` before checking against the shared ROM
    goldens — code identical across seeds, state seed-dependent."""
    c = Circuit("mm")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    r = rng(sl[0])
    A = [[r.getrandbits(16) for _ in range(n)] for _ in range(n)]
    B = [[r.getrandbits(16) for _ in range(n)] for _ in range(n)]
    Cg = [[sum(A[i][k] * B[k][j] for k in range(n)) & M32
           for j in range(n)] for i in range(n)]

    lg = (n - 1).bit_length()
    ctr = make_counter(c, 16)
    k_idx = ctr[lg - 1:0]
    j_idx = ctr[2 * lg - 1:lg]
    # shared B element (same for every PE): one mux tree over (j,k)
    b_flat = [B[k][j] for j in range(n) for k in range(n)]
    b_el = rom16(c, b_flat, ctr[2 * lg - 1:0], 16)

    checks = []
    for i in range(n):
        a_el = rom16(c, A[i], k_idx, 16)
        acc0s = [0] if not planes.live else \
            [rng(s * 1013 + i).getrandbits(32) for s in sl]
        acc = planes.reg(32, acc0s, f"acc{i}")
        prod = (a_el.zext(32) * b_el.zext(32))
        at_last_k = k_idx.eq(n - 1)
        nxt = c.mux(at_last_k, c.const(0, 32), acc + prod)
        c.set_next(acc, nxt)
        # per-cycle golden compare accumulates into a *sticky* error bit so
        # the check logic stays inside the PE's process (a per-cycle EXPECT
        # would drag every PE cone into the privileged core)
        cg_el = rom16(c, [Cg[i][j] & M16 for j in range(n)], j_idx, 16)
        cg_hi = rom16(c, [(Cg[i][j] >> 16) & M16 for j in range(n)], j_idx, 16)
        full = acc + prod
        if planes.live:
            # block j=0 starts from the per-seed acc0 — subtract it before
            # comparing against the (shared, structural) golden ROM
            a0 = planes.hold(acc0s, 32, f"acc0h{i}")
            corr = c.mux(j_idx.eq(0), a0, c.const(0, 32))
            full = full - corr
        mism = at_last_k & (full[15:0].ne(cg_el) | full[31:16].ne(cg_hi))
        err = c.reg(1, init=0, name=f"err{i}")
        c.set_next(err, err | mism)
        checks.append((err, 0))
        checks.append((acc, 0))  # accumulator parks at 0 after last reset

    total = finish_and_check(c, ctr, n * n, checks, planes)
    return Bench(c, total, meta={"C00": Cg[0][0]}).attach(planes, sl)


def build_mc(n_walkers: int = 16, n_cycles: int = 128, seed: int = 3,
             seeds=None) -> Bench:
    """Monte-Carlo price evolution with fixed-point arithmetic + xorshift
    RNG per walker (paper's mc)."""
    c = Circuit("mc")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    ctr = make_counter(c, 16)
    rs = [rng(s) for s in sl]
    checks = []
    csum_g = 0
    sums: List[Sig] = []
    for wk in range(n_walkers):
        seed_ws = [r.getrandbits(32) | 1 for r in rs]
        p0s = [(1 << 16) + r.getrandbits(12) for r in rs]
        x = planes.reg(32, seed_ws, f"rng{wk}")
        p = planes.reg(32, p0s, f"price{wk}")
        c.set_next(x, xorshift32_sig(c, x))
        up = (p * (x & 0xFF)) >> 12
        dn = p >> 6
        c.set_next(p, p + up - dn)
        sums.append(p)

        # golden, per seed
        golds = []
        for seed_w, p0 in zip(seed_ws, p0s):
            xp, pp = seed_w, p0
            for _ in range(n_cycles):
                pup = (pp * (xp & 0xFF)) >> 12
                pdn = pp >> 6
                pp = (pp + pup - pdn) & M32
                xp = xorshift32_py(xp)
            golds.append(pp)
        checks.append((p, golds))
        csum_g = (csum_g + golds[0]) & M32
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta={"csum": csum_g}).attach(planes, sl)


def build_cgra(rows: int = 4, cols: int = 4, n_cycles: int = 96,
               seed: int = 5, seeds=None) -> Bench:
    """Coarse-grained reconfigurable array: fixed-point MAC PEs on a 2-D
    torus, each combining its north and east neighbours every cycle. The
    weights are structure (``seeds[0]``); the per-seed stimulus is the
    initial PE state."""
    c = Circuit("cgra")
    sl = seed_list(seed, seeds)
    planes = make_planes(c, seed, seeds)
    ctr = make_counter(c, 16)
    n = rows * cols
    r0 = rng(sl[0])
    inits = [[r0.getrandbits(32) for _ in range(n)]]
    wgt = [r0.getrandbits(8) | 1 for _ in range(n)]   # structure: seeds[0]
    for s in sl[1:]:
        r = rng(s)
        inits.append([r.getrandbits(32) for _ in range(n)])
    v = [planes.reg(32, [inits[b][i] for b in range(len(sl))], f"pe{i}")
         for i in range(n)]
    for i in range(n):
        row, col = divmod(i, cols)
        north = v[((row - 1) % rows) * cols + col]
        east = v[row * cols + (col + 1) % cols]
        mac = v[i] + ((north * wgt[i]) >> 8)
        c.set_next(v[i], mac ^ (east >> 1))

    # golden, per seed
    finals = []
    for b in range(len(sl)):
        vp = list(inits[b])
        for _ in range(n_cycles):
            nxt = []
            for i in range(n):
                row, col = divmod(i, cols)
                north = vp[((row - 1) % rows) * cols + col]
                east = vp[row * cols + (col + 1) % cols]
                mac = (vp[i] + (((north * wgt[i]) & M32) >> 8)) & M32
                nxt.append(mac ^ (east >> 1))
            vp = nxt
        finals.append(vp)
    checks = [(v[i], [finals[b][i] for b in range(len(sl))])
              for i in range(0, n, 3)]
    total = finish_and_check(c, ctr, n_cycles, checks, planes)
    return Bench(c, total, meta={"pe0": finals[0][0]}).attach(planes, sl)
