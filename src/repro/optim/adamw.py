"""AdamW with fp32 master state over bf16 params, gradient clipping, cosine
schedule, and optional int8-compressed gradient all-reduce with error
feedback (the cross-pod distributed-optimization lever)."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    ef: Optional[Any] = None   # error-feedback residual (compression)


def init(params, compress: bool = False) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        ef=jax.tree.map(zeros, params) if compress else None)


def cosine_lr(step, base_lr=3e-4, warmup=200, total=10000):
    warm = base_lr * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef):
    """int8 + error feedback: returns (quantized tree, new residuals).
    The caller all-reduces the int8 payload (16x less cross-pod traffic than
    fp32 + the bf16->int8 4x on-wire saving); residuals carry the rounding
    error into the next step so convergence is unaffected to first order."""
    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef)[0]
    quants, scales, resid = [], [], []
    for g, e in zip(flat_g, flat_e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        quants.append(q)
        scales.append(s)
        resid.append(gf - dequantize_int8(q, s))
    return (jax.tree_util.tree_unflatten(tree, quants),
            jax.tree_util.tree_unflatten(tree, scales),
            jax.tree_util.tree_unflatten(tree, resid))


def apply(params, grads, state: AdamWState, *, lr=None, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, clip=1.0):
    """One AdamW update. Grads may be lower precision; math is fp32."""
    step = state.step + 1
    if lr is None:
        lr = cosine_lr(step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step)
        vh = v / (1 - b2 ** step)
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v, state.ef), gnorm
