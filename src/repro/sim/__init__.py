"""``repro.sim`` — the unified simulation front-end.

One import gives the whole workflow::

    import repro.sim as sim

    s = sim.compile("rv32r", scale="small", cache=True)   # or a Circuit/Bench
    result = s.run()                   # RunResult: cycles/exceptions/probes
    s.save("rv32r.npz")                # persistent compiled artifact
    s2 = sim.load("rv32r.npz")         # ...reloaded without recompiling

Layers (each importable on its own):

- :mod:`repro.sim.result` — :class:`RunResult`, the uniform return shape.
- :mod:`repro.sim.engine` — the :class:`Engine` protocol and adapters over
  all five executors (``Machine``, ``BatchedMachine``, ``GridMachine``,
  ``IsaSim``, ``NetlistSim``).
- :mod:`repro.sim.artifact` — versioned ``.npz`` Program serialization
  (``Program.save``/``Program.load`` delegate here).
- :mod:`repro.sim.cache` — the fingerprint-keyed on-disk compile cache.
- :mod:`repro.sim.facade` — :func:`compile`, :func:`load` and
  :class:`Simulation` tying it together.

``repro.core.*`` remains importable unchanged — this package is a facade
over those modules, not a replacement. See ``docs/api.md``.
"""
from .artifact import FORMAT_VERSION, load_program, save_program
from .cache import CompileCache, cache_key, default_cache_dir
from .engine import (BatchedEngine, Engine, GridEngine, IsaEngine,
                     MachineEngine, OracleEngine, ShardedBatchedEngine)
from .facade import CYCLE_SLACK, Simulation, compile, load
from .result import FINISH, MISMATCH, RunResult

__all__ = [
    "compile", "load", "Simulation", "RunResult", "Engine",
    "MachineEngine", "BatchedEngine", "ShardedBatchedEngine", "GridEngine",
    "IsaEngine",
    "OracleEngine", "save_program", "load_program", "FORMAT_VERSION",
    "CompileCache", "cache_key", "default_cache_dir",
    "FINISH", "MISMATCH", "CYCLE_SLACK",
]
