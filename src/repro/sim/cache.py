"""On-disk compile cache: fingerprint the request, reuse the artifact.

Compilation is the expensive half of the Manticore bargain (lower → opt →
partition → lutsynth → schedule → regalloc); simulation of the resulting
static binary is the cheap half. A production service replaying the same
designs across many scenarios should pay compilation once *per design per
configuration* — across processes, not just within one.

The cache key is a SHA-256 over:

* the **circuit fingerprint** (:meth:`repro.core.netlist.Circuit.fingerprint`
  — a structural hash of nodes, memories, register init/next maps and
  latched input values; two builds of the same design collide, any
  semantic difference does not),
* every :class:`~repro.core.isa.HardwareConfig` field,
* the compiler options (``strategy``, ``use_luts``, ``optimize``,
  ``sched_strategy``, ``placement``, ``pipeline``),
* the artifact :data:`~repro.sim.artifact.FORMAT_VERSION` (a schema bump
  silently invalidates old entries — they just miss).

Entries are ordinary :mod:`repro.sim.artifact` files named ``<key>.npz``
under the cache directory (``REPRO_SIM_CACHE`` env var, default
``~/.cache/repro-sim``), so a cache entry doubles as a shareable artifact.
A loaded entry is marked ``stats["cache_hit"] = True`` — the flag the
acceptance timing checks (and ``benchmarks/bench_compile.py``'s cold/warm
rows) key on.
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict
from pathlib import Path
from typing import Optional, Union

from ..core.compile import Program
from ..core.isa import HardwareConfig
from ..core.netlist import Circuit
from .artifact import FORMAT_VERSION, load_program, save_program

ENV_VAR = "REPRO_SIM_CACHE"


def default_cache_dir() -> Path:
    return Path(os.environ.get(ENV_VAR, "~/.cache/repro-sim")).expanduser()


def cache_key(circuit: Optional[Circuit], hw: HardwareConfig, *,
              strategy: str = "balanced", use_luts: bool = True,
              optimize: bool = True, sched_strategy: str = "slack",
              placement: str = "anneal", pipeline: str = "modulo",
              fingerprint: Optional[str] = None) -> str:
    """Deterministic key for one (circuit, hardware, options) request.
    ``fingerprint`` supplies a precomputed ``Circuit.fingerprint()`` (the
    facade and the serving layer hash each circuit once); without it the
    circuit is fingerprinted here."""
    payload = json.dumps({
        "format_version": FORMAT_VERSION,
        "circuit": fingerprint or circuit.fingerprint(),
        "hw": asdict(hw),
        "strategy": strategy,
        "use_luts": bool(use_luts),
        "optimize": bool(optimize),
        "sched_strategy": sched_strategy,
        "placement": placement,
        "pipeline": pipeline,
    }, sort_keys=True)
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()


class CompileCache:
    """A directory of ``<key>.npz`` Program artifacts.

    **Concurrency contract (last-writer-wins, no locks).** Entries are
    published by :func:`repro.sim.artifact.save_program`, which writes to a
    uniquely-named temp file in the cache directory and ``os.replace``-s it
    over the entry — an atomic rename on POSIX and Windows. Two processes
    (or daemon workers) cold-compiling the same key therefore race
    harmlessly: each publishes a *complete* artifact, the later rename
    wins, and a concurrent :meth:`load` observes either a full old entry, a
    full new entry, or no entry — never a torn file. Determinism makes
    last-writer-wins sound: both writers compiled the same key, so the
    artifacts are interchangeable. A reader that does catch a half-state
    (entry vanishing mid-read, incompatible version) reads it as a miss and
    recompiles. ``tests/test_serve.py`` hammers this contract with
    concurrent writer/reader threads.
    """

    def __init__(self, root: Union[str, Path, None] = None):
        self.root = Path(root) if root is not None else default_cache_dir()

    def path(self, key: str) -> Path:
        return self.root / f"{key}.npz"

    def load(self, key: str) -> Optional[Program]:
        """The cached Program for ``key`` (marked ``stats['cache_hit']``),
        or None. A corrupt or version-incompatible entry reads as a miss —
        the caller recompiles and overwrites it."""
        p = self.path(key)
        if not p.is_file():
            return None
        try:
            prog = load_program(p)
        except Exception:
            return None
        prog.stats["cache_hit"] = True
        return prog

    def store(self, key: str, prog: Program) -> Path:
        return save_program(prog, self.path(key))


def resolve_cache(cache: Union[bool, str, Path, "CompileCache", None]
                  ) -> Optional[CompileCache]:
    """Normalize the facade's ``cache=`` argument: ``False``/``None``
    disables caching, ``True`` uses the default directory, a path or a
    :class:`CompileCache` selects an explicit one."""
    if cache is None or cache is False:
        return None
    if cache is True:
        return CompileCache()
    if isinstance(cache, CompileCache):
        return cache
    return CompileCache(cache)
