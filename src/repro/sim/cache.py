"""On-disk compile cache: fingerprint the request, reuse the artifact.

Compilation is the expensive half of the Manticore bargain (lower → opt →
partition → lutsynth → schedule → regalloc); simulation of the resulting
static binary is the cheap half. A production service replaying the same
designs across many scenarios should pay compilation once *per design per
configuration* — across processes, not just within one.

The cache key is a SHA-256 over:

* the **circuit fingerprint** (:meth:`repro.core.netlist.Circuit.fingerprint`
  — a structural hash of nodes, memories, register init/next maps and
  latched input values; two builds of the same design collide, any
  semantic difference does not),
* every :class:`~repro.core.isa.HardwareConfig` field,
* the compiler options (``strategy``, ``use_luts``, ``optimize``,
  ``sched_strategy``, ``placement``, ``pipeline``),
* the artifact :data:`~repro.sim.artifact.FORMAT_VERSION` (a schema bump
  silently invalidates old entries — they just miss).

Entries are ordinary :mod:`repro.sim.artifact` files named ``<key>.npz``
under the cache directory (``REPRO_SIM_CACHE`` env var, default
``~/.cache/repro-sim``), so a cache entry doubles as a shareable artifact.
A loaded entry is marked ``stats["cache_hit"] = True`` — the flag the
acceptance timing checks (and ``benchmarks/bench_compile.py``'s cold/warm
rows) key on.
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import asdict
from pathlib import Path
from typing import Optional, Union

from ..core.compile import Program
from ..core.isa import HardwareConfig
from ..core.netlist import Circuit
from .artifact import FORMAT_VERSION, load_program, save_program

ENV_VAR = "REPRO_SIM_CACHE"


def default_cache_dir() -> Path:
    return Path(os.environ.get(ENV_VAR, "~/.cache/repro-sim")).expanduser()


def cache_key(circuit: Circuit, hw: HardwareConfig, *,
              strategy: str = "balanced", use_luts: bool = True,
              optimize: bool = True, sched_strategy: str = "slack",
              placement: str = "anneal", pipeline: str = "modulo") -> str:
    """Deterministic key for one (circuit, hardware, options) request."""
    payload = json.dumps({
        "format_version": FORMAT_VERSION,
        "circuit": circuit.fingerprint(),
        "hw": asdict(hw),
        "strategy": strategy,
        "use_luts": bool(use_luts),
        "optimize": bool(optimize),
        "sched_strategy": sched_strategy,
        "placement": placement,
        "pipeline": pipeline,
    }, sort_keys=True)
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()


class CompileCache:
    """A directory of ``<key>.npz`` Program artifacts."""

    def __init__(self, root: Union[str, Path, None] = None):
        self.root = Path(root) if root is not None else default_cache_dir()

    def path(self, key: str) -> Path:
        return self.root / f"{key}.npz"

    def load(self, key: str) -> Optional[Program]:
        """The cached Program for ``key`` (marked ``stats['cache_hit']``),
        or None. A corrupt or version-incompatible entry reads as a miss —
        the caller recompiles and overwrites it."""
        p = self.path(key)
        if not p.is_file():
            return None
        try:
            prog = load_program(p)
        except Exception:
            return None
        prog.stats["cache_hit"] = True
        return prog

    def store(self, key: str, prog: Program) -> Path:
        return save_program(prog, self.path(key))


def resolve_cache(cache: Union[bool, str, Path, "CompileCache", None]
                  ) -> Optional[CompileCache]:
    """Normalize the facade's ``cache=`` argument: ``False``/``None``
    disables caching, ``True`` uses the default directory, a path or a
    :class:`CompileCache` selects an explicit one."""
    if cache is None or cache is False:
        return None
    if cache is True:
        return CompileCache()
    if isinstance(cache, CompileCache):
        return cache
    return CompileCache(cache)
