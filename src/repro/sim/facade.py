"""``repro.sim`` front door: compile once, simulate anywhere.

``compile(source, ...)`` accepts a benchmark *name* (``"mc"``), a built
:class:`~repro.circuits.common.Bench` or a raw
:class:`~repro.core.netlist.Circuit`, runs (or cache-loads) the static-BSP
compiler, and returns a :class:`Simulation` — a handle that owns the
compiled :class:`~repro.core.compile.Program`, remembers the source bench
(cycle budget, per-seed init planes) and hands out protocol-conforming
engines on demand::

    import repro.sim as sim

    s = sim.compile("mc", scale="small", seeds=[1, 2, 3], cache=True)
    results = s.run()                  # auto: BatchedEngine, 3 stimuli
    assert all(r.finished for r in results)

    r = s.run(engine="isa")            # same Program, numpy backend
    s.save("mc.npz"); s2 = sim.load("mc.npz")   # persistent artifact

Engine auto-selection: a ``mesh=`` requests the core-sharded
``GridEngine``; a batch (``seeds=``/``images=`` with more than one
stimulus) picks the mesh-sharded ``ShardedBatchedEngine`` when more than
one device is visible and B >= 2*D (or ``shard_batch=True`` forces it) and
the vmapped single-device ``BatchedEngine`` otherwise; a single stimulus
gets the specialized jnp engine.
``engine="oracle"`` cross-checks against the netlist interpreter (available
whenever the Simulation still knows its source circuit). All
``init_images``/``Planes`` plumbing stays behind this module.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

import jax

from ..core.compile import Program, compile_circuit
from ..core.isa import HardwareConfig
from ..core.netlist import Circuit
from .artifact import load_program
from .cache import CompileCache, cache_key, resolve_cache
from .engine import (BatchedEngine, Engine, GridEngine, Images, IsaEngine,
                     MachineEngine, OracleEngine, ShardedBatchedEngine)
from .result import RunResult

# Extra Vcycles past a bench's FINISH cycle: the budget must overshoot so a
# missing exception is detected as "ran past the end", never masked.
CYCLE_SLACK = 10

_ENGINE_KINDS = ("auto", "machine", "jnp", "pallas", "seed", "batched",
                 "sharded", "grid", "isa", "oracle", "netlist", "reference")


def _auto_shard(shard_batch, B: int, devices) -> bool:
    """Auto-selection rule for the batch-sharded engine: an explicit
    ``shard_batch`` wins; otherwise shard when the mesh has more than one
    device and every device gets at least two elements (B >= 2*D — below
    that the plain vmapped engine wins on dispatch overhead)."""
    if shard_batch is not None:
        return bool(shard_batch)
    D = len(devices) if devices is not None else len(jax.devices())
    return D > 1 and B >= 2 * D


@dataclass
class Simulation:
    """A compiled design plus everything needed to simulate it."""

    program: Program
    bench: Optional["Bench"] = None          # noqa: F821 (circuits.common)
    circuit: Optional[Circuit] = None
    meta: Dict = field(default_factory=dict)
    # default-option engine memo per kind (see Simulation.run)
    _engines: Dict[str, Engine] = field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------
    @property
    def n_cycles(self) -> Optional[int]:
        """The bench's self-checking FINISH cycle, when known."""
        return self.bench.n_cycles if self.bench is not None else None

    @property
    def batch(self) -> int:
        """Stimulus count carried by the source bench (1 when legacy)."""
        return self.bench.batch if self.bench is not None else 1

    @property
    def cache_hit(self) -> bool:
        return bool(self.program.stats.get("cache_hit", False))

    @property
    def fingerprint(self) -> Optional[str]:
        """Structural fingerprint of the compiled circuit
        (:meth:`repro.core.netlist.Circuit.fingerprint`) — the identity the
        compile cache and the serving batcher key on. Recorded in
        ``Program.stats`` at compile time, so it survives artifact
        round-trips; None only for hand-built Programs that never saw a
        circuit."""
        fp = self.meta.get("fingerprint") \
            or self.program.stats.get("fingerprint")
        if fp is None and self.circuit is not None:
            fp = self.circuit.fingerprint()
            self.meta["fingerprint"] = fp
        return fp

    def select_engine_kind(self, batch: Optional[int] = None, *,
                           mesh=None, devices=None,
                           shard_batch: Optional[bool] = None) -> str:
        """The engine kind ``engine("auto")`` resolves to — without
        constructing it. ``batch`` defaults to this Simulation's own
        stimulus count; a serving layer passes the coalesced batch size it
        is about to launch."""
        if mesh is not None:
            return "grid"
        B = self.batch if batch is None else int(batch)
        if shard_batch is None:
            shard_batch = self.meta.get("shard_batch")
        if B > 1 and _auto_shard(shard_batch, B, devices):
            return "sharded"
        if B > 1:
            return "batched"
        return "machine"

    @property
    def engine_kind(self) -> str:
        """Auto-selected engine kind for this Simulation's own batch."""
        return self.select_engine_kind()

    def default_cycles(self) -> int:
        if self.n_cycles is None:
            raise ValueError(
                "this Simulation has no bench cycle budget — pass "
                "cycles= explicitly")
        return self.n_cycles + CYCLE_SLACK

    def images(self) -> Optional[List[Images]]:
        """Per-stimulus (reg, spad, gmem) init images from the bench's
        seed planes, or None for a legacy single-stimulus build."""
        if self.bench is None or self.bench.reg_planes is None:
            return None
        return self.bench.images(self.program)

    def images_stacked(self, workers: Optional[int] = None):
        """Stacked ``([B, C, R], [B, C, S], [B, G])`` init images,
        generated host-parallel — the layout the batched/sharded engines
        consume directly (None for a legacy single-stimulus build)."""
        if self.bench is None or self.bench.reg_planes is None:
            return None
        return self.bench.images_batch(self.program, workers=workers)

    # ------------------------------------------------------------------
    def engine(self, kind: str = "auto", *, mesh=None,
               images: Optional[Sequence[Images]] = None,
               batch: Optional[int] = None, backend: str = "jnp",
               specialize: bool = True, shard_batch: Optional[bool] = None,
               devices=None, workers: Optional[int] = None,
               **opts) -> Engine:
        """Construct a protocol-conforming engine over this Program.

        ``kind="auto"`` picks grid (when ``mesh`` is given),
        batch-sharded (multi-stimulus on a multi-device mesh with
        B >= 2*D, or ``shard_batch=True`` — here or at
        :func:`compile` time), batched (several stimuli on one device)
        or the single-stimulus jnp engine. Explicit kinds:
        ``machine``/``jnp``, ``pallas``, ``seed`` (the unspecialized
        baseline arm), ``batched``, ``sharded``, ``grid``, ``isa``,
        ``oracle``/``netlist``/``reference``.
        """
        if kind not in _ENGINE_KINDS:
            raise ValueError(
                f"unknown engine kind {kind!r}; choose from "
                f"{', '.join(_ENGINE_KINDS)}")
        if batch is not None:
            B = batch
        elif images is not None:
            B = (int(images[0].shape[0])
                 if getattr(images[0], "ndim", 0) == 3 else len(images))
        else:
            B = self.batch

        if kind == "auto":
            kind = self.select_engine_kind(B, mesh=mesh, devices=devices,
                                           shard_batch=shard_batch)
        if kind in ("oracle", "netlist", "reference"):
            if self.circuit is None:
                raise ValueError(
                    "oracle engine needs the source circuit — this "
                    "Simulation was loaded from an artifact")
            return OracleEngine(self.circuit, self.program)
        if kind == "grid":
            if mesh is None:
                raise ValueError("grid engine needs a mesh=")
            if images is None:
                images = self.images()
            return GridEngine(self.program, mesh, images=images, **opts)
        if kind == "sharded":
            if images is None:
                # host-parallel image generation straight into the
                # stacked/sharded layout
                images = self.images_stacked(workers=workers)
            return ShardedBatchedEngine(
                self.program, images=images,
                batch=None if images is not None else B,
                devices=devices, backend=backend, **opts)
        if kind == "batched":
            if images is None:
                images = self.images_stacked(workers=workers)
            return BatchedEngine(self.program, images=images,
                                 batch=None if images is not None else B,
                                 backend=backend, **opts)
        if images is None:
            images = self.images()
        img0 = _first_image(images)
        if kind == "isa":
            return IsaEngine(self.program, images=img0)
        if kind == "pallas":
            backend = "pallas"
        if kind == "seed":
            specialize = False
        return MachineEngine(self.program, backend=backend,
                             specialize=specialize, images=img0, **opts)

    def run(self, cycles: Optional[int] = None, *, engine: str = "auto",
            **opts) -> Union[RunResult, List[RunResult]]:
        """Compile-free simulation in one call: build the (auto-selected)
        engine, run ``cycles`` Vcycles (default: the bench budget plus
        slack) and return the uniform result — one :class:`RunResult`, or
        a per-stimulus list when the engine is batched.

        Engines built with default options are memoized per kind (reset
        before each run), so repeated ``run()`` calls pay the XLA trace
        once; calls with explicit options construct a fresh engine — hold
        your own ``Simulation.engine(...)`` to amortize those."""
        if opts:
            eng = self.engine(engine, **opts)
        else:
            eng = self._engines.get(engine)
            if eng is None:
                eng = self._engines[engine] = self.engine(engine)
            else:
                eng.reset()
        n = cycles if cycles is not None else self.default_cycles()
        if eng.batch > 1:
            return eng.run_batch(n)
        return eng.run(n)

    # ------------------------------------------------------------------
    def save(self, path: Union[str, Path]) -> Path:
        """Persist the compiled Program (see :mod:`repro.sim.artifact`).
        The bench/circuit are *not* serialized — a loaded Simulation can
        run every compiled engine but not the netlist oracle."""
        return self.program.save(path)

    @classmethod
    def load(cls, path: Union[str, Path]) -> "Simulation":
        return cls(program=load_program(path))


def _first_image(images):
    """Stimulus 0's (reg, spad, gmem) tuple from either image form."""
    if not images:
        return None
    if getattr(images[0], "ndim", 0) == 3:          # stacked arrays
        return tuple(a[0] for a in images)
    return images[0]


def _resolve_source(source, scale: str, seeds, overrides):
    """(bench, circuit) from a name / Bench / Circuit source."""
    from ..circuits import build
    from ..circuits.common import Bench
    if isinstance(source, str):
        return build(source, scale, seeds=seeds, **overrides), None
    if seeds is not None or overrides:
        raise ValueError(
            "seeds=/build overrides apply when compiling by circuit name; "
            "pass a name like sim.compile('mc', seeds=[...])")
    if isinstance(source, Bench):
        return source, None
    if isinstance(source, Circuit):
        return None, source
    raise TypeError(
        f"cannot compile {type(source).__name__}: expected a circuit "
        "name, a Bench, or a Circuit")


def compile(source, hw: Optional[HardwareConfig] = None, *,
            scale: str = "full", seeds: Optional[Sequence[int]] = None,
            optimize: bool = True, use_luts: bool = True,
            strategy: str = "balanced", sched_strategy: str = "slack",
            placement: str = "anneal", pipeline: str = "modulo",
            cache: Union[bool, str, Path, CompileCache, None] = None,
            shard_batch: Optional[bool] = None,
            **overrides) -> Simulation:
    """Compile ``source`` (benchmark name, Bench, or Circuit) into a
    :class:`Simulation`.

    ``seeds=[s0, s1, ...]`` (name sources) builds a batched bench: one
    structural netlist, per-seed init planes, so every stimulus shares the
    compiled Program. ``cache=True`` (or a directory path) consults the
    on-disk compile cache first — on a hit the entire middle-end is
    skipped and ``Simulation.cache_hit`` is set; on a miss the freshly
    compiled Program is stored for next time.

    ``shard_batch=True`` forces batched runs onto the mesh-sharded engine
    (``[D, B/D]`` elements per device); ``False`` pins the single-device
    vmapped engine; the default (None) auto-selects sharding when more
    than one device is visible and B >= 2*D.

    ``sched_strategy`` selects the scheduler: ``"slack"`` (default, the
    slack-driven list scheduler with rematerialization) or ``"greedy"``
    (the frozen differential baseline); see ``core.schedule``.
    ``placement`` selects the process-to-core mapping: ``"anneal"``
    (default, the communication-aware annealer — ships the better of the
    annealed and identity geometries) or ``"identity"`` (the frozen
    process-p-on-core-p order); see ``core.place``.
    ``pipeline`` controls cross-Vcycle modulo pipelining: ``"modulo"``
    (default — best-of-two, the pipelined schedule ships only when its
    steady-state II beats the unpipelined VCPL) or ``"off"`` (the frozen
    barrier-per-Vcycle path); see ``core.schedule.pipeline_schedule``.
    """
    bench, circuit = _resolve_source(source, scale, seeds, overrides)
    if bench is not None:
        circuit = bench.circuit
    hw = hw or HardwareConfig()

    fp = circuit.fingerprint()
    cc = resolve_cache(cache)
    prog = None
    key = None
    if cc is not None:
        key = cache_key(circuit, hw, strategy=strategy, use_luts=use_luts,
                        optimize=optimize, sched_strategy=sched_strategy,
                        placement=placement, pipeline=pipeline,
                        fingerprint=fp)
        prog = cc.load(key)
    if prog is None:
        prog = compile_circuit(circuit, hw, strategy=strategy,
                               use_luts=use_luts, optimize=optimize,
                               sched_strategy=sched_strategy,
                               placement=placement, pipeline=pipeline)
        prog.stats["cache_hit"] = False
        prog.stats["fingerprint"] = fp
        if cc is not None:
            cc.store(key, prog)
    else:
        # entries written before the fingerprint was recorded still get it
        prog.stats["fingerprint"] = fp
    return Simulation(program=prog, bench=bench, circuit=circuit,
                      meta={"cache_key": key, "shard_batch": shard_batch,
                            "fingerprint": fp})


def load(path: Union[str, Path]) -> Simulation:
    """Load a persisted Program artifact as a ready-to-run Simulation."""
    return Simulation.load(path)
