"""The ``Engine`` protocol and adapters for every executor in the repo.

One compiled :class:`~repro.core.compile.Program` can be executed by four
different machines (the specialized/seed jnp ``Machine``, the vmapped
``BatchedMachine``, the mesh-sharded ``GridMachine``, the numpy ``IsaSim``)
and validated against a fifth (the ``NetlistSim`` oracle, which consumes
the source circuit instead of the binary). Before this module their calling
conventions diverged: some take explicit state, some mutate themselves,
``read_*``/``exceptions``/``perf`` signatures differ per class.

Every adapter here conforms to :class:`Engine`: it owns its simulation
state, ``run(num_cycles)`` advances *all* stimuli and returns the
:class:`~repro.sim.result.RunResult` of element 0, ``run_batch`` the full
per-stimulus list, and the probe methods take a uniform optional batch
index. The underlying engine classes are untouched — ``repro.core.*``
callers keep working — the adapters are the single place signature
divergence is absorbed.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

import numpy as np

from ..core.bsp import (DEFAULT_CHUNK, BatchedMachine, Machine,
                        ShardedBatchedMachine)
from ..core.compile import Program
from ..core.interpreter import NetlistSim
from ..core.isasim import IsaSim
from ..core.netlist import Circuit
from .result import ORACLE_CORE, RunResult

Images = Tuple[np.ndarray, np.ndarray, np.ndarray]


@runtime_checkable
class Engine(Protocol):
    """What every simulation backend exposes to the front door.

    ``batch`` is the stimulus count (1 for single-stimulus engines). A
    ``run`` call advances the whole batch by up to ``num_cycles`` Vcycles
    (stopping early on exceptions, per element where supported) and
    snapshots results; ``reset`` rewinds to the initial images.
    """

    batch: int

    def reset(self) -> None: ...

    def run(self, num_cycles: int) -> RunResult: ...

    def run_batch(self, num_cycles: int) -> List[RunResult]: ...

    def read_reg(self, name: str, b: int = 0) -> int: ...

    def read_output(self, name: str, b: int = 0) -> int: ...

    def exceptions(self, b: int = 0) -> Dict[int, int]: ...

    def perf(self, b: Optional[int] = None) -> Dict[str, float]: ...


def _probe_registers(prog: Program, regs: np.ndarray) -> Dict[str, int]:
    out = {}
    for nm, words in prog.state_regs.items():
        v = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            v |= int(regs[c, r]) << (16 * j)
        out[nm] = v
    return out


def _probe_outputs(prog: Program, regs: np.ndarray) -> Dict[str, int]:
    out = {}
    for nm, (core, mregs) in prog.outputs.items():
        v = 0
        for j, r in enumerate(mregs):
            v |= int(regs[core, r]) << (16 * j)
        out[nm] = v
    return out


def _snapshot(eng, b: int) -> RunResult:
    """Uniform probe sweep: every architectural register and every
    host-visible output the program kept, plus exceptions and counters.
    The register file is pulled off-device once per snapshot (not once
    per probe — ``read_reg`` on the raw engines transfers per call)."""
    prog: Program = eng.program
    regs = eng._regs_np(b)
    perf = dict(eng.perf(b))
    return RunResult(
        cycles=int(perf["vcycles"]),
        exceptions=dict(eng.exceptions(b)),
        perf=perf,
        registers=_probe_registers(prog, regs),
        outputs=_probe_outputs(prog, regs),
        batch_index=b,
    )


class MachineEngine:
    """Single-stimulus jnp/Pallas engine (``core.bsp.Machine``).

    ``specialize=False`` selects the seed baseline arm; ``backend="pallas"``
    the chunked whole-machine kernel. ``images`` is one
    ``(reg_init, spad_init, gmem_init)`` stimulus plane
    (``Program.init_images``); omitted means the program's base init.
    """

    kind = "machine"
    batch = 1

    def __init__(self, program: Program, *, backend: str = "jnp",
                 specialize: bool = True, interpret: bool = True,
                 compact: bool = True, chunk: int = DEFAULT_CHUNK,
                 images: Optional[Images] = None):
        self.program = program
        self.m = Machine(program, backend=backend, compact=compact,
                         interpret=interpret, specialize=specialize,
                         chunk=chunk)
        self._images = images
        self.reset()

    def reset(self) -> None:
        self.state = self.m.init_state(self._images)

    def run(self, num_cycles: int) -> RunResult:
        self.state = self.m.run(self.state, num_cycles)
        return _snapshot(self, 0)

    def run_batch(self, num_cycles: int) -> List[RunResult]:
        return [self.run(num_cycles)]

    def _regs_np(self, b: int) -> np.ndarray:
        return np.asarray(self.state.regs)

    def read_reg(self, name: str, b: int = 0) -> int:
        return self.m.read_reg(self.state, name)

    def read_output(self, name: str, b: int = 0) -> int:
        return self.m.read_output(self.state, name)

    def exceptions(self, b: int = 0) -> Dict[int, int]:
        return self.m.exceptions(self.state)

    def perf(self, b: Optional[int] = None) -> Dict[str, float]:
        return self.m.perf(self.state)


class BatchedEngine:
    """B stimuli per launch (``core.bsp.BatchedMachine``)."""

    kind = "batched"

    def __init__(self, program: Program, *,
                 images: Optional[Sequence[Images]] = None,
                 batch: Optional[int] = None, backend: str = "jnp",
                 interpret: bool = True, compact: bool = True,
                 chunk: int = DEFAULT_CHUNK):
        self.program = program
        self.m = BatchedMachine(program, images=images, batch=batch,
                                backend=backend, interpret=interpret,
                                compact=compact, chunk=chunk)
        self.batch = self.m.B
        self.reset()

    def reset(self) -> None:
        self.state = self.m.init_state()

    def rebind(self, images) -> None:
        """Swap this engine onto a new batch of stimuli (same B) and
        reset. The underlying machine keeps its traced/jitted Vcycle
        dispatch (``BatchedMachine.rebind_images``), so a serving layer
        can reuse one hot engine across successive coalesced batches."""
        self.m.rebind_images(images)
        self.batch = self.m.B
        self.reset()

    def run(self, num_cycles: int) -> RunResult:
        self.state = self.m.run(self.state, num_cycles)
        return _snapshot(self, 0)

    def run_batch(self, num_cycles: int) -> List[RunResult]:
        self.state = self.m.run(self.state, num_cycles)
        return [_snapshot(self, b) for b in range(self.batch)]

    def _regs_np(self, b: int) -> np.ndarray:
        return np.asarray(self.state.regs[b])

    def read_reg(self, name: str, b: int = 0) -> int:
        return self.m.read_reg(self.state, name, b)

    def read_output(self, name: str, b: int = 0) -> int:
        return self.m.read_output(self.state, name, b)

    def exceptions(self, b: int = 0) -> Dict[int, int]:
        return self.m.exceptions(self.state, b)

    def perf(self, b: Optional[int] = None) -> Dict[str, float]:
        return self.m.perf(self.state, b)


class ShardedBatchedEngine(BatchedEngine):
    """B stimuli data-parallel over the device mesh
    (``core.bsp.ShardedBatchedMachine``): each of D devices runs B/D
    elements of the same compiled Program; per-element exceptions are
    device-local and results (``RunResult`` per stimulus) are reassembled
    across shards by the inherited accessors — padding elements (B not a
    multiple of D) never appear in them."""

    kind = "sharded"

    def __init__(self, program: Program, *,
                 images: Optional[Sequence[Images]] = None,
                 batch: Optional[int] = None, devices=None,
                 backend: str = "jnp", interpret: bool = True,
                 compact: bool = True, chunk: int = DEFAULT_CHUNK):
        self.program = program
        self.m = ShardedBatchedMachine(
            program, images=images, batch=batch, devices=devices,
            backend=backend, interpret=interpret, compact=compact,
            chunk=chunk)
        self.batch = self.m.B
        self.reset()


class GridEngine:
    """Mesh-sharded multi-device engine (``core.grid.GridMachine``).

    ``images=None`` runs the program's base stimulus; a list of image
    tuples selects batched mode (each state leaf gains a ``[B]`` axis,
    still sharded over the mesh's ``cores`` axis).
    """

    kind = "grid"

    def __init__(self, program: Program, mesh, *,
                 images: Optional[Sequence[Images]] = None,
                 chunk: int = DEFAULT_CHUNK):
        from ..core.grid import GridMachine
        self.program = program
        self.m = GridMachine(program, mesh, images=images, chunk=chunk)
        self.batch = self.m.B or 1
        self._batched = self.m.B is not None
        self.reset()

    def reset(self) -> None:
        self.state = self.m.init_state()

    def run(self, num_cycles: int) -> RunResult:
        self.state = self.m.run(self.state, num_cycles)
        return _snapshot(self, 0)

    def run_batch(self, num_cycles: int) -> List[RunResult]:
        self.state = self.m.run(self.state, num_cycles)
        return [_snapshot(self, b) for b in range(self.batch)]

    def _b(self, b: int):
        return b if self._batched else None

    def _regs_np(self, b: int) -> np.ndarray:
        return np.asarray(self.m._elem(self.state.regs, self._b(b)))

    def read_reg(self, name: str, b: int = 0) -> int:
        return self.m.read_reg(self.state, name, self._b(b))

    def read_output(self, name: str, b: int = 0) -> int:
        return self.m.read_output(self.state, name, self._b(b))

    def exceptions(self, b: int = 0) -> Dict[int, int]:
        return self.m.exceptions(self.state, self._b(b))

    def perf(self, b: Optional[int] = None) -> Dict[str, float]:
        if b is None and not self._batched:
            return self.m.perf(self.state)
        return self.m.perf(self.state, b)


class IsaEngine:
    """Vectorized numpy ISA simulator (``core.isasim.IsaSim``) — the
    jit-free second oracle, now with the same probes as the jnp engines
    (``IsaSim`` itself has no ``read_output``/``perf``; the adapter
    derives them from the program's tables)."""

    kind = "isa"
    batch = 1

    def __init__(self, program: Program, *,
                 images: Optional[Images] = None):
        self.program = program
        self._images = images
        self.reset()

    def reset(self) -> None:
        self.sim = IsaSim(self.program)
        if self._images is not None:
            ri, si, gi = self._images
            C, R = self.sim.C, self.sim.R
            self.sim.regs = np.asarray(ri)[:C, :R].astype(np.uint32).copy()
            self.sim.spads = np.asarray(si)[:C].astype(np.uint32).copy()
            self.sim.gmem = np.asarray(gi).astype(np.uint32).copy()

    def run(self, num_cycles: int) -> RunResult:
        self.sim.run(num_cycles)
        return _snapshot(self, 0)

    def run_batch(self, num_cycles: int) -> List[RunResult]:
        return [self.run(num_cycles)]

    def _regs_np(self, b: int) -> np.ndarray:
        return self.sim.regs

    def read_reg(self, name: str, b: int = 0) -> int:
        return self.sim.read_reg(name)

    def read_output(self, name: str, b: int = 0) -> int:
        return _probe_outputs(self.program, self.sim.regs)[name]

    def exceptions(self, b: int = 0) -> Dict[int, int]:
        return self.sim.exceptions()

    def perf(self, b: Optional[int] = None) -> Dict[str, float]:
        return {"vcycles": self.sim.cycle,
                "machine_cycles": self.sim.cycle * self.program.vcpl}


class OracleEngine:
    """The reference netlist interpreter (``core.interpreter.NetlistSim``).

    The only engine driven by the *circuit* rather than the compiled
    binary — it needs no Program, but when one is supplied its
    ``state_regs``/``outputs`` maps choose which probes land in the
    :class:`RunResult` so oracle results are directly comparable with the
    compiled engines'. Exceptions carry no core, so they are keyed by
    negative pseudo-cores (``ORACLE_CORE - k``).
    """

    kind = "oracle"
    batch = 1

    def __init__(self, circuit: Circuit,
                 program: Optional[Program] = None):
        self.circuit = circuit
        self.program = program
        self.reset()

    def reset(self) -> None:
        self.sim = NetlistSim(self.circuit)
        self._exc: List[int] = []
        self._outputs: Dict[str, int] = {}

    def run(self, num_cycles: int) -> RunResult:
        for _ in range(num_cycles):
            if self._exc:
                break
            r = self.sim.step()
            self._outputs.update(r.outputs)
            self._exc.extend(r.exceptions)
        # probe the registers/outputs the compiled Program kept (directly
        # comparable with the binary engines) when one is known, else
        # every named register the circuit has
        prog = self.program
        reg_names = (prog.state_regs.keys() if prog is not None
                     else self.sim.c.reg_names.values())
        out_names = (prog.outputs.keys() if prog is not None
                     else self._outputs.keys())
        return RunResult(
            cycles=self.sim.cycle, exceptions=self.exceptions(),
            perf=self.perf(),
            registers={nm: self.sim.reg_value(nm) for nm in reg_names},
            outputs={nm: self._outputs[nm] for nm in out_names
                     if nm in self._outputs})

    def run_batch(self, num_cycles: int) -> List[RunResult]:
        return [self.run(num_cycles)]

    def read_reg(self, name: str, b: int = 0) -> int:
        return self.sim.reg_value(name)

    def read_output(self, name: str, b: int = 0) -> int:
        return self._outputs[name]

    def exceptions(self, b: int = 0) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for k, eid in enumerate(dict.fromkeys(self._exc)):
            out[ORACLE_CORE - k] = eid
        return out

    def perf(self, b: Optional[int] = None) -> Dict[str, float]:
        return {"vcycles": self.sim.cycle}
