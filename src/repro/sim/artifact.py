"""Persistent ``Program`` artifacts: one ``.npz`` file, versioned.

A compiled Program is the whole point of Manticore's economics — the
middle-end/partition/schedule/regalloc cost is paid once and the resulting
static binary simulates at hardware speed forever after. This module makes
that artifact durable: ``save_program``/``load_program`` serialize every
dense array (code, LUTs, init images, exchange tables, slot-op masks) in
native dtype inside a single NumPy ``.npz`` container, with the scalar and
structured metadata (hardware config, ``outputs``/``state_regs`` maps,
``stats``) as one embedded JSON document. The round trip is bit-exact:
arrays keep shape and dtype, JSON floats round-trip via shortest-repr, and
tuple-shaped metadata is restored to the exact in-memory form
``core.compile`` produces.

``FORMAT_VERSION`` gates compatibility: a loader refuses artifacts written
by an incompatible schema instead of mis-reading them. Bump it whenever a
field changes meaning; the on-disk compile cache (:mod:`repro.sim.cache`)
keys on it too, so stale cache entries simply miss.
"""
from __future__ import annotations

import io
import json
import os
from dataclasses import asdict
from pathlib import Path
from typing import Any, Dict, Union
from uuid import uuid4

import numpy as np

from ..core.compile import Program
from ..core.isa import HardwareConfig

FORMAT_VERSION = 1

# Every dense array field of Program, saved in native dtype.
_ARRAY_FIELDS = (
    "code", "luts", "reg_init", "spad_init", "gmem_init",
    "xchg_src_core", "xchg_src_slot", "xchg_dst_core", "xchg_dst_reg",
)


def _jsonable(obj: Any) -> Any:
    """Strip numpy scalar/array types so ``stats`` always serializes;
    tuples become lists (restored by the typed loaders below)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [_jsonable(v) for v in obj.tolist()]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def _restore_stats(stats: Dict[str, Any]) -> Dict[str, Any]:
    """Re-impose the tuple shapes ``core.compile`` uses inside stats."""
    out = dict(stats)
    if "mem_layout" in out:
        out["mem_layout"] = {
            name: (int(v[0]), int(v[1]), int(v[2]), bool(v[3]))
            for name, v in out["mem_layout"].items()}
    return out


def save_program(prog: Program, path: Union[str, Path]) -> Path:
    """Write ``prog`` to ``path`` (a single ``.npz`` container). Returns
    the path written. The file is self-contained: ``load_program`` needs
    nothing but the file."""
    path = Path(path)
    meta = {
        "format_version": FORMAT_VERSION,
        "name": prog.name,
        "hw": asdict(prog.hw),
        "t_compute": int(prog.t_compute),
        "vcpl": int(prog.vcpl),
        "used_cores": int(prog.used_cores),
        "pipe_prologue": int(prog.pipe_prologue),
        "outputs": {nm: [int(core), [int(r) for r in mregs]]
                    for nm, (core, mregs) in prog.outputs.items()},
        "state_regs": {
            nm: [[[int(c), int(r)] for (c, r) in locs] for locs in words]
            for nm, words in prog.state_regs.items()},
        "stats": _jsonable(prog.stats),
    }
    arrays = {f: getattr(prog, f) for f in _ARRAY_FIELDS}
    arrays["slot_op_mask"] = prog._op_masks()
    buf = io.BytesIO()
    np.savez_compressed(
        buf, __meta__=np.frombuffer(json.dumps(meta).encode("utf-8"),
                                    dtype=np.uint8), **arrays)
    path.parent.mkdir(parents=True, exist_ok=True)
    # unique tmp name + rename: concurrent writers of the same artifact
    # (two processes cold-compiling one cache key) each publish a complete
    # file, never a torn one
    tmp = path.with_name(f".{path.name}.{os.getpid()}.{uuid4().hex}.tmp")
    try:
        tmp.write_bytes(buf.getvalue())
        tmp.replace(path)
    finally:
        tmp.unlink(missing_ok=True)
    return path


def load_program(path: Union[str, Path]) -> Program:
    """Read a Program artifact written by :func:`save_program`."""
    path = Path(path)
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        version = meta.get("format_version")
        if version != FORMAT_VERSION:
            raise ValueError(
                f"{path}: artifact format {version!r} is not supported by "
                f"this build (expected {FORMAT_VERSION})")
        arrays = {f: np.array(z[f]) for f in _ARRAY_FIELDS}
        slot_op_mask = np.array(z["slot_op_mask"])
    return Program(
        name=meta["name"],
        hw=HardwareConfig(**meta["hw"]),
        t_compute=int(meta["t_compute"]),
        vcpl=int(meta["vcpl"]),
        used_cores=int(meta["used_cores"]),
        pipe_prologue=int(meta.get("pipe_prologue", 0)),
        outputs={nm: (core, list(mregs))
                 for nm, (core, mregs) in meta["outputs"].items()},
        state_regs={nm: [[(c, r) for c, r in locs] for locs in words]
                    for nm, words in meta["state_regs"].items()},
        stats=_restore_stats(meta["stats"]),
        slot_op_mask=slot_op_mask,
        **arrays,
    )
