"""Uniform run results for every simulation engine.

The five executors historically returned five different shapes: ``Machine``
hands back a ``MachineState`` tuple the caller probes with
``read_reg``/``exceptions``, ``IsaSim`` mutates itself and returns a cycle
count, ``NetlistSim`` returns ``(cycles, [CycleResult])``. A
:class:`RunResult` is the one shape the :mod:`repro.sim` front door returns
everywhere: the finish cycle, the exception map, the perf counters and the
probed architectural values, snapshotted at the moment the run stopped.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet

# Exception-id conventions from repro.circuits.common: every self-checking
# bench raises FINISH (1) on success and MISMATCH (2) on a failed golden
# check. Engines that cannot attribute an exception to a core (the netlist
# oracle) report it under negative pseudo-core keys.
FINISH = 1
MISMATCH = 2
ORACLE_CORE = -1


@dataclass(frozen=True)
class RunResult:
    """Snapshot of one stimulus after a ``run`` call.

    ``cycles``
        Vcycles (simulated RTL cycles) actually executed; on an exception
        this includes the raising cycle (the machine freezes *at* it).
    ``exceptions``
        ``{core: first exception id}`` — empty when the run exhausted its
        budget cleanly. The netlist oracle, which has no cores, uses
        negative pseudo-core keys (``ORACLE_CORE - k``).
    ``perf``
        Engine performance counters; every engine reports at least
        ``vcycles``, the hardware-modelling ones add cache hits/misses,
        stall cycles and ``machine_cycles``.
    ``registers``
        Architectural (RTL-named) register probes at stop time.
    ``outputs``
        Host-visible output probes at stop time.
    ``batch_index``
        Which stimulus of a batched run this snapshot belongs to.
    """

    cycles: int
    exceptions: Dict[int, int] = field(default_factory=dict)
    perf: Dict[str, float] = field(default_factory=dict)
    registers: Dict[str, int] = field(default_factory=dict)
    outputs: Dict[str, int] = field(default_factory=dict)
    batch_index: int = 0

    @property
    def exception_ids(self) -> FrozenSet[int]:
        """Raised exception ids, core-agnostic (what parity checks compare
        across engines that locate exceptions differently)."""
        return frozenset(self.exceptions.values())

    @property
    def finished(self) -> bool:
        """True iff the run ended with the circuits' clean-FINISH id."""
        return self.exception_ids == {FINISH}

    @property
    def failed(self) -> bool:
        """True iff a golden check fired (MISMATCH raised anywhere)."""
        return MISMATCH in self.exception_ids
