"""Deterministic synthetic token pipeline.

Counter-based (stateless-resumable): batch ``i`` is a pure function of
(seed, i), so restart-after-failure resumes exactly by restoring the step
counter from the checkpoint — no data-state files, no skew between hosts.
Each host materializes only its shard of the global batch (``host_slice``),
which is how the pipeline scales to thousands of nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Markov-chain-ish synthetic LM data (learnable structure, so loss
    decreases during the example training run)."""

    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        B, S = self.local_batch, cfg.seq_len
        # structured stream: x[t+1] = (a*x[t] + b + noise) % vocab
        a = 31
        x = np.empty((B, S + 1), np.int64)
        x[:, 0] = rng.integers(0, cfg.vocab, (B,))
        noise = (rng.random((B, S)) < 0.1)
        rnd = rng.integers(0, cfg.vocab, (B, S))
        for t in range(S):
            nxt = (a * x[:, t] + 7) % cfg.vocab
            x[:, t + 1] = np.where(noise[:, t], rnd[:, t], nxt)
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
