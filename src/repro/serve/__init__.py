"""Simulation-as-a-service: a long-lived daemon with dynamic batching.

Four cooperating layers turn the one-shot ``repro.sim`` facade into a
serving system (the ROADMAP's "millions of users" item — the inference-
server shape applied to RTL simulation):

* :mod:`~repro.serve.protocol` — :class:`SimRequest`/:class:`SimResponse`
  dataclasses plus their newline-delimited-JSON wire form;
* :mod:`~repro.serve.batcher` — per-fingerprint queues with a
  max-batch/max-wait admission policy, deadline timeouts and
  queue-depth backpressure;
* :mod:`~repro.serve.sessions` — an LRU of hot compiled ``Simulation``s
  keyed by ``Circuit.fingerprint()`` + hardware + compiler knobs,
  warm-started through the on-disk compile cache;
* :mod:`~repro.serve.daemon` — :class:`SimServer`, coalescing concurrent
  same-fingerprint requests into one batched (or mesh-sharded, when
  ``B >= 2*D``) launch and demuxing per-request results; in-process
  ``await server.submit(req)`` and a TCP front-end
  (``python -m repro.serve``).

See ``docs/serving.md`` for the architecture and tuning guide, and
``benchmarks/bench_serve.py`` for the load benchmark (coalesced dynamic
batching vs sequential B=1).
"""
from .batcher import BatchPolicy, Batcher, Pending, Rejected
from .daemon import SimServer
from .protocol import (ERROR, OK, REJECTED, TIMEOUT, SimRequest,
                       SimResponse, decode_request, decode_response,
                       encode_request, encode_response)
from .sessions import (CANONICAL_SEED, Session, SessionKey,
                       SessionManager)

__all__ = [
    "BatchPolicy", "Batcher", "Pending", "Rejected", "SimServer",
    "SimRequest", "SimResponse", "OK", "REJECTED", "TIMEOUT", "ERROR",
    "encode_request", "decode_request", "encode_response",
    "decode_response", "CANONICAL_SEED", "Session", "SessionKey",
    "SessionManager",
]
