"""Simulation-as-a-service: a long-lived daemon with dynamic batching.

Five cooperating layers turn the one-shot ``repro.sim`` facade into a
serving system (the ROADMAP's "millions of users" item — the inference-
server shape applied to RTL simulation):

* :mod:`~repro.serve.protocol` — :class:`SimRequest`/:class:`SimResponse`
  dataclasses plus their newline-delimited-JSON wire form, including the
  machine-readable failure taxonomy (``SimResponse.error_code``);
* :mod:`~repro.serve.batcher` — per-fingerprint queues with a
  max-batch/max-wait admission policy, deadline timeouts, queue-depth
  backpressure, and a drain/abort shutdown contract (every admitted
  request resolves exactly once);
* :mod:`~repro.serve.sessions` — an LRU of hot compiled ``Simulation``s
  keyed by ``Circuit.fingerprint()`` + hardware + compiler knobs,
  warm-started through the on-disk compile cache, with per-identity
  :class:`CircuitBreaker` quarantine of failing builds;
* :mod:`~repro.serve.faults` — a deterministic, seedable fault-injection
  harness (:class:`FaultPlan`) armed at the four recovery sites
  (compile, image build, engine launch, TCP write) so every failure path
  is testable and CI-drillable;
* :mod:`~repro.serve.daemon` — :class:`SimServer`, coalescing concurrent
  same-fingerprint requests into one batched (or mesh-sharded, when
  ``B >= 2*D``) launch and demuxing per-request results, with
  poison-isolating bisection retry (:class:`RetryPolicy`) and graceful
  drain; in-process ``await server.submit(req)`` and a TCP front-end
  (``python -m repro.serve``; ``--chaos-drill N`` runs the fault drill).

See ``docs/serving.md`` for the architecture, failure model, and tuning
guide, and ``benchmarks/bench_serve.py`` for the load benchmark
(coalesced dynamic batching vs sequential B=1, plus the hardened-but-
fault-free arm showing the recovery machinery costs ~nothing when idle).
"""
from .batcher import BatchPolicy, Batcher, Pending, Rejected
from .daemon import RetryPolicy, SimServer
from .faults import (COMPILE, IMAGE_BUILD, LAUNCH, SITES, TCP_WRITE,
                     FaultPlan, FaultSpec, InjectedFault)
from .protocol import (DRAINING, ERR_BAD_REQUEST, ERR_COMPILE_FAILED,
                       ERR_DRAINING, ERR_IMAGE_BUILD_FAILED,
                       ERR_LAUNCH_FAILED, ERR_POISONED, ERR_QUEUE_FULL,
                       ERR_TIMEOUT, ERR_UNAVAILABLE, ERROR, ERROR_CODES,
                       OK, REJECTED, TIMEOUT, UNAVAILABLE, SimRequest,
                       SimResponse, decode_request, decode_response,
                       encode_request, encode_response)
from .sessions import (CANONICAL_SEED, CircuitBreaker, CompileFailed,
                       Session, SessionKey, SessionManager, Unavailable)

__all__ = [
    "BatchPolicy", "Batcher", "Pending", "Rejected", "SimServer",
    "RetryPolicy", "SimRequest", "SimResponse",
    "OK", "REJECTED", "TIMEOUT", "ERROR", "UNAVAILABLE", "DRAINING",
    "ERROR_CODES", "ERR_BAD_REQUEST", "ERR_COMPILE_FAILED",
    "ERR_IMAGE_BUILD_FAILED", "ERR_LAUNCH_FAILED", "ERR_POISONED",
    "ERR_UNAVAILABLE", "ERR_DRAINING", "ERR_TIMEOUT", "ERR_QUEUE_FULL",
    "encode_request", "decode_request", "encode_response",
    "decode_response", "CANONICAL_SEED", "Session", "SessionKey",
    "SessionManager", "CircuitBreaker", "Unavailable", "CompileFailed",
    "FaultPlan", "FaultSpec", "InjectedFault",
    "COMPILE", "IMAGE_BUILD", "LAUNCH", "TCP_WRITE", "SITES",
]
