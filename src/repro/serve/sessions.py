"""Session/cache manager: hot compiled ``Simulation``s, LRU-evicted.

A *session* is one design the daemon can simulate without compiling:
``(circuit fingerprint, hardware config, compiler knobs)`` → a compiled
:class:`~repro.sim.facade.Simulation` plus the device-resident engines
built over it. Sessions are what make the service economics work — the
Manticore bargain is "compile once, simulate forever", and a long-lived
daemon is where "forever" actually accumulates.

**Canonical identity.** Some builders bake ``seeds[0]``-derived values
into the *structure* (mm's ROM matrices, cgra's weights, rv32r's
instruction immediates), so the fingerprint of ``build(name, seeds=[s])``
is seed-dependent in general. The service therefore anchors every design
to a canonical build — ``build(name, scale, seeds=[CANONICAL_SEED])`` —
and defines a request's stimulus as *seed s of the canonical design*:
per-batch init planes come from ``build(name, scale,
seeds=[CANONICAL_SEED, s1, ..., sB])``, whose structure is exactly the
canonical one (live-plane builds take structure from ``seeds[0]``), so
every plane patches the one compiled Program. Requests that share the
canonical fingerprint (plus hw + knobs) coalesce; for builders whose
structure is seed-invariant (bc, mc, ...) the results are additionally
bit-exact against an independent ``sim.compile(name, seeds=[s]).run()``.

**Warm starts.** Compilation goes through :func:`repro.sim.compile` with
the on-disk compile cache, so a restarted daemon (or an LRU-evicted
session being re-admitted) pays an artifact load, not a recompile.
Concurrent workers asking for the same uncompiled session serialize on a
per-identity ``asyncio.Lock`` — one compile, everyone shares it; across
*processes* the cache's atomic-rename last-writer-wins contract holds
(see :class:`repro.sim.cache.CompileCache`).

**Eviction.** Sessions are kept in an ``OrderedDict`` LRU bounded by
``max_sessions`` and by ``memory_budget`` bytes (the sum of each
session's program arrays plus its resident engines' state estimate) —
the stand-in for device memory on interpret-mode CPU, and the real
constraint on an accelerator.

**Quarantine.** Each session *identity* — the ``(circuit, scale, hw,
options)`` tuple, before it ever resolves to a fingerprint — carries a
:class:`CircuitBreaker`. Consecutive compile or launch failures open it:
further requests for that identity fast-fail with :class:`Unavailable`
(the daemon answers ``UNAVAILABLE`` + ``retry_after_s``) instead of
re-paying the failing compile or convoying the device behind a broken
build. After a cooldown the breaker goes **half-open** and admits one
probe; a successful compile/launch closes it, a failed probe re-opens it
with doubled cooldown. Breaker state is part of the
:meth:`SessionManager.stats` snapshot.
"""
from __future__ import annotations

import asyncio
import json
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from ..circuits import build
from ..core.isa import HardwareConfig
from ..sim import facade
from ..sim.cache import CompileCache, resolve_cache
from . import faults as faultlib
from .protocol import SimRequest

# the structural anchor: every session's netlist/planes are built with
# this as seeds[0] (see module docstring)
CANONICAL_SEED = 0

# compiler knobs a request may set; anything else is a client error
COMPILE_OPTIONS = frozenset(
    ("optimize", "use_luts", "strategy", "sched_strategy", "placement",
     "pipeline"))

# per-session bound on memoized per-seed init planes (host memory)
MAX_PLANE_CACHE = 4096


class Unavailable(Exception):
    """The identity's circuit breaker is open: fast-fail, retry later."""

    def __init__(self, retry_after: float, state: str):
        super().__init__(
            f"session quarantined (breaker {state}); "
            f"retry in {retry_after:.2f}s")
        self.retry_after = float(retry_after)
        self.state = state


class CompileFailed(Exception):
    """The session compile raised — distinct from a bad request (unknown
    circuit/option), which never trips the breaker."""

    def __init__(self, cause: BaseException):
        super().__init__(f"compile failed: {cause!r}")
        self.cause = cause


class CircuitBreaker:
    """Closed → (``threshold`` consecutive failures) → open →
    (``cooldown_s``) → half-open, one probe → closed or re-open.

    Single-event-loop use: ``allow()`` admits, ``record_success()`` /
    ``record_failure()`` report outcomes. Re-opens double the cooldown up
    to ``cooldown_max_s`` so a persistently broken identity backs off; a
    half-open probe that never reports (e.g. its rider timed out in the
    queue) is replaced after ``cooldown_s`` rather than wedging the
    identity in half-open forever.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, cooldown_s: float = 1.0,
                 cooldown_max_s: float = 60.0):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.cooldown_max_s = float(cooldown_max_s)
        self.state = self.CLOSED
        self.failures = 0          # consecutive
        self.opens = 0             # lifetime re-opens (scales cooldown)
        self._open_until = 0.0
        self._probe_started: Optional[float] = None

    def _cooldown(self) -> float:
        return min(self.cooldown_s * (2 ** max(self.opens - 1, 0)),
                   self.cooldown_max_s)

    def allow(self) -> Tuple[bool, float]:
        """(admitted, retry_after_s). Admission from OPEN past the
        cooldown transitions to HALF_OPEN and marks the caller as the
        probe."""
        now = time.monotonic()
        if self.state == self.CLOSED:
            return True, 0.0
        if self.state == self.OPEN:
            if now < self._open_until:
                return False, self._open_until - now
            self.state = self.HALF_OPEN
            self._probe_started = now
            return True, 0.0
        # HALF_OPEN: one probe at a time, but a stale probe (rider lost
        # to a queue timeout) must not wedge the identity
        if (self._probe_started is not None
                and now - self._probe_started >= self.cooldown_s):
            self._probe_started = now
            return True, 0.0
        return False, max(self.cooldown_s / 4, 0.01)

    def record_success(self) -> None:
        self.state = self.CLOSED
        self.failures = 0
        self.opens = 0
        self._probe_started = None

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == self.HALF_OPEN or self.failures >= self.threshold:
            self.state = self.OPEN
            self.opens += 1
            self._open_until = time.monotonic() + self._cooldown()
            self._probe_started = None

    def snapshot(self) -> Dict[str, Any]:
        return {
            "state": self.state,
            "failures": self.failures,
            "opens": self.opens,
            "retry_after_s": max(self._open_until - time.monotonic(), 0.0)
            if self.state == self.OPEN else 0.0,
        }


@dataclass(frozen=True)
class SessionKey:
    """What the daemon coalesces on: same design, same hardware, same
    compiler knobs → same compiled Program → one batched launch."""
    fingerprint: str
    hw_key: str
    options_key: str


def _hw_from(req: SimRequest) -> HardwareConfig:
    return HardwareConfig(**req.hw) if req.hw else HardwareConfig()


def _options_from(req: SimRequest) -> Dict[str, Any]:
    opts = dict(req.options or {})
    unknown = set(opts) - COMPILE_OPTIONS
    if unknown:
        raise ValueError(
            f"unknown compile options {sorted(unknown)}; valid options are "
            f"{sorted(COMPILE_OPTIONS)}")
    return opts


class Session:
    """One hot design: compiled Simulation + plane cache + engine cache."""

    def __init__(self, key: SessionKey, name: str, scale: str,
                 hw: HardwareConfig, options: Dict[str, Any],
                 sim: "facade.Simulation"):
        self.key = key
        self.name = name
        self.scale = scale
        self.hw = hw
        self.options = dict(options)
        self.sim = sim
        self.last_used = time.monotonic()
        self.launches = 0
        # the identity's CircuitBreaker; assigned by the SessionManager
        # (launch outcomes reported by the daemon feed it)
        self.breaker: Optional[CircuitBreaker] = None
        # seed -> (reg_plane, mem_plane), LRU-bounded
        self._planes: "OrderedDict[int, Tuple[Dict, Dict]]" = OrderedDict()
        # (engine kind, B) -> hot engine, images rebound per batch
        self._engines: Dict[Tuple[str, int], Any] = {}

    # ------------------------------------------------------------------
    def touch(self) -> None:
        self.last_used = time.monotonic()

    def default_cycles(self) -> int:
        return self.sim.default_cycles()

    @property
    def fingerprint(self) -> str:
        return self.key.fingerprint

    # ------------------------------------------------------------------
    def planes_for(self, seeds: List[int]) -> Tuple[List[Dict], List[Dict]]:
        """Per-seed init planes for ``seeds``, memoized. Missing seeds are
        produced by one netlist build anchored on the canonical seed
        (structure identical to the compiled Program's), which is pure
        host-side Python — no compilation."""
        missing = [s for s in dict.fromkeys(seeds) if s not in self._planes]
        if missing:
            bench = build(self.name, self.scale,
                          seeds=[CANONICAL_SEED] + missing)
            for i, s in enumerate(missing):
                self._planes[s] = (bench.reg_planes[i + 1],
                                   bench.mem_planes[i + 1])
        for s in seeds:
            self._planes.move_to_end(s)
        while len(self._planes) > MAX_PLANE_CACHE:
            self._planes.popitem(last=False)
        return ([self._planes[s][0] for s in seeds],
                [self._planes[s][1] for s in seeds])

    def images_for(self, seeds: List[int], workers: Optional[int] = None):
        """Stacked ``[B, ...]`` init images for one coalesced batch."""
        reg_planes, mem_planes = self.planes_for(seeds)
        return self.sim.program.init_images_batch(reg_planes, mem_planes,
                                                  workers=workers)

    def engine_for(self, kind: str, images):
        """A hot engine of ``kind`` for this batch shape: cached per
        (kind, B) and rebound onto the new images (no retrace); first use
        of a shape constructs (and traces) it once."""
        B = int(images[0].shape[0])
        eng = self._engines.get((kind, B))
        if eng is None:
            eng = self.sim.engine(kind, images=images)
            self._engines[(kind, B)] = eng
        else:
            eng.rebind(images)
        return eng

    # ------------------------------------------------------------------
    def nbytes(self) -> int:
        """Resident-memory estimate: program arrays + per-engine batched
        state (the device-budget currency the manager evicts on)."""
        p = self.sim.program
        base = sum(getattr(p, f).nbytes for f in
                   ("code", "luts", "reg_init", "spad_init", "gmem_init"))
        per_elem = (p.reg_init.nbytes + p.spad_init.nbytes
                    + p.gmem_init.nbytes) * 4 // 2   # u16 images → u32 state
        for (_, B) in self._engines:
            base += B * per_elem
        return base


class SessionManager:
    """LRU of compiled sessions behind one async front.

    ``cache`` is the on-disk compile cache argument
    (:func:`repro.sim.cache.resolve_cache` forms: True = default dir, a
    path, a :class:`CompileCache`, or None/False to disable warm starts).
    """

    def __init__(self, *, cache=True, max_sessions: int = 8,
                 memory_budget: Optional[int] = None,
                 faults: Optional["faultlib.FaultPlan"] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 compile_retries: int = 2,
                 compile_backoff_s: float = 0.02):
        self.cache: Optional[CompileCache] = resolve_cache(cache)
        self.max_sessions = int(max_sessions)
        self.memory_budget = memory_budget
        self.faults = faults
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.compile_retries = int(compile_retries)
        self.compile_backoff_s = float(compile_backoff_s)
        self._sessions: "OrderedDict[SessionKey, Session]" = OrderedDict()
        # (name, scale, hw_key, options_key) -> canonical fingerprint
        self._fingerprints: Dict[Tuple, str] = {}
        self._locks: Dict[Tuple, asyncio.Lock] = {}
        self._breakers: Dict[Tuple, CircuitBreaker] = {}
        self.counters: Dict[str, int] = {
            "compiles": 0, "cache_hits": 0, "evictions": 0, "lookups": 0,
            "compile_failures": 0, "unavailable": 0}

    # ------------------------------------------------------------------
    def _lock(self, ident: Tuple) -> asyncio.Lock:
        lock = self._locks.get(ident)
        if lock is None:
            lock = self._locks[ident] = asyncio.Lock()
        return lock

    def breaker_for(self, ident: Tuple) -> CircuitBreaker:
        br = self._breakers.get(ident)
        if br is None:
            br = self._breakers[ident] = CircuitBreaker(
                threshold=self.breaker_threshold,
                cooldown_s=self.breaker_cooldown_s)
        return br

    async def get(self, req: SimRequest) -> Session:
        """The (possibly freshly compiled) session for ``req``.

        Raises ``KeyError``/``ValueError`` for unknown circuits/scales/
        options (bad requests — never counted by the breaker),
        :class:`Unavailable` when the identity's breaker is open, and
        :class:`CompileFailed` when the compile itself raised (counted;
        transient injected faults are retried ``compile_retries`` times
        first)."""
        self.counters["lookups"] += 1
        hw = _hw_from(req)
        options = _options_from(req)
        hw_key = json.dumps(req.hw or {}, sort_keys=True)
        options_key = json.dumps(options, sort_keys=True)
        ident = (req.circuit, req.scale, hw_key, options_key)

        breaker = self.breaker_for(ident)
        allowed, retry_after = breaker.allow()
        if not allowed:
            self.counters["unavailable"] += 1
            raise Unavailable(retry_after, breaker.state)

        # fast path: fingerprint known and session resident
        fp = self._fingerprints.get(ident)
        if fp is not None:
            sess = self._sessions.get(
                SessionKey(fp, hw_key, options_key))
            if sess is not None:
                self._sessions.move_to_end(sess.key)
                sess.touch()
                return sess

        async with self._lock(ident):
            # re-check under the lock: a concurrent worker may have
            # compiled this session while we waited
            fp = self._fingerprints.get(ident)
            if fp is not None:
                sess = self._sessions.get(SessionKey(fp, hw_key,
                                                     options_key))
                if sess is not None:
                    self._sessions.move_to_end(sess.key)
                    sess.touch()
                    return sess
            sess = await self._compile_with_retry(
                breaker, req.circuit, req.scale, hw, hw_key, options,
                options_key)
            sess.breaker = breaker
            self._fingerprints[ident] = sess.key.fingerprint
            self._sessions[sess.key] = sess
            self.counters["compiles"] += 1
            if sess.sim.cache_hit:
                self.counters["cache_hits"] += 1
            breaker.record_success()
            self._evict()
            return sess

    async def _compile_with_retry(self, breaker: CircuitBreaker,
                                  name: str, scale: str,
                                  hw: HardwareConfig, hw_key: str,
                                  options: Dict[str, Any],
                                  options_key: str) -> Session:
        """Compile on a worker thread; transient faults retry with
        backoff, terminal failures count against the breaker."""
        delay = self.compile_backoff_s
        attempt = 0
        while True:
            try:
                return await asyncio.to_thread(
                    self._compile, name, scale, hw, hw_key, options,
                    options_key)
            except (KeyError, ValueError, TypeError):
                # bad request (unknown circuit/scale/knob value): the
                # identity is not broken, the request is
                raise
            except Exception as exc:
                if (getattr(exc, "transient", False)
                        and attempt < self.compile_retries):
                    attempt += 1
                    await asyncio.sleep(delay)
                    delay *= 2
                    continue
                self.counters["compile_failures"] += 1
                breaker.record_failure()
                raise CompileFailed(exc) from exc

    def _compile(self, name: str, scale: str, hw: HardwareConfig,
                 hw_key: str, options: Dict[str, Any],
                 options_key: str) -> Session:
        """Blocking compile (runs on a worker thread): canonical bench →
        facade compile through the on-disk cache."""
        if self.faults is not None:
            self.faults.check(faultlib.COMPILE, detail=f"{name}/{scale}")
        bench = build(name, scale, seeds=[CANONICAL_SEED])
        sim = facade.compile(bench, hw, cache=self.cache, **options)
        key = SessionKey(sim.fingerprint, hw_key, options_key)
        return Session(key, name, scale, hw, options, sim)

    def _evict(self) -> None:
        def over() -> bool:
            if len(self._sessions) > self.max_sessions:
                return True
            if self.memory_budget is not None:
                total = sum(s.nbytes() for s in self._sessions.values())
                return total > self.memory_budget
            return False

        while len(self._sessions) > 1 and over():
            self._sessions.popitem(last=False)
            self.counters["evictions"] += 1

    # ------------------------------------------------------------------
    def resident(self) -> List[SessionKey]:
        return list(self._sessions)

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self._sessions.values())

    def stats(self) -> Dict[str, Any]:
        """Introspection snapshot: counters, residency, and per-identity
        breaker state (the serving dashboard / drill assertion surface)."""
        return {
            "counters": dict(self.counters),
            "resident": len(self._sessions),
            "nbytes": self.nbytes(),
            "breakers": {
                f"{ident[0]}/{ident[1]}": br.snapshot()
                for ident, br in self._breakers.items()},
        }
