"""The simulation daemon: hot Simulations + dynamic batching + front-ends.

:class:`SimServer` wires the lower layers together:

* :mod:`repro.serve.sessions` keeps compiled ``Simulation``s resident
  (LRU, warm-started through the on-disk compile cache) and quarantines
  failing identities behind per-identity circuit breakers;
* :mod:`repro.serve.batcher` coalesces concurrent requests that share a
  ``(session, cycle budget)`` key — i.e. one circuit fingerprint + hw +
  knobs — into one batched launch;
* :mod:`repro.serve.protocol` is the request/response shape, in-process
  and over TCP (newline-delimited JSON);
* :mod:`repro.serve.faults` injects deterministic failures at the four
  recovery sites so every path below is drillable (zero overhead when
  ``faults=None``).

A coalesced launch builds the per-seed init planes (host-side netlist
rebuild anchored on the canonical seed, memoized per seed), stacks them
host-parallel (``Program.init_images_batch``), picks the engine through
the facade's auto-selection (``Simulation.select_engine_kind``: B >= 2*D
on a multi-device mesh → the sharded engine, otherwise the vmapped
batched engine), runs it on a worker thread under the device lock, and
demuxes the per-element :class:`~repro.sim.result.RunResult`\\ s back to
their riders.

**Fault tolerance.** A failed batched launch no longer errors all its
riders. The daemon distinguishes:

* *transient* failures (``InjectedFault(transient=True)``, or anything a
  deployment marks as such): the identical group is retried under an
  exponential-backoff budget (:class:`RetryPolicy`);
* *persistent* failures of a multi-rider group: **bounded bisection** —
  split the seed list in half and launch each half independently, so
  healthy riders still get ``OK`` and only the isolated culprit gets
  ``ERROR``/``POISONED``. The total number of launches per original
  batch is capped (``max_extra_launches``), so a pathological batch
  cannot occupy the device unboundedly;
* launch outcomes feed the session's circuit breaker: a launch where at
  least one sub-group succeeded counts as a success (poison isolation
  must not quarantine a healthy build), an all-fail launch counts as a
  failure.

**Drain.** ``close(drain=True)`` stops admission (new submissions get a
``DRAINING`` response), flushes already-queued batches, waits for
in-flight launches, then tears down — every admitted request still gets
exactly one terminal response. ``close()`` without drain aborts queued
riders with ``DRAINING`` responses rather than abandoning their futures.

In-process use::

    server = SimServer(policy=BatchPolicy(max_batch=64, max_wait_s=0.02))
    resp = await server.submit(SimRequest("mc", scale="small", seed=7))
    assert resp.ok and resp.result.finished

TCP use: ``python -m repro.serve --port 8421`` (see ``__main__.py``),
clients write one request JSON per line and read one response per line
(responses may interleave across a pipelined connection; match on
``rid``).
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass
from typing import Any, Dict, Hashable, List, Optional, Tuple

from . import faults as faultlib
from .batcher import BatchPolicy, Batcher, Pending, Rejected
from .protocol import (DRAINING, ERR_BAD_REQUEST, ERR_COMPILE_FAILED,
                       ERR_DRAINING, ERR_IMAGE_BUILD_FAILED,
                       ERR_LAUNCH_FAILED, ERR_POISONED, ERR_QUEUE_FULL,
                       ERR_TIMEOUT, ERR_UNAVAILABLE, ERROR, OK, REJECTED,
                       TIMEOUT, UNAVAILABLE, SimRequest, SimResponse,
                       decode_request, encode_response)
from .sessions import CompileFailed, Session, SessionManager, Unavailable

# per-connection cap on in-flight pipelined requests: a client that
# floods one socket stalls (backpressure) instead of growing the task set
MAX_INFLIGHT_PER_CONN = 256


@dataclass(frozen=True)
class RetryPolicy:
    """Recovery budget for one coalesced batch.

    ``max_attempts`` bounds identical-group retries of *transient*
    failures (exponential backoff from ``backoff_base_s`` capped at
    ``backoff_max_s``); ``max_extra_launches`` bounds the total extra
    device launches (retries + bisection probes) one original batch may
    spend before its unresolved riders are failed outright.
    """
    max_attempts: int = 4
    backoff_base_s: float = 0.02
    backoff_max_s: float = 0.5
    max_extra_launches: int = 16


class _LaunchError(Exception):
    """Internal: one failed launch attempt, classified by stage."""

    def __init__(self, code: str, cause: BaseException):
        super().__init__(repr(cause))
        self.code = code
        self.cause = cause
        self.transient = bool(getattr(cause, "transient", False))


class SimServer:
    """Long-lived serving daemon over the ``repro.sim`` facade."""

    def __init__(self, *, sessions: Optional[SessionManager] = None,
                 policy: Optional[BatchPolicy] = None, cache=True,
                 image_workers: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None,
                 faults: Optional[faultlib.FaultPlan] = None,
                 max_inflight_per_conn: int = MAX_INFLIGHT_PER_CONN):
        self.faults = faults
        self.sessions = sessions if sessions is not None \
            else SessionManager(cache=cache, faults=faults)
        if faults is not None and self.sessions.faults is None:
            self.sessions.faults = faults
        self.policy = policy if policy is not None else BatchPolicy()
        self.retry = retry if retry is not None else RetryPolicy()
        self.batcher = Batcher(self.policy, self._launch, self._timeout,
                               self._abort)
        self.image_workers = image_workers
        self.max_inflight_per_conn = int(max_inflight_per_conn)
        # one launch on the device at a time: the engines are synchronous
        # and the device is a shared resource; admission keeps queueing
        # fair while a launch is in flight
        self._device_lock = asyncio.Lock()
        self._tcp_server: Optional[asyncio.base_events.Server] = None
        self._state = "serving"        # serving | draining | closed
        self.launch_stats: Dict[str, int] = {
            "attempts": 0, "retries": 0, "bisections": 0, "poisoned": 0,
            "failed_groups": 0, "budget_exhausted": 0}

    # ------------------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    def stats(self) -> Dict[str, Any]:
        """One snapshot across all layers (drill/dashboard surface)."""
        out: Dict[str, Any] = {
            "state": self._state,
            "batcher": dict(self.batcher.stats),
            "launch": dict(self.launch_stats),
            "sessions": self.sessions.stats(),
        }
        if self.faults is not None:
            out["faults"] = self.faults.stats()
        return out

    # ------------------------------------------------------------------
    # in-process front-end
    # ------------------------------------------------------------------
    async def submit(self, req: SimRequest) -> SimResponse:
        """Serve one request end-to-end: resolve (or compile) its
        session, enqueue it for coalescing, await its demuxed result.
        Exactly one terminal response per request, always."""
        if self._state != "serving":
            return SimResponse(
                req.rid, DRAINING, error="daemon is draining; resubmit "
                "to another instance", error_code=ERR_DRAINING)
        try:
            session = await self.sessions.get(req)
        except Unavailable as exc:
            return SimResponse(
                req.rid, UNAVAILABLE, error=str(exc),
                error_code=ERR_UNAVAILABLE,
                retry_after_s=exc.retry_after)
        except CompileFailed as exc:
            return SimResponse(req.rid, ERROR, error=str(exc),
                               error_code=ERR_COMPILE_FAILED)
        except (KeyError, ValueError, TypeError) as exc:
            return SimResponse(req.rid, ERROR, error=str(exc),
                               error_code=ERR_BAD_REQUEST)
        try:
            cycles = int(req.cycles) if req.cycles is not None \
                else session.default_cycles()
        except ValueError as exc:
            return SimResponse(req.rid, ERROR, error=str(exc),
                               error_code=ERR_BAD_REQUEST,
                               fingerprint=session.fingerprint)
        pending = Pending(
            req=req,
            future=asyncio.get_running_loop().create_future(),
            session=session,
            deadline=(time.monotonic() + req.timeout
                      if req.timeout is not None else None))
        key: Tuple[Hashable, int] = (session.key, cycles)
        try:
            self.batcher.submit(key, pending)
        except Rejected as exc:
            return SimResponse(req.rid, REJECTED, error=str(exc),
                               error_code=ERR_QUEUE_FULL,
                               fingerprint=session.fingerprint)
        return await pending.future

    # ------------------------------------------------------------------
    # batcher callbacks
    # ------------------------------------------------------------------
    def _timeout(self, key: Hashable, expired: List[Pending]) -> None:
        for p in expired:
            if not p.future.done():
                p.future.set_result(SimResponse(
                    p.req.rid, TIMEOUT,
                    error="deadline passed before launch",
                    error_code=ERR_TIMEOUT,
                    fingerprint=p.session.fingerprint,
                    wait_s=time.monotonic() - p.enqueued))

    def _abort(self, key: Hashable, pendings: List[Pending]) -> None:
        """Abrupt close: queued riders still get a terminal response."""
        for p in pendings:
            if not p.future.done():
                p.future.set_result(SimResponse(
                    p.req.rid, DRAINING,
                    error="daemon closed before launch",
                    error_code=ERR_DRAINING,
                    fingerprint=p.session.fingerprint))

    # ------------------------------------------------------------------
    # launch path: attempt → retry (transient) → bisect (persistent)
    # ------------------------------------------------------------------
    async def _launch(self, key: Hashable, batch: List[Pending]) -> None:
        """Execute one coalesced batch, isolating failures so healthy
        riders still get their results; feed the session breaker."""
        session: Session = batch[0].session
        cycles: int = key[1]
        # launches the whole original batch may still spend (first
        # attempt + retries + bisection probes)
        budget = [1 + self.retry.max_extra_launches]
        any_ok = await self._run_group(session, cycles, batch, budget,
                                       isolated=False)
        if session.breaker is not None:
            if any_ok:
                session.breaker.record_success()
            else:
                session.breaker.record_failure()

    async def _run_group(self, session: Session, cycles: int,
                         group: List[Pending], budget: List[int],
                         isolated: bool) -> bool:
        """Run ``group`` (retrying/bisecting as needed); resolve every
        unresolved rider in it; return True iff any launch succeeded."""
        delay = self.retry.backoff_base_s
        attempt = 0
        while True:
            live = [p for p in group if not p.future.done()]
            if not live:
                return True     # nothing left to prove (all timed out)
            if budget[0] <= 0:
                self.launch_stats["budget_exhausted"] += 1
                self._fail_group(live, ERR_LAUNCH_FAILED,
                                 "retry budget exhausted", session)
                return False
            budget[0] -= 1
            try:
                results, kind, run_s, launched = await self._attempt(
                    session, cycles, live)
            except _LaunchError as err:
                attempt += 1
                if err.transient and attempt < self.retry.max_attempts \
                        and budget[0] > 0:
                    self.launch_stats["retries"] += 1
                    await asyncio.sleep(delay)
                    delay = min(delay * 2, self.retry.backoff_max_s)
                    continue
                if len(live) > 1 and budget[0] > 0:
                    # persistent failure of a multi-rider group: bisect
                    # to isolate the culprit, healthy halves still serve
                    self.launch_stats["bisections"] += 1
                    mid = len(live) // 2
                    ok_lo = await self._run_group(
                        session, cycles, live[:mid], budget, True)
                    ok_hi = await self._run_group(
                        session, cycles, live[mid:], budget, True)
                    return ok_lo or ok_hi
                code = ERR_POISONED if (
                    (isolated and len(live) == 1)
                    or getattr(err.cause, "poisoned", ())) else err.code
                if code == ERR_POISONED:
                    self.launch_stats["poisoned"] += len(live)
                self._fail_group(live, code, str(err), session)
                return False
            else:
                for i, p in enumerate(live):
                    if not p.future.done():
                        p.future.set_result(SimResponse(
                            p.req.rid, OK, result=results[i],
                            fingerprint=session.fingerprint,
                            engine_kind=kind, batch=len(live),
                            wait_s=launched - p.enqueued, run_s=run_s))
                return True

    async def _attempt(self, session: Session, cycles: int,
                       group: List[Pending]):
        """One device launch of ``group``; raises :class:`_LaunchError`
        classified by stage (image build vs engine launch)."""
        self.launch_stats["attempts"] += 1
        seeds = [p.req.seed for p in group]
        try:
            if self.faults is not None:
                self.faults.check(faultlib.IMAGE_BUILD, seeds=seeds)
            images = await asyncio.to_thread(
                session.images_for, seeds, self.image_workers)
        except Exception as exc:
            raise _LaunchError(ERR_IMAGE_BUILD_FAILED, exc) from exc
        kind = session.sim.select_engine_kind(len(group))
        if kind == "machine":
            kind = "batched"       # B=1 rides the no-vmap fast path
        async with self._device_lock:
            launched = time.monotonic()
            try:
                if self.faults is not None:
                    self.faults.check(faultlib.LAUNCH, seeds=seeds)
                engine = await asyncio.to_thread(
                    session.engine_for, kind, images)
                results = await asyncio.to_thread(
                    engine.run_batch, cycles)
            except Exception as exc:
                raise _LaunchError(ERR_LAUNCH_FAILED, exc) from exc
            run_s = time.monotonic() - launched
        session.touch()
        session.launches += 1
        return results, kind, run_s, launched

    def _fail_group(self, group: List[Pending], code: str, msg: str,
                    session: Session) -> None:
        self.launch_stats["failed_groups"] += 1
        for p in group:
            if not p.future.done():
                p.future.set_result(SimResponse(
                    p.req.rid, ERROR, error=msg, error_code=code,
                    fingerprint=session.fingerprint))

    # ------------------------------------------------------------------
    # TCP front-end (newline-delimited JSON, pipelined per connection)
    # ------------------------------------------------------------------
    async def serve_tcp(self, host: str = "127.0.0.1",
                        port: int = 8421) -> asyncio.base_events.Server:
        self._tcp_server = await asyncio.start_server(
            self._client, host, port)
        return self._tcp_server

    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        wlock = asyncio.Lock()
        tasks: set = set()
        dead = False      # writer unusable (client gone / write fault)

        async def one(line: bytes) -> None:
            nonlocal dead
            try:
                req = decode_request(line)
            except Exception as exc:
                resp = SimResponse("?", ERROR,
                                   error=f"bad request: {exc!r}",
                                   error_code=ERR_BAD_REQUEST)
            else:
                resp = await self.submit(req)
            if dead:
                return
            async with wlock:
                if dead:
                    return
                try:
                    if self.faults is not None:
                        self.faults.check(faultlib.TCP_WRITE)
                    writer.write(encode_response(resp))
                    await writer.drain()
                except Exception:
                    # client disconnected mid-response (or injected
                    # broken pipe): the connection is dead; the server —
                    # and this handler's remaining tasks — must not be
                    dead = True

        try:
            while True:
                if len(tasks) >= self.max_inflight_per_conn:
                    await asyncio.wait(set(tasks),
                                       return_when=asyncio.FIRST_COMPLETED)
                line = await reader.readline()
                if not line:
                    break
                if not line.strip():
                    continue
                t = asyncio.get_running_loop().create_task(one(line))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
            if tasks:
                # client closed its write side (or vanished): finish the
                # in-flight requests so every admitted rider resolves
                await asyncio.gather(*list(tasks), return_exceptions=True)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:
                pass

    # ------------------------------------------------------------------
    async def close(self, drain: bool = False) -> None:
        """Shut down. ``drain=True``: stop admission (new submissions
        answered ``DRAINING``), flush queued batches and finish in-flight
        launches, then tear down — every admitted request gets its
        terminal response. ``drain=False``: abrupt, but queued riders
        are still answered ``DRAINING`` instead of abandoned."""
        if self._state == "closed":
            return
        self._state = "draining"
        if self._tcp_server is not None:
            self._tcp_server.close()
            try:
                # py>=3.12 wait_closed() also waits for open connection
                # handlers; an idle client must not wedge shutdown
                await asyncio.wait_for(self._tcp_server.wait_closed(),
                                       timeout=5.0)
            except asyncio.TimeoutError:
                pass
            self._tcp_server = None
        if drain:
            await self.batcher.drain()
        await self.batcher.close()
        self._state = "closed"
