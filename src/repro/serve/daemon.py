"""The simulation daemon: hot Simulations + dynamic batching + front-ends.

:class:`SimServer` wires the three lower layers together:

* :mod:`repro.serve.sessions` keeps compiled ``Simulation``s resident
  (LRU, warm-started through the on-disk compile cache);
* :mod:`repro.serve.batcher` coalesces concurrent requests that share a
  ``(session, cycle budget)`` key — i.e. one circuit fingerprint + hw +
  knobs — into one batched launch;
* :mod:`repro.serve.protocol` is the request/response shape, in-process
  and over TCP (newline-delimited JSON).

A coalesced launch builds the per-seed init planes (host-side netlist
rebuild anchored on the canonical seed, memoized per seed), stacks them
host-parallel (``Program.init_images_batch``), picks the engine through
the facade's auto-selection (``Simulation.select_engine_kind``: B >= 2*D
on a multi-device mesh → the sharded engine, otherwise the vmapped
batched engine), runs it on a worker thread under the device lock, and
demuxes the per-element :class:`~repro.sim.result.RunResult`\\ s back to
their riders. Engines are cached per (kind, B) inside the session and
rebound onto each batch's images, so steady-state traffic pays one
host→device transfer per launch and zero retraces.

In-process use::

    server = SimServer(policy=BatchPolicy(max_batch=64, max_wait_s=0.02))
    resp = await server.submit(SimRequest("mc", scale="small", seed=7))
    assert resp.ok and resp.result.finished

TCP use: ``python -m repro.serve --port 8421`` (see ``__main__.py``),
clients write one request JSON per line and read one response per line
(responses may interleave across a pipelined connection; match on
``rid``).
"""
from __future__ import annotations

import asyncio
import time
from typing import Hashable, List, Optional, Tuple

from .batcher import BatchPolicy, Batcher, Pending, Rejected
from .protocol import (ERROR, OK, REJECTED, TIMEOUT, SimRequest,
                       SimResponse, decode_request, encode_response)
from .sessions import Session, SessionManager


class SimServer:
    """Long-lived serving daemon over the ``repro.sim`` facade."""

    def __init__(self, *, sessions: Optional[SessionManager] = None,
                 policy: Optional[BatchPolicy] = None, cache=True,
                 image_workers: Optional[int] = None):
        self.sessions = sessions if sessions is not None \
            else SessionManager(cache=cache)
        self.policy = policy if policy is not None else BatchPolicy()
        self.batcher = Batcher(self.policy, self._launch, self._timeout)
        self.image_workers = image_workers
        # one launch on the device at a time: the engines are synchronous
        # and the device is a shared resource; admission keeps queueing
        # fair while a launch is in flight
        self._device_lock = asyncio.Lock()
        self._tcp_server: Optional[asyncio.base_events.Server] = None

    # ------------------------------------------------------------------
    # in-process front-end
    # ------------------------------------------------------------------
    async def submit(self, req: SimRequest) -> SimResponse:
        """Serve one request end-to-end: resolve (or compile) its
        session, enqueue it for coalescing, await its demuxed result."""
        try:
            session = await self.sessions.get(req)
        except (KeyError, ValueError, TypeError) as exc:
            return SimResponse(req.rid, ERROR, error=str(exc))
        try:
            cycles = int(req.cycles) if req.cycles is not None \
                else session.default_cycles()
        except ValueError as exc:
            return SimResponse(req.rid, ERROR, error=str(exc),
                               fingerprint=session.fingerprint)
        pending = Pending(
            req=req,
            future=asyncio.get_running_loop().create_future(),
            session=session,
            deadline=(time.monotonic() + req.timeout
                      if req.timeout is not None else None))
        key: Tuple[Hashable, int] = (session.key, cycles)
        try:
            self.batcher.submit(key, pending)
        except Rejected as exc:
            return SimResponse(req.rid, REJECTED, error=str(exc),
                               fingerprint=session.fingerprint)
        return await pending.future

    # ------------------------------------------------------------------
    # batcher callbacks
    # ------------------------------------------------------------------
    def _timeout(self, key: Hashable, expired: List[Pending]) -> None:
        for p in expired:
            if not p.future.done():
                p.future.set_result(SimResponse(
                    p.req.rid, TIMEOUT,
                    error="deadline passed before launch",
                    fingerprint=p.session.fingerprint,
                    wait_s=time.monotonic() - p.enqueued))

    async def _launch(self, key: Hashable, batch: List[Pending]) -> None:
        """Execute one coalesced batch and demux per-rider results."""
        session: Session = batch[0].session
        cycles: int = key[1]
        seeds = [p.req.seed for p in batch]
        try:
            images = await asyncio.to_thread(
                session.images_for, seeds, self.image_workers)
            kind = session.sim.select_engine_kind(len(batch))
            if kind == "machine":
                kind = "batched"       # B=1 rides the no-vmap fast path
            async with self._device_lock:
                launched = time.monotonic()
                engine = await asyncio.to_thread(
                    session.engine_for, kind, images)
                results = await asyncio.to_thread(
                    engine.run_batch, cycles)
                run_s = time.monotonic() - launched
        except Exception as exc:
            for p in batch:
                if not p.future.done():
                    p.future.set_result(SimResponse(
                        p.req.rid, ERROR, error=repr(exc),
                        fingerprint=session.fingerprint))
            return
        session.touch()
        session.launches += 1
        for i, p in enumerate(batch):
            if not p.future.done():
                p.future.set_result(SimResponse(
                    p.req.rid, OK, result=results[i],
                    fingerprint=session.fingerprint, engine_kind=kind,
                    batch=len(batch), wait_s=launched - p.enqueued,
                    run_s=run_s))

    # ------------------------------------------------------------------
    # TCP front-end (newline-delimited JSON, pipelined per connection)
    # ------------------------------------------------------------------
    async def serve_tcp(self, host: str = "127.0.0.1",
                        port: int = 8421) -> asyncio.base_events.Server:
        self._tcp_server = await asyncio.start_server(
            self._client, host, port)
        return self._tcp_server

    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        wlock = asyncio.Lock()
        tasks: List[asyncio.Task] = []

        async def one(line: bytes) -> None:
            try:
                req = decode_request(line)
            except Exception as exc:
                resp = SimResponse("?", ERROR,
                                   error=f"bad request: {exc!r}")
            else:
                resp = await self.submit(req)
            async with wlock:
                writer.write(encode_response(resp))
                await writer.drain()

        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                if not line.strip():
                    continue
                tasks.append(asyncio.get_running_loop().create_task(
                    one(line)))
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:
                pass

    # ------------------------------------------------------------------
    async def close(self) -> None:
        if self._tcp_server is not None:
            self._tcp_server.close()
            await self._tcp_server.wait_closed()
            self._tcp_server = None
        await self.batcher.close()
