"""Request/response protocol for the simulation service.

One :class:`SimRequest` asks for one *stimulus* of one circuit: "simulate
the canonical ``(circuit, scale)`` design with seed ``seed`` for ``cycles``
Vcycles under this hardware config and these compiler knobs". The daemon
answers with a :class:`SimResponse` wrapping the per-element
:class:`~repro.sim.result.RunResult` the batched/sharded engines already
demux, plus the serving metadata a client needs to reason about latency
(which fingerprint queue it rode, how large the coalesced launch was, how
long it waited for admission).

The dataclasses are the in-process API; ``encode_*``/``decode_*`` give the
TCP front-end a newline-delimited JSON wire form of the same objects
(``{"v": 1, ...}\\n`` per message). Unknown JSON keys are ignored on
decode, so clients and servers can skew by small protocol additions.
"""
from __future__ import annotations

import json
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional, Union

from ..sim.result import RunResult

PROTOCOL_VERSION = 1

# response statuses
OK = "ok"                # result carries the RunResult
REJECTED = "rejected"    # admission refused (queue full) — retry later
TIMEOUT = "timeout"      # deadline passed before the request was launched
ERROR = "error"          # request invalid or the launch raised


def _rid() -> str:
    return uuid.uuid4().hex[:12]


@dataclass(frozen=True)
class SimRequest:
    """One simulation stimulus.

    ``circuit``/``scale`` name the design (``repro.circuits.build``);
    ``seed`` selects the stimulus (per-seed init planes on the canonical
    structural netlist — see :mod:`repro.serve.sessions`). ``cycles`` is
    the Vcycle budget (None = the bench's self-checking budget plus
    slack). ``hw`` overrides :class:`~repro.core.isa.HardwareConfig`
    fields; ``options`` passes compiler knobs (``optimize``, ``use_luts``,
    ``strategy``, ``sched_strategy``, ``placement``, ``pipeline``).
    ``timeout`` is the admission deadline in seconds: if the request has
    not been launched by then it is answered ``TIMEOUT`` instead of
    holding the client forever.
    """

    circuit: str
    scale: str = "full"
    seed: int = 0
    cycles: Optional[int] = None
    hw: Optional[Dict[str, int]] = None
    options: Dict[str, Any] = field(default_factory=dict)
    timeout: Optional[float] = None
    rid: str = field(default_factory=_rid)


@dataclass
class SimResponse:
    """The daemon's answer to one :class:`SimRequest`.

    ``batch`` is the size of the coalesced launch this request rode in
    (the whole point of the service: many concurrent requests, one
    launch); ``wait_s`` the time from admission to launch, ``run_s`` the
    device occupancy of that launch (shared by all ``batch`` riders).
    """

    rid: str
    status: str
    result: Optional[RunResult] = None
    error: Optional[str] = None
    fingerprint: Optional[str] = None
    engine_kind: Optional[str] = None
    batch: int = 0
    wait_s: float = 0.0
    run_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == OK


# ----------------------------------------------------------------------
# wire form (newline-delimited JSON)
# ----------------------------------------------------------------------

def result_to_json(r: RunResult) -> Dict[str, Any]:
    return {
        "cycles": int(r.cycles),
        # JSON object keys are strings; exception cores are ints
        "exceptions": {str(k): int(v) for k, v in r.exceptions.items()},
        "perf": {k: float(v) for k, v in r.perf.items()},
        "registers": {k: int(v) for k, v in r.registers.items()},
        "outputs": {k: int(v) for k, v in r.outputs.items()},
        "batch_index": int(r.batch_index),
    }


def result_from_json(d: Dict[str, Any]) -> RunResult:
    return RunResult(
        cycles=int(d["cycles"]),
        exceptions={int(k): int(v)
                    for k, v in d.get("exceptions", {}).items()},
        perf=dict(d.get("perf", {})),
        registers={k: int(v) for k, v in d.get("registers", {}).items()},
        outputs={k: int(v) for k, v in d.get("outputs", {}).items()},
        batch_index=int(d.get("batch_index", 0)),
    )


def _fields(cls, d: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the keys ``cls`` knows — forward-compatible decode."""
    names = cls.__dataclass_fields__.keys()
    return {k: v for k, v in d.items() if k in names}


def encode_request(req: SimRequest) -> bytes:
    doc = {"v": PROTOCOL_VERSION, **asdict(req)}
    return (json.dumps(doc) + "\n").encode("utf-8")


def decode_request(line: Union[str, bytes]) -> SimRequest:
    d = json.loads(line)
    v = d.pop("v", PROTOCOL_VERSION)
    if v != PROTOCOL_VERSION:
        raise ValueError(f"unsupported protocol version {v!r}")
    return SimRequest(**_fields(SimRequest, d))


def encode_response(resp: SimResponse) -> bytes:
    doc = {"v": PROTOCOL_VERSION, **asdict(resp)}
    if resp.result is not None:
        doc["result"] = result_to_json(resp.result)
    return (json.dumps(doc) + "\n").encode("utf-8")


def decode_response(line: Union[str, bytes]) -> SimResponse:
    d = json.loads(line)
    v = d.pop("v", PROTOCOL_VERSION)
    if v != PROTOCOL_VERSION:
        raise ValueError(f"unsupported protocol version {v!r}")
    result = d.pop("result", None)
    resp = SimResponse(**_fields(SimResponse, d))
    if result is not None:
        resp.result = result_from_json(result)
    return resp
