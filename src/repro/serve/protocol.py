"""Request/response protocol for the simulation service.

One :class:`SimRequest` asks for one *stimulus* of one circuit: "simulate
the canonical ``(circuit, scale)`` design with seed ``seed`` for ``cycles``
Vcycles under this hardware config and these compiler knobs". The daemon
answers with a :class:`SimResponse` wrapping the per-element
:class:`~repro.sim.result.RunResult` the batched/sharded engines already
demux, plus the serving metadata a client needs to reason about latency
(which fingerprint queue it rode, how large the coalesced launch was, how
long it waited for admission).

The dataclasses are the in-process API; ``encode_*``/``decode_*`` give the
TCP front-end a newline-delimited JSON wire form of the same objects
(``{"v": 2, ...}\\n`` per message). Unknown JSON keys are ignored on
decode and ``None``-valued fields are omitted on encode, so clients and
servers can skew by small protocol additions: a v1 client never sees the
v2 fields (``error_code``, ``retry_after_s``) unless they are set, and a
v2 server still accepts v1 requests (``SUPPORTED_VERSIONS``).

Failures are machine-readable: terminal non-OK responses carry an
``error_code`` from ``ERROR_CODES`` alongside the human ``error`` string,
so clients can branch (retry later on ``UNAVAILABLE``/``DRAINING``,
resubmit elsewhere on ``QUEUE_FULL``, give up on ``POISONED``) without
parsing ``repr(exc)`` prose. Absent ``error_code`` ⇒ a legacy (v1)
server — clients must treat it as optional.
"""
from __future__ import annotations

import json
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional, Union

from ..sim.result import RunResult

PROTOCOL_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)

# response statuses
OK = "ok"                  # result carries the RunResult
REJECTED = "rejected"      # admission refused (queue full) — retry later
TIMEOUT = "timeout"        # deadline passed before the request was launched
ERROR = "error"            # request invalid or the launch raised
UNAVAILABLE = "unavailable"  # session circuit breaker open — retry after
DRAINING = "draining"      # daemon shutting down — resubmit elsewhere

# machine-readable error codes (SimResponse.error_code, protocol v2)
ERR_BAD_REQUEST = "BAD_REQUEST"          # malformed request / unknown knobs
ERR_COMPILE_FAILED = "COMPILE_FAILED"    # session compile raised
ERR_IMAGE_BUILD_FAILED = "IMAGE_BUILD_FAILED"  # stimulus image build raised
ERR_LAUNCH_FAILED = "LAUNCH_FAILED"      # engine launch raised (not isolated)
ERR_POISONED = "POISONED"                # bisection isolated this stimulus
ERR_UNAVAILABLE = "UNAVAILABLE"          # breaker open; see retry_after_s
ERR_DRAINING = "DRAINING"                # admission stopped for shutdown
ERR_TIMEOUT = "TIMEOUT"                  # deadline passed before launch
ERR_QUEUE_FULL = "QUEUE_FULL"            # backpressure rejection

ERROR_CODES = frozenset((
    ERR_BAD_REQUEST, ERR_COMPILE_FAILED, ERR_IMAGE_BUILD_FAILED,
    ERR_LAUNCH_FAILED, ERR_POISONED, ERR_UNAVAILABLE, ERR_DRAINING,
    ERR_TIMEOUT, ERR_QUEUE_FULL))


def _rid() -> str:
    return uuid.uuid4().hex[:12]


@dataclass(frozen=True)
class SimRequest:
    """One simulation stimulus.

    ``circuit``/``scale`` name the design (``repro.circuits.build``);
    ``seed`` selects the stimulus (per-seed init planes on the canonical
    structural netlist — see :mod:`repro.serve.sessions`). ``cycles`` is
    the Vcycle budget (None = the bench's self-checking budget plus
    slack). ``hw`` overrides :class:`~repro.core.isa.HardwareConfig`
    fields; ``options`` passes compiler knobs (``optimize``, ``use_luts``,
    ``strategy``, ``sched_strategy``, ``placement``, ``pipeline``).
    ``timeout`` is the admission deadline in seconds: if the request has
    not been launched by then it is answered ``TIMEOUT`` instead of
    holding the client forever.
    """

    circuit: str
    scale: str = "full"
    seed: int = 0
    cycles: Optional[int] = None
    hw: Optional[Dict[str, int]] = None
    options: Dict[str, Any] = field(default_factory=dict)
    timeout: Optional[float] = None
    rid: str = field(default_factory=_rid)


@dataclass
class SimResponse:
    """The daemon's answer to one :class:`SimRequest`.

    ``batch`` is the size of the coalesced launch this request rode in
    (the whole point of the service: many concurrent requests, one
    launch); ``wait_s`` the time from admission to launch, ``run_s`` the
    device occupancy of that launch (shared by all ``batch`` riders).

    ``error_code`` (v2) is the machine-readable failure class (one of
    ``ERROR_CODES``; None on OK and on responses from legacy servers);
    ``retry_after_s`` (v2) accompanies ``UNAVAILABLE``/``DRAINING`` —
    the earliest time a retry of this identity can be admitted.
    """

    rid: str
    status: str
    result: Optional[RunResult] = None
    error: Optional[str] = None
    error_code: Optional[str] = None
    retry_after_s: Optional[float] = None
    fingerprint: Optional[str] = None
    engine_kind: Optional[str] = None
    batch: int = 0
    wait_s: float = 0.0
    run_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == OK

    @property
    def terminal(self) -> bool:
        """Every response the daemon emits is terminal — exactly one per
        request; the property exists so drill/assert code reads clearly."""
        return self.status in (OK, REJECTED, TIMEOUT, ERROR, UNAVAILABLE,
                               DRAINING)


# ----------------------------------------------------------------------
# wire form (newline-delimited JSON)
# ----------------------------------------------------------------------

def result_to_json(r: RunResult) -> Dict[str, Any]:
    return {
        "cycles": int(r.cycles),
        # JSON object keys are strings; exception cores are ints
        "exceptions": {str(k): int(v) for k, v in r.exceptions.items()},
        "perf": {k: float(v) for k, v in r.perf.items()},
        "registers": {k: int(v) for k, v in r.registers.items()},
        "outputs": {k: int(v) for k, v in r.outputs.items()},
        "batch_index": int(r.batch_index),
    }


def result_from_json(d: Dict[str, Any]) -> RunResult:
    return RunResult(
        cycles=int(d["cycles"]),
        exceptions={int(k): int(v)
                    for k, v in d.get("exceptions", {}).items()},
        perf=dict(d.get("perf", {})),
        registers={k: int(v) for k, v in d.get("registers", {}).items()},
        outputs={k: int(v) for k, v in d.get("outputs", {}).items()},
        batch_index=int(d.get("batch_index", 0)),
    )


def _fields(cls, d: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only the keys ``cls`` knows — forward-compatible decode."""
    names = cls.__dataclass_fields__.keys()
    return {k: v for k, v in d.items() if k in names}


def _strip_none(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Omit None-valued keys on the wire: decoders default them, and a
    legacy (v1) peer never sees fields it does not know about."""
    return {k: v for k, v in doc.items() if v is not None}


def _check_version(d: Dict[str, Any]) -> None:
    v = d.pop("v", PROTOCOL_VERSION)
    if v not in SUPPORTED_VERSIONS:
        raise ValueError(f"unsupported protocol version {v!r}")


def encode_request(req: SimRequest) -> bytes:
    doc = {"v": PROTOCOL_VERSION, **_strip_none(asdict(req))}
    return (json.dumps(doc) + "\n").encode("utf-8")


def decode_request(line: Union[str, bytes]) -> SimRequest:
    d = json.loads(line)
    _check_version(d)
    return SimRequest(**_fields(SimRequest, d))


def encode_response(resp: SimResponse) -> bytes:
    doc = {"v": PROTOCOL_VERSION, **_strip_none(asdict(resp))}
    if resp.result is not None:
        doc["result"] = result_to_json(resp.result)
    return (json.dumps(doc) + "\n").encode("utf-8")


def decode_response(line: Union[str, bytes]) -> SimResponse:
    d = json.loads(line)
    _check_version(d)
    result = d.pop("result", None)
    resp = SimResponse(**_fields(SimResponse, d))
    if result is not None:
        resp.result = result_from_json(result)
    return resp
