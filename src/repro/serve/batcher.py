"""Fingerprint-keyed dynamic batching: many requests, one launch.

The inference-server shape (continuous/dynamic batching) applied to RTL
simulation: requests land on per-key queues — one key per ``(session,
cycle budget)``, i.e. per compiled Program that could execute them in one
batched launch — and a drain task per key assembles batches under a
**max-batch / max-wait admission policy**:

* the first request of a batch opens a window of ``max_wait_s``;
* the batch launches as soon as ``max_batch`` riders arrived, or when the
  window closes, whichever is first (``max_wait_s`` bounds the latency
  cost of coalescing; ``max_batch`` bounds device memory);
* a queue deeper than ``max_queue`` refuses admission
  (:class:`Rejected` → the daemon answers ``REJECTED``: explicit
  backpressure beats unbounded queueing);
* each request may carry a deadline; requests whose deadline passed by
  launch time are answered ``TIMEOUT`` and never occupy a batch slot.

``max_batch=1`` degenerates to sequential per-request launches — the
baseline :mod:`benchmarks.bench_serve` measures coalescing against.

**Shutdown contract.** Every admitted :class:`Pending` resolves exactly
once, even across shutdown: the batcher counts outstanding admitted
requests (decremented by a done-callback on each future, so the count is
correct no matter *who* resolves it — launch, timeout, or abort) and

* :meth:`Batcher.drain` (graceful): stop opening new admission windows,
  flush already-queued requests into launches, and return once every
  outstanding future has resolved — the daemon's ``close(drain=True)``
  path;
* :meth:`Batcher.close` (abrupt): cancel drain tasks and hand any
  still-unresolved requests — queued or mid-formation — to the
  ``on_abort`` callback so no rider ever hangs on an abandoned future.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Awaitable, Callable, Dict, Hashable, List, Optional

from .protocol import SimRequest


@dataclass
class BatchPolicy:
    """Admission policy knobs (see module docstring and docs/serving.md)."""
    max_batch: int = 64       # riders per coalesced launch
    max_wait_s: float = 0.02  # window the first rider holds open
    max_queue: int = 256      # per-key depth before admission refuses


class Rejected(Exception):
    """Admission refused: the key's queue is at ``max_queue``."""


@dataclass
class Pending:
    """One enqueued request: the future the submitter awaits plus the
    timing/admission metadata the drain loop needs."""
    req: SimRequest
    future: "asyncio.Future[Any]"
    session: Any = None
    enqueued: float = field(default_factory=time.monotonic)
    deadline: Optional[float] = None    # monotonic; None = wait forever

    @property
    def expired(self) -> bool:
        return self.deadline is not None \
            and time.monotonic() >= self.deadline


LaunchFn = Callable[[Hashable, List[Pending]], Awaitable[None]]
TimeoutFn = Callable[[Hashable, List[Pending]], None]
AbortFn = Callable[[Hashable, List[Pending]], None]


class Batcher:
    """Per-key queues + drain tasks feeding an async ``launch`` callable.

    ``launch(key, batch)`` receives only live (non-expired) requests and
    must resolve every ``Pending.future``; ``on_timeout(key, expired)``
    (if given) resolves the requests dropped at admission time;
    ``on_abort(key, pendings)`` (if given) resolves requests the batcher
    had to give up on at :meth:`close` time — otherwise their futures
    get a ``RuntimeError``.
    """

    def __init__(self, policy: BatchPolicy, launch: LaunchFn,
                 on_timeout: Optional[TimeoutFn] = None,
                 on_abort: Optional[AbortFn] = None):
        self.policy = policy
        self._launch = launch
        self._on_timeout = on_timeout
        self._on_abort = on_abort
        self._queues: Dict[Hashable, asyncio.Queue] = {}
        self._tasks: Dict[Hashable, asyncio.Task] = {}
        self._draining = False
        # admitted requests whose future has not resolved yet; the done
        # callback attached at submit() keeps it exact regardless of who
        # resolves the future (launch, timeout, abort)
        self._outstanding = 0
        self.stats: Dict[str, int] = {
            "submitted": 0, "rejected": 0, "timed_out": 0,
            "launches": 0, "launched_requests": 0, "max_seen_batch": 0,
            "aborted": 0}

    # ------------------------------------------------------------------
    @property
    def outstanding(self) -> int:
        return self._outstanding

    @property
    def draining(self) -> bool:
        return self._draining

    def submit(self, key: Hashable, pending: Pending) -> None:
        """Admit ``pending`` onto ``key``'s queue (creating its drain
        task on first use) or raise :class:`Rejected`."""
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = asyncio.Queue()
            self._tasks[key] = asyncio.get_running_loop().create_task(
                self._drain(key, q))
        if q.qsize() >= self.policy.max_queue:
            self.stats["rejected"] += 1
            raise Rejected(
                f"queue for {key!r} is full "
                f"({self.policy.max_queue} pending)")
        self.stats["submitted"] += 1
        self._outstanding += 1
        pending.future.add_done_callback(self._resolved)
        q.put_nowait(pending)

    def _resolved(self, _future) -> None:
        self._outstanding -= 1

    async def _drain(self, key: Hashable, q: asyncio.Queue) -> None:
        pol = self.policy
        batch: List[Pending] = []
        try:
            while True:
                batch = [await q.get()]
                if not self._draining:
                    window_ends = time.monotonic() + pol.max_wait_s
                    while len(batch) < pol.max_batch:
                        remaining = window_ends - time.monotonic()
                        if self._draining or remaining <= 0:
                            # window closed (or flushing): take whatever
                            # already queued, no wait
                            try:
                                batch.append(q.get_nowait())
                                continue
                            except asyncio.QueueEmpty:
                                break
                        try:
                            batch.append(
                                await asyncio.wait_for(q.get(), remaining))
                        except asyncio.TimeoutError:
                            break
                else:
                    # draining: no admission window, flush what's queued
                    while len(batch) < pol.max_batch:
                        try:
                            batch.append(q.get_nowait())
                        except asyncio.QueueEmpty:
                            break
                live = [p for p in batch if not p.expired]
                dead = [p for p in batch if p.expired]
                if dead:
                    self.stats["timed_out"] += len(dead)
                    if self._on_timeout is not None:
                        self._on_timeout(key, dead)
                if not live:
                    batch = []
                    continue
                self.stats["launches"] += 1
                self.stats["launched_requests"] += len(live)
                self.stats["max_seen_batch"] = max(
                    self.stats["max_seen_batch"], len(live))
                try:
                    await self._launch(key, live)
                except Exception as exc:   # launch() should not raise, but
                    for p in live:         # a rider must never hang on it
                        if not p.future.done():
                            p.future.set_exception(
                                RuntimeError(f"launch failed: {exc!r}"))
                batch = []
        except asyncio.CancelledError:
            # abrupt close mid-formation or mid-launch: the current
            # batch's unresolved riders must still terminate
            self._abort(key, batch)
            raise

    # ------------------------------------------------------------------
    def _abort(self, key: Hashable, pendings: List[Pending]) -> None:
        undone = [p for p in pendings if not p.future.done()]
        if not undone:
            return
        self.stats["aborted"] += len(undone)
        if self._on_abort is not None:
            self._on_abort(key, undone)
        for p in undone:
            if not p.future.done():
                p.future.set_exception(
                    RuntimeError("batcher closed before launch"))

    def depth(self, key: Hashable) -> int:
        q = self._queues.get(key)
        return q.qsize() if q is not None else 0

    async def drain(self, poll_s: float = 0.005) -> None:
        """Graceful flush: stop opening admission windows (queued
        requests launch immediately in max_batch groups) and return once
        every admitted request has resolved. New submissions remain
        possible — the daemon stops admission at its layer first."""
        self._draining = True
        while self._outstanding > 0:
            await asyncio.sleep(poll_s)

    async def close(self) -> None:
        """Cancel every drain task; unresolved requests (queued or in a
        forming batch) are aborted via ``on_abort`` — nothing hangs."""
        for t in self._tasks.values():
            t.cancel()
        for t in self._tasks.values():
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        for key, q in self._queues.items():
            leftovers: List[Pending] = []
            while not q.empty():
                leftovers.append(q.get_nowait())
            self._abort(key, leftovers)
        self._tasks.clear()
        self._queues.clear()
