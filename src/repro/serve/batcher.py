"""Fingerprint-keyed dynamic batching: many requests, one launch.

The inference-server shape (continuous/dynamic batching) applied to RTL
simulation: requests land on per-key queues — one key per ``(session,
cycle budget)``, i.e. per compiled Program that could execute them in one
batched launch — and a drain task per key assembles batches under a
**max-batch / max-wait admission policy**:

* the first request of a batch opens a window of ``max_wait_s``;
* the batch launches as soon as ``max_batch`` riders arrived, or when the
  window closes, whichever is first (``max_wait_s`` bounds the latency
  cost of coalescing; ``max_batch`` bounds device memory);
* a queue deeper than ``max_queue`` refuses admission
  (:class:`Rejected` → the daemon answers ``REJECTED``: explicit
  backpressure beats unbounded queueing);
* each request may carry a deadline; requests whose deadline passed by
  launch time are answered ``TIMEOUT`` and never occupy a batch slot.

``max_batch=1`` degenerates to sequential per-request launches — the
baseline :mod:`benchmarks.bench_serve` measures coalescing against.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Awaitable, Callable, Dict, Hashable, List, Optional

from .protocol import SimRequest


@dataclass
class BatchPolicy:
    """Admission policy knobs (see module docstring and docs/serving.md)."""
    max_batch: int = 64       # riders per coalesced launch
    max_wait_s: float = 0.02  # window the first rider holds open
    max_queue: int = 256      # per-key depth before admission refuses


class Rejected(Exception):
    """Admission refused: the key's queue is at ``max_queue``."""


@dataclass
class Pending:
    """One enqueued request: the future the submitter awaits plus the
    timing/admission metadata the drain loop needs."""
    req: SimRequest
    future: "asyncio.Future[Any]"
    session: Any = None
    enqueued: float = field(default_factory=time.monotonic)
    deadline: Optional[float] = None    # monotonic; None = wait forever

    @property
    def expired(self) -> bool:
        return self.deadline is not None \
            and time.monotonic() >= self.deadline


LaunchFn = Callable[[Hashable, List[Pending]], Awaitable[None]]
TimeoutFn = Callable[[Hashable, List[Pending]], None]


class Batcher:
    """Per-key queues + drain tasks feeding an async ``launch`` callable.

    ``launch(key, batch)`` receives only live (non-expired) requests and
    must resolve every ``Pending.future``; ``on_timeout(key, expired)``
    (if given) resolves the requests dropped at admission time.
    """

    def __init__(self, policy: BatchPolicy, launch: LaunchFn,
                 on_timeout: Optional[TimeoutFn] = None):
        self.policy = policy
        self._launch = launch
        self._on_timeout = on_timeout
        self._queues: Dict[Hashable, asyncio.Queue] = {}
        self._tasks: Dict[Hashable, asyncio.Task] = {}
        self.stats: Dict[str, int] = {
            "submitted": 0, "rejected": 0, "timed_out": 0,
            "launches": 0, "launched_requests": 0, "max_seen_batch": 0}

    # ------------------------------------------------------------------
    def submit(self, key: Hashable, pending: Pending) -> None:
        """Admit ``pending`` onto ``key``'s queue (creating its drain
        task on first use) or raise :class:`Rejected`."""
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = asyncio.Queue()
            self._tasks[key] = asyncio.get_running_loop().create_task(
                self._drain(key, q))
        if q.qsize() >= self.policy.max_queue:
            self.stats["rejected"] += 1
            raise Rejected(
                f"queue for {key!r} is full "
                f"({self.policy.max_queue} pending)")
        self.stats["submitted"] += 1
        q.put_nowait(pending)

    async def _drain(self, key: Hashable, q: asyncio.Queue) -> None:
        pol = self.policy
        while True:
            batch: List[Pending] = [await q.get()]
            window_ends = time.monotonic() + pol.max_wait_s
            while len(batch) < pol.max_batch:
                remaining = window_ends - time.monotonic()
                if remaining <= 0:
                    # window closed: take whatever already queued, no wait
                    try:
                        batch.append(q.get_nowait())
                        continue
                    except asyncio.QueueEmpty:
                        break
                try:
                    batch.append(
                        await asyncio.wait_for(q.get(), remaining))
                except asyncio.TimeoutError:
                    break
            live = [p for p in batch if not p.expired]
            dead = [p for p in batch if p.expired]
            if dead:
                self.stats["timed_out"] += len(dead)
                if self._on_timeout is not None:
                    self._on_timeout(key, dead)
            if not live:
                continue
            self.stats["launches"] += 1
            self.stats["launched_requests"] += len(live)
            self.stats["max_seen_batch"] = max(
                self.stats["max_seen_batch"], len(live))
            try:
                await self._launch(key, live)
            except Exception as exc:       # launch() should not raise, but
                for p in live:             # a rider must never hang on it
                    if not p.future.done():
                        p.future.set_exception(
                            RuntimeError(f"launch failed: {exc!r}"))

    # ------------------------------------------------------------------
    def depth(self, key: Hashable) -> int:
        q = self._queues.get(key)
        return q.qsize() if q is not None else 0

    async def close(self) -> None:
        """Cancel every drain task (pending requests are abandoned — the
        daemon drains before closing in an orderly shutdown)."""
        for t in self._tasks.values():
            t.cancel()
        for t in self._tasks.values():
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        self._tasks.clear()
        self._queues.clear()
