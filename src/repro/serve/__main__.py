"""Run the simulation daemon.

TCP service (newline-delimited JSON; see ``repro.serve.protocol``)::

  PYTHONPATH=src python -m repro.serve --host 127.0.0.1 --port 8421

In-process self-test (submits a few mixed requests and exits non-zero on
any failure — a deployment smoke check, no sockets needed)::

  PYTHONPATH=src python -m repro.serve --self-test --scale small
"""
from __future__ import annotations

import argparse
import asyncio
import sys

from .batcher import BatchPolicy
from .daemon import SimServer
from .protocol import SimRequest
from .sessions import SessionManager


def _args() -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Manticore simulation-as-a-service daemon")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8421)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--max-sessions", type=int, default=8)
    ap.add_argument("--cache-dir", default=None,
                    help="compile-cache directory (default: REPRO_SIM_CACHE"
                         " or ~/.cache/repro-sim)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk compile cache")
    ap.add_argument("--self-test", action="store_true",
                    help="serve a few in-process requests and exit")
    ap.add_argument("--circuits", default="mc,bc",
                    help="self-test circuits (comma-separated)")
    ap.add_argument("--scale", default="small",
                    help="self-test scale")
    return ap.parse_args()


def _server(args: argparse.Namespace) -> SimServer:
    cache = False if args.no_cache else (args.cache_dir or True)
    return SimServer(
        sessions=SessionManager(cache=cache,
                                max_sessions=args.max_sessions),
        policy=BatchPolicy(max_batch=args.max_batch,
                           max_wait_s=args.max_wait_ms / 1e3,
                           max_queue=args.max_queue))


async def _self_test(server: SimServer, circuits, scale: str) -> int:
    reqs = [SimRequest(name, scale=scale, seed=100 + i)
            for name in circuits for i in range(4)]
    resps = await asyncio.gather(*(server.submit(r) for r in reqs))
    bad = [r for r in resps if not (r.ok and r.result.finished)]
    for r in resps:
        print(f"  {r.rid}: {r.status} batch={r.batch} "
              f"engine={r.engine_kind} wait={r.wait_s * 1e3:.1f}ms")
    if bad:
        print(f"self-test FAILED: {len(bad)}/{len(resps)} requests bad")
        return 1
    print(f"self-test ok: {len(resps)} requests, "
          f"{server.batcher.stats['launches']} launches")
    return 0


async def _main() -> int:
    args = _args()
    server = _server(args)
    if args.self_test:
        try:
            return await _self_test(
                server, [c for c in args.circuits.split(",") if c],
                args.scale)
        finally:
            await server.close()
    tcp = await server.serve_tcp(args.host, args.port)
    addr = tcp.sockets[0].getsockname()
    print(f"repro.serve listening on {addr[0]}:{addr[1]} "
          f"(max_batch={args.max_batch}, "
          f"max_wait={args.max_wait_ms:.0f}ms)")
    try:
        await tcp.serve_forever()
    except (KeyboardInterrupt, asyncio.CancelledError):
        pass
    finally:
        await server.close()
    return 0


if __name__ == "__main__":
    sys.exit(asyncio.run(_main()))
