"""Run the simulation daemon.

TCP service (newline-delimited JSON; see ``repro.serve.protocol``)::

  PYTHONPATH=src python -m repro.serve --host 127.0.0.1 --port 8421

SIGTERM/SIGINT trigger a **drained** shutdown: admission stops (new
requests get ``DRAINING``), queued batches flush, in-flight launches
finish, then the process exits 0.

In-process self-test (submits a few mixed requests and exits non-zero on
any failure — a deployment smoke check, no sockets needed)::

  PYTHONPATH=src python -m repro.serve --self-test --scale small

Chaos drill (the fault-tolerance CI gate): serve N mixed mc+bc requests
under an aggressive :class:`~repro.serve.faults.FaultPlan` (default
p=0.2 at all four sites, plus deterministic poison seeds), assert that
every request receives **exactly one terminal response**, that no
poison-free request is answered ``ERROR``, that exactly the poisoned
stimuli are isolated as ``POISONED``, and that the daemon then exits
cleanly via a drained SIGTERM::

  PYTHONPATH=src python -m repro.serve --chaos-drill 500 --scale small
"""
from __future__ import annotations

import argparse
import asyncio
import os
import signal
import sys
from typing import List

from .batcher import BatchPolicy
from .daemon import RetryPolicy, SimServer
from .faults import FaultPlan
from .protocol import (DRAINING, ERR_POISONED, ERROR, OK, REJECTED,
                       TIMEOUT, UNAVAILABLE, SimRequest, decode_response,
                       encode_request)
from .sessions import SessionManager


def _args() -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Manticore simulation-as-a-service daemon")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8421)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--max-sessions", type=int, default=8)
    ap.add_argument("--breaker-threshold", type=int, default=3,
                    help="consecutive failures before an identity is "
                         "quarantined")
    ap.add_argument("--breaker-cooldown-s", type=float, default=1.0,
                    help="quarantine cooldown before a half-open probe")
    ap.add_argument("--cache-dir", default=None,
                    help="compile-cache directory (default: REPRO_SIM_CACHE"
                         " or ~/.cache/repro-sim)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the on-disk compile cache")
    ap.add_argument("--self-test", action="store_true",
                    help="serve a few in-process requests and exit")
    ap.add_argument("--chaos-drill", type=int, default=0, metavar="N",
                    help="serve N requests under an aggressive fault plan,"
                         " assert the exactly-one-terminal-response"
                         " invariant, drain via SIGTERM, exit")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="fault-plan RNG seed (drill is deterministic)")
    ap.add_argument("--chaos-p", type=float, default=0.2,
                    help="per-site fault probability for the drill")
    ap.add_argument("--circuits", default="mc,bc",
                    help="self-test/drill circuits (comma-separated)")
    ap.add_argument("--scale", default="small",
                    help="self-test/drill scale")
    return ap.parse_args()


def _server(args: argparse.Namespace, faults=None,
            breaker_cooldown_s=None) -> SimServer:
    cache = False if args.no_cache else (args.cache_dir or True)
    return SimServer(
        sessions=SessionManager(
            cache=cache, max_sessions=args.max_sessions, faults=faults,
            breaker_threshold=args.breaker_threshold,
            breaker_cooldown_s=(breaker_cooldown_s
                                if breaker_cooldown_s is not None
                                else args.breaker_cooldown_s)),
        policy=BatchPolicy(max_batch=args.max_batch,
                           max_wait_s=args.max_wait_ms / 1e3,
                           max_queue=args.max_queue),
        faults=faults)


async def _self_test(server: SimServer, circuits, scale: str) -> int:
    reqs = [SimRequest(name, scale=scale, seed=100 + i)
            for name in circuits for i in range(4)]
    resps = await asyncio.gather(*(server.submit(r) for r in reqs))
    bad = [r for r in resps if not (r.ok and r.result.finished)]
    for r in resps:
        print(f"  {r.rid}: {r.status} batch={r.batch} "
              f"engine={r.engine_kind} wait={r.wait_s * 1e3:.1f}ms")
    if bad:
        print(f"self-test FAILED: {len(bad)}/{len(resps)} requests bad")
        return 1
    print(f"self-test ok: {len(resps)} requests, "
          f"{server.batcher.stats['launches']} launches")
    return 0


# ----------------------------------------------------------------------
# chaos drill
# ----------------------------------------------------------------------

POISON_SEEDS = frozenset({666, 667})


async def chaos_drill(server: SimServer, circuits: List[str], scale: str,
                      n: int, plan: FaultPlan) -> int:
    """The drill body (importable for tests): N mixed requests in bursts,
    every one must terminate exactly once, poison must be isolated to
    exactly the poisoned stimuli, then drained SIGTERM shutdown."""
    poison = sorted(plan.spec("launch").poison_seeds)
    reqs: List[SimRequest] = []
    for i in range(n):
        name = circuits[i % len(circuits)]
        # sprinkle the deterministic poison seeds through the traffic
        seed = poison[i // 50 % len(poison)] if poison and i % 50 == 7 \
            else 1000 + i
        reqs.append(SimRequest(name, scale=scale, seed=seed))

    # submit in bursts so batches form, retry UNAVAILABLE (breaker
    # quarantine is *supposed* to fast-fail us while a build is sick)
    resps = {}

    async def drive(r: SimRequest):
        for _ in range(40):
            resp = await server.submit(r)
            assert r.rid not in resps, f"double response for {r.rid}"
            if resp.status == UNAVAILABLE:
                await asyncio.sleep(max(resp.retry_after_s or 0.05, 0.05))
                continue
            resps[r.rid] = resp
            return
        resps[r.rid] = resp     # give up retrying: still terminal

    burst = 64
    for at in range(0, len(reqs), burst):
        await asyncio.gather(*(drive(r) for r in reqs[at:at + burst]))

    # exercise the TCP front-end (incl. the tcp_write fault site): the
    # server must survive write faults; lost responses are expected there
    tcp = await server.serve_tcp("127.0.0.1", 0)
    port = tcp.sockets[0].getsockname()[1]
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    n_tcp = min(32, max(n // 8, 4))
    for i in range(n_tcp):
        writer.write(encode_request(
            SimRequest(circuits[i % len(circuits)], scale=scale,
                       seed=5000 + i)))
    await writer.drain()
    got_tcp = 0
    try:
        while got_tcp < n_tcp:
            line = await asyncio.wait_for(reader.readline(), timeout=3.0)
            if not line:
                break
            decode_response(line)
            got_tcp += 1
    except asyncio.TimeoutError:
        # a tcp_write fault marks the connection dead server-side, so
        # everything after the first fault is (correctly) never written
        pass
    writer.close()

    # ---- invariants ---------------------------------------------------
    failures: List[str] = []
    if len(resps) != n:
        failures.append(f"{n - len(resps)} requests never terminated")
    poison_set = set(poison)
    poisoned_rids = {r.rid for r in reqs if r.seed in poison_set}
    statuses = {}
    for r in reqs:
        resp = resps.get(r.rid)
        if resp is None:
            continue
        statuses[resp.status] = statuses.get(resp.status, 0) + 1
        if r.rid in poisoned_rids:
            if resp.status == ERROR and resp.error_code != ERR_POISONED:
                failures.append(
                    f"poisoned {r.rid} errored with {resp.error_code}, "
                    f"expected {ERR_POISONED}")
        elif resp.status == ERROR:
            failures.append(
                f"poison-free {r.rid} (seed {r.seed}) answered ERROR "
                f"({resp.error_code}: {resp.error})")
        elif resp.status not in (OK, REJECTED, TIMEOUT, UNAVAILABLE,
                                 DRAINING):
            failures.append(f"{r.rid}: unknown status {resp.status}")
    n_poison_err = sum(
        1 for r in reqs if r.rid in poisoned_rids
        and resps.get(r.rid) is not None
        and resps[r.rid].status == ERROR)
    if poisoned_rids and n_poison_err == 0:
        failures.append("no poisoned request was isolated as ERROR")

    stats = server.stats()
    print(f"chaos drill: {n} requests -> {statuses}; "
          f"tcp {got_tcp}/{n_tcp} responses (write faults eat the rest)")
    print(f"  launch: {stats['launch']}")
    print(f"  faults: {stats['faults']['fired']}")
    print(f"  breakers: "
          f"{ {k: v['state'] for k, v in stats['sessions']['breakers'].items()} }")
    for f in failures[:10]:
        print(f"  INVARIANT VIOLATED: {f}")
    return 1 if failures else 0


async def _run_drill(args: argparse.Namespace) -> int:
    plan = FaultPlan.chaos(seed=args.chaos_seed, p=args.chaos_p,
                           poison_seeds=POISON_SEEDS)
    # short cooldown so quarantined identities recover within the drill;
    # generous retry budget so transient storms never surface as ERROR
    server = _server(args, faults=plan, breaker_cooldown_s=0.2)
    server.retry = RetryPolicy(max_attempts=8, backoff_base_s=0.01,
                               max_extra_launches=32)
    # deep transient-retry budget: a p=0.2 storm must dry up through
    # retries, never surface as a terminal ERROR on a healthy request
    server.sessions.compile_retries = 6

    drained = asyncio.Event()
    loop = asyncio.get_running_loop()
    loop.add_signal_handler(signal.SIGTERM, drained.set)
    rc = await chaos_drill(
        server, [c for c in args.circuits.split(",") if c], args.scale,
        args.chaos_drill, plan)
    # the drill ends the way a real deployment does: SIGTERM → drain
    os.kill(os.getpid(), signal.SIGTERM)
    await asyncio.wait_for(drained.wait(), timeout=10.0)
    await server.close(drain=True)
    assert server.state == "closed"
    late = await server.submit(SimRequest("mc", scale=args.scale))
    assert late.status == DRAINING     # admission stays stopped
    print(f"chaos drill {'FAILED' if rc else 'ok'}: drained SIGTERM "
          f"shutdown clean")
    return rc


# ----------------------------------------------------------------------

async def _main() -> int:
    args = _args()
    if args.chaos_drill > 0:
        return await _run_drill(args)
    server = _server(args)
    if args.self_test:
        try:
            return await _self_test(
                server, [c for c in args.circuits.split(",") if c],
                args.scale)
        finally:
            await server.close(drain=True)
    tcp = await server.serve_tcp(args.host, args.port)
    addr = tcp.sockets[0].getsockname()
    print(f"repro.serve listening on {addr[0]}:{addr[1]} "
          f"(max_batch={args.max_batch}, "
          f"max_wait={args.max_wait_ms:.0f}ms)")
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            loop.add_signal_handler(sig, stop.set)
        except NotImplementedError:      # non-POSIX event loop
            pass
    try:
        await stop.wait()
        print("signal received: draining (queued batches flush, "
              "in-flight launches finish) ...")
    finally:
        await server.close(drain=True)
        print("drained; exiting")
    return 0


if __name__ == "__main__":
    sys.exit(asyncio.run(_main()))
