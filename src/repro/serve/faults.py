"""Deterministic fault injection for the serving stack.

Partial failure is the steady state of a large deployment (Parendi runs
the same BSP model thousand-way), so every recovery path in
:mod:`repro.serve` — batch-retry bisection, the session circuit breaker,
graceful drain — must be *testable on demand*, not only observable in
production. This module is the harness: a :class:`FaultPlan` describes,
per fault **site**, when an :class:`InjectedFault` should be raised, and
the serve layers call :meth:`FaultPlan.check` at exactly four places:

========== =========================================================
site        where the check runs
========== =========================================================
COMPILE     ``SessionManager._compile`` (worker thread), before the
            facade compile — models toolchain/OOM compile failures
IMAGE_BUILD ``SimServer`` before per-batch init-image stacking —
            models host-side stimulus build failures
LAUNCH      ``SimServer`` under the device lock, before the engine
            runs — models device resets, XLA launch errors, and
            **poisoned stimuli** (``poison_seeds``)
TCP_WRITE   the per-connection writer — models a client that
            disconnected mid-response (broken pipe)
========== =========================================================

Determinism: probabilistic fires draw from one seeded
``random.Random`` under a lock, so a given ``(seed, traffic)`` pair
replays the same fault sequence — the chaos drill
(``python -m repro.serve --chaos-drill N``) relies on this to be a
reproducible CI gate rather than a flake generator. ``poison_seeds``
fires are *stateless* (any launch whose batch contains a poisoned seed
fails), which is what gives bisection a fixed point to isolate.

Zero overhead when disabled: the serve layers hold ``faults=None`` by
default and guard every check with ``if faults is not None`` — no plan,
no call, no branch beyond the None test.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, Optional

# fault sites (the only strings FaultPlan accepts)
COMPILE = "compile"
IMAGE_BUILD = "image_build"
LAUNCH = "launch"
TCP_WRITE = "tcp_write"
SITES = (COMPILE, IMAGE_BUILD, LAUNCH, TCP_WRITE)


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultPlan.check` at an armed site.

    ``transient`` is the retry contract: the daemon's retry/backoff loop
    only re-attempts an identical launch for transient faults;
    non-transient faults go straight to bisection (batches) or a
    terminal ERROR (singletons). ``poisoned`` carries the seeds whose
    presence triggered a poison fire (empty for probabilistic fires).
    """

    def __init__(self, site: str, message: str, *, transient: bool = False,
                 poisoned: Iterable[int] = ()):
        super().__init__(message)
        self.site = site
        self.transient = bool(transient)
        self.poisoned = tuple(poisoned)


@dataclass(frozen=True)
class FaultSpec:
    """Arming of one site.

    ``p`` — per-check fire probability; ``times`` caps the total number
    of probabilistic fires (None = unlimited) so transient storms dry up
    deterministically; ``transient`` marks fires as retryable;
    ``poison_seeds`` (LAUNCH only) fires — statelessly, independent of
    ``p``/``times`` — whenever the checked batch contains one of these
    seeds.
    """
    p: float = 0.0
    times: Optional[int] = None
    transient: bool = False
    poison_seeds: FrozenSet[int] = field(default_factory=frozenset)

    @property
    def armed(self) -> bool:
        return self.p > 0.0 or bool(self.poison_seeds)


class FaultPlan:
    """Seedable per-site fault schedule. Thread-safe (COMPILE checks run
    on compile worker threads)."""

    def __init__(self, seed: int = 0, *, compile: Optional[FaultSpec] = None,
                 image_build: Optional[FaultSpec] = None,
                 launch: Optional[FaultSpec] = None,
                 tcp_write: Optional[FaultSpec] = None):
        self._specs: Dict[str, FaultSpec] = {
            COMPILE: compile or FaultSpec(),
            IMAGE_BUILD: image_build or FaultSpec(),
            LAUNCH: launch or FaultSpec(),
            TCP_WRITE: tcp_write or FaultSpec(),
        }
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()
        self._fired: Dict[str, int] = {s: 0 for s in SITES}
        self._checked: Dict[str, int] = {s: 0 for s in SITES}

    @classmethod
    def chaos(cls, seed: int = 0, p: float = 0.2,
              poison_seeds: Iterable[int] = ()) -> "FaultPlan":
        """The aggressive all-sites plan the chaos drill runs under:
        transient probabilistic faults at every site (so retries can
        succeed) plus optional deterministic launch poison."""
        return cls(
            seed,
            compile=FaultSpec(p=p, transient=True),
            image_build=FaultSpec(p=p, transient=True),
            launch=FaultSpec(p=p, transient=True,
                             poison_seeds=frozenset(poison_seeds)),
            tcp_write=FaultSpec(p=p))

    # ------------------------------------------------------------------
    def spec(self, site: str) -> FaultSpec:
        return self._specs[site]

    def check(self, site: str, *, seeds: Optional[Iterable[int]] = None,
              detail: str = "") -> None:
        """Raise :class:`InjectedFault` if ``site`` fires for this call.

        Poison fires (LAUNCH + ``poison_seeds`` ∩ ``seeds``) are checked
        first and are deterministic; probabilistic fires consume one RNG
        draw per armed check and honour the ``times`` cap.
        """
        spec = self._specs[site]
        with self._lock:
            self._checked[site] += 1
            if site == LAUNCH and spec.poison_seeds and seeds is not None:
                hit = [s for s in seeds if s in spec.poison_seeds]
                if hit:
                    self._fired[site] += 1
                    raise InjectedFault(
                        site, f"injected poison stimulus (seeds {hit})",
                        transient=False, poisoned=hit)
            if spec.p <= 0.0:
                return
            if spec.times is not None and self._fired[site] >= spec.times:
                return
            if self._rng.random() < spec.p:
                self._fired[site] += 1
                raise InjectedFault(
                    site,
                    f"injected {site} fault"
                    + (f" ({detail})" if detail else ""),
                    transient=spec.transient)

    # ------------------------------------------------------------------
    def fired(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._fired)

    def checked(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._checked)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {"fired": dict(self._fired),
                    "checked": dict(self._checked)}
