"""Custom function synthesis (paper §6.2).

Collapses chains of bitwise logic (AND/OR/XOR/NOT) into single 4-input LUT
instructions evaluated by the per-core custom function unit (CFU). Mirrors
the paper's flow:

  * prune non-logic vertices -> connected logic components;
  * enumerate 4-feasible cuts (cut enumeration, Cong et al.);
  * keep maximum fanout-free cones (MFFC): no interior value may be used
    outside the cone;
  * compute the 16x16-bit truth table. The CFU applies an independent 4-input
    boolean function per bit lane, which lets *constant* operands be folded
    into the table for free (the paper's (a & 0xf) | b | (c & 0x3) | (d ^ 1)
    example) — constants do not consume LUT inputs;
  * group equivalent tables (logic equivalence = identical tables here) and
    select non-overlapping cones. The paper uses an MILP; we use weighted
    greedy set cover (largest savings first), which the evaluation shows is
    within noise for these workloads, and cap distinct tables at the
    hardware's 32 CFU slots.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .isa import Instr, LOGIC_OPS, NUM_LUTS, Op, WORD_MASK
from .lower import Lowered, def_index, use_index

# per-lane truth tables for the 4 cut variables: table bit p = value of
# variable i under input pattern p (p encodes (v3,v2,v1,v0))
_VAR_TABLE = [sum(((p >> i) & 1) << p for p in range(16)) for i in range(4)]


@dataclass
class LutCandidate:
    root: int                  # local instr index
    var_leaves: Tuple[int, ...]  # vregs feeding LUT inputs (<= 4)
    covered: Tuple[int, ...]   # local instr indices replaced (incl. root)
    table: Tuple[int, ...]     # 16 entries, entry p = per-lane bits (uint16)

    @property
    def savings(self) -> int:
        return len(self.covered) - 1


def _eval_cone(instrs: List[Instr], root: int, leaves: Sequence[int],
               const_of: Dict[int, int],
               defs: Dict[int, int]) -> Optional[Tuple[int, ...]]:
    """Symbolically evaluate the cone over its <=4 variable leaves.
    Returns the 16-entry LUT table or None if not expressible."""
    var_idx = {v: i for i, v in enumerate(leaves)}
    lane_tables: Dict[int, List[int]] = {}

    def value_of(vreg: int) -> Optional[List[int]]:
        if vreg in var_idx:
            t = _VAR_TABLE[var_idx[vreg]]
            return [t] * 16
        if vreg in const_of:
            c = const_of[vreg]
            return [0xFFFF if (c >> j) & 1 else 0 for j in range(16)]
        if vreg == 0:
            return [0] * 16
        d = defs.get(vreg)
        if d is None:
            return None
        return lane_tables.get(d)

    # evaluate in topo order (instrs are emitted in topo order)
    pending = sorted(_cone_instrs(instrs, root, set(leaves), defs))
    for idx in pending:
        ins = instrs[idx]
        vals = [value_of(s) for s in ins.srcs]
        if any(v is None for v in vals):
            return None
        if ins.op == Op.AND:
            lane_tables[idx] = [a & b for a, b in zip(vals[0], vals[1])]
        elif ins.op == Op.OR:
            lane_tables[idx] = [a | b for a, b in zip(vals[0], vals[1])]
        elif ins.op == Op.XOR:
            lane_tables[idx] = [a ^ b for a, b in zip(vals[0], vals[1])]
        elif ins.op == Op.NOT:
            lane_tables[idx] = [(~a) & WORD_MASK for a in vals[0]]
        else:
            return None
    lanes = lane_tables[root]
    # convert per-lane 16-bit tables into 16 pattern entries of per-lane bits
    return tuple(sum(((lanes[j] >> p) & 1) << j for j in range(16))
                 for p in range(16))


def _cone_instrs(instrs: List[Instr], root: int, leaves: Set[int],
                 defs: Dict[int, int]) -> Set[int]:
    out: Set[int] = set()
    stack = [root]
    while stack:
        idx = stack.pop()
        if idx in out:
            continue
        out.add(idx)
        for s in instrs[idx].srcs:
            if s in leaves:
                continue
            d = defs.get(s)
            if d is not None and instrs[d].op in LOGIC_OPS:
                stack.append(d)
    return out


def synthesize(instrs: List[Instr], vreg_init: Dict[int, object],
               protected: frozenset = frozenset(),
               max_tables: int = NUM_LUTS, max_cuts: int = 8,
               ) -> Tuple[List[Instr], List[Tuple[int, ...]]]:
    """Rewrite one process: fuse logic cones into LUT instructions.

    ``protected`` vregs (next-register values, outputs, sent values) have
    consumers outside the instruction list and must survive as explicit defs
    — they may be LUT roots but never fused-away interiors.

    Since PR 3 the input is the post-opt IR: copy propagation has collapsed
    MOV chains between logic ops (a MOV is not in ``LOGIC_OPS``, so it used
    to sever a logic component in two), which exposes larger fanout-free
    cones to the cut enumeration, and ``vreg_init`` may contain constants
    the middle-end materialized — both fold into tables for free.

    Returns (new instruction list, LUT tables used by this process).
    """
    defs: Dict[int, int] = def_index(instrs)
    const_of = dict(vreg_init)  # caller passes *true constants only*
    uses: Dict[int, List[int]] = use_index(instrs)

    # ---- cut enumeration over logic nodes -----------------------------
    # a cut is a frozenset of *variable* vregs (constants are free)
    cuts: Dict[int, List[frozenset]] = {}

    def leaf_cut(vreg: int) -> Optional[frozenset]:
        if vreg in const_of or vreg == 0:
            return frozenset()
        return frozenset([vreg])

    candidates: List[LutCandidate] = []
    for i, ins in enumerate(instrs):
        if ins.op not in LOGIC_OPS:
            continue
        src_cut_sets: List[List[frozenset]] = []
        for s in ins.srcs:
            d = defs.get(s)
            if d is not None and instrs[d].op in LOGIC_OPS:
                src_cut_sets.append(cuts.get(d, []) + [leaf_cut(s) or
                                                       frozenset([s])])
            else:
                lc = leaf_cut(s)
                src_cut_sets.append([lc if lc is not None else frozenset([s])])
        merged: Set[frozenset] = set()
        if len(src_cut_sets) == 1:
            for a in src_cut_sets[0]:
                if len(a) <= 4:
                    merged.add(a)
        else:
            for a in src_cut_sets[0]:
                for b in src_cut_sets[1]:
                    u = a | b
                    if len(u) <= 4:
                        merged.add(u)
        # prune: prefer small cuts, keep a bounded frontier
        kept = sorted(merged, key=len)[:max_cuts]
        cuts[i] = kept

        # ---- candidate cones at this root ------------------------------
        for cut in kept:
            cone = _cone_instrs(instrs, i, set(cut), defs)
            if len(cone) < 2:
                continue  # no savings
            # MFFC check: interior values must not escape the cone
            ok = True
            for idx in cone:
                if idx == i:
                    continue
                w = instrs[idx].writes()
                if (w is None or w in protected or
                        any(u not in cone for u in uses.get(w, []))):
                    ok = False
                    break
            if not ok:
                continue
            table = _eval_cone(instrs, i, tuple(sorted(cut)), const_of, defs)
            if table is None:
                continue
            candidates.append(LutCandidate(i, tuple(sorted(cut)),
                                           tuple(sorted(cone)), table))

    # ---- greedy selection (largest savings first) -----------------------
    candidates.sort(key=lambda c: (-c.savings, c.root))
    covered: Set[int] = set()
    tables: List[Tuple[int, ...]] = []
    table_idx: Dict[Tuple[int, ...], int] = {}
    chosen: Dict[int, LutCandidate] = {}
    for cand in candidates:
        if cand.savings <= 0 or any(x in covered for x in cand.covered):
            continue
        if cand.table not in table_idx and len(tables) >= max_tables:
            continue
        if cand.table not in table_idx:
            table_idx[cand.table] = len(tables)
            tables.append(cand.table)
        covered.update(cand.covered)
        chosen[cand.root] = cand

    # ---- rewrite ---------------------------------------------------------
    out: List[Instr] = []
    dropped: Set[int] = set()
    for c in chosen.values():
        dropped.update(x for x in c.covered if x != c.root)
    for i, ins in enumerate(instrs):
        if i in dropped:
            continue
        if i in chosen:
            c = chosen[i]
            srcs = list(c.var_leaves) + [0] * (4 - len(c.var_leaves))
            out.append(Instr(Op.LUT, ins.dst, tuple(srcs),
                             imm=table_idx[c.table]))
        else:
            out.append(ins)
    return out, tables
