"""Linear-scan register allocation (paper §6.3).

The 2048-entry register file makes spills practically impossible for the
paper's workloads; we still reuse temporaries so heavily duplicated processes
fit. State (current register values, constants, relocatable memory bases) is
*pinned* — those machine registers persist across Vcycles. The Wimmer-Franz
optimization shares one machine register between a register's current and
next value when the schedule orders the next-value write after every read of
the current value.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .isa import Instr, Op
from .lower import InitVal


@dataclass
class CoreAlloc:
    vreg_to_mreg: Dict[int, int]
    init: List[Tuple[int, InitVal]]     # (machine reg, initial value/reloc)
    used: int


def allocate(slots: Sequence[Optional[Instr]],
             pinned_init: Dict[int, InitVal],
             share: Dict[int, int],
             num_regs: int,
             no_recycle: Optional[Set[int]] = None) -> CoreAlloc:
    """Allocate machine registers for one core.

    ``pinned_init``: leaf vregs (state/constants) and their initial values.
    ``share``: nxt vreg -> cur vreg register-sharing pairs (pre-validated).
    ``no_recycle``: vregs whose machine register must stay private for the
    whole stream — prologue carries of a modulo-pipelined schedule live
    across the Vcycle boundary, so their register cannot be handed to a
    later temporary even after their last in-stream read.
    """
    keep = no_recycle or set()
    vmap: Dict[int, int] = {0: 0}  # vreg 0 == machine r0 == 0
    init: List[Tuple[int, InitVal]] = []
    next_reg = 1

    # referenced vregs only
    referenced: Set[int] = set()
    for ins in slots:
        if ins is None:
            continue
        referenced.update(ins.srcs)
        w = ins.writes()
        if w is not None:
            referenced.add(w)
    for n, c in share.items():
        if n in referenced:
            referenced.add(c)

    # pin state & constants
    for v in sorted(referenced & set(pinned_init)):
        if v == 0:
            continue
        if next_reg >= num_regs:
            raise RuntimeError(f"register file overflow: {len(referenced)} "
                               f"values, {num_regs} registers")
        vmap[v] = next_reg
        init.append((next_reg, pinned_init[v]))
        next_reg += 1
    for n, c in sorted(share.items()):
        if n in referenced:
            vmap[n] = vmap[c]

    # linear scan over temporaries
    last_use: Dict[int, int] = {}
    for t, ins in enumerate(slots):
        if ins is None:
            continue
        for s in ins.srcs:
            last_use[s] = t
    free: List[int] = []
    for t, ins in enumerate(slots):
        if ins is None:
            continue
        w = ins.writes()
        if w is not None and w not in vmap:
            if free:
                vmap[w] = free.pop()
            else:
                if next_reg >= num_regs:
                    raise RuntimeError(
                        f"register file overflow at slot {t}: {num_regs} regs")
                vmap[w] = next_reg
                next_reg += 1
        # release temporaries whose last read is this slot
        for s in ins.srcs:
            if (last_use.get(s) == t and s in vmap and s != 0
                    and s not in pinned_init and s not in share
                    and s not in keep and vmap[s] not in free):
                # never recycle a register another vreg still maps to via share
                free.append(vmap[s])
    return CoreAlloc(vmap, init, next_reg)
