"""Optimizing middle-end: a pass pipeline over the lowered SSA IR (PR 3).

Manticore's premise is that the *compiler* pays for scheduling, so every
instruction deleted before partitioning shrinks VCPL for every engine at
once — and duplicated cones multiply each saved instruction across
processes. This module runs a small pass manager over the monolithic
:class:`~repro.core.lower.Lowered` process, between ``lower`` and
``partition`` (see ``core.compile.compile_circuit(optimize=True)``):

  * **fold** — constant folding + propagation over ``const_vregs`` (true
    constants only — never register state, latched inputs or
    :class:`~repro.core.lower.Reloc` leaves, which is precisely the
    batched-stimulus liveness contract, enforced by ``Lowered.check``);
  * **copyprop** — MOV/copy propagation (protected defs excepted);
  * **strength** — word-level strength reduction and algebraic identities
    (x*2^k -> shifts, ADD/SUB/AND/OR/XOR/MUX identities, carry/borrow
    chains with provably-zero inputs), driven by a known-bits analysis
    seeded from the per-word register widths (``Lowered.cur_word_masks``,
    i.e. the ``_mask_top`` contract) — this is what erases redundant
    top-word masking;
  * **cse** — global value numbering over pure ops *and* memory loads
    (full-cycle semantics order all loads of a memory before its stores,
    so two loads of the same (memory, address) are equivalent), with
    commutative operand canonicalization;
  * **dce** — dead-code elimination from the sink set (stores, EXPECTs,
    next-register and output definitions).

Passes never remove or rename a *protected* definition (next-register and
output vregs — ``Lowered.protected_vregs``): those have consumers outside
the instruction list (the commit plan, SEND payloads, host reads). A
protected def whose value folds is rewritten to ``MOV dst, const`` so the
sink survives. Per-pass instruction deltas and timings are recorded and
surface in ``Program.stats["opt_passes"]`` (see
``benchmarks/table8_compile_time.py``).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

from .isa import (COMMUTATIVE_OPS, Instr, MEM_READ_OPS, Op, PURE_OPS,
                  SIDE_EFFECT_OPS, WORD_MASK)
from .lower import Lowered, def_index

M = WORD_MASK
_SIGN = 0x8000


# ----------------------------------------------------------------------
# shared machinery
# ----------------------------------------------------------------------

def _find(subst: Dict[int, int], v: int) -> int:
    """Resolve ``v`` through the substitution map (with path compression)."""
    r = v
    while r in subst:
        r = subst[r]
    while v in subst and subst[v] != r:
        subst[v], v = r, subst[v]
    return r


def _resolve(subst: Dict[int, int], srcs: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(_find(subst, s) for s in srcs)


def pure_backward_cone(low: Lowered, vreg: int, max_size: int,
                       defs: Optional[Dict[int, int]] = None):
    """Bounded backward closure of the pure expression computing ``vreg``.

    Walks def-use chains from ``vreg``'s defining instruction. Returns
    ``(instr_indices, state_reads)`` — frozensets of instruction indices
    and of current-register leaves the cone reads — when the whole cone is
    :data:`~repro.core.isa.PURE_OPS` and at most ``max_size`` instructions;
    ``None`` when the cone is impure (loads, sends, side effects), too
    large, or ``vreg`` has no defining instruction. Constant / input /
    Reloc leaves are free (their init is materialized on every core by
    regalloc) and are not reported. Used by
    :mod:`~repro.core.remat` to price rematerialization candidates."""
    if defs is None:
        defs = low.defs()
    d0 = defs.get(vreg)
    if d0 is None:
        return None
    state = low.state_vregs()
    instrs: set = set()
    reads: set = set()
    stack = [d0]
    while stack:
        idx = stack.pop()
        if idx in instrs:
            continue
        if low.instrs[idx].op not in PURE_OPS:
            return None
        instrs.add(idx)
        if len(instrs) > max_size:
            return None
        for s in low.instrs[idx].srcs:
            dd = defs.get(s)
            if dd is not None:
                if dd not in instrs:
                    stack.append(dd)
            elif s in state:
                reads.add(s)
    return frozenset(instrs), frozenset(reads)


class _ConstPool:
    """Reverse map value -> const vreg; materializes new leaves on demand."""

    def __init__(self, low: Lowered):
        self.low = low
        self.rev: Dict[int, int] = {0: 0}
        for v in sorted(low.const_vregs):
            self.rev.setdefault(low.const_vregs[v], v)

    def vreg(self, value: int) -> int:
        value &= M
        v = self.rev.get(value)
        if v is None:
            low = self.low
            v = low.num_vregs
            low.num_vregs += 1
            low.vreg_init[v] = value
            low.const_vregs[v] = value
            self.rev[value] = v
        return v


def eval_op(op: Op, vals: List[int], imm: int) -> Optional[int]:
    """Evaluate one pure op over constant operands (16-bit semantics,
    mirroring ``core.isasim``)."""
    v = list(vals) + [0] * (4 - len(vals))
    if op == Op.MOV:
        return v[0]
    if op == Op.MOVI:
        return imm & M
    if op == Op.ADD:
        return (v[0] + v[1]) & M
    if op == Op.ADDC:
        return (v[0] + v[1] + v[2]) & M
    if op == Op.CARRY:
        return (v[0] + v[1] + v[2]) >> 16
    if op == Op.SUB:
        return (v[0] - v[1]) & M
    if op == Op.SUBB:
        return (v[0] - v[1] - v[2]) & M
    if op == Op.BORROW:
        return int(v[0] - v[1] - v[2] < 0)
    if op == Op.MUL:
        return (v[0] * v[1]) & M
    if op == Op.MULH:
        return ((v[0] * v[1]) >> 16) & M
    if op == Op.AND:
        return v[0] & v[1]
    if op == Op.OR:
        return v[0] | v[1]
    if op == Op.XOR:
        return v[0] ^ v[1]
    if op == Op.NOT:
        return (~v[0]) & M
    if op == Op.MUX:
        return v[1] if v[0] else v[2]
    if op == Op.SEQ:
        return int(v[0] == v[1])
    if op == Op.SNE:
        return int(v[0] != v[1])
    if op == Op.SLTU:
        return int(v[0] < v[1])
    if op == Op.SLL:
        return (v[0] << (imm & 15)) & M
    if op == Op.SRL:
        return v[0] >> (imm & 15)
    if op == Op.SRA:
        return (((v[0] ^ _SIGN) - _SIGN) >> (imm & 15)) & M
    if op == Op.SLLV:
        return (v[0] << (v[1] & 15)) & M
    if op == Op.SRLV:
        return v[0] >> (v[1] & 15)
    if op == Op.SLICE:
        return (v[0] >> (imm >> 5)) & ((1 << (imm & 31)) - 1)
    return None


def _bound(x: int) -> int:
    """Smallest all-ones mask covering every value <= x (16-bit clip)."""
    return M if x >= M else (1 << x.bit_length()) - 1


def maybe_mask(op: Op, m: List[int], imm: int) -> int:
    """Known-bits transfer function: mask of possibly-set result bits given
    the operands' possibly-set masks (a mask is also an upper bound on the
    operand's value)."""
    m = list(m) + [0] * (4 - len(m))
    if op == Op.MOV:
        return m[0]
    if op == Op.MOVI:
        return imm & M
    if op == Op.AND:
        return m[0] & m[1]
    if op in (Op.OR, Op.XOR):
        return m[0] | m[1]
    if op == Op.MUX:
        return m[1] | m[2]
    if op in (Op.SEQ, Op.SNE, Op.SLTU, Op.BORROW):
        return 1
    if op == Op.CARRY:
        return 1 if m[0] + m[1] + m[2] > M else 0
    if op in (Op.ADD, Op.ADDC):
        s = m[0] + m[1] + (m[2] if op == Op.ADDC else 0)
        return M if s > M else _bound(s)
    if op == Op.MUL:
        p = m[0] * m[1]
        return M if p > M else _bound(p)
    if op == Op.MULH:
        return _bound((m[0] * m[1]) >> 16)
    if op == Op.SLL:
        return (m[0] << (imm & 15)) & M
    if op == Op.SRL:
        return m[0] >> (imm & 15)
    if op == Op.SRA:
        return M if m[0] & _SIGN else m[0] >> (imm & 15)
    if op == Op.SLLV:
        return M if m[0] else 0
    if op == Op.SRLV:
        return _bound(m[0])
    if op == Op.SLICE:
        return (m[0] >> (imm >> 5)) & ((1 << (imm & 31)) - 1)
    return M  # NOT, LD, GLD, LUT, unknown: every bit may be set


def _init_masks(low: Lowered) -> Dict[int, int]:
    masks = {0: 0}
    for v in low.vreg_init:
        masks[v] = M                        # inputs / Reloc: opaque
    masks.update(low.const_vregs)           # true constants: exact
    masks.update(low.cur_word_masks())      # register words: width-bounded
    return masks


# ----------------------------------------------------------------------
# passes — each rewrites low.instrs in place and returns a change count
# ----------------------------------------------------------------------

def const_fold(low: Lowered) -> int:
    """Fold pure ops whose operands are all true constants; propagate the
    folded values forward. Protected defs become ``MOV dst, const``."""
    protected = low.protected_vregs()
    pool = _ConstPool(low)
    const_of = dict(low.const_vregs)
    subst: Dict[int, int] = {}
    out: List[Instr] = []
    changed = 0
    for ins in low.instrs:
        srcs = _resolve(subst, ins.srcs)
        w = ins.writes()
        if ins.op in PURE_OPS and w != 0 and \
                all(s == 0 or s in const_of for s in srcs):
            val = eval_op(ins.op, [const_of.get(s, 0) for s in srcs], ins.imm)
            if val is not None:
                cv = pool.vreg(val)
                const_of[w] = val
                if w in protected:
                    if not (ins.op == Op.MOV and srcs == (cv,)):
                        changed += 1
                    out.append(Instr(Op.MOV, w, (cv,)))
                else:
                    subst[w] = cv
                    changed += 1
                continue
        if srcs != ins.srcs:
            ins = Instr(ins.op, ins.dst, srcs, ins.imm, mem=ins.mem)
        out.append(ins)
    low.replace_instrs(out)
    return changed


def copy_prop(low: Lowered) -> int:
    """Remove non-protected MOVs by substituting their source forward."""
    protected = low.protected_vregs()
    subst: Dict[int, int] = {}
    out: List[Instr] = []
    changed = 0
    for ins in low.instrs:
        srcs = _resolve(subst, ins.srcs)
        if ins.op == Op.MOV and ins.dst != 0 and ins.dst not in protected:
            subst[ins.dst] = srcs[0]
            changed += 1
            continue
        if srcs != ins.srcs:
            ins = Instr(ins.op, ins.dst, srcs, ins.imm, mem=ins.mem)
        out.append(ins)
    low.replace_instrs(out)
    return changed


def _pow2(c: Optional[int]) -> Optional[int]:
    if c is not None and c > 0 and c & (c - 1) == 0:
        return c.bit_length() - 1
    return None


def _simplify(op: Op, srcs: Tuple[int, ...], imm: int,
              const_of: Dict[int, int], mb: List[int]):
    """One algebraic rewrite step. Returns ("subst", vreg) |
    ("const", value) | ("rewrite", op, srcs, imm) | None."""
    def c(i):
        s = srcs[i]
        return 0 if s == 0 else const_of.get(s)

    a = srcs[0] if srcs else 0
    b = srcs[1] if len(srcs) > 1 else 0
    if op == Op.ADD:
        if mb[1] == 0:
            return ("subst", a)
        if mb[0] == 0:
            return ("subst", b)
    elif op == Op.ADDC:
        if mb[2] == 0:
            return ("rewrite", Op.ADD, srcs[:2], 0)
    elif op == Op.SUB:
        if mb[1] == 0:
            return ("subst", a)
        if a == b:
            return ("const", 0)
    elif op == Op.SUBB:
        if mb[2] == 0:
            return ("rewrite", Op.SUB, srcs[:2], 0)
    elif op == Op.BORROW:
        if mb[1] == 0 and mb[2] == 0:
            return ("const", 0)
        if a == b and mb[2] == 0:
            return ("const", 0)
    elif op == Op.MUL:
        for x, y in ((0, 1), (1, 0)):
            if c(y) == 1:
                return ("subst", srcs[x])
            k = _pow2(c(y))
            if k is not None and 1 <= k <= 15:
                return ("rewrite", Op.SLL, (srcs[x],), k)
    elif op == Op.MULH:
        for x, y in ((0, 1), (1, 0)):
            k = _pow2(c(y))
            if k is not None and 1 <= k <= 15:
                return ("rewrite", Op.SRL, (srcs[x],), 16 - k)
    elif op == Op.AND:
        if a == b:
            return ("subst", a)
        if mb[0] & mb[1] == 0:
            return ("const", 0)
        for x, y in ((0, 1), (1, 0)):
            cy = c(y)
            if cy is not None and mb[x] & ~cy == 0:
                return ("subst", srcs[x])
    elif op == Op.OR:
        if a == b or mb[1] == 0:
            return ("subst", a)
        if mb[0] == 0:
            return ("subst", b)
        for x, y in ((0, 1), (1, 0)):
            cy = c(y)
            if cy is not None and mb[x] & ~cy == 0:
                return ("const", cy)
    elif op == Op.XOR:
        if a == b:
            return ("const", 0)
        if mb[1] == 0:
            return ("subst", a)
        if mb[0] == 0:
            return ("subst", b)
        for x, y in ((0, 1), (1, 0)):
            if c(y) == M:
                return ("rewrite", Op.NOT, (srcs[x],), 0)
    elif op == Op.MUX:
        sel = c(0)
        if sel is not None:
            return ("subst", srcs[1] if sel else srcs[2])
        if mb[0] == 0:
            return ("subst", srcs[2])
        if srcs[1] == srcs[2]:
            return ("subst", srcs[1])
    elif op == Op.SEQ:
        if a == b:
            return ("const", 1)
    elif op in (Op.SNE, Op.SLTU):
        if a == b:
            return ("const", 0)
        if op == Op.SLTU and mb[1] == 0:
            return ("const", 0)
    elif op in (Op.SLL, Op.SRL, Op.SRA):
        if imm & 15 == 0:
            return ("subst", a)
        if op == Op.SRA and mb[0] & _SIGN == 0:
            return ("rewrite", Op.SRL, srcs, imm)
    elif op in (Op.SLLV, Op.SRLV):
        amt = c(1)
        if amt is not None:
            return ("rewrite", Op.SLL if op == Op.SLLV else Op.SRL,
                    (a,), amt & 15)
        if mb[1] == 0:
            return ("subst", a)
    elif op == Op.SLICE:
        off, width = imm >> 5, imm & 31
        if off == 0 and mb[0] & ~((1 << width) - 1) == 0:
            return ("subst", a)
    return None


def strength_reduce(low: Lowered) -> int:
    """Known-bits-driven identities, strength reduction (x*2^k -> shifts,
    carry/borrow chains with provably-zero inputs), dead predicated stores
    and always-true EXPECTs."""
    protected = low.protected_vregs()
    pool = _ConstPool(low)
    const_of = dict(low.const_vregs)
    maybe = _init_masks(low)
    subst: Dict[int, int] = {}
    out: List[Instr] = []
    changed = 0

    def emit_const(w: int, val: int, cur_op: Op,
                   cur_srcs: Tuple[int, ...]) -> None:
        nonlocal changed
        cv = pool.vreg(val)
        const_of[w] = val
        maybe[cv] = val
        if w in protected:
            maybe[w] = val
            out.append(Instr(Op.MOV, w, (cv,)))
            if not (cur_op == Op.MOV and cur_srcs == (cv,)):
                changed += 1       # already canonical: not a change
        else:
            subst[w] = cv
            changed += 1

    for ins in low.instrs:
        srcs = _resolve(subst, ins.srcs)
        op, imm = ins.op, ins.imm
        w = ins.writes()
        # predicated sinks with provably-false predicates are dead; an
        # EXPECT comparing a value with itself can never raise
        if op in (Op.ST, Op.GST):
            en = srcs[2] if op == Op.ST else srcs[3]
            if maybe.get(en, M) == 0:
                changed += 1
                continue
        if op == Op.EXPECT and srcs[0] == srcs[1]:
            changed += 1
            continue
        if op in PURE_OPS and w is not None and w != 0:
            rewritten = False
            for _ in range(4):  # a rewrite may expose another identity
                mb = [maybe.get(s, M) for s in srcs] + [0] * (4 - len(srcs))
                act = _simplify(op, srcs, imm, const_of, mb)
                if act is None:
                    break
                if act[0] == "subst":
                    v = act[1]
                    if w in protected:
                        maybe[w] = maybe.get(v, M)
                        if v in const_of:
                            const_of[w] = const_of[v]
                        out.append(Instr(Op.MOV, w, (v,)))
                        if not (op == Op.MOV and srcs == (v,)):
                            changed += 1
                    else:
                        subst[w] = v
                        changed += 1
                    break
                if act[0] == "const":
                    emit_const(w, act[1], op, srcs)
                    break
                _, op, srcs, imm = act
                rewritten = True
            else:
                act = None
            if act is not None:
                continue
            mask = maybe_mask(op, [maybe.get(s, M) for s in srcs], imm)
            if mask == 0:
                emit_const(w, 0, op, srcs)
                continue
            maybe[w] = mask
            if rewritten or srcs != ins.srcs:
                if rewritten:
                    changed += 1
                ins = Instr(op, ins.dst, srcs, imm, mem=ins.mem)
            out.append(ins)
            continue
        if w is not None:
            maybe[w] = maybe_mask(op, [maybe.get(s, M) for s in srcs], imm)
        if srcs != ins.srcs:
            ins = Instr(op, ins.dst, srcs, imm, mem=ins.mem)
        out.append(ins)
    low.replace_instrs(out)
    return changed


def cse(low: Lowered) -> int:
    """Global value numbering: identical pure ops (and loads — full-cycle
    semantics order every load before any store of its memory) collapse to
    one definition. Commutative operands are canonicalized."""
    protected = low.protected_vregs()
    subst: Dict[int, int] = {}
    table: Dict[Tuple, int] = {}
    out: List[Instr] = []
    changed = 0
    for ins in low.instrs:
        srcs = _resolve(subst, ins.srcs)
        w = ins.writes()
        key = None
        # MOVs are excluded: numbering a copy saves no instruction (copies
        # are either protected or already gone via copy_prop), would couple
        # otherwise-independent cones, and oscillates against const_fold
        # (MOV w,const <-> MOV w,canon) defeating fixpoint detection.
        if w is not None and w != 0 and ins.op != Op.MOV and \
                (ins.op in PURE_OPS or ins.op in MEM_READ_OPS):
            k_srcs = srcs
            if ins.op in COMMUTATIVE_OPS:
                k_srcs = tuple(sorted(srcs[:2])) + srcs[2:]
            key = (ins.op, k_srcs, ins.imm, ins.mem)
            canon = table.get(key)
            if canon is not None:
                if w in protected:
                    out.append(Instr(Op.MOV, w, (canon,)))
                else:
                    subst[w] = canon
                changed += 1
                continue
            table[key] = w
        if srcs != ins.srcs:
            ins = Instr(ins.op, ins.dst, srcs, ins.imm, mem=ins.mem)
        out.append(ins)
    low.replace_instrs(out)
    return changed


def dce(low: Lowered) -> int:
    """Dead-code elimination from the sink set: stores, EXPECTs and the
    protected (next-register / output) definitions stay live; everything
    not reachable backwards from them goes."""
    protected = low.protected_vregs()
    defs = def_index(low.instrs)
    live: set = set()
    stack: List[int] = []
    for i, ins in enumerate(low.instrs):
        w = ins.writes()
        if ins.op in SIDE_EFFECT_OPS or (w is not None and w in protected):
            stack.append(i)
    while stack:
        i = stack.pop()
        if i in live:
            continue
        live.add(i)
        for s in low.instrs[i].srcs:
            d = defs.get(s)
            if d is not None and d not in live:
                stack.append(d)
    removed = len(low.instrs) - len(live)
    if removed:
        low.replace_instrs([ins for i, ins in enumerate(low.instrs)
                            if i in live])
    return removed


# ----------------------------------------------------------------------
# pass manager
# ----------------------------------------------------------------------

# one round of the pipeline; repeated to fixpoint by optimize_lowered
PIPELINE: List[Tuple[str, Callable[[Lowered], int]]] = [
    ("fold", const_fold),
    ("copyprop", copy_prop),
    ("strength", strength_reduce),
    ("copyprop", copy_prop),
    ("cse", cse),
    ("dce", dce),
]

MAX_ROUNDS = 8


def optimize_lowered(low: Lowered,
                     pipeline: Optional[List[Tuple[str, Callable]]] = None,
                     max_rounds: int = MAX_ROUNDS,
                     check: bool = True) -> Tuple[Lowered, List[Dict]]:
    """Run the pass pipeline to fixpoint. Returns ``(low, records)`` where
    ``records`` lists per-pass instruction deltas and wall times (surfaced
    as ``Program.stats["opt_passes"]``)."""
    pipeline = PIPELINE if pipeline is None else pipeline
    records: List[Dict] = []
    if check:
        low.check()
    for rnd in range(max_rounds):
        round_changes = 0
        for name, fn in pipeline:
            before = len(low.instrs)
            t0 = time.perf_counter()
            ch = fn(low)
            records.append({
                "pass": name, "round": rnd, "changed": ch,
                "instrs_before": before, "instrs_after": len(low.instrs),
                "seconds": time.perf_counter() - t0,
            })
            round_changes += ch
        if not round_changes:
            break
    low.compact()
    if check:
        low.check()
    return low, records
