"""Netlist IR + circuit-builder DSL.

The paper's frontend is Yosys (Verilog -> netlist assembly). Rebuilding Yosys
is out of scope; this module provides the equivalent *netlist IR* plus an
embedded-Python builder DSL so the 9 evaluation benchmarks can be expressed
directly (see ``repro.circuits``). Semantics are single-clock, full-cycle,
cycle-accurate (paper §2):

  * a cycle evaluates the combinational DAG from *current* register / memory
    state, producing *next* register values, memory writes, and exceptions;
  * state commits atomically at the cycle boundary.

Signals are SSA values with a width of 1..64 bits (wider RTL values are
composed from several signals by the benchmark builders, exactly as the
paper's frontend legalizes wide Verilog vectors into 16-bit words later on).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

MAX_WIDTH = 64


class NOp(enum.Enum):
    """Netlist node kinds (word-level, arbitrary width <= 64)."""
    INPUT = "input"      # host-driven primary input (constant-latched)
    CONST = "const"
    REG = "reg"          # current value of a register
    AND = "and"
    OR = "or"
    XOR = "xor"
    NOT = "not"
    ADD = "add"
    SUB = "sub"
    MUL = "mul"
    EQ = "eq"
    NE = "ne"
    LTU = "ltu"
    SHL = "shl"          # static shift, params["amount"]
    SHR = "shr"
    SRA = "sra"
    MUX = "mux"          # args = (sel, a, b): sel ? a : b
    SLICE = "slice"      # params: off, width
    CAT = "cat"          # args = (hi, lo); width = hi.w + lo.w
    MEMRD = "memrd"      # combinational read of memory params["mem"]
    # sinks (no value):
    MEMWR = "memwr"      # args = (addr, data, en)
    EXPECT = "expect"    # args = (a, b); raise params["eid"] if a != b
    OUTPUT = "output"    # host-visible value, params["name"]

SINK_OPS = frozenset({NOp.MEMWR, NOp.EXPECT, NOp.OUTPUT})
LOGIC_NOPS = frozenset({NOp.AND, NOp.OR, NOp.XOR, NOp.NOT})


@dataclass(frozen=True)
class Sig:
    """Handle to a netlist node (SSA value)."""
    nid: int
    width: int
    circuit: "Circuit" = field(repr=False, compare=False, hash=False)

    # -- operator sugar -------------------------------------------------
    def _lift(self, other) -> "Sig":
        if isinstance(other, Sig):
            return other
        return self.circuit.const(int(other), self.width)

    def __and__(self, o): return self.circuit._bin(NOp.AND, self, self._lift(o))
    def __or__(self, o):  return self.circuit._bin(NOp.OR, self, self._lift(o))
    def __xor__(self, o): return self.circuit._bin(NOp.XOR, self, self._lift(o))
    def __invert__(self): return self.circuit._node(NOp.NOT, [self], self.width)
    def __add__(self, o): return self.circuit._bin(NOp.ADD, self, self._lift(o))
    def __sub__(self, o): return self.circuit._bin(NOp.SUB, self, self._lift(o))
    def __mul__(self, o): return self.circuit._bin(NOp.MUL, self, self._lift(o))
    def __lshift__(self, k: int):
        return self.circuit._node(NOp.SHL, [self], self.width, amount=int(k))
    def __rshift__(self, k: int):
        return self.circuit._node(NOp.SHR, [self], self.width, amount=int(k))

    def eq(self, o):  return self.circuit._cmp(NOp.EQ, self, self._lift(o))
    def ne(self, o):  return self.circuit._cmp(NOp.NE, self, self._lift(o))
    def ltu(self, o): return self.circuit._cmp(NOp.LTU, self, self._lift(o))
    def geu(self, o): return ~self.ltu(o)

    def __getitem__(self, sl) -> "Sig":
        """Bit slicing: s[3], s[7:4] (verilog-style msb:lsb inclusive)."""
        if isinstance(sl, int):
            off, width = sl, 1
        else:
            msb = sl.start if sl.start is not None else self.width - 1
            lsb = sl.stop if sl.stop is not None else 0
            off, width = lsb, msb - lsb + 1
        assert 0 <= off and off + width <= self.width, (off, width, self.width)
        return self.circuit._node(NOp.SLICE, [self], width, off=off, w=width)

    def cat(self, lo: "Sig") -> "Sig":
        """{self, lo} — self becomes the high bits."""
        return self.circuit._node(NOp.CAT, [self, lo], self.width + lo.width)

    def zext(self, width: int) -> "Sig":
        if width == self.width:
            return self
        assert width > self.width
        return self.circuit.const(0, width - self.width).cat(self)

    def sext(self, width: int) -> "Sig":
        if width == self.width:
            return self
        sign = self[self.width - 1]
        ext = self.circuit.mux(sign,
                               self.circuit.const((1 << (width - self.width)) - 1,
                                                  width - self.width),
                               self.circuit.const(0, width - self.width))
        return ext.cat(self)

    def trunc(self, width: int) -> "Sig":
        return self if width == self.width else self[width - 1:0]


@dataclass
class Node:
    nid: int
    op: NOp
    args: Tuple[int, ...]
    width: int
    params: Dict

@dataclass
class Memory:
    name: str
    depth: int
    width: int
    init: List[int]
    is_global: bool = False   # does not fit scratchpads -> privileged GLD/GST


class Circuit:
    """Builder + container for a single-clock netlist."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: List[Node] = []
        self.mems: Dict[str, Memory] = {}
        self.reg_next: Dict[int, int] = {}     # reg nid -> next-value nid
        self.reg_init: Dict[int, int] = {}     # reg nid -> reset value
        self.reg_names: Dict[int, str] = {}
        self.input_values: Dict[int, int] = {}  # INPUT nid -> latched value
        self._const_cache: Dict[Tuple[int, int], int] = {}

    # ---- node construction --------------------------------------------
    def _node(self, op: NOp, args: Sequence[Sig], width: int, **params) -> Sig:
        assert 1 <= width <= MAX_WIDTH, width
        nid = len(self.nodes)
        self.nodes.append(Node(nid, op, tuple(a.nid for a in args), width,
                               params))
        return Sig(nid, width, self)

    def _bin(self, op: NOp, a: Sig, b: Sig) -> Sig:
        assert a.width == b.width, (op, a.width, b.width)
        return self._node(op, [a, b], a.width)

    def _cmp(self, op: NOp, a: Sig, b: Sig) -> Sig:
        assert a.width == b.width, (op, a.width, b.width)
        return self._node(op, [a, b], 1)

    def input(self, name: str, width: int, value: int = 0) -> Sig:
        s = self._node(NOp.INPUT, [], width, name=name)
        self.input_values[s.nid] = value & ((1 << width) - 1)
        return s

    def const(self, value: int, width: int) -> Sig:
        value &= (1 << width) - 1
        key = (value, width)
        if key not in self._const_cache:
            s = self._node(NOp.CONST, [], width, value=value)
            self._const_cache[key] = s.nid
        return Sig(self._const_cache[key], width, self)

    def reg(self, width: int, init: int = 0, name: Optional[str] = None) -> Sig:
        s = self._node(NOp.REG, [], width)
        self.reg_init[s.nid] = init & ((1 << width) - 1)
        if name:
            self.reg_names[s.nid] = name
        return s

    def set_next(self, r: Sig, nxt: Sig) -> None:
        assert self.nodes[r.nid].op == NOp.REG
        assert r.width == nxt.width, (r.width, nxt.width)
        assert r.nid not in self.reg_next, "register already driven"
        self.reg_next[r.nid] = nxt.nid

    def mux(self, sel: Sig, a: Sig, b: Sig) -> Sig:
        """sel ? a : b"""
        assert sel.width == 1 and a.width == b.width
        return self._node(NOp.MUX, [sel, a, b], a.width)

    # ---- memories ------------------------------------------------------
    def mem(self, name: str, depth: int, width: int,
            init: Optional[Sequence[int]] = None,
            is_global: bool = False) -> Memory:
        assert name not in self.mems
        vals = list(init) if init is not None else [0] * depth
        assert len(vals) == depth
        m = Memory(name, depth, width, [v & ((1 << width) - 1) for v in vals],
                   is_global=is_global)
        self.mems[name] = m
        return m

    def mem_read(self, m: Memory, addr: Sig) -> Sig:
        return self._node(NOp.MEMRD, [addr], m.width, mem=m.name)

    def mem_write(self, m: Memory, addr: Sig, data: Sig, en: Sig) -> None:
        assert data.width == m.width and en.width == 1
        self._node(NOp.MEMWR, [addr, data, en], 1, mem=m.name)

    # ---- sinks -----------------------------------------------------------
    def expect_eq(self, a: Sig, b: Sig, eid: int) -> None:
        """Raise exception ``eid`` when a != b (paper's EXPECT, §4.2)."""
        assert a.width == b.width
        self._node(NOp.EXPECT, [a, b], 1, eid=eid)

    def finish_when(self, cond: Sig, eid: int = 1) -> None:
        """$finish analogue: raise ``eid`` when cond is non-zero."""
        assert cond.width == 1
        self.expect_eq(cond, self.const(0, 1), eid)

    def output(self, name: str, sig: Sig) -> None:
        self._node(NOp.OUTPUT, [sig], sig.width, name=name)

    # ---- composite helpers used by benchmark circuits -------------------
    def shl_dyn(self, v: Sig, amt: Sig) -> Sig:
        """Dynamic left shift via a mux barrel (log2 stages of static shifts)."""
        out = v
        for k in range(amt.width):
            if (1 << k) >= v.width:
                break
            out = self.mux(amt[k], out << (1 << k), out)
        # amounts >= width zero the value
        big = self.const(0, v.width)
        hi_bits = [amt[k] for k in range(amt.width) if (1 << k) >= v.width]
        for b in hi_bits:
            out = self.mux(b, big, out)
        return out

    def shr_dyn(self, v: Sig, amt: Sig, arith: bool = False) -> Sig:
        out = v
        for k in range(amt.width):
            if (1 << k) >= v.width:
                break
            shifted = self._node(NOp.SRA if arith else NOp.SHR, [out], v.width,
                                 amount=(1 << k))
            out = self.mux(amt[k], shifted, out)
        if not arith:
            big = self.const(0, v.width)
            hi_bits = [amt[k] for k in range(amt.width) if (1 << k) >= v.width]
            for b in hi_bits:
                out = self.mux(b, big, out)
        return out

    def sra(self, v: Sig, k: int) -> Sig:
        return self._node(NOp.SRA, [v], v.width, amount=int(k))

    def lts(self, a: Sig, b: Sig) -> Sig:
        """Signed less-than via the unsigned compare with flipped sign bits."""
        bias = self.const(1 << (a.width - 1), a.width)
        return (a ^ bias).ltu(b ^ bias)

    def reduce_or(self, s: Sig) -> Sig:
        return s.ne(self.const(0, s.width))

    def onehot_mux(self, sel: Sig, options: Sequence[Sig]) -> Sig:
        """options[sel] as a mux tree (sel is an index)."""
        opts = list(options)
        assert opts, "empty mux"
        k = 0
        while len(opts) > 1:
            nxt = []
            for i in range(0, len(opts) - 1, 2):
                nxt.append(self.mux(sel[k], opts[i + 1], opts[i]))
            if len(opts) % 2 == 1:
                nxt.append(opts[-1])
            opts = nxt
            k += 1
        return opts[0]

    # ---- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        per_op: Dict[str, int] = {}
        for n in self.nodes:
            per_op[n.op.value] = per_op.get(n.op.value, 0) + 1
        return {
            "nodes": len(self.nodes),
            "regs": len(self.reg_init),
            "mems": len(self.mems),
            "mem_bits": sum(m.depth * m.width for m in self.mems.values()),
            **{f"op_{k}": v for k, v in sorted(per_op.items())},
        }

    def validate(self) -> None:
        for rid in self.reg_init:
            assert rid in self.reg_next, \
                f"register {self.reg_names.get(rid, rid)} has no next value"
        for n in self.nodes:
            if n.op == NOp.MEMRD or n.op == NOp.MEMWR:
                assert n.params["mem"] in self.mems

    def fingerprint(self) -> str:
        """Structural SHA-256 of the netlist — the identity the
        ``repro.sim`` compile cache keys on. Covers everything that can
        change simulation semantics or the compiled binary: every node
        (op, args, width, params), every memory (shape + init image +
        placement class), the register init/next/name maps and the latched
        input values. Two independent builds of the same design hash
        equal; any semantic difference does not.
        """
        import hashlib
        h = hashlib.sha256()

        def feed(*parts) -> None:
            for p in parts:
                h.update(repr(p).encode("utf-8"))
                h.update(b"\x00")

        feed("circuit", self.name, len(self.nodes))
        for n in self.nodes:
            feed(n.nid, n.op.value, n.args, n.width,
                 sorted(n.params.items()))
        for name in sorted(self.mems):
            m = self.mems[name]
            feed("mem", name, m.depth, m.width, tuple(m.init), m.is_global)
        feed("reg_next", sorted(self.reg_next.items()))
        feed("reg_init", sorted(self.reg_init.items()))
        feed("reg_names", sorted(self.reg_names.items()))
        feed("inputs", sorted(self.input_values.items()))
        return h.hexdigest()
