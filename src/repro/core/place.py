"""Communication-aware process placement: chatty processes on adjacent cores.

The partitioner assigns processes to grid cores in identity order, so every
SEND route is an accident of construction order. On the uni-directional 2D
torus that is expensive twice over: long dimension-ordered routes occupy
more link slots (more collision retries for the earliest-slot reservation
in ``core.schedule``), and messages arrive later (``t_compute`` stretches to
cover the last arrival). This pass runs between
:func:`~repro.core.partition.partition` and
:func:`~repro.core.remat.rematerialize` and chooses *which* core each
process occupies:

  * **traffic graph** — for every surviving :class:`SendEdge`, one directed
    (src_proc, dst_proc) edge weighted by the sender value's criticality:
    ``1 + (1 - slack/horizon)`` where slack is the ALAP−ASAP mobility of the
    value's defining instruction inside its process DAG. A message on its
    producer's critical path counts double; a fully slack one counts once.
  * **region** — processes are packed into a near-square block of the grid
    (``ceil(sqrt(n))`` wide) instead of identity's row-major prefix: a
    square block has a smaller forward diameter and spreads traffic over
    both link dimensions. Identity placement stays available (and frozen)
    as ``"identity"``.
  * **seeding** — greedy recursive bisection: split the region along its
    longer axis, split the processes to match capacity by greedily growing
    the half with the strongest internal traffic, recurse.
  * **refinement** — simulated annealing under a fixed move budget with
    swap and relocate moves, geometric cooling, incremental (incident-edge)
    cost deltas, and a deterministic seed so compiles are reproducible and
    cacheable. The best placement ever seen is returned, and identity is
    kept instead when it scores better in the weighted-hop objective.

The objective is slack-weighted hop count — a proxy for the scheduler's
real figure of merit (VCPL). ``compile_circuit`` therefore schedules *both*
the annealed and the identity geometry and ships whichever lands the lower
VCPL (``stats["place_pick"]``): placement can only ever improve the
schedule, never regress it.
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .isa import HardwareConfig
from .lower import Lowered
from .partition import Partition, SendEdge

PLACEMENTS = ("identity", "anneal")
DEFAULT_SEED = 0
# SA move budget: scales with process count, bounded so full-grid circuits
# stay well under the scheduler's own wall time share
MOVES_PER_PROC = 220
MAX_MOVES = 45000


@dataclass
class Placement:
    """A core assignment plus the pass's accounting.

    ``stats`` carries ``total_hops`` / ``weighted_hops`` for the chosen
    mapping, the identity baseline (``identity_hops`` /
    ``identity_weighted_hops``), and the SA accounting
    (``place_moves`` attempted, ``place_accepted``, ``place_seconds``).
    """
    core_of_proc: List[int]
    stats: Dict[str, float] = field(default_factory=dict)


# ----------------------------------------------------------------------
# traffic graph
# ----------------------------------------------------------------------

def traffic_graph(low: Lowered, part: Partition,
                  hw: HardwareConfig) -> Dict[Tuple[int, int], float]:
    """Slack-weighted inter-process traffic: (src, dst) -> weight.

    Each :class:`SendEdge` contributes ``1 + crit`` where ``crit`` is how
    critical the sent value's defining instruction is inside its producer
    process (1 on the critical path, 0 at maximal slack) — so the annealer
    shortens the routes whose flight time the schedule cannot hide.
    """
    L = hw.raw_latency
    defs = low.defs()

    # per-process ALAP - ASAP slack of every member instruction
    slack: List[Dict[int, int]] = []
    horizon = 1
    for p in part.procs:
        idx = {i: k for k, i in enumerate(p)}   # sorted == topo order
        n = len(p)
        asap = [0] * n
        succs: List[List[int]] = [[] for _ in range(n)]
        for k, i in enumerate(p):
            for s in low.instrs[i].reads():
                d = defs.get(s)
                if d is None:
                    continue
                kd = idx.get(d)
                if kd is not None and kd < k:
                    succs[kd].append(k)
                    if asap[kd] + L > asap[k]:
                        asap[k] = asap[kd] + L
        height = [1] * n
        for k in range(n - 1, -1, -1):
            for j in succs[k]:
                if height[j] + L > height[k]:
                    height[k] = height[j] + L
        T = max((asap[k] + height[k] for k in range(n)), default=1)
        horizon = max(horizon, T)
        slack.append({i: (T - height[k]) - asap[k] for k, i in enumerate(p)})

    g: Dict[Tuple[int, int], float] = {}
    for e in part.sends:
        d = defs.get(e.nxt_vreg)
        sl = slack[e.src_proc].get(d, horizon)
        crit = 1.0 - min(sl, horizon) / horizon
        k = (e.src_proc, e.dst_proc)
        g[k] = g.get(k, 0.0) + 1.0 + crit
    return g


# ----------------------------------------------------------------------
# cost helpers (shared with compile stats / benchmarks / tests)
# ----------------------------------------------------------------------

def hop_cost(core_of_proc: Sequence[int], sends: Sequence[SendEdge],
             hw: HardwareConfig) -> int:
    """Unweighted total hop count of ``sends`` under a placement."""
    return sum(hw.route_hops(core_of_proc[e.src_proc],
                             core_of_proc[e.dst_proc]) for e in sends)


def weighted_cost(core_of_proc: Sequence[int],
                  traffic: Dict[Tuple[int, int], float],
                  hw: HardwareConfig) -> float:
    """Slack-weighted hop count of a traffic graph under a placement."""
    return sum(w * hw.route_hops(core_of_proc[a], core_of_proc[b])
               for (a, b), w in traffic.items())


# ----------------------------------------------------------------------
# region + bisection seed
# ----------------------------------------------------------------------

def _region_cells(hw: HardwareConfig, n: int) -> List[int]:
    """A near-square block of core ids holding at least ``n`` cells.

    Identity fills row-major core ids 0..n-1 — a 15-wide strip whose
    forward x-diameter is the whole grid. A ``ceil(sqrt(n))``-wide block
    halves the typical forward distance and gives every process +x *and*
    +y neighbours to trade traffic over.
    """
    w = min(hw.grid_width, max(1, math.ceil(math.sqrt(n))))
    h = min(hw.grid_height, math.ceil(n / w))
    if w * h < n:                      # height capped: widen instead
        w = min(hw.grid_width, math.ceil(n / h))
    assert w * h >= n, (n, w, h)
    return [hw.xy_core(x, y) for y in range(h) for x in range(w)]


def _bisect_seed(procs: Sequence[int], cells: List[int], hw: HardwareConfig,
                 sym: Dict[int, Dict[int, float]]) -> Dict[int, int]:
    """Greedy recursive bisection: strongest-coupled processes end up in
    the same half of the region. Deterministic (ties break on proc id)."""
    out: Dict[int, int] = {}

    def rec(ps: List[int], cs: List[int]) -> None:
        if len(ps) <= 2 or len(cs) <= 3:
            for p, c in zip(ps, cs):
                out[p] = c
            return
        xs = [hw.core_xy(c)[0] for c in cs]
        ys = [hw.core_xy(c)[1] for c in cs]
        if max(xs) - min(xs) >= max(ys) - min(ys):
            cs_sorted = sorted(cs, key=lambda c: hw.core_xy(c))
        else:
            cs_sorted = sorted(cs, key=lambda c: hw.core_xy(c)[::-1])
        half = (len(cs_sorted) + 1) // 2
        cs_a, cs_b = cs_sorted[:half], cs_sorted[half:]
        lo = max(0, len(ps) - len(cs_b))
        hi = min(len(ps), len(cs_a))
        target = max(lo, min(hi, (len(ps) * len(cs_a)
                                  + len(cs) // 2) // len(cs)))
        rest = set(ps)
        in_rest = {p: sum(w for q, w in sym.get(p, {}).items()
                          if q in rest) for p in ps}
        conn = {p: 0.0 for p in ps}
        a: List[int] = []
        while len(a) < target:
            if a:
                # gain = attraction to A minus attraction to what remains
                p = max(rest, key=lambda p: (2 * conn[p] - in_rest[p], -p))
            else:
                p = max(rest, key=lambda p: (in_rest[p], -p))
            a.append(p)
            rest.remove(p)
            for q, w in sym.get(p, {}).items():
                if q in rest:
                    conn[q] += w
        rec(sorted(a), cs_a)
        rec(sorted(rest), cs_b)

    rec(sorted(procs), cells)
    return out


# ----------------------------------------------------------------------
# simulated annealing
# ----------------------------------------------------------------------

def _anneal(pos: Dict[int, int], cells: List[int],
            traffic: Dict[Tuple[int, int], float], hw: HardwareConfig,
            seed: int, moves: int) -> Tuple[Dict[int, int], Dict[str, float]]:
    W, H = hw.grid_width, hw.grid_height
    ncores = hw.num_cores
    X = [c % W for c in range(ncores)]
    Y = [c // W for c in range(ncores)]

    def hop(a: int, b: int) -> int:
        return (X[b] - X[a]) % W + (Y[b] - Y[a]) % H

    # per-pair directed weights, indexed from both endpoints
    pairs: Dict[Tuple[int, int], List[float]] = {}
    for (a, b), w in traffic.items():
        key, fwd = ((a, b), 0) if a < b else ((b, a), 1)
        pairs.setdefault(key, [0.0, 0.0])[fwd] += w
    und: Dict[int, List[Tuple[int, float, float]]] = {p: [] for p in pos}
    for (a, b), (wab, wba) in sorted(pairs.items()):
        und[a].append((b, wab, wba))      # (other, w out, w in)
        und[b].append((a, wba, wab))

    def local(s: frozenset) -> float:
        t = 0.0
        for p in s:
            pc = pos[p]
            for (q, wo, wi) in und[p]:
                if q in s and q < p:      # internal pair counted once
                    continue
                qc = pos[q]
                t += wo * hop(pc, qc) + wi * hop(qc, pc)
        return t

    def total() -> float:
        return sum(wab * hop(pos[a], pos[b]) + wba * hop(pos[b], pos[a])
                   for (a, b), (wab, wba) in pairs.items())

    procs = sorted(pos)
    occ = {c: p for p, c in pos.items()}
    free = [c for c in cells if c not in occ]
    rnd = random.Random(seed)
    cur = total()
    best = cur
    best_pos = dict(pos)
    t0 = 0.25 * (W + H)
    t_end = 0.05
    accepted = 0
    for m in range(moves):
        temp = t0 * (t_end / t0) ** (m / max(moves - 1, 1))
        p = procs[rnd.randrange(len(procs))]
        if free and rnd.random() < 0.3:           # relocate to a free cell
            j = rnd.randrange(len(free))
            c_new, c_old = free[j], pos[p]
            s = frozenset((p,))
            old = local(s)
            pos[p] = c_new
            d = local(s) - old
            if d <= 0 or rnd.random() < math.exp(-d / temp):
                del occ[c_old]
                occ[c_new] = p
                free[j] = c_old
                cur += d
                accepted += 1
            else:
                pos[p] = c_old
        else:                                     # swap two occupants
            q = procs[rnd.randrange(len(procs))]
            if q == p:
                continue
            s = frozenset((p, q))
            old = local(s)
            pos[p], pos[q] = pos[q], pos[p]
            d = local(s) - old
            if d <= 0 or rnd.random() < math.exp(-d / temp):
                occ[pos[p]], occ[pos[q]] = p, q
                cur += d
                accepted += 1
            else:
                pos[p], pos[q] = pos[q], pos[p]
        if cur < best:
            best = cur
            best_pos = dict(pos)
    return best_pos, {"place_moves": float(moves),
                      "place_accepted": float(accepted)}


# ----------------------------------------------------------------------
# entry point
# ----------------------------------------------------------------------

def place(low: Lowered, part: Partition, hw: HardwareConfig,
          strategy: str = "anneal", seed: int = DEFAULT_SEED,
          moves: Optional[int] = None) -> Placement:
    """Map processes onto grid cores.

    ``"identity"`` is the frozen default-for-CI mapping (process p on core
    p, bit-identical to the pre-placement compiler). ``"anneal"`` builds
    the slack-weighted traffic graph, seeds with recursive bisection over
    a near-square region and refines with simulated annealing; when the
    result does not beat identity in the weighted objective, identity is
    returned (the scheduler-level best-of-two in ``compile_circuit`` is
    the final arbiter either way).
    """
    if strategy not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {strategy!r}; choose from {PLACEMENTS}")
    n = part.num_procs
    ident = list(range(n))
    t0 = time.perf_counter()
    if strategy == "identity" or n <= 1 or not part.sends:
        hops = hop_cost(ident, part.sends, hw)
        return Placement(ident, {
            "total_hops": float(hops), "weighted_hops": 0.0,
            "identity_hops": float(hops), "identity_weighted_hops": 0.0,
            "place_moves": 0.0, "place_accepted": 0.0,
            "place_seconds": round(time.perf_counter() - t0, 6)})

    traffic = traffic_graph(low, part, hw)
    sym: Dict[int, Dict[int, float]] = {}
    for (a, b), w in traffic.items():
        sym.setdefault(a, {})[b] = sym.setdefault(a, {}).get(b, 0.0) + w
        sym.setdefault(b, {})[a] = sym.setdefault(b, {}).get(a, 0.0) + w

    cells = _region_cells(hw, n)
    pos = _bisect_seed(range(n), cells, hw, sym)
    if moves is None:
        moves = min(MAX_MOVES, max(4000, MOVES_PER_PROC * n))
    pos, sa = _anneal(pos, cells, traffic, hw, seed, moves)

    cop = [pos[p] for p in range(n)]
    w_ident = weighted_cost(ident, traffic, hw)
    w_final = weighted_cost(cop, traffic, hw)
    if w_ident <= w_final:      # objective says identity is no worse: keep it
        cop, w_final = ident, w_ident
    stats = {
        "total_hops": float(hop_cost(cop, part.sends, hw)),
        "weighted_hops": round(w_final, 3),
        "identity_hops": float(hop_cost(ident, part.sends, hw)),
        "identity_weighted_hops": round(w_ident, 3),
        "place_seconds": round(time.perf_counter() - t0, 6),
        **sa,
    }
    return Placement(cop, stats)
