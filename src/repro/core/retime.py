"""Boundary retiming: choose the work that crosses the Vcycle commit.

Cross-Vcycle software pipelining (``core.schedule.pipeline_schedule``)
overlaps consecutive Vcycles: slots of cycle k+1 that depend only on state
already committed by cycle k issue during cycle k's epilogue / idle tail.
This pass picks *which* instructions those are — the **hoist set** H, one
set per process. A hoisted instruction is executed in the schedule's
prologue region (slots ``[0, P)``) and, by the rotated engine convention,
realized at the *end* of the previous engine Vcycle, gated on "no exception
raised" — which is exactly retiming a pure op backwards across the
register-commit boundary.

Legality (per process ``p``, instruction ``i``):

  * **pure** — ``op in PURE_OPS | {LUT}`` with a register result. No
    memory traffic (a prologue never touches scratchpads), no SEND, no
    privileged op: the hoisted value lives only in its destination
    register, so withholding the whole prologue on an exception is a
    single register-plane select in every engine.
  * **not a commit** — the destination must not be architectural state:
    not a register-share commit (those write the current register
    directly), not a commit-MOV, and not a host-visible output vreg (a
    hoisted output would be one cycle ahead of the netlist oracle).
  * **committed-state sources only** — every source is either (a) defined
    by another hoisted instruction of ``p`` (the hoist set is
    ancestor-closed), (b) an uncommitted leaf (constant / pinned init), or
    (c) a *locally* committed current register whose baseline commit
    becomes visible by slot ``theta`` — late commits would drag the
    initiation interval right back up (the cross-iteration RAW constraint
    is ``II >= sigma - s``).  Exchange-fed registers are never eligible:
    their commit is the epilogue replay, ``sigma ~ t_compute``.

Selection is budgeted and height-ranked: instructions at the head of the
latency-weighted critical chain hoist first (their removal shortens the
body's span, which is the only way a prologue lowers II), until the
per-core budget — sized to the schedule's idle tail,
``(vcpl - crit_path_lb) + epilogue`` — is spent. Because a predecessor's
height strictly exceeds its consumer's, ranking by height admits ancestors
before dependants, keeping the set closed by construction.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from .isa import HardwareConfig, Instr, Op, PURE_OPS
from .schedule import RAW, ScheduleResult, _build_deps

HOISTABLE_OPS = frozenset(PURE_OPS | {Op.LUT})


def plan_retime(core_instrs: List[List[Instr]],
                core_of_proc: List[int],
                hw: HardwareConfig,
                base: ScheduleResult,
                share: List[Dict[int, int]],
                commit_def: List[Dict[int, int]],
                war_edges: List[List[Tuple[int, int]]],
                order_edges: List[List[Tuple[int, int]]],
                output_vregs: Set[int],
                theta: int,
                budget: int) -> List[Set[int]]:
    """Per-process hoist sets for the modulo pipeliner.

    ``base`` is the unpipelined schedule (slot positions feed the
    commit-visibility test). ``commit_def[p]`` maps each locally committed
    current-register vreg to its committing instruction index (the shared
    next-value def or the commit MOV). ``theta`` caps the baseline
    visibility slot of any current-register source (``theta < 0`` forbids
    committed-register sources entirely — the conservative arm).
    ``budget`` caps hoisted instructions per core.
    """
    L = hw.raw_latency
    nproc = len(core_instrs)
    preds, succs = _build_deps(core_instrs, war_edges, order_edges)

    # baseline slot of every instruction (placement keyed per core by id)
    placed: List[Dict[int, int]] = [{} for _ in base.cores]
    for c, cp in enumerate(base.cores):
        for s, ins in enumerate(cp.slots):
            if ins is not None:
                placed[c][id(ins)] = s

    hoist: List[Set[int]] = [set() for _ in range(nproc)]
    if budget <= 0:
        return hoist

    for p, instrs in enumerate(core_instrs):
        if not instrs:
            continue
        c = core_of_proc[p]
        slot_of = [placed[c].get(id(ins), 0) for ins in instrs]

        # vregs whose write is a commit: shared next-value defs, commit-MOV
        # destinations, and exchange-fed current registers of *other* procs
        # (the SEND payload def itself stays hoistable — the SEND reads it
        # from the body under the prologue->body RAW constraint).
        commit_dsts: Set[int] = set(share[p])            # nxt of shared
        for cur, di in commit_def[p].items():
            commit_dsts.add(instrs[di].dst)              # cur (MOV) or nxt

        # locally committed curs and their visibility slots; exchange-fed
        # curs (inbound SENDs) are poisoned outright.
        sigma0: Dict[int, int] = {}
        for cur, di in commit_def[p].items():
            sigma0[cur] = slot_of[di] + L
        poisoned: Set[int] = set()
        for q, qinstrs in enumerate(core_instrs):
            for ins in qinstrs:
                if ins.op == Op.SEND and ins.send_dst_proc == p:
                    poisoned.add(ins.send_dst_vreg)

        # RAW def per source, from the incremental dependence graph (a
        # current register read *before* its commit-MOV must resolve to the
        # committed leaf, not to the MOV that recommits it later)
        pred_of_src: List[Dict[int, int]] = []
        for i in range(len(instrs)):
            m: Dict[int, int] = {}
            for (j, kind) in preds[p][i]:
                if kind == RAW:
                    w = instrs[j].writes()
                    if w is not None:
                        m[w] = j
            pred_of_src.append(m)

        # forward eligibility pass (lists are topo-ordered, so every local
        # def precedes its readers and one pass reaches the fixpoint)
        eligible: List[bool] = [False] * len(instrs)
        for i, ins in enumerate(instrs):
            w = ins.writes()
            if (ins.op not in HOISTABLE_OPS or w is None or w == 0
                    or w in commit_dsts or w in output_vregs):
                continue
            # a WAR/ORDER predecessor pins the instruction into the body
            if any(k != RAW for (_, k) in preds[p][i]):
                continue
            ok = True
            for s in ins.srcs:
                if s in poisoned:
                    ok = False
                    break
                d = pred_of_src[i].get(s)
                if d is not None:
                    if not eligible[d]:
                        ok = False
                        break
                elif s in sigma0:
                    if theta < 0 or sigma0[s] > theta:
                        ok = False
                        break
                # else: uncommitted leaf (constant / pinned init) — fine
            eligible[i] = ok

        if not any(eligible):
            continue

        # latency-weighted height to the schedule exit: chain heads first
        height = [1] * len(instrs)
        for i in range(len(instrs) - 1, -1, -1):
            best = 1
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                if lat + height[j] > best:
                    best = lat + height[j]
            height[i] = best

        order = sorted((i for i in range(len(instrs)) if eligible[i]),
                       key=lambda i: (-height[i], i))
        chosen = hoist[p]
        for i in order:
            if len(chosen) >= budget:
                break
            # ancestor-closed: every locally defined source already chosen
            # (a predecessor's height strictly exceeds its consumer's, so
            # ranking by height admits ancestors first; a budget-evicted
            # ancestor simply drops its dependants here)
            if all(d in chosen for d in pred_of_src[i].values()):
                chosen.add(i)
    return hoist
