"""Static BSP executor — vectorized lockstep interpretation of a Program.

TPU adaptation of the Manticore grid (DESIGN.md §2): core *c* of the paper's
MIMD grid becomes lane *c* of ``[C]``-wide vectors. Every slot, all lanes
execute their own instruction simultaneously (compute-all-select over the
opcode — NOp lanes are masked), which is exactly the paper's lockstep
guarantee expressed as SIMD. One Vcycle is:

    lax.scan over the slot stream  ->  BSP exchange (deferred register
    updates from SENDs land at the Vcycle boundary)  ->  commit done.

The engine is **partially evaluated against the program's own static code
stream** — the paper's thesis (everything about the schedule is known at
compile time) applied to the simulator itself:

  * ``make_slot_step`` emits only the opcode branches the program actually
    contains (``Program.op_set()``): a LUT-free program never pays the
    16-pattern loop, a program with no off-chip traffic skips the cache
    model entirely;
  * the per-slot trace is gone — SEND values are scattered through the
    static ``Program.send_capture`` index table into a compact
    ``[n_sends + 1]`` buffer (last entry sacrificial), so the Vcycle
    exchange reads ``n_sends`` words instead of ``T*C``;
  * slots execute in **pipeline windows** of ``hw.raw_latency``: the
    scheduler guarantees a result is not readable for ``raw_latency``
    slots (the hardware's 4-stage exec pipeline, §5.1), so reads and ALU
    work for a whole window batch into one [W, C] tensor op — register
    writes, stores and the cache model stay slot-ordered within the
    window;
  * Vcycles run in **chunks** of K under one ``lax.scan`` with per-Vcycle
    freeze predication; the host checks exceptions once per chunk instead
    of dispatching (and recompiling for) every ``num_cycles`` value.

The privileged core's off-chip traffic (GLD/GST) is modeled with the paper's
direct-mapped cache + global-stall cost model: stalls do not change
simulation *results* (the whole machine freezes together), so the engine
executes them inline and accumulates the stall cycles performance counters
(§7.7 / Fig. 8).

``Machine(..., specialize=False)`` keeps the seed behaviour (compute-all
branches, full [T, C] trace, per-Vcycle ``while_loop``) as the baseline arm
for ``benchmarks/bench_engine.py``.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compile import Program
from .isa import Op

U32 = jnp.uint32

# Vcycles per chunked dispatch: one XLA launch simulates up to K RTL cycles;
# the host looks at the exception flags once per chunk.
DEFAULT_CHUNK = 32

# unrolling the window loop (full per-window specialization) is bounded by
# slot count to keep trace/compile time sane on very deep schedules
UNROLL_SLOTS = 4096

# opcodes with no register result (SEND's value goes to the exchange only)
_NO_WRITE_OPS = (Op.NOP, Op.ST, Op.GST, Op.EXPECT, Op.SEND)


class MachineState(NamedTuple):
    regs: jax.Array      # [C, R] uint32 (values are 16-bit)
    spads: jax.Array     # [C, S] uint32
    gmem: jax.Array      # [G] uint32
    flags: jax.Array     # [C] uint32 — first exception id per core (0 = none)
    cache_tags: jax.Array  # [LINES] int32 (-1 = invalid)
    counters: jax.Array  # [4] uint32: vcycles, ghits, gmisses, stall_cycles


def _alu_branches(ops, v1, v2, v3, v4, imm, lut_tt=None, ld_val=None,
                  gld_val=None):
    """(op, value) branch list for every result-producing opcode in ``ops``
    — the single definition of the ALU semantics, shared by the scan/window
    engines and the unrolled fast path. Operand shapes propagate ([C] or
    [W, C]); ``lut_tt`` is the pre-gathered [..., 16] truth table,
    ``ld_val``/``gld_val`` the pre-gathered memory reads (required iff
    LUT/LD/GLD is in ``ops``)."""
    branches = []

    def b(o, thunk):
        if o in ops:
            branches.append((o, thunk()))

    b(Op.MOV, lambda: v1)
    b(Op.MOVI, lambda: imm & 0xFFFF)
    b(Op.ADD, lambda: (v1 + v2) & 0xFFFF)
    b(Op.ADDC, lambda: (v1 + v2 + v3) & 0xFFFF)
    b(Op.CARRY, lambda: ((v1 + v2 + v3) >> 16) & 0xFFFF)
    b(Op.SUB, lambda: (v1 - v2) & 0xFFFF)
    b(Op.SUBB, lambda: (v1 - v2 - v3) & 0xFFFF)
    b(Op.BORROW, lambda: (v1 < v2 + v3).astype(U32))
    b(Op.MUL, lambda: (v1 * v2) & 0xFFFF)
    b(Op.MULH, lambda: ((v1 * v2) >> 16) & 0xFFFF)
    b(Op.AND, lambda: v1 & v2)
    b(Op.OR, lambda: v1 | v2)
    b(Op.XOR, lambda: v1 ^ v2)
    b(Op.NOT, lambda: (~v1) & 0xFFFF)
    b(Op.MUX, lambda: jnp.where(v1 != 0, v2, v3))
    b(Op.SEQ, lambda: (v1 == v2).astype(U32))
    b(Op.SNE, lambda: (v1 != v2).astype(U32))
    b(Op.SLTU, lambda: (v1 < v2).astype(U32))
    b(Op.SLL, lambda: (v1 << (imm & 15)) & 0xFFFF)
    b(Op.SRL, lambda: v1 >> (imm & 15))
    b(Op.SRA, lambda: ((((v1 ^ 0x8000) - 0x8000).astype(jnp.int32)
                        >> (imm & 15)).astype(U32)) & 0xFFFF)
    b(Op.SLLV, lambda: (v1 << (v2 & 15)) & 0xFFFF)
    b(Op.SRLV, lambda: v1 >> (v2 & 15))
    b(Op.SLICE, lambda: (v1 >> (imm >> 5)) & ((1 << (imm & 31)) - 1))

    if Op.LUT in ops:
        # LUT: 16-pattern compute-all-select (per-bit-lane 4-input fn);
        # pattern bit i corresponds to LUT input i (s1 -> bit 0)
        lut_out = jnp.zeros_like(v1)
        nv = [(~x) & 0xFFFF for x in (v1, v2, v3, v4)]
        for p in range(16):
            m = (v1 if p & 1 else nv[0]) & (v2 if p & 2 else nv[1]) \
                & (v3 if p & 4 else nv[2]) & (v4 if p & 8 else nv[3])
            lut_out = lut_out | (m & lut_tt[..., p])
        branches.append((Op.LUT, lut_out))
    if Op.LD in ops:
        branches.append((Op.LD, ld_val))
    if Op.GLD in ops:
        branches.append((Op.GLD, gld_val))
    b(Op.SEND, lambda: v1)
    return branches


def make_slot_step(luts, spad_words, gmem_words, cache_lines, line_words,
                   hit_stall, miss_stall,
                   op_set: Optional[FrozenSet[Op]] = None):
    """Build the per-slot executor, specialized to ``op_set``.

    The returned ``step(carry, xs)`` is a ``lax.scan`` body with
    ``carry = (regs, spads, gmem, flags, tags, counters, sbuf)`` and
    ``xs = (instr [C, 7] int32, cap [C] int32)`` where ``cap`` maps each
    lane to its compact SEND-buffer slot (or the sacrificial last slot).
    Only branches for opcodes in ``op_set`` are traced; ``op_set=None``
    emits everything (the unspecialized compute-all form).
    """
    win = make_window_step(luts, spad_words, gmem_words, cache_lines,
                           line_words, hit_stall, miss_stall,
                           op_set=op_set, window=1)

    def step(carry, xs):
        instr, cap = xs
        return win(carry, (instr[None], cap[None]))

    return step


def make_window_step(luts, spad_words, gmem_words, cache_lines, line_words,
                     hit_stall, miss_stall,
                     op_set: Optional[FrozenSet[Op]] = None,
                     window: int = 1):
    """Build the pipeline-window executor, specialized to ``op_set``.

    Executes ``window`` consecutive slots per call: all register/memory
    *reads* and the ALU run batched over a [W, C] tensor — sound because
    the scheduler spaces every RAW def->use pair by ``hw.raw_latency``
    slots (use ``window <= raw_latency``) and orders all loads of a memory
    before its stores — while register writes, stores and the cache model
    are applied slot-by-slot to preserve WAW/memory order.

    ``step(carry, xs)`` with ``carry = (regs, spads, gmem, flags, tags,
    counters, sbuf)`` and ``xs = (instr [W, C, 7], cap [W, C])``.
    """
    W = window
    ops = frozenset(Op) if op_set is None else frozenset(op_set)
    need_v3 = bool(ops & {Op.ADDC, Op.CARRY, Op.SUBB, Op.BORROW,
                          Op.MUX, Op.ST, Op.GST, Op.LUT})
    need_v4 = bool(ops & {Op.LUT, Op.GST})
    has_global = bool(ops & {Op.GLD, Op.GST})
    writes = bool(ops - set(_NO_WRITE_OPS))

    def step(carry, xs):
        regs, spads, gmem, flags, tags, counters, sbuf = carry
        instr, cap = xs
        C = regs.shape[0]
        ar = jnp.arange(C)
        col = jnp.broadcast_to(ar[None, :], (W, C))

        op = instr[..., 0]
        dst = instr[..., 1]
        imm = instr[..., 6].astype(U32)
        zero = jnp.zeros((W, C), U32)
        v1 = regs[col, instr[..., 2]]
        v2 = regs[col, instr[..., 3]]
        v3 = regs[col, instr[..., 4]] if need_v3 else zero
        v4 = regs[col, instr[..., 5]] if need_v4 else zero

        lut_tt = (luts[col, jnp.minimum(imm, luts.shape[1] - 1)]
                  if Op.LUT in ops else None)                 # [W, C, 16]
        ld_val = spads[col, v1 % spad_words] if Op.LD in ops else None
        if has_global:
            g_addr = ((v1 << 16) | v2) % gmem_words
        gld_val = gmem[g_addr] if Op.GLD in ops else None
        branches = _alu_branches(ops, v1, v2, v3, v4, imm,
                                 lut_tt, ld_val, gld_val)

        result = zero
        for code_op, val in branches:
            result = jnp.where(op == int(code_op), val, result)

        # ---- register writes (slot-ordered; never r0) ----
        if writes:
            no_write = dst == 0
            for o in _NO_WRITE_OPS:
                if o in ops:
                    no_write = no_write | (op == int(o))
            wdst = jnp.where(no_write, 0, dst)
            for w in range(W):
                wval = jnp.where(no_write[w], regs[ar, 0], result[w])
                regs = regs.at[ar, wdst[w]].set(wval)

        # ---- scratchpad stores (predicated, slot-ordered) ----
        if Op.ST in ops:
            st_mask = (op == int(Op.ST)) & (v3 != 0)
            st_addr = v1 % spad_words
            for w in range(W):
                spads = spads.at[ar, st_addr[w]].set(
                    jnp.where(st_mask[w], v2[w], spads[ar, st_addr[w]]))

        # ---- global stores + cache/stall model (privileged lanes) ----
        if has_global:
            gst_mask = (op == int(Op.GST)) & (v4 != 0)
            for w in range(W):
                if Op.GST in ops:
                    w_addr = jnp.where(gst_mask[w], g_addr[w], 0)
                    gmem = gmem.at[w_addr].set(
                        jnp.where(gst_mask[w], v3[w], gmem[w_addr]))
                g_access = (op[w] == int(Op.GLD)) | gst_mask[w]
                any_g = jnp.any(g_access)
                # model the (single) privileged access through the cache
                lane = jnp.argmax(g_access)
                line = (g_addr[w, lane] // line_words).astype(jnp.int32)
                idx = line % cache_lines
                hit = (tags[idx] == line) & any_g
                miss = (~hit) & any_g
                tags = tags.at[idx].set(jnp.where(any_g, line, tags[idx]))
                counters = counters.at[1].add(hit.astype(jnp.uint32))
                counters = counters.at[2].add(miss.astype(jnp.uint32))
                counters = counters.at[3].add(
                    jnp.where(hit, jnp.uint32(hit_stall),
                              jnp.where(miss, jnp.uint32(miss_stall),
                                        jnp.uint32(0))))

        # ---- exceptions (EXPECT raises when operands differ) ----
        if Op.EXPECT in ops:
            exc = (op == int(Op.EXPECT)) & (v1 != v2)     # [W, C]
            any_exc = exc.any(axis=0)
            first_w = jnp.argmax(exc, axis=0)             # earliest slot wins
            imm_sel = imm[first_w, ar]
            flags = jnp.where((flags == 0) & any_exc, imm_sel, flags)

        # ---- compact SEND capture (non-senders hit the sacrificial slot) --
        sbuf = sbuf.at[cap.reshape(-1)].set(
            (result & 0xFFFF).reshape(-1))
        return (regs, spads, gmem, flags, tags, counters, sbuf), None

    return step


class Machine:
    """Executable instance of a compiled Program (single host/device).

    ``specialize=True`` (default) runs the partially-evaluated fast path:
    opcode-set-specialized pipeline-window step, compact SEND capture and
    chunked K-Vcycle dispatch. ``specialize=False`` reproduces the seed
    engine (full ISA select, [T, C] trace, per-Vcycle while_loop) and
    exists so the perf trajectory can be measured against it.
    """

    def __init__(self, program: Program, backend: str = "jnp",
                 compact: bool = True, interpret: bool = True,
                 specialize: bool = True, chunk: int = DEFAULT_CHUNK):
        self.p = program
        self.backend = backend
        self.specialize = specialize
        self.chunk = max(1, int(chunk))
        hw = program.hw
        # active-core compaction: the FPGA burns idle cores for free, the
        # interpreter need not simulate them (beyond-paper optimization).
        C = program.used_cores if compact else program.code.shape[0]
        C = max(C, 1)
        self.C = C
        self.code = jnp.asarray(
            np.ascontiguousarray(program.code[:C].transpose(1, 0, 2)),
            dtype=jnp.int32)                                    # [T, C, 7]
        self.luts = jnp.asarray(program.luts[:C], dtype=U32)    # [C, 32, 16]
        self.reg0 = jnp.asarray(program.reg_init[:C], dtype=U32)
        self.spad0 = jnp.asarray(program.spad_init[:C], dtype=U32)
        self.gmem0 = jnp.asarray(program.gmem_init, dtype=U32)
        self.xchg = tuple(jnp.asarray(a) for a in (
            program.xchg_src_slot, program.xchg_src_core,
            program.xchg_dst_core, program.xchg_dst_reg))
        self.n_sends = program.n_sends
        self.cache_lines = hw.cache_words // hw.cache_line_words
        self.op_set = program.op_set() if specialize else None
        if not specialize:
            # seed engine: unspecialized compute-all step + full trace
            self._step = make_slot_step(
                self.luts, max(self.spad0.shape[1], 1),
                max(self.gmem0.shape[0], 1), self.cache_lines,
                hw.cache_line_words, hw.cache_hit_stall,
                hw.cache_miss_stall, op_set=None)

        # pipeline-windowed code stream: [T/W, W, C, 7] with W = the
        # hardware RAW latency (all-NOP padding rows; sacrificial capture).
        # Only the specialized jnp paths consume it — the pallas backend
        # builds its own padded capture table and the seed path scans the
        # raw code.
        T = self.code.shape[0]
        W = max(1, int(hw.raw_latency))
        Tp = ((T + W - 1) // W) * W
        self.W = W
        if specialize and backend != "pallas":
            code_p = np.zeros((Tp, C, 7), np.int32)
            code_p[:T] = np.asarray(self.code)
            cap_p = np.full((Tp, C), self.n_sends, np.int32)
            cap_p[:T] = program.send_capture(C)

        # static per-window metadata for the fully-unrolled fast path:
        # (instr, ops, write/store/send/expect/global sites — all constant)
        self._unrolled = (specialize and backend != "pallas"
                          and T <= UNROLL_SLOTS)
        if specialize and backend != "pallas" and not self._unrolled:
            # deep-schedule fallback: scan over specialized windows
            self.wcode = jnp.asarray(code_p.reshape(Tp // W, W, C, 7))
            self.wcap = jnp.asarray(cap_p.reshape(Tp // W, W, C))
            self._wstep = make_window_step(
                self.luts, max(self.spad0.shape[1], 1),
                max(self.gmem0.shape[0], 1), self.cache_lines,
                hw.cache_line_words, hw.cache_hit_stall,
                hw.cache_miss_stall, op_set=self.op_set, window=W)
        self._windows = []
        if self._unrolled:
            no_write_ops = {int(o) for o in _NO_WRITE_OPS}
            for iw in range(Tp // W):
                instr = code_p[iw * W:(iw + 1) * W]          # [W, C, 7]
                wcapn = cap_p[iw * W:(iw + 1) * W]           # [W, C]
                opw = instr[..., 0]
                if not opw.any():
                    continue                                 # all-NOP window
                wops = frozenset(Op(int(o)) for o in np.unique(opw) if o)
                wr_rows, st_rows, send_rows, exp_rows, glb_rows = \
                    [], [], [], [], []
                for w in range(W):
                    row = instr[w]
                    opr = row[:, 0]
                    wr = np.nonzero((row[:, 1] != 0) &
                                    ~np.isin(opr, list(no_write_ops)))[0]
                    if wr.size:
                        wr_rows.append((w, wr, row[wr, 1]))
                    st = np.nonzero(opr == int(Op.ST))[0]
                    if st.size:
                        st_rows.append((w, st))
                    sn = np.nonzero(opr == int(Op.SEND))[0]
                    if sn.size:
                        send_rows.append((w, sn, wcapn[w, sn]))
                    ex = np.nonzero(opr == int(Op.EXPECT))[0]
                    if ex.size:
                        exp_rows.append((w, ex))
                    for gop, is_gst in ((Op.GLD, False), (Op.GST, True)):
                        gl = np.nonzero(opr == int(gop))[0]
                        if gl.size:
                            glb_rows.append((w, gl, is_gst))
                # merge the window's register writes into one scatter when
                # no (core, reg) cell is written twice (WAW inside a RAW
                # window can only come from dead writes — regalloc never
                # emits them, but stay exact if it ever does)
                if len(wr_rows) > 1:
                    wss = np.concatenate([np.full(c.shape, w, np.int32)
                                          for (w, c, _) in wr_rows])
                    css = np.concatenate([c for (_, c, _) in wr_rows])
                    dss = np.concatenate([d for (_, _, d) in wr_rows])
                    cells = css.astype(np.int64) * hw.num_regs + dss
                    if np.unique(cells).size == cells.size:
                        wr_rows = [(wss, css, dss)]
                self._windows.append((instr, wops, wr_rows, st_rows,
                                      send_rows, exp_rows, glb_rows))

        if backend == "pallas":
            from ..kernels import ops as kops
            if specialize:
                self._chunk_kernel = kops.make_vcycle_chunk(
                    program, C, self.chunk, interpret=interpret)
            else:
                self._vcycle_kernel = kops.make_vcycle(
                    program, C, interpret=interpret)
        if specialize:
            if backend == "pallas":
                self._run_chunk = jax.jit(self._chunk_kernel)
            else:
                self._run_chunk = jax.jit(self._chunk_impl)
        else:
            self._run = jax.jit(self._run_legacy,
                                static_argnames=("num_cycles",))

    # ------------------------------------------------------------------
    def init_state(self) -> MachineState:
        return MachineState(
            regs=self.reg0,
            spads=self.spad0,
            gmem=self.gmem0,
            flags=jnp.zeros((self.C,), U32),
            cache_tags=-jnp.ones((self.cache_lines,), jnp.int32),
            counters=jnp.zeros((4,), jnp.uint32),
        )

    # ------------------------------------------------ specialized path ----
    def _vcycle(self, carry):
        if self._unrolled:
            return self._vcycle_unrolled(carry)
        regs, spads, gmem, flags, tags, counters = carry
        sbuf = jnp.zeros((self.n_sends + 1,), U32)
        (regs, spads, gmem, flags, tags, counters, sbuf), _ = jax.lax.scan(
            self._wstep, (regs, spads, gmem, flags, tags, counters, sbuf),
            (self.wcode, self.wcap), unroll=2)
        # ---- BSP exchange straight from the compact SEND buffer ----
        if self.n_sends:
            _, _, d_core, d_reg = self.xchg
            regs = regs.at[d_core, d_reg].set(sbuf[:self.n_sends])
        counters = counters.at[0].add(jnp.uint32(1))
        return (regs, spads, gmem, flags, tags, counters)

    def _vcycle_unrolled(self, carry):
        """Fully partially-evaluated Vcycle: the window loop is unrolled
        over the static code stream. Every window traces only the branches
        for *its own* opcodes (the per-slot usage metadata), every
        gather/scatter site (writes, stores, SENDs, EXPECTs, global ops) is
        emitted only where the schedule actually contains one — with
        constant index arrays — and all SEND values merge into a single
        exchange scatter. The XLA graph *is* the program."""
        regs, spads, gmem, flags, tags, counters = carry
        hw = self.p.hw
        S = max(self.spad0.shape[1], 1)
        G = max(self.gmem0.shape[0], 1)
        send_idx, send_parts = [], []

        for wi in self._windows:
            (instr, wops, wr_rows, st_rows, send_rows, exp_rows,
             glb_rows) = wi
            W = instr.shape[0]
            col = np.broadcast_to(np.arange(self.C)[None, :],
                                  (W, self.C))
            imm = instr[..., 6].astype(np.uint32)
            op = instr[..., 0]
            # ST/GST operands must also come from the window-start batch:
            # a WAR/ORDER edge lets another instruction overwrite a store's
            # predicate register as little as 1 slot after the store reads
            # it, and the register writes above are applied before the
            # store sites below
            need_v3 = bool(wops & {Op.ADDC, Op.CARRY, Op.SUBB, Op.BORROW,
                                   Op.MUX, Op.LUT, Op.ST, Op.GST})
            need_v4 = bool(wops & {Op.LUT, Op.GST})
            v1 = regs[col, instr[..., 2]]
            v2 = regs[col, instr[..., 3]]
            v3 = regs[col, instr[..., 4]] if need_v3 else None
            v4 = regs[col, instr[..., 5]] if need_v4 else None

            lut_tt = (self.luts[col,
                                np.minimum(imm, self.luts.shape[1] - 1)]
                      if Op.LUT in wops else None)
            ld_val = spads[col, v1 % S] if Op.LD in wops else None
            gld_val = (gmem[((v1 << 16) | v2) % G]
                       if Op.GLD in wops else None)
            branches = _alu_branches(wops, v1, v2, v3, v4, imm,
                                     lut_tt, ld_val, gld_val)

            if len(branches) == 1:
                result = branches[0][1]
            else:
                result = jnp.zeros((W, self.C), U32)
                for code_op, val in branches:
                    result = jnp.where(op == int(code_op), val, result)

            # ---- register writes: static (row, cores, dsts) sites; a
            # merged site has an array row index (one scatter per window) --
            for (w, cores, dsts) in wr_rows:
                regs = regs.at[cores, dsts].set(result[w, cores] & 0xFFFF)

            # ---- predicated scratchpad stores ----
            for (w, cores) in st_rows:
                pred = v3[w, cores] != 0
                addr = v1[w, cores] % S
                spads = spads.at[cores, addr].set(
                    jnp.where(pred, v2[w, cores], spads[cores, addr]))

            # ---- SEND capture (merged into one exchange scatter) ----
            for (w, cores, sid) in send_rows:
                send_idx.append(sid)
                send_parts.append(v1[w, cores] & 0xFFFF)

            # ---- exceptions ----
            for (w, cores) in exp_rows:
                exc = (v1[w, cores] != v2[w, cores]) & (flags[cores] == 0)
                flags = flags.at[cores].set(
                    jnp.where(exc, jnp.asarray(imm[w, cores], U32),
                              flags[cores]))

            # ---- privileged global ops + cache/stall model ----
            for (w, cores, is_gst) in glb_rows:
                g_addr = ((v1[w, cores] << 16) | v2[w, cores]) % G
                if is_gst:
                    pred = v4[w, cores] != 0
                    w_addr = jnp.where(pred, g_addr, 0)
                    gmem = gmem.at[w_addr].set(
                        jnp.where(pred, v3[w, cores], gmem[w_addr]))
                    any_g = pred[0]
                else:
                    any_g = jnp.bool_(True)
                line = (g_addr[0] // hw.cache_line_words).astype(jnp.int32)
                idx = line % self.cache_lines
                hit = (tags[idx] == line) & any_g
                miss = (~hit) & any_g
                tags = tags.at[idx].set(jnp.where(any_g, line, tags[idx]))
                counters = counters.at[1].add(hit.astype(jnp.uint32))
                counters = counters.at[2].add(miss.astype(jnp.uint32))
                counters = counters.at[3].add(
                    jnp.where(hit, jnp.uint32(hw.cache_hit_stall),
                              jnp.where(miss,
                                        jnp.uint32(hw.cache_miss_stall),
                                        jnp.uint32(0))))

        # ---- BSP exchange: one scatter from the captured SEND values ----
        if self.n_sends:
            sid = np.concatenate(send_idx)
            vals = (jnp.concatenate(send_parts) if len(send_parts) > 1
                    else send_parts[0])
            regs = regs.at[self.p.xchg_dst_core[sid],
                           self.p.xchg_dst_reg[sid]].set(vals)
        counters = counters.at[0].add(jnp.uint32(1))
        return (regs, spads, gmem, flags, tags, counters)

    def _chunk_impl(self, cyc, budget, carry):
        """K predicated Vcycles under one scan: a Vcycle whose start state
        already carries an exception (or that exceeds the budget) freezes —
        the machine stops *within* the chunk, exactly at the raising cycle."""
        def body(c, _):
            cyc, st = c
            active = (cyc < budget) & jnp.all(st[3] == 0)
            st = jax.lax.cond(active, self._vcycle, lambda s: s, st)
            return (cyc + active.astype(jnp.int32), st), None

        (cyc, carry), _ = jax.lax.scan(body, (cyc, carry), None,
                                       length=self.chunk)
        return cyc, carry

    # ------------------------------------------------ seed (baseline) ----
    def _vcycle_legacy(self, carry):
        if self.backend == "pallas":
            carry, trace = self._vcycle_kernel(carry)
        else:
            # self._step is the unspecialized (op_set=None) form here
            carry, trace = _scan_with_trace(self._step, carry, self.code)
        regs, spads, gmem, flags, tags, counters = carry
        s_slot, s_core, d_core, d_reg = self.xchg
        if s_slot.shape[0]:
            vals = trace[s_slot, s_core]
            regs = regs.at[d_core, d_reg].set(vals)
        counters = counters.at[0].add(jnp.uint32(1))
        return (regs, spads, gmem, flags, tags, counters)

    def _run_legacy(self, state: MachineState, num_cycles: int):
        def cond(c):
            cyc, st = c
            return (cyc < num_cycles) & jnp.all(st[3] == 0)

        def body(c):
            cyc, st = c
            return cyc + 1, self._vcycle_legacy(st)

        _, out = jax.lax.while_loop(cond, body, (jnp.int32(0), tuple(state)))
        return MachineState(*out)

    # ------------------------------------------------------------------
    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        """Run up to ``num_cycles`` Vcycles; freezes on the first exception
        (the host services it — paper's global stall + host handshake)."""
        if not self.specialize:
            return self._run(state, num_cycles=num_cycles)
        num_cycles = int(num_cycles)
        cyc = jnp.int32(0)
        budget = jnp.int32(num_cycles)
        carry = tuple(state)
        n_launch = -(-num_cycles // self.chunk) if num_cycles > 0 else 0
        for _ in range(n_launch):
            cyc, carry = self._run_chunk(cyc, budget, carry)
            # per-chunk exception check (the only host sync point)
            if np.asarray(carry[3]).any():
                break
        return MachineState(*carry)

    def exceptions(self, state: MachineState) -> Dict[int, int]:
        f = np.asarray(state.flags)
        return {int(c): int(e) for c, e in enumerate(f) if e}

    def read_output(self, state: MachineState, name: str) -> int:
        core, mregs = self.p.outputs[name]
        regs = np.asarray(state.regs)
        out = 0
        for j, r in enumerate(mregs):
            out |= int(regs[core, r]) << (16 * j)
        return out

    def read_reg(self, state: MachineState, rtl_name: str) -> int:
        words = self.p.state_regs[rtl_name]
        regs = np.asarray(state.regs)
        out = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            out |= int(regs[c, r]) << (16 * j)
        return out

    def perf(self, state: MachineState) -> Dict[str, int]:
        cnt = np.asarray(state.counters)
        vcycles = int(cnt[0])
        stalls = int(cnt[3])
        return {
            "vcycles": vcycles,
            "ghits": int(cnt[1]),
            "gmisses": int(cnt[2]),
            "stall_cycles": stalls,
            "machine_cycles": vcycles * self.p.vcpl + stalls,
        }


def _scan_with_trace(step, carry, code):
    """Seed-style scan: run the (compact-capture) step but also emit the
    full per-slot result trace for the legacy exchange."""
    C = code.shape[1]

    def body(sc, instr):
        # capture every lane: cap = identity into a [C+1] buffer per slot
        cap = jnp.arange(C, dtype=jnp.int32)
        regs, spads, gmem, flags, tags, counters = sc
        sbuf = jnp.zeros((C + 1,), U32)
        (regs, spads, gmem, flags, tags, counters, sbuf), _ = step(
            (regs, spads, gmem, flags, tags, counters, sbuf), (instr, cap))
        return (regs, spads, gmem, flags, tags, counters), sbuf[:C]

    return jax.lax.scan(body, carry, code)
