"""Static BSP executor — vectorized lockstep interpretation of a Program.

TPU adaptation of the Manticore grid (DESIGN.md §2): core *c* of the paper's
MIMD grid becomes lane *c* of ``[C]``-wide vectors. Every slot, all lanes
execute their own instruction simultaneously (compute-all-select over the
opcode — NOp lanes are masked), which is exactly the paper's lockstep
guarantee expressed as SIMD. One Vcycle is:

    lax.scan over the slot stream  ->  BSP exchange (deferred register
    updates from SENDs land at the Vcycle boundary)  ->  commit done.

The engine is **partially evaluated against the program's own static code
stream** — the paper's thesis (everything about the schedule is known at
compile time) applied to the simulator itself:

  * ``make_slot_step`` emits only the opcode branches the program actually
    contains (``Program.op_set()``): a LUT-free program never pays the
    16-pattern loop, a program with no off-chip traffic skips the cache
    model entirely;
  * the per-slot trace is gone — SEND values are scattered through the
    static ``Program.send_capture`` index table into a compact
    ``[n_sends + 1]`` buffer (last entry sacrificial), so the Vcycle
    exchange reads ``n_sends`` words instead of ``T*C``;
  * slots execute in **pipeline windows** of ``hw.raw_latency``: the
    scheduler guarantees a result is not readable for ``raw_latency``
    slots (the hardware's 4-stage exec pipeline, §5.1), so reads and ALU
    work for a whole window batch into one [W, C] tensor op — register
    writes, stores and the cache model stay slot-ordered within the
    window;
  * Vcycles run in **chunks** of K under one ``lax.scan`` with per-Vcycle
    freeze predication; the host checks exceptions once per chunk instead
    of dispatching (and recompiling for) every ``num_cycles`` value.

The privileged core's off-chip traffic (GLD/GST) is modeled with the paper's
direct-mapped cache + global-stall cost model: stalls do not change
simulation *results* (the whole machine freezes together), so the engine
executes them inline and accumulates the stall cycles performance counters
(§7.7 / Fig. 8).

``Machine(..., specialize=False)`` keeps the seed behaviour (compute-all
branches, full [T, C] trace, per-Vcycle ``while_loop``) as the baseline arm
for ``benchmarks/bench_engine.py``.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.compat import shard_map
from .compile import Program
from .isa import Op

U32 = jnp.uint32

# Vcycles per chunked dispatch: one XLA launch simulates up to K RTL cycles;
# the host looks at the exception flags once per chunk.
DEFAULT_CHUNK = 32

# unrolling the window loop (full per-window specialization) is bounded by
# slot count to keep trace/compile time sane on very deep schedules
UNROLL_SLOTS = 4096

# deep-schedule fallback: the window stream is segmented into runs of
# windows sharing an opcode set, one specialized lax.scan per run; the
# segment count is bounded so a wildly heterogeneous schedule cannot blow
# up trace time (short neighbouring runs merge, unioning their op sets)
MAX_SCAN_SEGMENTS = 32

# opcodes with no register result (SEND's value goes to the exchange only)
_NO_WRITE_OPS = (Op.NOP, Op.ST, Op.GST, Op.EXPECT, Op.SEND)

# per-element cycle counter value that marks a batch-padding element: it is
# >= any real budget, so the element's freeze predicate is never active —
# padding executes nothing, raises nothing, and costs nothing beyond the
# dead lanes of its shard's vectorized ops
PAD_FROZEN_CYC = np.int32(1 << 30)


def _is_stacked(images) -> bool:
    """True for the stacked ``([B, C, R], [B, C, S], [B, G])`` image form
    (``Program.init_images_batch``) as opposed to a per-stimulus list of
    ``(reg, spad, gmem)`` tuples. Shape-driven, not type-driven: a
    per-stimulus sequence holds tuples (no ``ndim``), never 3-D arrays."""
    return (len(images) == 3
            and getattr(images[0], "ndim", 0) == 3
            and getattr(images[1], "ndim", 0) == 3
            and getattr(images[2], "ndim", 0) == 2)


class MachineState(NamedTuple):
    regs: jax.Array      # [C, R] uint32 (values are 16-bit)
    spads: jax.Array     # [C, S] uint32
    gmem: jax.Array      # [G] uint32
    flags: jax.Array     # [C] uint32 — first exception id per core (0 = none)
    cache_tags: jax.Array  # [LINES] int32 (-1 = invalid)
    counters: jax.Array  # [4] uint32: vcycles, ghits, gmisses, stall_cycles


def _alu_branches(ops, v1, v2, v3, v4, imm, lut_tt=None, ld_val=None,
                  gld_val=None):
    """(op, value) branch list for every result-producing opcode in ``ops``
    — the single definition of the ALU semantics, shared by the scan/window
    engines and the unrolled fast path. Operand shapes propagate ([C] or
    [W, C]); ``lut_tt`` is the pre-gathered [..., 16] truth table,
    ``ld_val``/``gld_val`` the pre-gathered memory reads (required iff
    LUT/LD/GLD is in ``ops``)."""
    branches = []

    def b(o, thunk):
        if o in ops:
            branches.append((o, thunk()))

    b(Op.MOV, lambda: v1)
    b(Op.MOVI, lambda: imm & 0xFFFF)
    b(Op.ADD, lambda: (v1 + v2) & 0xFFFF)
    b(Op.ADDC, lambda: (v1 + v2 + v3) & 0xFFFF)
    b(Op.CARRY, lambda: ((v1 + v2 + v3) >> 16) & 0xFFFF)
    b(Op.SUB, lambda: (v1 - v2) & 0xFFFF)
    b(Op.SUBB, lambda: (v1 - v2 - v3) & 0xFFFF)
    b(Op.BORROW, lambda: (v1 < v2 + v3).astype(U32))
    b(Op.MUL, lambda: (v1 * v2) & 0xFFFF)
    b(Op.MULH, lambda: ((v1 * v2) >> 16) & 0xFFFF)
    b(Op.AND, lambda: v1 & v2)
    b(Op.OR, lambda: v1 | v2)
    b(Op.XOR, lambda: v1 ^ v2)
    b(Op.NOT, lambda: (~v1) & 0xFFFF)
    b(Op.MUX, lambda: jnp.where(v1 != 0, v2, v3))
    b(Op.SEQ, lambda: (v1 == v2).astype(U32))
    b(Op.SNE, lambda: (v1 != v2).astype(U32))
    b(Op.SLTU, lambda: (v1 < v2).astype(U32))
    b(Op.SLL, lambda: (v1 << (imm & 15)) & 0xFFFF)
    b(Op.SRL, lambda: v1 >> (imm & 15))
    b(Op.SRA, lambda: ((((v1 ^ 0x8000) - 0x8000).astype(jnp.int32)
                        >> (imm & 15)).astype(U32)) & 0xFFFF)
    b(Op.SLLV, lambda: (v1 << (v2 & 15)) & 0xFFFF)
    b(Op.SRLV, lambda: v1 >> (v2 & 15))
    b(Op.SLICE, lambda: (v1 >> (imm >> 5)) & ((1 << (imm & 31)) - 1))

    if Op.LUT in ops:
        # LUT: 16-pattern compute-all-select (per-bit-lane 4-input fn);
        # pattern bit i corresponds to LUT input i (s1 -> bit 0)
        lut_out = jnp.zeros_like(v1)
        nv = [(~x) & 0xFFFF for x in (v1, v2, v3, v4)]
        for p in range(16):
            m = (v1 if p & 1 else nv[0]) & (v2 if p & 2 else nv[1]) \
                & (v3 if p & 4 else nv[2]) & (v4 if p & 8 else nv[3])
            lut_out = lut_out | (m & lut_tt[..., p])
        branches.append((Op.LUT, lut_out))
    if Op.LD in ops:
        branches.append((Op.LD, ld_val))
    if Op.GLD in ops:
        branches.append((Op.GLD, gld_val))
    b(Op.SEND, lambda: v1)
    return branches


def make_slot_step(luts, spad_words, gmem_words, cache_lines, line_words,
                   hit_stall, miss_stall,
                   op_set: Optional[FrozenSet[Op]] = None):
    """Build the per-slot executor, specialized to ``op_set``.

    The returned ``step(carry, xs)`` is a ``lax.scan`` body with
    ``carry = (regs, spads, gmem, flags, tags, counters, sbuf)`` and
    ``xs = (instr [C, 7] int32, cap [C] int32)`` where ``cap`` maps each
    lane to its compact SEND-buffer slot (or the sacrificial last slot).
    Only branches for opcodes in ``op_set`` are traced; ``op_set=None``
    emits everything (the unspecialized compute-all form).
    """
    win = make_window_step(luts, spad_words, gmem_words, cache_lines,
                           line_words, hit_stall, miss_stall,
                           op_set=op_set, window=1)

    def step(carry, xs):
        instr, cap = xs
        return win(carry, (instr[None], cap[None]))

    return step


def make_window_step(luts, spad_words, gmem_words, cache_lines, line_words,
                     hit_stall, miss_stall,
                     op_set: Optional[FrozenSet[Op]] = None,
                     window: int = 1):
    """Build the pipeline-window executor, specialized to ``op_set``.

    Executes ``window`` consecutive slots per call: all register/memory
    *reads* and the ALU run batched over a [W, C] tensor — sound because
    the scheduler spaces every RAW def->use pair by ``hw.raw_latency``
    slots (use ``window <= raw_latency``) and orders all loads of a memory
    before its stores — while register writes, stores and the cache model
    are applied slot-by-slot to preserve WAW/memory order.

    ``step(carry, xs)`` with ``carry = (regs, spads, gmem, flags, tags,
    counters, sbuf)`` and ``xs = (instr [W, C, 7], cap [W, C])``.
    """
    W = window
    ops = frozenset(Op) if op_set is None else frozenset(op_set)
    need_v3 = bool(ops & {Op.ADDC, Op.CARRY, Op.SUBB, Op.BORROW,
                          Op.MUX, Op.ST, Op.GST, Op.LUT})
    need_v4 = bool(ops & {Op.LUT, Op.GST})
    has_global = bool(ops & {Op.GLD, Op.GST})
    writes = bool(ops - set(_NO_WRITE_OPS))

    def step(carry, xs):
        regs, spads, gmem, flags, tags, counters, sbuf = carry
        instr, cap = xs
        C = regs.shape[0]
        ar = jnp.arange(C)
        col = jnp.broadcast_to(ar[None, :], (W, C))

        op = instr[..., 0]
        dst = instr[..., 1]
        imm = instr[..., 6].astype(U32)
        zero = jnp.zeros((W, C), U32)
        v1 = regs[col, instr[..., 2]]
        v2 = regs[col, instr[..., 3]]
        v3 = regs[col, instr[..., 4]] if need_v3 else zero
        v4 = regs[col, instr[..., 5]] if need_v4 else zero

        lut_tt = (luts[col, jnp.minimum(imm, luts.shape[1] - 1)]
                  if Op.LUT in ops else None)                 # [W, C, 16]
        ld_val = spads[col, v1 % spad_words] if Op.LD in ops else None
        if has_global:
            g_addr = ((v1 << 16) | v2) % gmem_words
        gld_val = gmem[g_addr] if Op.GLD in ops else None
        branches = _alu_branches(ops, v1, v2, v3, v4, imm,
                                 lut_tt, ld_val, gld_val)

        result = zero
        for code_op, val in branches:
            result = jnp.where(op == int(code_op), val, result)

        # ---- register writes (slot-ordered; never r0) ----
        if writes:
            no_write = dst == 0
            for o in _NO_WRITE_OPS:
                if o in ops:
                    no_write = no_write | (op == int(o))
            wdst = jnp.where(no_write, 0, dst)
            for w in range(W):
                wval = jnp.where(no_write[w], regs[ar, 0], result[w])
                regs = regs.at[ar, wdst[w]].set(wval)

        # ---- scratchpad stores (predicated, slot-ordered) ----
        if Op.ST in ops:
            st_mask = (op == int(Op.ST)) & (v3 != 0)
            st_addr = v1 % spad_words
            for w in range(W):
                spads = spads.at[ar, st_addr[w]].set(
                    jnp.where(st_mask[w], v2[w], spads[ar, st_addr[w]]))

        # ---- global stores + cache/stall model (privileged lanes) ----
        if has_global:
            gst_mask = (op == int(Op.GST)) & (v4 != 0)
            for w in range(W):
                if Op.GST in ops:
                    w_addr = jnp.where(gst_mask[w], g_addr[w], 0)
                    gmem = gmem.at[w_addr].set(
                        jnp.where(gst_mask[w], v3[w], gmem[w_addr]))
                g_access = (op[w] == int(Op.GLD)) | gst_mask[w]
                any_g = jnp.any(g_access)
                # model the (single) privileged access through the cache
                lane = jnp.argmax(g_access)
                line = (g_addr[w, lane] // line_words).astype(jnp.int32)
                idx = line % cache_lines
                hit = (tags[idx] == line) & any_g
                miss = (~hit) & any_g
                tags = tags.at[idx].set(jnp.where(any_g, line, tags[idx]))
                counters = counters.at[1].add(hit.astype(jnp.uint32))
                counters = counters.at[2].add(miss.astype(jnp.uint32))
                counters = counters.at[3].add(
                    jnp.where(hit, jnp.uint32(hit_stall),
                              jnp.where(miss, jnp.uint32(miss_stall),
                                        jnp.uint32(0))))

        # ---- exceptions (EXPECT raises when operands differ) ----
        if Op.EXPECT in ops:
            exc = (op == int(Op.EXPECT)) & (v1 != v2)     # [W, C]
            any_exc = exc.any(axis=0)
            first_w = jnp.argmax(exc, axis=0)             # earliest slot wins
            imm_sel = imm[first_w, ar]
            flags = jnp.where((flags == 0) & any_exc, imm_sel, flags)

        # ---- compact SEND capture (non-senders hit the sacrificial slot) --
        sbuf = sbuf.at[cap.reshape(-1)].set(
            (result & 0xFFFF).reshape(-1))
        return (regs, spads, gmem, flags, tags, counters, sbuf), None

    return step


def dispatch_chunks(run_chunk, cyc, carry, chunk: int, num_cycles: int,
                    done):
    """Host side of the chunked K-Vcycle dispatch, shared by the single,
    batched and multi-device engines: launch ceil(num_cycles/chunk)
    chunks, reading the exception flags once per chunk (the only host
    sync point) and stopping early when ``done(flags)``."""
    budget = jnp.int32(num_cycles)
    n_launch = -(-num_cycles // chunk) if num_cycles > 0 else 0
    for _ in range(n_launch):
        cyc, carry = run_chunk(cyc, budget, carry)
        if done(np.asarray(carry[3])):
            break
    return carry


class Machine:
    """Executable instance of a compiled Program (single host/device).

    ``specialize=True`` (default) runs the partially-evaluated fast path:
    opcode-set-specialized pipeline-window step, compact SEND capture and
    chunked K-Vcycle dispatch. ``specialize=False`` reproduces the seed
    engine (full ISA select, [T, C] trace, per-Vcycle while_loop) and
    exists so the perf trajectory can be measured against it.
    """

    def __init__(self, program: Program, backend: str = "jnp",
                 compact: bool = True, interpret: bool = True,
                 specialize: bool = True, chunk: int = DEFAULT_CHUNK):
        self.p = program
        self.backend = backend
        self.specialize = specialize
        self.chunk = max(1, int(chunk))
        hw = program.hw
        # active-core / active-register compaction: the FPGA burns idle
        # cores and its 2048-entry register file for free, the interpreter
        # need not simulate them (beyond-paper optimization).
        C = program.used_cores if compact else program.code.shape[0]
        C = max(C, 1)
        self.C = C
        R = program.used_reg_count() if compact else hw.num_regs
        self.R = R
        self.code = jnp.asarray(
            np.ascontiguousarray(program.code[:C].transpose(1, 0, 2)),
            dtype=jnp.int32)                                    # [T, C, 7]
        self.luts = jnp.asarray(program.luts[:C], dtype=U32)    # [C, 32, 16]
        self.reg0 = jnp.asarray(program.reg_init[:C, :R], dtype=U32)
        self.spad0 = jnp.asarray(program.spad_init[:C], dtype=U32)
        self.gmem0 = jnp.asarray(program.gmem_init, dtype=U32)
        self.xchg = tuple(jnp.asarray(a) for a in (
            program.xchg_src_slot, program.xchg_src_core,
            program.xchg_dst_core, program.xchg_dst_reg))
        self.n_sends = program.n_sends
        self.cache_lines = hw.cache_words // hw.cache_line_words
        self.op_set = program.op_set() if specialize else None
        if not specialize:
            # seed engine: unspecialized compute-all step + full trace
            self._step = make_slot_step(
                self.luts, max(self.spad0.shape[1], 1),
                max(self.gmem0.shape[0], 1), self.cache_lines,
                hw.cache_line_words, hw.cache_hit_stall,
                hw.cache_miss_stall, op_set=None)

        # pipeline-windowed code stream: [T/W, W, C, 7] with W = the
        # hardware RAW latency (all-NOP padding rows; sacrificial capture).
        # Only the specialized jnp paths consume it — the pallas backend
        # builds its own padded capture table and the seed path scans the
        # raw code.
        T = self.code.shape[0]
        W = max(1, int(hw.raw_latency))
        self.W = W
        # rotated dispatch of a modulo-pipelined program: the combined
        # stream's first ``pipe_prologue`` slots hold the *next* Vcycle's
        # hoisted pure ops. The specialized engines split the stream there:
        # the body executes in the Vcycle, the prologue re-executes after
        # the exchange gated on "no exception this cycle" (cycle k+1's
        # in-flight prologue never commits when cycle k raises), and
        # ``init_state`` applies iteration 0's prologue once. The seed
        # engine keeps the full stream: executing the prologue rows at the
        # stream head is idempotent (pure ops whose inputs are untouched
        # since the previous epilogue recomputed them), so both dispatch
        # forms produce bit-identical register planes.
        self.Tpro = int(program.pipe_prologue) if specialize else 0
        if self.Tpro:
            head_ops = {int(o) for o in
                        np.unique(np.asarray(self.code)[:self.Tpro, :, 0])}
            illegal = head_ops & {int(o) for o in
                                  (Op.ST, Op.GST, Op.EXPECT, Op.SEND,
                                   Op.LD, Op.GLD)}
            if illegal:
                raise ValueError(
                    f"pipelined prologue contains impure opcodes {illegal}")

        def _pad_windows(rows_code, rows_cap):
            t = rows_code.shape[0]
            tp = ((t + W - 1) // W) * W
            cp = np.zeros((tp, C, 7), np.int32)
            cp[:t] = rows_code
            kp = np.full((tp, C), self.n_sends, np.int32)
            kp[:t] = rows_cap
            return cp, kp

        self._pro_windows = []
        if specialize:
            cap_full = program.send_capture(C)
            code_np = np.asarray(self.code)
            code_p, cap_p = _pad_windows(code_np[self.Tpro:],
                                         cap_full[self.Tpro:])
            Tp = code_p.shape[0]
            if self.Tpro:
                pro_p, pcap_p = _pad_windows(code_np[:self.Tpro],
                                             cap_full[:self.Tpro])
                self._pro_windows = self._build_windows(pro_p, pcap_p, hw)
        T = T - self.Tpro       # body slot count drives the unroll bound

        # static per-window metadata for the fully-unrolled fast path:
        # (instr, ops, write/store/send/expect/global sites — all constant)
        self._unrolled = (specialize and backend != "pallas"
                          and T <= UNROLL_SLOTS)
        if specialize and backend != "pallas" and not self._unrolled:
            # deep-schedule fallback: per-window specialization inside the
            # scan. Windows are grouped into consecutive runs sharing an
            # opcode set; each run gets its own window body traced with
            # only that run's branches (all-NOP windows are dropped — their
            # capture rows are all-sacrificial by construction), and the
            # Vcycle executes the runs in schedule order.
            wcode_np = code_p.reshape(Tp // W, W, C, 7)
            wcap_np = cap_p.reshape(Tp // W, W, C)
            runs = []      # [frozenset(ops), [window indices]]
            for iw in range(Tp // W):
                wops = frozenset(Op(int(o))
                                 for o in np.unique(wcode_np[iw, ..., 0])
                                 if o)
                if not wops:
                    continue                       # all-NOP window
                if runs and runs[-1][0] == wops:
                    runs[-1][1].append(iw)
                else:
                    runs.append([wops, [iw]])
            while len(runs) > MAX_SCAN_SEGMENTS:
                k = min(range(len(runs) - 1),
                        key=lambda i: len(runs[i][1]) + len(runs[i + 1][1]))
                runs[k] = [runs[k][0] | runs[k + 1][0],
                           runs[k][1] + runs[k + 1][1]]
                del runs[k + 1]
            self._segments = []
            self._segment_ops = [ops for ops, _ in runs]
            for seg_ops, idxs in runs:
                step = make_window_step(
                    self.luts, max(self.spad0.shape[1], 1),
                    max(self.gmem0.shape[0], 1), self.cache_lines,
                    hw.cache_line_words, hw.cache_hit_stall,
                    hw.cache_miss_stall,
                    op_set=seg_ops | {Op.NOP}, window=W)
                self._segments.append(
                    (step, jnp.asarray(wcode_np[idxs]),
                     jnp.asarray(wcap_np[idxs])))
        self._windows = (self._build_windows(code_p, cap_p, hw)
                         if self._unrolled else [])

        if backend == "pallas":
            from ..kernels import ops as kops
            if specialize:
                self._chunk_kernel = kops.make_vcycle_chunk(
                    program, C, self.chunk, interpret=interpret)
            else:
                self._vcycle_kernel = kops.make_vcycle(
                    program, C, interpret=interpret)
        if specialize:
            if backend == "pallas":
                self._run_chunk = jax.jit(self._chunk_kernel)
            else:
                self._run_chunk = jax.jit(self._chunk_impl)
        else:
            self._run = jax.jit(self._run_legacy,
                                static_argnames=("num_cycles",))

    def _build_windows(self, code_p, cap_p, hw):
        """Static per-window metadata for the fully-unrolled fast path
        (one entry per non-NOP window; see ``_exec_windows``)."""
        C = self.C
        W = self.W
        windows = []
        no_write_ops = {int(o) for o in _NO_WRITE_OPS}
        for iw in range(code_p.shape[0] // W):
            instr = code_p[iw * W:(iw + 1) * W]          # [W, C, 7]
            wcapn = cap_p[iw * W:(iw + 1) * W]           # [W, C]
            opw = instr[..., 0]
            if not opw.any():
                continue                                 # all-NOP window
            # flat active-lane vector: the schedule's NOP lanes are
            # known statically, so gathers/ALU run over the k non-NOP
            # (slot, core) lanes only — a low-utilization schedule
            # (e.g. mc at 13%) pays for the work it contains, not for
            # the [W, C] rectangle around it
            w_arr, c_arr = np.nonzero(opw)               # [k], w-major
            lane = instr[w_arr, c_arr]                   # [k, 7]
            opl = lane[:, 0]
            wops = frozenset(Op(int(o)) for o in np.unique(opl))
            wr_rows, st_rows, send_rows, exp_rows, glb_rows = \
                [], [], [], [], []
            for w in range(W):
                in_w = w_arr == w
                wr = np.nonzero(in_w & (lane[:, 1] != 0) &
                                ~np.isin(opl, list(no_write_ops)))[0]
                if wr.size:
                    wr_rows.append((wr, c_arr[wr], lane[wr, 1]))
                st = np.nonzero(in_w & (opl == int(Op.ST)))[0]
                if st.size:
                    st_rows.append((st, c_arr[st]))
                sn = np.nonzero(in_w & (opl == int(Op.SEND)))[0]
                if sn.size:
                    send_rows.append((sn, wcapn[w, c_arr[sn]]))
                ex = np.nonzero(in_w & (opl == int(Op.EXPECT)))[0]
                if ex.size:
                    exp_rows.append((ex, c_arr[ex]))
                for gop, is_gst in ((Op.GLD, False), (Op.GST, True)):
                    gl = np.nonzero(in_w & (opl == int(gop)))[0]
                    if gl.size:
                        glb_rows.append((gl, c_arr[gl], is_gst))
            # merge the window's register writes into one scatter when
            # no (core, reg) cell is written twice (WAW inside a RAW
            # window can only come from dead writes — regalloc never
            # emits them, but stay exact if it ever does)
            if len(wr_rows) > 1:
                sss = np.concatenate([s for (s, _, _) in wr_rows])
                css = np.concatenate([c for (_, c, _) in wr_rows])
                dss = np.concatenate([d for (_, _, d) in wr_rows])
                cells = css.astype(np.int64) * hw.num_regs + dss
                if np.unique(cells).size == cells.size:
                    wr_rows = [(sss, css, dss)]
            windows.append((lane, c_arr, wops, wr_rows, st_rows,
                            send_rows, exp_rows, glb_rows))
        return windows

    # ------------------------------------------------------------------
    def init_state(self, images=None) -> MachineState:
        """Initial machine state; ``images=(reg_init, spad_init, gmem_init)``
        (full-width arrays, e.g. from ``Program.init_images``) selects a
        different stimulus than the program's base init."""
        if images is None:
            regs, spads, gmem = self.reg0, self.spad0, self.gmem0
        else:
            ri, si, gi = images
            regs = jnp.asarray(np.asarray(ri)[:self.C, :self.R], U32)
            spads = jnp.asarray(np.asarray(si)[:self.C], U32)
            gmem = jnp.asarray(np.asarray(gi), U32)
        if self.Tpro:
            # rotated prologue dispatch: iteration 0's hoisted pure ops
            # run once, before the first Vcycle's steady-state body
            regs = self._apply_prologue(regs, spads, gmem)
        return MachineState(
            regs=regs,
            spads=spads,
            gmem=gmem,
            flags=jnp.zeros((self.C,), U32),
            cache_tags=-jnp.ones((self.cache_lines,), jnp.int32),
            counters=jnp.zeros((4,), jnp.uint32),
        )

    def _apply_prologue(self, regs, spads, gmem):
        """Execute the prologue rows (pure ops — only ``regs`` changes) on
        the given state; used for iteration 0 at init and for iteration
        k+1 at the tail of every specialized Vcycle."""
        flags = jnp.zeros((self.C,), U32)
        tags = -jnp.ones((self.cache_lines,), jnp.int32)
        counters = jnp.zeros((4,), jnp.uint32)
        return self._exec_windows(self._pro_windows, regs, spads, gmem,
                                  flags, tags, counters, None, [], [])[0]

    # ------------------------------------------------ specialized path ----
    def _vcycle(self, carry, active=None):
        """One Vcycle. ``active`` (a traced bool, used by the batched
        engine under vmap) freezes an inactive element bit-identically:
        the unrolled path gates each write site individually (no
        whole-state select); the segmented-scan fallback selects the
        state leaves once at the Vcycle boundary."""
        if self._unrolled:
            return self._vcycle_unrolled(carry, active)
        regs, spads, gmem, flags, tags, counters = carry
        sbuf = jnp.zeros((self.n_sends + 1,), U32)
        c7 = (regs, spads, gmem, flags, tags, counters, sbuf)
        for step, wcode, wcap in self._segments:
            if wcode.shape[0] == 1:
                c7, _ = step(c7, (wcode[0], wcap[0]))
            else:
                c7, _ = jax.lax.scan(step, c7, (wcode, wcap), unroll=2)
        nregs, nspads, ngmem, nflags, ntags, ncounters, sbuf = c7
        # ---- BSP exchange straight from the compact SEND buffer ----
        if self.n_sends:
            _, _, d_core, d_reg = self.xchg
            nregs = nregs.at[d_core, d_reg].set(sbuf[:self.n_sends])
        ncounters = ncounters.at[0].add(jnp.uint32(1))
        if self._pro_windows:
            # cycle k+1's prologue issues in cycle k's idle tail; its
            # register carries commit only when cycle k raised nothing
            # (``active`` freezing is handled by the leaf select below)
            nregs = self._exec_windows(
                self._pro_windows, nregs, nspads, ngmem, nflags, ntags,
                ncounters, jnp.all(nflags == 0), [], [])[0]
        new = (nregs, nspads, ngmem, nflags, ntags, ncounters)
        if active is None:
            return new
        return tuple(jnp.where(active, n, o) for n, o in zip(new, carry))

    def _vcycle_unrolled(self, carry, active=None):
        """Fully partially-evaluated Vcycle: the window loop is unrolled
        over the static code stream. Every window traces only the branches
        for *its own* opcodes (the per-slot usage metadata), every
        gather/scatter site (writes, stores, SENDs, EXPECTs, global ops) is
        emitted only where the schedule actually contains one — with
        constant index arrays — and all SEND values merge into a single
        exchange scatter. The XLA graph *is* the program.

        ``active`` gates every write site (see ``_vcycle``): the per-site
        selects touch only the written cells, so a frozen batch element
        costs nothing beyond the dead compute it discards."""
        regs, spads, gmem, flags, tags, counters = carry
        send_idx, send_parts = [], []
        regs, spads, gmem, flags, tags, counters = self._exec_windows(
            self._windows, regs, spads, gmem, flags, tags, counters,
            active, send_idx, send_parts)

        # ---- BSP exchange: one scatter from the captured SEND values ----
        if self.n_sends:
            sid = np.concatenate(send_idx)
            d_core = self.p.xchg_dst_core[sid]
            d_reg = self.p.xchg_dst_reg[sid]
            vals = (jnp.concatenate(send_parts) if len(send_parts) > 1
                    else send_parts[0])
            if active is not None:
                vals = jnp.where(active, vals, regs[d_core, d_reg])
            regs = regs.at[d_core, d_reg].set(vals)
        counters = counters.at[0].add(jnp.uint32(1) if active is None
                                      else active.astype(jnp.uint32))
        if self._pro_windows:
            # cycle k+1's prologue (pure register carries) issues in cycle
            # k's idle tail and commits only when cycle k raised nothing —
            # an in-flight prologue is dropped on exception
            pgate = jnp.all(flags == 0)
            if active is not None:
                pgate = pgate & active
            regs = self._exec_windows(
                self._pro_windows, regs, spads, gmem, flags, tags,
                counters, pgate, [], [])[0]
        return (regs, spads, gmem, flags, tags, counters)

    def _exec_windows(self, windows, regs, spads, gmem, flags, tags,
                      counters, active, send_idx, send_parts):
        """Execute a list of static unrolled windows on the given leaves;
        SEND captures are appended to ``send_idx``/``send_parts`` for the
        caller's exchange scatter. ``active`` (None, or a scalar bool per
        batch element) gates every write site individually."""
        gate = ((lambda p: p) if active is None
                else (lambda p: p & active))
        hw = self.p.hw
        S = max(self.spad0.shape[1], 1)
        G = max(self.gmem0.shape[0], 1)

        for wi in windows:
            (lane, c_arr, wops, wr_rows, st_rows, send_rows, exp_rows,
             glb_rows) = wi
            imm = lane[:, 6].astype(np.uint32)
            op = lane[:, 0]
            # ST/GST operands must also come from the window-start batch:
            # a WAR/ORDER edge lets another instruction overwrite a store's
            # predicate register as little as 1 slot after the store reads
            # it, and the register writes above are applied before the
            # store sites below
            need_v3 = bool(wops & {Op.ADDC, Op.CARRY, Op.SUBB, Op.BORROW,
                                   Op.MUX, Op.LUT, Op.ST, Op.GST})
            need_v4 = bool(wops & {Op.LUT, Op.GST})
            v1 = regs[c_arr, lane[:, 2]]
            v2 = regs[c_arr, lane[:, 3]]
            v3 = regs[c_arr, lane[:, 4]] if need_v3 else None
            v4 = regs[c_arr, lane[:, 5]] if need_v4 else None

            lut_tt = (self.luts[c_arr,
                                np.minimum(imm, self.luts.shape[1] - 1)]
                      if Op.LUT in wops else None)
            ld_val = spads[c_arr, v1 % S] if Op.LD in wops else None
            gld_val = (gmem[((v1 << 16) | v2) % G]
                       if Op.GLD in wops else None)
            branches = _alu_branches(wops, v1, v2, v3, v4, imm,
                                     lut_tt, ld_val, gld_val)

            if len(branches) == 1:
                result = branches[0][1]
            elif branches:
                result = jnp.zeros(v1.shape, U32)
                for code_op, val in branches:
                    result = jnp.where(op == int(code_op), val, result)
            else:
                result = None                  # store/expect-only window

            # ---- register writes: static (lane, cores, dsts) sites; a
            # merged site spans the window (one scatter per window) ----
            for (sel, cores, dsts) in wr_rows:
                vals = result[..., sel] & 0xFFFF
                if active is not None:
                    vals = jnp.where(active, vals, regs[cores, dsts])
                regs = regs.at[cores, dsts].set(vals)

            # ---- predicated scratchpad stores ----
            for (sel, cores) in st_rows:
                pred = gate(v3[..., sel] != 0)
                addr = v1[..., sel] % S
                spads = spads.at[cores, addr].set(
                    jnp.where(pred, v2[..., sel], spads[cores, addr]))

            # ---- SEND capture (merged into one exchange scatter) ----
            for (sel, sid) in send_rows:
                send_idx.append(sid)
                send_parts.append(v1[..., sel] & 0xFFFF)

            # ---- exceptions ----
            for (sel, cores) in exp_rows:
                exc = gate((v1[..., sel] != v2[..., sel])
                           & (flags[cores] == 0))
                flags = flags.at[cores].set(
                    jnp.where(exc, jnp.asarray(imm[sel], U32),
                              flags[cores]))

            # ---- privileged global ops + cache/stall model ----
            for (sel, cores, is_gst) in glb_rows:
                g_addr = ((v1[..., sel] << 16) | v2[..., sel]) % G
                if is_gst:
                    pred = gate(v4[..., sel] != 0)
                    w_addr = jnp.where(pred, g_addr, 0)
                    gmem = gmem.at[w_addr].set(
                        jnp.where(pred, v3[..., sel], gmem[w_addr]))
                    any_g = pred[..., 0]
                else:
                    any_g = gate(jnp.bool_(True))
                line = (g_addr[..., 0]
                        // hw.cache_line_words).astype(jnp.int32)
                idx = line % self.cache_lines
                hit = (tags[idx] == line) & any_g
                miss = (~hit) & any_g
                tags = tags.at[idx].set(jnp.where(any_g, line, tags[idx]))
                counters = counters.at[1].add(hit.astype(jnp.uint32))
                counters = counters.at[2].add(miss.astype(jnp.uint32))
                counters = counters.at[3].add(
                    jnp.where(hit, jnp.uint32(hw.cache_hit_stall),
                              jnp.where(miss,
                                        jnp.uint32(hw.cache_miss_stall),
                                        jnp.uint32(0))))

        return regs, spads, gmem, flags, tags, counters

    def _chunk_impl(self, cyc, budget, carry):
        """K predicated Vcycles under one scan: a Vcycle whose start state
        already carries an exception (or that exceeds the budget) freezes —
        the machine stops *within* the chunk, exactly at the raising cycle."""
        def body(c, _):
            cyc, st = c
            active = (cyc < budget) & jnp.all(st[3] == 0)
            st = jax.lax.cond(active, self._vcycle, lambda s: s, st)
            return (cyc + active.astype(jnp.int32), st), None

        (cyc, carry), _ = jax.lax.scan(body, (cyc, carry), None,
                                       length=self.chunk)
        return cyc, carry

    # ------------------------------------------------ seed (baseline) ----
    def _vcycle_legacy(self, carry):
        if self.backend == "pallas":
            carry, trace = self._vcycle_kernel(carry)
        else:
            # self._step is the unspecialized (op_set=None) form here
            carry, trace = _scan_with_trace(self._step, carry, self.code)
        regs, spads, gmem, flags, tags, counters = carry
        s_slot, s_core, d_core, d_reg = self.xchg
        if s_slot.shape[0]:
            vals = trace[s_slot, s_core]
            regs = regs.at[d_core, d_reg].set(vals)
        counters = counters.at[0].add(jnp.uint32(1))
        return (regs, spads, gmem, flags, tags, counters)

    def _run_legacy(self, state: MachineState, num_cycles: int):
        def cond(c):
            cyc, st = c
            return (cyc < num_cycles) & jnp.all(st[3] == 0)

        def body(c):
            cyc, st = c
            return cyc + 1, self._vcycle_legacy(st)

        _, out = jax.lax.while_loop(cond, body, (jnp.int32(0), tuple(state)))
        return MachineState(*out)

    # ------------------------------------------------------------------
    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        """Run up to ``num_cycles`` Vcycles; freezes on the first exception
        (the host services it — paper's global stall + host handshake)."""
        if not self.specialize:
            return self._run(state, num_cycles=num_cycles)
        carry = dispatch_chunks(
            self._run_chunk, jnp.int32(0), tuple(state), self.chunk,
            int(num_cycles), lambda f: f.any())
        return MachineState(*carry)

    def exceptions(self, state: MachineState) -> Dict[int, int]:
        f = np.asarray(state.flags)
        return {int(c): int(e) for c, e in enumerate(f) if e}

    def read_output(self, state: MachineState, name: str) -> int:
        core, mregs = self.p.outputs[name]
        regs = np.asarray(state.regs)
        out = 0
        for j, r in enumerate(mregs):
            out |= int(regs[core, r]) << (16 * j)
        return out

    def read_reg(self, state: MachineState, rtl_name: str) -> int:
        words = self.p.state_regs[rtl_name]
        regs = np.asarray(state.regs)
        out = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            out |= int(regs[c, r]) << (16 * j)
        return out

    def perf(self, state: MachineState) -> Dict[str, int]:
        cnt = np.asarray(state.counters)
        vcycles = int(cnt[0])
        stalls = int(cnt[3])
        return {
            "vcycles": vcycles,
            "ghits": int(cnt[1]),
            "gmisses": int(cnt[2]),
            "stall_cycles": stalls,
            "machine_cycles": vcycles * self.p.vcpl + stalls,
        }


class BatchedMachine(Machine):
    """B independent stimuli of one compiled Program per device launch.

    The compile-time pipeline (partition → schedule → regalloc →
    trace/unroll) is paid once per *design*; the accelerator's data-parallel
    axis then carries B testbenches that share ``code``/``luts`` and differ
    only in initial state (``Program.init_images`` planes). Every
    ``MachineState`` leaf gains a leading ``[B]`` axis and the specialized
    Vcycle graph (unrolled or segmented-scan) is ``jax.vmap``-ed over it.

    Exception semantics are per batch element: element ``b`` freezes at its
    raising Vcycle (its chunk iterations become no-ops via predication)
    while the other elements run on; the host syncs the exception flags
    once per K-Vcycle chunk, exactly like the single-stimulus dispatch.

    ``backend="pallas"`` runs the chunked whole-machine kernel with a grid
    axis over B, so each batch element's registers/scratchpads stay
    VMEM-resident for the whole chunk.
    """

    def __init__(self, program: Program, images=None, batch: Optional[int] = None,
                 backend: str = "jnp", interpret: bool = True,
                 compact: bool = True, chunk: int = DEFAULT_CHUNK):
        # build the jnp machinery (windows/unroll metadata) on the base
        # Machine; the pallas backend swaps in the batched chunk kernel below
        super().__init__(program, backend="jnp", compact=compact,
                         specialize=True, chunk=chunk)
        self._set_images(images, batch)
        B = self.B
        self.backend = backend
        # B=1 pays the plain specialized graph, not a vmap wrapper around it
        self._plain = backend != "pallas" and B == 1
        if backend == "pallas":
            from ..kernels import ops as kops
            self._run_chunk = jax.jit(kops.make_vcycle_chunk(
                program, self.C, self.chunk, interpret=interpret, batch=B))
        elif self._plain:
            self._run_chunk = jax.jit(self._b1chunk_impl)
        else:
            self._run_chunk = jax.jit(self._bchunk_impl)

    # ------------------------------------------------------------------
    def _set_images(self, images, batch: Optional[int]) -> None:
        """Load the per-stimulus init images into the batched ``[B, ...]``
        layout (sets ``breg0``/``bspad0``/``bgmem0`` and ``B``)."""
        C, R = self.C, self.R
        if images is None:
            assert batch is not None and batch >= 1, \
                "BatchedMachine needs init images or an explicit batch size"
            B = int(batch)
            self.breg0 = jnp.broadcast_to(self.reg0, (B,) + self.reg0.shape)
            self.bspad0 = jnp.broadcast_to(self.spad0,
                                           (B,) + self.spad0.shape)
            self.bgmem0 = jnp.broadcast_to(self.gmem0,
                                           (B,) + self.gmem0.shape)
        elif _is_stacked(images):
            # pre-stacked [B, ...] image arrays (Program.init_images_batch /
            # Bench.images_batch): already in the batched layout, no
            # per-stimulus copies
            ri, si, gi = images
            B = int(np.asarray(ri).shape[0])
            self.breg0 = jnp.asarray(np.asarray(ri)[:, :C, :R], U32)
            self.bspad0 = jnp.asarray(np.asarray(si)[:, :C], U32)
            self.bgmem0 = jnp.asarray(np.asarray(gi), U32)
        else:
            B = len(images)
            self.breg0 = jnp.asarray(
                np.stack([np.asarray(ri)[:C, :R] for ri, _, _ in images]),
                U32)
            self.bspad0 = jnp.asarray(
                np.stack([np.asarray(si)[:C] for _, si, _ in images]), U32)
            self.bgmem0 = jnp.asarray(
                np.stack([np.asarray(gi) for _, _, gi in images]), U32)
        self.B = B
        if self.Tpro:
            # iteration 0's prologue, once per stimulus (pure — regs only)
            self.breg0 = jax.vmap(self._apply_prologue)(
                self.breg0, self.bspad0, self.bgmem0)

    def rebind_images(self, images) -> None:
        """Swap in a new batch of per-stimulus init images *in place*.

        The batch size must match — the jitted chunk dispatch is
        shape-specialized on B — so only the initial state changes and the
        traced Vcycle graph stays hot. ``init_state()`` after a rebind
        starts the new stimuli. This is what keeps a serving daemon's
        compiled Simulations device-resident: per-batch image turnover
        costs one host→device transfer, never a retrace.
        """
        if images is None:
            raise ValueError("rebind_images needs init images")
        B = (int(np.asarray(images[0]).shape[0]) if _is_stacked(images)
             else len(images))
        if B != self.B:
            raise ValueError(
                f"rebind_images: batch size changed {self.B} -> {B}; "
                "build a new machine for a different B")
        self._set_images(images, None)

    def init_state(self) -> MachineState:
        B = self.B
        return MachineState(
            regs=self.breg0,
            spads=self.bspad0,
            gmem=self.bgmem0,
            flags=jnp.zeros((B, self.C), U32),
            cache_tags=-jnp.ones((B, self.cache_lines), jnp.int32),
            counters=jnp.zeros((B, 4), jnp.uint32),
        )

    def _b1chunk_impl(self, cyc, budget, carry):
        """B=1 fast path: dispatch the plain specialized chunk on the
        squeezed state — a batch of one should not pay the vmap wrapper
        (BENCH_batch showed B=1 "batched" at ~1.2-1.4x the cost of the
        single-stimulus engine for no benefit)."""
        c1, out = self._chunk_impl(cyc[0], budget,
                                   tuple(leaf[0] for leaf in carry))
        return c1[None], tuple(leaf[None] for leaf in out)

    def _bchunk_impl(self, cyc, budget, carry):
        """K Vcycles for all B elements under one scan; element b freezes
        (its state stops advancing) from its raising Vcycle on. The freeze
        predicate rides *into* the vmapped Vcycle — per-write-site gating
        on the unrolled path (no whole-state select per Vcycle), a
        per-Vcycle leaf select on the deep-schedule fallback."""
        def body(c, _):
            cyc, st = c
            active = (cyc < budget) & jnp.all(st[3] == 0, axis=1)   # [B]
            st = jax.vmap(self._vcycle)(st, active)
            return (cyc + active.astype(jnp.int32), st), None

        (cyc, carry), _ = jax.lax.scan(body, (cyc, carry), None,
                                       length=self.chunk)
        return cyc, carry

    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        # stop dispatching only once *every* element froze
        carry = dispatch_chunks(
            self._run_chunk, jnp.zeros((self.B,), jnp.int32), tuple(state),
            self.chunk, int(num_cycles), lambda f: f.any(axis=1).all())
        return MachineState(*carry)

    # ---------------------------------------------- per-element access ----
    def element(self, state: MachineState, b: int) -> MachineState:
        """Single-stimulus view of batch element ``b`` (host-side)."""
        return MachineState(*(leaf[b] for leaf in state))

    def exceptions(self, state: MachineState, b: Optional[int] = None):
        if b is not None:
            return super().exceptions(self.element(state, b))
        return [super(BatchedMachine, self).exceptions(self.element(state, i))
                for i in range(self.B)]

    def read_output(self, state: MachineState, name: str, b: int = 0) -> int:
        return super().read_output(self.element(state, b), name)

    def read_reg(self, state: MachineState, rtl_name: str, b: int = 0) -> int:
        return super().read_reg(self.element(state, b), rtl_name)

    def perf(self, state: MachineState, b: Optional[int] = None):
        if b is not None:
            return super().perf(self.element(state, b))
        cnt = np.asarray(state.counters)
        vcycles = int(cnt[:, 0].sum())
        stalls = int(cnt[:, 3].sum())
        return {
            "batch": self.B,
            "vcycles": vcycles,                 # aggregate over the batch
            "ghits": int(cnt[:, 1].sum()),
            "gmisses": int(cnt[:, 2].sum()),
            "stall_cycles": stalls,
            "machine_cycles": vcycles * self.p.vcpl + stalls,
        }


class ShardedBatchedMachine(BatchedMachine):
    """Data-parallel batched execution over a device mesh: ``[D, B/D]``.

    ``BatchedMachine`` fills one device's data-parallel axis with B
    stimuli; this engine shards *the batch axis itself* over a 1-D mesh of
    D devices (the ROADMAP's next lever past PR 2, Parendi's thousand-way
    extension of the paper's model). Each device runs the **same**
    specialized Vcycle chunk — the exact ``_bchunk_impl`` graph (or the
    grid-over-B Pallas chunk kernel) — on its own ``B/D``-element shard of
    every state leaf under ``shard_map``. There is **no cross-device
    communication at all**: stimuli are independent, so the BSP exchange
    stays device-local and the only global coordination is the host's
    once-per-chunk exception sync.

    **Padding.** B is padded up to ``Bp = ceil(B/D)*D``. Padding elements
    replicate stimulus 0's images but start their per-element cycle
    counter at ``PAD_FROZEN_CYC`` (>= any budget), so their freeze
    predicate is never active: they execute nothing, raise nothing, and
    never appear in results — every accessor indexes only the logical
    ``B`` elements.

    **Sync model.** The per-device chunk additionally returns a ``[B/D]``
    ``frozen`` mask (raised an exception, or exhausted the budget —
    padding is always frozen by construction). The host's once-per-chunk
    sync reads only the assembled ``[Bp]`` bool mask — an any-reduce over
    the per-device masks, not the ``[Bp, C]`` flag planes — and stops
    dispatching when every element froze.

    Per-element semantics (freeze at the raising Vcycle, bit-exact state,
    counters) are exactly ``BatchedMachine``'s: the same chunk body runs,
    merely on a shard.
    """

    AXIS = "batch"

    def __init__(self, program: Program, images=None,
                 batch: Optional[int] = None, devices=None,
                 backend: str = "jnp", interpret: bool = True,
                 compact: bool = True, chunk: int = DEFAULT_CHUNK):
        super().__init__(program, images=images, batch=batch,
                         backend="jnp", interpret=interpret,
                         compact=compact, chunk=chunk)
        devices = list(devices) if devices is not None else jax.devices()
        D = len(devices)
        self.D = D
        self.backend = backend
        self.mesh = Mesh(np.asarray(devices), (self.AXIS,))
        B = self.B
        Bp = -(-B // D) * D
        self.Bp = Bp
        self._pad_images()
        # padding elements start pre-frozen (see PAD_FROZEN_CYC)
        self._cyc0 = jnp.asarray(
            np.where(np.arange(Bp) < B, 0, PAD_FROZEN_CYC).astype(np.int32))

        if backend == "pallas":
            from ..kernels import ops as kops
            local_chunk = kops.make_vcycle_chunk(
                program, self.C, self.chunk, interpret=interpret,
                batch=Bp // D)
        else:
            local_chunk = self._bchunk_impl

        lead = lambda *tail: P(self.AXIS, *tail)
        state_specs = (lead(None, None), lead(None, None), lead(None),
                       lead(None), lead(None), lead(None))

        def device_chunk(cyc, budget, *leaves):
            """One device's K-Vcycle chunk on its local [B/D] shard; the
            extra ``frozen`` output is what the host syncs on."""
            cyc, out = local_chunk(cyc, budget, tuple(leaves))
            frozen = jnp.any(out[3] != 0, axis=1) | (cyc >= budget)
            return (cyc, frozen) + out

        sharded = shard_map(
            device_chunk, self.mesh,
            in_specs=(lead(), P()) + state_specs,
            out_specs=(lead(), lead()) + state_specs)
        self._run_chunk = jax.jit(
            lambda cyc, budget, carry: sharded(cyc, budget, *carry))

    # ------------------------------------------------------------------
    def _pad_images(self) -> None:
        """Pad the ``[B, ...]`` image arrays to ``[Bp, ...]`` with replicas
        of stimulus 0 (padding elements never execute — ``_cyc0`` starts
        them pre-frozen)."""
        B, Bp = self.B, self.Bp
        if Bp > B:
            def padb(a):
                return jnp.concatenate(
                    [a, jnp.broadcast_to(a[:1], (Bp - B,) + a.shape[1:])], 0)
            self.breg0 = padb(self.breg0)
            self.bspad0 = padb(self.bspad0)
            self.bgmem0 = padb(self.bgmem0)

    def rebind_images(self, images) -> None:
        super().rebind_images(images)      # checks the logical B matches
        self._pad_images()

    def init_state(self) -> MachineState:
        """Initial state in the sharded ``[Bp, ...]`` layout: every leaf
        is placed batch-sharded over the mesh up front, so the first chunk
        launch pays no resharding."""
        sh = lambda n_tail: NamedSharding(
            self.mesh, P(self.AXIS, *([None] * n_tail)))
        Bp = self.Bp
        return MachineState(
            regs=jax.device_put(self.breg0, sh(2)),
            spads=jax.device_put(self.bspad0, sh(2)),
            gmem=jax.device_put(self.bgmem0, sh(1)),
            flags=jax.device_put(jnp.zeros((Bp, self.C), U32), sh(1)),
            cache_tags=jax.device_put(
                -jnp.ones((Bp, self.cache_lines), jnp.int32), sh(1)),
            counters=jax.device_put(jnp.zeros((Bp, 4), jnp.uint32), sh(1)),
        )

    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        """Chunked dispatch over the mesh: one host sync per chunk, on the
        assembled per-device frozen masks only."""
        cyc = self._cyc0
        budget = jnp.int32(num_cycles)
        n_launch = -(-int(num_cycles) // self.chunk) if num_cycles > 0 else 0
        carry = tuple(state)
        for _ in range(n_launch):
            cyc, frozen, *carry = self._run_chunk(cyc, budget, carry)
            carry = tuple(carry)
            if np.asarray(frozen).all():
                break
        return MachineState(*carry)

    def perf(self, state: MachineState, b: Optional[int] = None):
        if b is not None:
            return super().perf(state, b)
        # aggregate over the *logical* batch only (padding rows are all
        # zero by construction, but stay out of the contract regardless)
        logical = MachineState(*(leaf[:self.B] for leaf in state))
        return BatchedMachine.perf(self, logical)


def _scan_with_trace(step, carry, code):
    """Seed-style scan: run the (compact-capture) step but also emit the
    full per-slot result trace for the legacy exchange."""
    C = code.shape[1]

    def body(sc, instr):
        # capture every lane: cap = identity into a [C+1] buffer per slot
        cap = jnp.arange(C, dtype=jnp.int32)
        regs, spads, gmem, flags, tags, counters = sc
        sbuf = jnp.zeros((C + 1,), U32)
        (regs, spads, gmem, flags, tags, counters, sbuf), _ = step(
            (regs, spads, gmem, flags, tags, counters, sbuf), (instr, cap))
        return (regs, spads, gmem, flags, tags, counters), sbuf[:C]

    return jax.lax.scan(body, carry, code)
