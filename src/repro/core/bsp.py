"""Static BSP executor — vectorized lockstep interpretation of a Program.

TPU adaptation of the Manticore grid (DESIGN.md §2): core *c* of the paper's
MIMD grid becomes lane *c* of ``[C]``-wide vectors. Every slot, all lanes
execute their own instruction simultaneously (compute-all-select over the
opcode — NOp lanes are masked), which is exactly the paper's lockstep
guarantee expressed as SIMD. One Vcycle is:

    lax.scan over ``t_compute`` slots  ->  BSP exchange (deferred register
    updates from SENDs land at the Vcycle boundary)  ->  commit done.

The per-slot "result" of every lane is traced; the exchange is a pure static
gather/scatter over the trace — the paper's collision-free NoC schedule
becomes indexed addressing (single-device) or an ``all_to_all`` under
``shard_map`` (see ``core.grid``).

The privileged core's off-chip traffic (GLD/GST) is modeled with the paper's
direct-mapped cache + global-stall cost model: stalls do not change
simulation *results* (the whole machine freezes together), so the engine
executes them inline and accumulates the stall cycles performance counters
(§7.7 / Fig. 8).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compile import Program
from .isa import Op

U32 = jnp.uint32
MASK = jnp.uint32(0xFFFF)


class MachineState(NamedTuple):
    regs: jax.Array      # [C, R] uint32 (values are 16-bit)
    spads: jax.Array     # [C, S] uint32
    gmem: jax.Array      # [G] uint32
    flags: jax.Array     # [C] uint32 — first exception id per core (0 = none)
    cache_tags: jax.Array  # [LINES] int32 (-1 = invalid)
    counters: jax.Array  # [4] uint64: vcycles, ghits, gmisses, stall_cycles


def _slot_step(luts, spad_words, gmem_words, cache_lines, line_words,
               hit_stall, miss_stall, carry, instr):
    """Execute one slot for all lanes. ``instr`` is [C, 7] int32."""
    regs, spads, gmem, flags, tags, counters = carry
    C = regs.shape[0]
    ar = jnp.arange(C)

    op = instr[:, 0]
    dst = instr[:, 1]
    imm = instr[:, 6].astype(U32)
    v = [regs[ar, instr[:, k]] for k in range(2, 6)]
    v1, v2, v3, v4 = v

    # ---- arithmetic / logic (all elementwise over lanes) ----
    add3 = v1 + v2 + v3
    sub3 = v1 - v2 - v3
    prod = v1 * v2
    shamt = imm & 15
    res_slice_off = imm >> 5
    res_slice_msk = (U32(1) << (imm & 31)) - 1

    sgn = ((v1 ^ 0x8000) - 0x8000).astype(jnp.int32)

    # LUT: 16-pattern compute-all-select (per-bit-lane 4-input function)
    tt = luts[ar, jnp.minimum(imm, luts.shape[1] - 1)]  # [C, 16] uint32
    lut_out = jnp.zeros((C,), U32)
    nv = [(~x) & MASK for x in v]
    for p in range(16):
        # pattern bit i corresponds to LUT input i (s1 -> bit 0)
        m = (v1 if p & 1 else nv[0]) & (v2 if p & 2 else nv[1]) \
            & (v3 if p & 4 else nv[2]) & (v4 if p & 8 else nv[3])
        lut_out = lut_out | (m & tt[:, p])

    ld_addr = v1 % spad_words
    ld_val = spads[ar, ld_addr]
    g_addr = ((v1 << 16) | v2) % gmem_words
    gld_val = gmem[g_addr]

    branches = [
        (Op.MOV, v1),
        (Op.MOVI, imm & MASK),
        (Op.ADD, (v1 + v2) & MASK),
        (Op.ADDC, add3 & MASK),
        (Op.CARRY, (add3 >> 16) & MASK),
        (Op.SUB, (v1 - v2) & MASK),
        (Op.SUBB, sub3 & MASK),
        (Op.BORROW, (v1 < v2 + v3).astype(U32)),
        (Op.MUL, prod & MASK),
        (Op.MULH, (prod >> 16) & MASK),
        (Op.AND, v1 & v2),
        (Op.OR, v1 | v2),
        (Op.XOR, v1 ^ v2),
        (Op.NOT, (~v1) & MASK),
        (Op.MUX, jnp.where(v1 != 0, v2, v3)),
        (Op.SEQ, (v1 == v2).astype(U32)),
        (Op.SNE, (v1 != v2).astype(U32)),
        (Op.SLTU, (v1 < v2).astype(U32)),
        (Op.SLL, (v1 << shamt) & MASK),
        (Op.SRL, v1 >> shamt),
        (Op.SRA, (sgn >> shamt).astype(U32) & MASK),
        (Op.SLLV, (v1 << (v2 & 15)) & MASK),
        (Op.SRLV, v1 >> (v2 & 15)),
        (Op.SLICE, (v1 >> res_slice_off) & res_slice_msk),
        (Op.LUT, lut_out),
        (Op.LD, ld_val),
        (Op.GLD, gld_val),
        (Op.SEND, v1),
    ]
    result = jnp.zeros((C,), U32)
    for code_op, val in branches:
        result = jnp.where(op == int(code_op), val, result)

    # ---- register write (ops with a result; never r0) ----
    no_write = ((op == int(Op.NOP)) | (op == int(Op.ST)) |
                (op == int(Op.GST)) | (op == int(Op.EXPECT)) |
                (op == int(Op.SEND)) | (dst == 0))
    wdst = jnp.where(no_write, 0, dst)
    wval = jnp.where(no_write, regs[ar, 0], result)
    regs = regs.at[ar, wdst].set(wval)

    # ---- scratchpad store (predicated) ----
    st_mask = (op == int(Op.ST)) & (v3 != 0)
    st_addr = v1 % spad_words
    spads = spads.at[ar, st_addr].set(
        jnp.where(st_mask, v2, spads[ar, st_addr]))

    # ---- global store + cache/stall model (privileged lanes) ----
    gst_mask = (op == int(Op.GST)) & (v4 != 0)
    gmem = gmem.at[jnp.where(gst_mask, g_addr, 0)].set(
        jnp.where(gst_mask, v3, gmem[jnp.where(gst_mask, g_addr, 0)]))

    g_access = (op == int(Op.GLD)) | gst_mask
    any_g = jnp.any(g_access)
    # model the (single) privileged access through the direct-mapped cache
    lane = jnp.argmax(g_access)
    line = (g_addr[lane] // line_words).astype(jnp.int32)
    idx = line % cache_lines
    hit = (tags[idx] == line) & any_g
    miss = (~hit) & any_g
    tags = tags.at[idx].set(jnp.where(any_g, line, tags[idx]))
    counters = counters.at[1].add(hit.astype(jnp.uint64))
    counters = counters.at[2].add(miss.astype(jnp.uint64))
    counters = counters.at[3].add(
        jnp.where(hit, jnp.uint64(hit_stall),
                  jnp.where(miss, jnp.uint64(miss_stall), jnp.uint64(0))))

    # ---- exceptions (EXPECT raises when operands differ) ----
    exc = (op == int(Op.EXPECT)) & (v1 != v2)
    flags = jnp.where((flags == 0) & exc, imm, flags)

    return (regs, spads, gmem, flags, tags, counters), result & MASK


class Machine:
    """Executable instance of a compiled Program (single host/device)."""

    def __init__(self, program: Program, backend: str = "jnp",
                 compact: bool = True, interpret: bool = True):
        self.p = program
        self.backend = backend
        hw = program.hw
        # active-core compaction: the FPGA burns idle cores for free, the
        # interpreter need not simulate them (beyond-paper optimization).
        C = program.used_cores if compact else program.code.shape[0]
        C = max(C, 1)
        self.C = C
        self.code = jnp.asarray(
            np.ascontiguousarray(program.code[:C].transpose(1, 0, 2)),
            dtype=jnp.int32)                                    # [T, C, 7]
        self.luts = jnp.asarray(program.luts[:C], dtype=U32)    # [C, 32, 16]
        self.reg0 = jnp.asarray(program.reg_init[:C], dtype=U32)
        self.spad0 = jnp.asarray(program.spad_init[:C], dtype=U32)
        self.gmem0 = jnp.asarray(program.gmem_init, dtype=U32)
        self.xchg = tuple(jnp.asarray(a) for a in (
            program.xchg_src_slot, program.xchg_src_core,
            program.xchg_dst_core, program.xchg_dst_reg))
        self.cache_lines = hw.cache_words // hw.cache_line_words
        self._run = jax.jit(self._run_impl, static_argnames=("num_cycles",))
        if backend == "pallas":
            from ..kernels import ops as kops
            self._vcycle_kernel = kops.make_vcycle(
                program, C, interpret=interpret)

    # ------------------------------------------------------------------
    def init_state(self) -> MachineState:
        return MachineState(
            regs=self.reg0,
            spads=self.spad0,
            gmem=self.gmem0,
            flags=jnp.zeros((self.C,), U32),
            cache_tags=-jnp.ones((self.cache_lines,), jnp.int32),
            counters=jnp.zeros((4,), jnp.uint64),
        )

    def _vcycle(self, carry):
        hw = self.p.hw
        step = functools.partial(
            _slot_step, self.luts,
            max(self.spad0.shape[1], 1), max(self.gmem0.shape[0], 1),
            self.cache_lines, hw.cache_line_words,
            hw.cache_hit_stall, hw.cache_miss_stall)
        if self.backend == "pallas":
            carry, trace = self._vcycle_kernel(carry)
        else:
            carry, trace = jax.lax.scan(step, carry, self.code)
        regs, spads, gmem, flags, tags, counters = carry
        # ---- BSP exchange: deferred SEND register updates ----
        s_slot, s_core, d_core, d_reg = self.xchg
        if s_slot.shape[0]:
            vals = trace[s_slot, s_core]
            regs = regs.at[d_core, d_reg].set(vals)
        counters = counters.at[0].add(jnp.uint64(1))
        return (regs, spads, gmem, flags, tags, counters)

    def _run_impl(self, state: MachineState, num_cycles: int) -> MachineState:
        def cond(c):
            cyc, st = c
            return (cyc < num_cycles) & jnp.all(st[3] == 0)

        def body(c):
            cyc, st = c
            return cyc + 1, self._vcycle(st)

        _, out = jax.lax.while_loop(cond, body, (jnp.int32(0), tuple(state)))
        return MachineState(*out)

    # ------------------------------------------------------------------
    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        """Run up to ``num_cycles`` Vcycles; freezes on the first exception
        (the host services it — paper's global stall + host handshake)."""
        return self._run(state, num_cycles=num_cycles)

    def exceptions(self, state: MachineState) -> Dict[int, int]:
        f = np.asarray(state.flags)
        return {int(c): int(e) for c, e in enumerate(f) if e}

    def read_output(self, state: MachineState, name: str) -> int:
        core, mregs = self.p.outputs[name]
        regs = np.asarray(state.regs)
        out = 0
        for j, r in enumerate(mregs):
            out |= int(regs[core, r]) << (16 * j)
        return out

    def read_reg(self, state: MachineState, rtl_name: str) -> int:
        words = self.p.state_regs[rtl_name]
        regs = np.asarray(state.regs)
        out = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            out |= int(regs[c, r]) << (16 * j)
        return out

    def perf(self, state: MachineState) -> Dict[str, int]:
        cnt = np.asarray(state.counters)
        vcycles = int(cnt[0])
        stalls = int(cnt[3])
        return {
            "vcycles": vcycles,
            "ghits": int(cnt[1]),
            "gmisses": int(cnt[2]),
            "stall_cycles": stalls,
            "machine_cycles": vcycles * self.p.vcpl + stalls,
        }
