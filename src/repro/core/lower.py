"""Netlist -> lower assembly (16-bit datapath legalization).

Mirrors the paper's backend step (§6): *"We then transform the netlist
assembly instructions into an equivalent sequence of lower assembly
instructions whose operands match Manticore's 16-bit data path."*

Every netlist signal of width W becomes ceil(W/16) virtual registers (LSW
first). Wide arithmetic is legalized into ADDC/CARRY (resp. SUBB/BORROW)
chains — the paper's overflow-bit mechanism — wide shifts into word-level
shift/or networks, and memory accesses into LD/ST (scratchpad) or GLD/GST
(privileged, off-chip) with relocatable base addresses resolved at placement
time.

The output is a *monolithic process*: a flat SSA instruction list, exactly
what the paper's partitioner consumes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .isa import Instr, Op, WORD_BITS, WORD_MASK
from .netlist import Circuit, Memory, NOp, Node


def nwords(width: int) -> int:
    return (width + WORD_BITS - 1) // WORD_BITS


def def_index(instrs: Sequence[Instr]) -> Dict[int, int]:
    """vreg -> index of its (unique, SSA) defining instruction."""
    out: Dict[int, int] = {}
    for i, ins in enumerate(instrs):
        w = ins.writes()
        if w is not None and w != 0:
            out[w] = i
    return out


def use_index(instrs: Sequence[Instr]) -> Dict[int, List[int]]:
    """vreg -> indices of instructions reading it (def-use chains)."""
    out: Dict[int, List[int]] = {}
    for i, ins in enumerate(instrs):
        for s in ins.srcs:
            out.setdefault(s, []).append(i)
    return out


@dataclass(frozen=True)
class Reloc:
    """Relocatable constant: memory base address, resolved at placement."""
    mem: str
    part: str  # "lo" | "hi"
    offset: int = 0


InitVal = Union[int, Reloc]


@dataclass
class RegWords:
    """Lowered view of one RTL register."""
    name: str
    width: int
    cur: Tuple[int, ...]    # leaf vregs holding the current value
    nxt: Tuple[int, ...]    # vregs computed each Vcycle (the next value)
    init: int


@dataclass
class MemLayout:
    name: str
    depth: int
    width: int
    stride: int             # 16-bit words per entry
    is_global: bool
    init_words: List[int]


@dataclass
class Lowered:
    """Monolithic lower-assembly process (pre-partitioning).

    Since PR 3 this is a proper pass-friendly SSA IR: virtual registers are
    defined at most once, every definition precedes its uses (the list is
    topologically ordered), and the helpers below expose def-use chains,
    liveness roots and an invariant checker so optimization passes
    (``core.opt``) can rewrite the instruction stream safely.

    Liveness contract (the batched-stimulus roots, see ``docs/compiler.md``):

      * every next-register vreg (``regs[*].nxt``) keeps a *unique* defining
        instruction — it is a partitioning sink and commit source;
      * current-register vregs (``regs[*].cur``), including ``Planes`` init
        carriers, and :class:`Reloc` leaves are opaque state — they are never
        in ``const_vregs`` and must never be folded as constants (their value
        is patched per stimulus / at placement);
      * output vregs keep their defining instructions.
    """
    name: str
    instrs: List[Instr]
    vreg_init: Dict[int, InitVal]          # leaf vregs (consts/inputs/state)
    regs: List[RegWords]
    mems: Dict[str, MemLayout]
    outputs: Dict[str, List[int]]          # name -> vregs (in priv process)
    num_vregs: int
    # vregs that are *true constants* (foldable into LUT truth tables);
    # register state and latched inputs are NOT here.
    const_vregs: Dict[int, int] = field(default_factory=dict)

    def stats(self) -> Dict[str, int]:
        per_op: Dict[str, int] = {}
        for i in self.instrs:
            per_op[i.op.name] = per_op.get(i.op.name, 0) + 1
        return {"instrs": len(self.instrs), "vregs": self.num_vregs,
                "regs": len(self.regs), **per_op}

    # ---- pass-support helpers (PR 3) ---------------------------------
    def defs(self) -> Dict[int, int]:
        return def_index(self.instrs)

    def uses(self) -> Dict[int, List[int]]:
        return use_index(self.instrs)

    def protected_vregs(self) -> set:
        """Vregs with consumers outside the instruction list: next-register
        words (commit sources / SEND payloads) and host-visible outputs.
        Their defining instructions must survive every pass and must keep
        their ``dst``."""
        out = set()
        for r in self.regs:
            out.update(r.nxt)
        for vs in self.outputs.values():
            out.update(vs)
        return out

    def state_vregs(self) -> set:
        """Current-register leaves (incl. batched init-plane carriers)."""
        out = set()
        for r in self.regs:
            out.update(r.cur)
        return out

    def cur_word_masks(self) -> Dict[int, int]:
        """Per current-register-word mask of bits that can ever be set.

        Word ``j`` of a ``W``-bit register holds at most ``min(16, W-16j)``
        bits: inits are masked by the netlist builders (``Circuit.reg`` /
        ``circuits.common.Planes``) and every lowered next-value is masked
        via ``_mask_top``. The known-bits pass in ``core.opt`` leans on
        this to erase redundant top-word masking."""
        masks: Dict[int, int] = {}
        for r in self.regs:
            for j, cw in enumerate(r.cur):
                bits = min(WORD_BITS, r.width - WORD_BITS * j)
                masks[cw] = (1 << max(bits, 0)) - 1
        return masks

    def replace_instrs(self, instrs: List[Instr]) -> None:
        """Install a rewritten instruction list (passes call this so future
        bookkeeping has a single choke point)."""
        self.instrs = instrs

    def compact(self) -> Dict[int, int]:
        """Renumber vregs densely (0 stays 0), dropping leaf-init entries no
        longer referenced by instructions, register state or outputs.
        Returns the old->new mapping applied."""
        live: set = {0}
        for ins in self.instrs:
            live.update(ins.srcs)
            w = ins.writes()
            if w is not None:
                live.add(w)
        for r in self.regs:
            live.update(r.cur)
            live.update(r.nxt)
        for vs in self.outputs.values():
            live.update(vs)
        remap = {v: i for i, v in enumerate(sorted(live))}

        def m(v: int) -> int:
            return remap[v]

        self.instrs = [
            Instr(ins.op, m(ins.dst) if ins.writes() is not None else 0,
                  tuple(m(s) for s in ins.srcs), ins.imm, mem=ins.mem)
            for ins in self.instrs]
        self.vreg_init = {m(v): iv for v, iv in self.vreg_init.items()
                          if v in remap}
        self.const_vregs = {m(v): c for v, c in self.const_vregs.items()
                            if v in remap}
        self.regs = [RegWords(r.name, r.width, tuple(m(v) for v in r.cur),
                              tuple(m(v) for v in r.nxt), r.init)
                     for r in self.regs]
        self.outputs = {k: [m(v) for v in vs]
                        for k, vs in self.outputs.items()}
        self.num_vregs = len(remap)
        return remap

    def check(self) -> None:
        """Invariant checker: SSA well-formedness plus the batched-stimulus
        liveness contract. Raises AssertionError on violation."""
        defined: Dict[int, int] = {}
        for i, ins in enumerate(self.instrs):
            w = ins.writes()
            assert w != 0, \
                f"instr {i} writes the architectural zero register v0"
            if w is not None:
                assert w not in defined, \
                    f"vreg v{w} defined twice (instrs {defined[w]} and {i})"
                assert w not in self.vreg_init, \
                    f"leaf vreg v{w} redefined by instr {i}"
                assert 0 < w < self.num_vregs, (i, w)
                defined[w] = i
            for s in ins.srcs:
                assert 0 <= s < self.num_vregs, (i, s)
                if s != 0 and s not in self.vreg_init:
                    assert s in defined and defined[s] < i, \
                        f"instr {i} reads v{s} before its definition"
            if ins.op in (Op.LD, Op.ST, Op.GLD, Op.GST):
                assert ins.mem in self.mems, (i, ins.mem)
        # constants are true constants: int inits matching const_vregs,
        # never register state, never relocatable addresses
        state = self.state_vregs()
        for v, c in self.const_vregs.items():
            if v == 0:
                assert c == 0
                continue
            iv = self.vreg_init.get(v)
            assert isinstance(iv, int) and iv == c, \
                f"const vreg v{v} init {iv!r} != folded value {c}"
            assert v not in state, f"state vreg v{v} marked constant"
        for v, iv in self.vreg_init.items():
            if isinstance(iv, Reloc):
                assert v not in self.const_vregs, \
                    f"relocatable leaf v{v} marked constant"
        # batched-stimulus roots: every register word keeps its state leaf
        # and a unique next-value definition
        seen_nxt: set = set()
        for r in self.regs:
            assert len(r.cur) == len(r.nxt) == nwords(r.width), r.name
            for cw in r.cur:
                assert cw in self.vreg_init, \
                    f"state leaf v{cw} of {r.name} lost its init"
            for nw in r.nxt:
                assert nw in defined, \
                    f"next-register v{nw} of {r.name} has no definition"
                assert nw not in seen_nxt, \
                    f"next-register v{nw} of {r.name} shared between words"
                seen_nxt.add(nw)
        for name, vs in self.outputs.items():
            for v in vs:
                assert v in defined, f"output {name!r} vreg v{v} undefined"


class Lowerer:
    def __init__(self, circuit: Circuit):
        circuit.validate()
        self.c = circuit
        self.instrs: List[Instr] = []
        self.vreg_init: Dict[int, InitVal] = {}
        self._next_vreg = 1                      # vreg 0 == constant zero
        self._const_cache: Dict[int, int] = {0: 0}
        self.const_vregs: Dict[int, int] = {0: 0}  # vreg -> folded value
        self.words: Dict[int, List[int]] = {}    # netlist nid -> vregs (LSW..)
        self.outputs: Dict[str, List[int]] = {}
        self.mems: Dict[str, MemLayout] = {}

    # ------------------------------------------------------------------
    def vreg(self) -> int:
        v = self._next_vreg
        self._next_vreg += 1
        return v

    def const(self, value: int) -> int:
        value &= WORD_MASK
        if value not in self._const_cache:
            v = self.vreg()
            self.vreg_init[v] = value
            self._const_cache[value] = v
            self.const_vregs[v] = value
        return self._const_cache[value]

    def leaf(self, init: InitVal) -> int:
        v = self.vreg()
        self.vreg_init[v] = init
        return v

    def emit(self, op: Op, srcs: Sequence[int] = (), imm: int = 0,
             mem: Optional[str] = None, dst: Optional[int] = None) -> int:
        d = self.vreg() if dst is None else dst
        self.instrs.append(Instr(op, d, tuple(srcs), imm, mem=mem))
        return d

    # ---- word-vector helpers -----------------------------------------
    def _mask_top(self, ws: List[int], width: int) -> List[int]:
        """Mask the top word so stored words never exceed ``width`` bits."""
        top_bits = width % WORD_BITS
        if top_bits:
            m = self.const((1 << top_bits) - 1)
            ws = ws[:-1] + [self.emit(Op.AND, [ws[-1], m])]
        return ws

    def _add(self, a: List[int], b: List[int], width: int,
             sub: bool = False) -> List[int]:
        n = nwords(width)
        out, carry = [], 0  # vreg 0 == zero
        lo_op, hi_op = (Op.SUBB, Op.BORROW) if sub else (Op.ADDC, Op.CARRY)
        for j in range(n):
            out.append(self.emit(lo_op, [a[j], b[j], carry]))
            if j + 1 < n:
                carry = self.emit(hi_op, [a[j], b[j], carry])
        return self._mask_top(out, width)

    def _mul(self, a: List[int], b: List[int], width: int) -> List[int]:
        n = nwords(width)
        if n == 1:
            return self._mask_top([self.emit(Op.MUL, [a[0], b[0]])], width)
        # schoolbook: acc[k] accumulates lo(a_i*b_j) for i+j==k and
        # hi(a_i*b_j) for i+j==k-1, with full carry propagation.
        acc: List[int] = [0] * n
        for i in range(n):
            for j in range(n - i):
                k = i + j
                lo = self.emit(Op.MUL, [a[i], b[j]])
                acc = self._acc_into(acc, k, lo, n)
                if k + 1 < n:
                    hi = self.emit(Op.MULH, [a[i], b[j]])
                    acc = self._acc_into(acc, k + 1, hi, n)
        return self._mask_top(acc, width)

    def _acc_into(self, acc: List[int], k: int, v: int, n: int) -> List[int]:
        carry = 0
        for j in range(k, n):
            add = v if j == k else 0
            if add == 0 and carry == 0:
                break
            new = self.emit(Op.ADDC, [acc[j], add, carry])
            if j + 1 < n:
                carry = self.emit(Op.CARRY, [acc[j], add, carry])
            acc[j] = new
        return acc

    def _shift_static(self, ws: List[int], width: int, amount: int,
                      kind: str) -> List[int]:
        """Static SHL/SHR/SRA on a word vector."""
        n = nwords(width)
        if amount == 0:
            return list(ws)
        if amount >= width:
            if kind != "sra":
                return [0] * n
            amount = width - 1
        wsh, bsh = amount // WORD_BITS, amount % WORD_BITS

        fill = 0
        if kind == "sra":
            # fill word = 0xffff if sign bit set else 0
            top_bits = (width - 1) % WORD_BITS
            sign = self.emit(Op.SLICE, [ws[-1]], imm=top_bits * 32 + 1)
            fill = self.emit(Op.MUX, [sign, self.const(WORD_MASK), 0])
            # pre-extend the top word to a full 16 bits of sign
            tb = width % WORD_BITS
            if tb:
                ext = self.emit(Op.AND, [fill,
                                         self.const(WORD_MASK ^ ((1 << tb) - 1))])
                ws = ws[:-1] + [self.emit(Op.OR, [ws[-1], ext])]

        def src(j: int) -> int:
            if 0 <= j < n:
                return ws[j]
            return fill if kind == "sra" and j >= n else 0

        out = []
        for j in range(n):
            if kind == "shl":
                lo_w, hi_w = src(j - wsh - 1), src(j - wsh)
                if bsh == 0:
                    out.append(hi_w)
                else:
                    hi = self.emit(Op.SLL, [hi_w], imm=bsh)
                    lo = self.emit(Op.SRL, [lo_w], imm=WORD_BITS - bsh)
                    out.append(self.emit(Op.OR, [hi, lo]))
            else:
                lo_w, hi_w = src(j + wsh), src(j + wsh + 1)
                if bsh == 0:
                    out.append(lo_w)
                else:
                    lo = self.emit(Op.SRL, [lo_w], imm=bsh)
                    hi = self.emit(Op.SLL, [hi_w], imm=WORD_BITS - bsh)
                    out.append(self.emit(Op.OR, [hi, lo]))
        return self._mask_top(out, width)

    def _ne_acc(self, a: List[int], b: List[int]) -> int:
        """OR-reduction of per-word XOR: 0 iff equal."""
        diffs = [self.emit(Op.XOR, [x, y]) if (x or y) else 0
                 for x, y in zip(a, b)]
        acc = diffs[0]
        for d in diffs[1:]:
            acc = self.emit(Op.OR, [acc, d])
        return acc

    def _ltu(self, a: List[int], b: List[int]) -> int:
        borrow = 0
        for x, y in zip(a, b):
            borrow = self.emit(Op.BORROW, [x, y, borrow])
        return borrow

    # ---- memory addressing ---------------------------------------------
    def _local_addr(self, m: MemLayout, idx: List[int], word: int) -> int:
        base = self.leaf(Reloc(m.name, "lo", word))
        i = idx[0]
        if m.stride == 1:
            scaled = i
        elif m.stride & (m.stride - 1) == 0:
            scaled = self.emit(Op.SLL, [i], imm=m.stride.bit_length() - 1)
        else:
            scaled = self.emit(Op.MUL, [i, self.const(m.stride)])
        return self.emit(Op.ADD, [base, scaled])

    def _global_addr(self, m: MemLayout, idx: List[int],
                     word: int) -> Tuple[int, int]:
        """32-bit (hi, lo) word address into global memory."""
        base_lo = self.leaf(Reloc(m.name, "lo", word))
        base_hi = self.leaf(Reloc(m.name, "hi", word))
        i_lo = idx[0]
        i_hi = idx[1] if len(idx) > 1 else 0
        s = self.const(m.stride)
        lo = self.emit(Op.MUL, [i_lo, s])
        hi_c = self.emit(Op.MULH, [i_lo, s])
        hi_p = self.emit(Op.MUL, [i_hi, s]) if i_hi else 0
        hi = self.emit(Op.ADD, [hi_c, hi_p]) if hi_p else hi_c
        alo = self.emit(Op.ADDC, [base_lo, lo, 0])
        ac = self.emit(Op.CARRY, [base_lo, lo, 0])
        ahi0 = self.emit(Op.ADD, [base_hi, hi])
        ahi = self.emit(Op.ADD, [ahi0, ac])
        return ahi, alo

    # ------------------------------------------------------------------
    def run(self) -> Lowered:
        c = self.c
        # memory layouts first (strides known before any access)
        for name, m in c.mems.items():
            stride = nwords(m.width)
            init_words: List[int] = []
            for v in m.init:
                for w in range(stride):
                    init_words.append((v >> (w * WORD_BITS)) & WORD_MASK)
            self.mems[name] = MemLayout(name, m.depth, m.width, stride,
                                        m.is_global, init_words)

        regs: List[RegWords] = []
        order = _toposort(c)
        for n in order:
            self._lower_node(n)

        # Every next-register word must have a *unique* defining instruction
        # (it is a partitioning sink and a commit source); alias cases
        # (next = const / another register's current value / a value shared
        # with another register's next) get an explicit MOV. Never mutate
        # self.words — those vregs are other signals' identities.
        defined = {i.dst for i in self.instrs if i.writes() is not None}
        used_nxt: set = set()
        for rid, nxt_nid in c.reg_next.items():
            node = c.nodes[rid]
            ws = self.words[nxt_nid]
            fixed = []
            for w in ws:
                if w not in defined or w in used_nxt:
                    w = self.emit(Op.MOV, [w])
                used_nxt.add(w)
                fixed.append(w)
            regs.append(RegWords(
                name=c.reg_names.get(rid, f"reg{rid}"),
                width=node.width,
                cur=tuple(self.words[rid]),
                nxt=tuple(fixed),
                init=c.reg_init[rid]))

        return Lowered(c.name, self.instrs, self.vreg_init, regs, self.mems,
                       self.outputs, self._next_vreg,
                       const_vregs=dict(self.const_vregs))

    # ------------------------------------------------------------------
    def _lower_node(self, n: Node) -> None:
        c, a = self.c, n.args
        W = n.width
        get = lambda i: self.words[a[i]]

        if n.op == NOp.CONST:
            v = n.params["value"]
            self.words[n.nid] = [self.const((v >> (16 * j)) & WORD_MASK)
                                 for j in range(nwords(W))]
        elif n.op == NOp.INPUT:
            v = c.input_values[n.nid]
            self.words[n.nid] = [self.leaf((v >> (16 * j)) & WORD_MASK)
                                 for j in range(nwords(W))]
        elif n.op == NOp.REG:
            init = c.reg_init[n.nid]
            self.words[n.nid] = [self.leaf((init >> (16 * j)) & WORD_MASK)
                                 for j in range(nwords(W))]
        elif n.op in (NOp.AND, NOp.OR, NOp.XOR):
            op = {NOp.AND: Op.AND, NOp.OR: Op.OR, NOp.XOR: Op.XOR}[n.op]
            self.words[n.nid] = [self.emit(op, [x, y])
                                 for x, y in zip(get(0), get(1))]
        elif n.op == NOp.NOT:
            out = [self.emit(Op.NOT, [x]) for x in get(0)]
            self.words[n.nid] = self._mask_top(out, W)
        elif n.op == NOp.ADD:
            self.words[n.nid] = self._add(get(0), get(1), W)
        elif n.op == NOp.SUB:
            self.words[n.nid] = self._add(get(0), get(1), W, sub=True)
        elif n.op == NOp.MUL:
            self.words[n.nid] = self._mul(get(0), get(1), W)
        elif n.op in (NOp.EQ, NOp.NE):
            acc = self._ne_acc(get(0), get(1))
            op = Op.SEQ if n.op == NOp.EQ else Op.SNE
            self.words[n.nid] = [self.emit(op, [acc, 0])]
        elif n.op == NOp.LTU:
            self.words[n.nid] = [self._ltu(get(0), get(1))]
        elif n.op in (NOp.SHL, NOp.SHR, NOp.SRA):
            kind = {NOp.SHL: "shl", NOp.SHR: "shr", NOp.SRA: "sra"}[n.op]
            src_w = c.nodes[a[0]].width
            ws = self._shift_static(get(0), src_w, n.params["amount"], kind)
            self.words[n.nid] = ws[:nwords(W)]
        elif n.op == NOp.MUX:
            sel = get(0)[0]
            self.words[n.nid] = [self.emit(Op.MUX, [sel, x, y])
                                 for x, y in zip(get(1), get(2))]
        elif n.op == NOp.SLICE:
            off, width = n.params["off"], n.params["w"]
            src_w = c.nodes[a[0]].width
            shifted = self._shift_static(get(0), src_w, off, "shr")
            out = shifted[:nwords(width)]
            self.words[n.nid] = self._mask_top(out, width)
        elif n.op == NOp.CAT:
            hi, lo = get(0), get(1)
            lo_w = c.nodes[a[1]].width
            n_out = nwords(W)
            # shift hi left by lo_w within the W-bit result
            hi_ext = list(hi) + [0] * (n_out - len(hi))
            hi_shifted = self._shift_static(hi_ext, W, lo_w, "shl")
            lo_ext = lo + [0] * (n_out - len(lo))
            self.words[n.nid] = [
                self.emit(Op.OR, [h, l]) if (h and l) else (h or l)
                for h, l in zip(hi_shifted, lo_ext)]
        elif n.op == NOp.MEMRD:
            m = self.mems[n.params["mem"]]
            idx = get(0)
            out = []
            for w in range(m.stride):
                if m.is_global:
                    ahi, alo = self._global_addr(m, idx, w)
                    out.append(self.emit(Op.GLD, [ahi, alo], mem=m.name))
                else:
                    addr = self._local_addr(m, idx, w)
                    out.append(self.emit(Op.LD, [addr], mem=m.name))
            self.words[n.nid] = self._mask_top(out[:nwords(W)], W)
        elif n.op == NOp.MEMWR:
            m = self.mems[n.params["mem"]]
            idx, data, en = get(0), get(1), get(2)[0]
            for w in range(m.stride):
                d = data[w] if w < len(data) else 0
                if m.is_global:
                    ahi, alo = self._global_addr(m, idx, w)
                    self.emit(Op.GST, [ahi, alo, d, en], mem=m.name)
                else:
                    addr = self._local_addr(m, idx, w)
                    self.emit(Op.ST, [addr, d, en], mem=m.name)
        elif n.op == NOp.EXPECT:
            acc = self._ne_acc(get(0), get(1))
            self.emit(Op.EXPECT, [acc, 0], imm=n.params["eid"])
        elif n.op == NOp.OUTPUT:
            name = n.params["name"]
            outs = [self.emit(Op.MOV, [w]) for w in get(0)]
            self.outputs[name] = outs
        else:  # pragma: no cover
            raise NotImplementedError(n.op)


def _toposort(c: Circuit) -> List[Node]:
    order: List[Node] = []
    state = [0] * len(c.nodes)
    for root in range(len(c.nodes)):
        if state[root]:
            continue
        stack = [(root, 0)]
        while stack:
            nid, ai = stack.pop()
            node = c.nodes[nid]
            if ai == 0:
                if state[nid] == 2:
                    continue
                state[nid] = 1
            if ai < len(node.args):
                stack.append((nid, ai + 1))
                if state[node.args[ai]] == 0:
                    stack.append((node.args[ai], 0))
            else:
                state[nid] = 2
                order.append(node)
    return order


def lower(circuit: Circuit) -> Lowered:
    return Lowerer(circuit).run()
