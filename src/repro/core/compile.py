"""End-to-end compiler: Circuit -> executable Program.

Pipeline (paper Fig. 4, plus the PR 3 optimizing middle-end — see
``docs/compiler.md``): lower -> **opt pass pipeline** (``core.opt``:
constant folding, copy propagation, strength reduction, CSE, DCE) ->
split/merge partition -> custom-function synthesis -> SEND insertion +
commit planning -> list scheduling + NoC routing -> register allocation ->
binary (dense arrays consumed by the static-BSP executors in ``core.bsp``
/ ``kernels``). ``optimize=False`` skips the middle-end entirely and is
bit-identical to the legacy path (the fixed cross-PR baseline).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from .isa import HardwareConfig, Instr, NUM_FIELDS, Op, WORD_MASK
from .lower import InitVal, Lowered, Reloc, def_index, lower
from .lutsynth import synthesize
from .opt import optimize_lowered
from .netlist import Circuit
from .partition import Partition, SendEdge, partition
from .place import PLACEMENTS, hop_cost, place
from .regalloc import CoreAlloc, allocate
from .remat import rematerialize
from .retime import plan_retime
from .schedule import (PIPELINES, PipelineInfo, ScheduleResult,
                       pipeline_schedule, schedule, validate_schedule)


@dataclass
class Program:
    """Compiled Manticore binary + static exchange schedule."""
    name: str
    hw: HardwareConfig
    code: np.ndarray           # [C, T, 7] int32 (op,dst,s1..s4,imm)
    luts: np.ndarray           # [C, 32, 16] uint16
    reg_init: np.ndarray       # [C, R] uint16
    spad_init: np.ndarray      # [C, S] uint16
    gmem_init: np.ndarray      # [G] uint16
    # static BSP exchange: value produced at (src_core, src_slot) lands in
    # (dst_core, dst_mreg) at the Vcycle boundary.
    xchg_src_core: np.ndarray  # [M] int32
    xchg_src_slot: np.ndarray  # [M] int32
    xchg_dst_core: np.ndarray  # [M] int32
    xchg_dst_reg: np.ndarray   # [M] int32
    t_compute: int
    vcpl: int
    used_cores: int
    outputs: Dict[str, Tuple[int, List[int]]]      # name -> (core, mregs)
    state_regs: Dict[str, List[List[Tuple[int, int]]]]  # reg -> per-word [(core, mreg), ...]
    stats: Dict[str, float] = field(default_factory=dict)
    # partial-evaluation metadata (filled by compile_circuit; recomputed on
    # demand for Programs built by hand, e.g. in tests): per-slot opcode
    # bitmask over the used cores, bit i set iff Op(i) appears in slot i.
    slot_op_mask: Optional[np.ndarray] = None      # [T] uint64
    # cross-Vcycle pipelining: the first pipe_prologue code slots are the
    # retimed prologue of the *next* Vcycle — the engines execute them at
    # the end of each cycle on post-exchange state, gated on "no exception
    # raised" (0 = unpipelined; see core.schedule.PipelineInfo)
    pipe_prologue: int = 0

    @property
    def num_cores(self) -> int:
        return self.code.shape[0]

    @property
    def has_global(self) -> bool:
        return bool(self.stats.get("global_ops", 0))

    @property
    def n_sends(self) -> int:
        return int(self.xchg_src_core.shape[0])

    def _op_masks(self) -> np.ndarray:
        if self.slot_op_mask is None:
            self.slot_op_mask = slot_op_masks(self.code, self.used_cores)
        return self.slot_op_mask

    def used_reg_count(self) -> int:
        """Number of machine registers the program can ever touch (max
        register index referenced by any used core's code or by the
        exchange, plus one). Register allocation only hands out registers
        that some instruction references, so slicing every per-core
        register file to this width is lossless — and it is what makes
        batched state ([B, C, R]) cache/VMEM-friendly: the paper's
        2048-entry BRAM file is free in hardware, but an interpreter
        should not carry the unused tail."""
        C = max(1, min(self.used_cores, self.code.shape[0]))
        r = int(self.code[:C, :, 1:6].max()) if self.code.size else 0
        if self.n_sends:
            r = max(r, int(self.xchg_dst_reg.max()))
        return min(r + 1, self.hw.num_regs)

    def op_set(self) -> frozenset:
        """Set of opcodes the program actually contains (used cores only).

        This is the compile-time knowledge the engines specialize on: a
        program with no LUT never pays the 16-pattern loop, one with no
        GLD/GST skips the cache model entirely, etc.
        """
        mask = int(np.bitwise_or.reduce(self._op_masks())) if \
            self._op_masks().size else 0
        return frozenset(Op(i) for i in range(64) if (mask >> i) & 1)

    def init_images(self, reg_plane: Dict[str, int],
                    mem_plane: Optional[Dict[str, List[int]]] = None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Apply one stimulus init plane to the base binary images.

        ``reg_plane`` maps RTL register name -> init value; every machine
        register holding a word of that register (owner *and* duplicated
        reader copies, from ``state_regs``) is patched. ``mem_plane`` maps
        memory name -> flattened 16-bit word image, placed at the memory's
        base via ``stats["mem_layout"]``. Returns fresh
        ``(reg_init, spad_init, gmem_init)`` arrays — the compiled
        ``code``/``luts`` are untouched, which is the whole point: B
        stimuli share one schedule and differ only in initial state.
        """
        reg_init = self.reg_init.copy()
        spad_init = self.spad_init.copy()
        gmem_init = self.gmem_init.copy()
        for name, val in reg_plane.items():
            words = self.state_regs.get(name)
            assert words is not None, (
                f"register {name!r} not in state_regs — its words were "
                "optimized away and cannot carry a per-stimulus init")
            for j, locs in enumerate(words):
                w = (int(val) >> (16 * j)) & WORD_MASK
                for (core, mreg) in locs:
                    reg_init[core, mreg] = w
        layout = self.stats.get("mem_layout", {})
        for name, image in (mem_plane or {}).items():
            core, base, size, is_global = layout[name]
            w = np.asarray(image, dtype=np.uint16)
            assert w.shape[0] <= size, (name, w.shape[0], size)
            if is_global:
                gmem_init[base:base + w.shape[0]] = w
            else:
                spad_init[core, base:base + w.shape[0]] = w
        return reg_init, spad_init, gmem_init

    def init_images_batch(self, reg_planes: Sequence[Dict[str, int]],
                          mem_planes: Optional[Sequence] = None,
                          workers: Optional[int] = None
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All B stimulus init images, generated **host-parallel** and
        stacked directly into the batched ``([B, C, R], [B, C, S],
        [B, G])`` layout the batched/sharded engines consume.

        ``init_images`` is pure host-side numpy patching; at large B it
        was the last serial stage of a batched launch. Each worker thread
        writes its stimulus straight into its row of the pre-allocated
        stacked arrays (no per-stimulus tuple list, no ``np.stack`` copy
        at the end). ``workers=None`` sizes the pool to ``os.cpu_count()``;
        ``workers=1`` (or B == 1) runs inline.
        """
        import os
        from concurrent.futures import ThreadPoolExecutor

        B = len(reg_planes)
        if mem_planes is None:
            mem_planes = [None] * B
        assert len(mem_planes) == B, (len(mem_planes), B)
        regs = np.empty((B,) + self.reg_init.shape, self.reg_init.dtype)
        spads = np.empty((B,) + self.spad_init.shape, self.spad_init.dtype)
        gmems = np.empty((B,) + self.gmem_init.shape, self.gmem_init.dtype)

        def one(b: int) -> None:
            r, s, g = self.init_images(reg_planes[b], mem_planes[b])
            regs[b], spads[b], gmems[b] = r, s, g

        if workers is None:
            workers = min(B, os.cpu_count() or 1)
        if B <= 1 or workers <= 1:
            for b in range(B):
                one(b)
        else:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                list(ex.map(one, range(B)))
        return regs, spads, gmems

    def save(self, path):
        """Persist this compiled Program as a single versioned ``.npz``
        artifact (see :mod:`repro.sim.artifact`). ``Program.load(path)``
        restores it bit-exactly — arrays, exchange tables,
        ``outputs``/``state_regs`` maps and ``stats`` — so the middle-end
        cost is paid once per design, not once per process."""
        from ..sim.artifact import save_program
        return save_program(self, path)

    @staticmethod
    def load(path) -> "Program":
        from ..sim.artifact import load_program
        return load_program(path)

    def send_capture(self, C: int) -> np.ndarray:
        """[T, C] int32 capture-index table: entry (t, c) is the flat SEND
        index whose value is produced at slot t on core c, or ``n_sends``
        (a sacrificial slot) everywhere else. The engines scatter each
        slot's results through this table into a compact ``[n_sends + 1]``
        buffer instead of materializing the full [T, C] trace."""
        T = self.code.shape[1]
        cap = np.full((T, C), self.n_sends, np.int32)
        for i in range(self.n_sends):
            t = int(self.xchg_src_slot[i])
            c = int(self.xchg_src_core[i])
            if c < C:
                cap[t, c] = i
        return cap


def slot_groups(program: "Program", C: int):
    """Partially evaluate the code stream into per-slot opcode groups.

    Returns a list over slots of lists of
    ``(op, cores, dst, s1, s2, s3, s4, imm, sid)`` — one entry per opcode
    present in that slot with the (static) core batch executing it, its
    decoded fields, and each lane's compact SEND-capture index. All-NOP
    slots produce empty lists, NOP lanes are dropped entirely: both the
    numpy ISA simulator and the unrolled jnp engine execute *only* the
    instructions the schedule actually contains.
    """
    from .isa import Op as _Op
    code = program.code[:C]
    cap = program.send_capture(C)
    T = code.shape[1]
    out = []
    for t in range(T):
        ops_t = code[:, t, 0]
        groups = []
        for opcode in np.unique(ops_t):
            if opcode == int(_Op.NOP):
                continue
            cores = np.nonzero(ops_t == opcode)[0]
            w = code[cores, t]
            groups.append((_Op(int(opcode)), cores, w[:, 1], w[:, 2],
                           w[:, 3], w[:, 4], w[:, 5],
                           w[:, 6].astype(np.uint32), cap[t, cores]))
        out.append(groups)
    return out


def slot_op_masks(code: np.ndarray, used_cores: int) -> np.ndarray:
    """Per-slot opcode-usage bitmask over the first ``used_cores`` cores.

    code is [C, T, 7]; returns [T] uint64 with bit ``op`` set iff any used
    core executes ``op`` in that slot."""
    C = max(1, min(used_cores, code.shape[0]))
    ops = code[:C, :, 0].astype(np.uint64)          # [C, T]
    masks = np.left_shift(np.uint64(1), ops)        # NOP -> bit 0 (harmless)
    return np.bitwise_or.reduce(masks, axis=0) if masks.size else \
        np.zeros((code.shape[1],), np.uint64)


def _raw_adjacency(instrs: List[Instr]) -> Dict[int, List[int]]:
    """RAW def->use adjacency within one process."""
    defs = def_index(instrs)
    adj: Dict[int, List[int]] = {}
    for i, ins in enumerate(instrs):
        for s in ins.srcs:
            d = defs.get(s)
            if d is not None:
                adj.setdefault(d, []).append(i)
    return adj


def _reachable(adj: Dict[int, List[int]], start: int) -> Set[int]:
    out: Set[int] = set()
    stack = [start]
    while stack:
        i = stack.pop()
        for u in adj.get(i, ()):
            if u not in out:
                out.add(u)
                stack.append(u)
    return out


@dataclass
class _Arm:
    """One scheduled compile arm: a candidate placement taken through
    remat + lutsynth + SEND insertion + commit planning + scheduling."""
    name: str
    core_of_proc: List[int]
    part: Partition
    proc_instrs: List[List[Instr]]
    proc_tables: List[List[Tuple[int, ...]]]
    send_dst_core: Dict[int, int]
    send_meta: List[Tuple[SendEdge, Instr]]
    war_edges: List[List[Tuple[int, int]]]
    order_edges: List[List[Tuple[int, int]]]
    share: List[Dict[int, int]]
    commit_def: List[Dict[int, int]]
    commit_movs: int
    shared_commits: int
    remat_stats: Dict[str, int]
    sched: ScheduleResult


def _compile_arm(name: str, core_of_proc: List[int], low: Lowered,
                 part: Partition, hw: HardwareConfig, use_luts: bool,
                 sched_strategy: str, check: bool,
                 tm: Dict[str, float]) -> _Arm:
    """Take one candidate placement through the placement-dependent
    backend: rematerialization (route costs), LUT synthesis, SEND
    insertion (destination cores), commit planning, and scheduling (NoC
    link/arrival reservation). ``part`` is mutated — pass a clone when
    scheduling more than one arm."""
    nproc = part.num_procs

    # ---- partition-aware rematerialization (slack strategy only: the
    # greedy path stays bit-identical to the frozen differential baseline)
    remat_stats: Dict[str, int] = {"remat_sends": 0, "remat_instrs": 0,
                                   "remat_procs": 0}
    if sched_strategy == "slack":
        t0 = time.perf_counter()
        remat_stats = rematerialize(low, part, hw,
                                    core_of_proc=core_of_proc)
        tm["remat"] = tm.get("remat", 0.0) + time.perf_counter() - t0

    # protected vregs: values with consumers outside the instruction lists
    # (the same liveness roots the opt passes preserve)
    protected: Set[int] = low.protected_vregs()

    # ---- per-process instruction lists + LUT synthesis -----------------
    t0 = time.perf_counter()
    proc_instrs: List[List[Instr]] = []
    proc_tables: List[List[Tuple[int, ...]]] = []
    for p in part.procs:
        instrs = [low.instrs[i] for i in p]
        if use_luts:
            instrs, tables = synthesize(instrs, low.const_vregs,
                                        frozenset(protected),
                                        max_tables=hw.num_luts)
        else:
            tables = []
        proc_instrs.append(instrs)
        proc_tables.append(tables)
    tm["lutsynth"] = tm.get("lutsynth", 0.0) + time.perf_counter() - t0

    # ---- SEND insertion + commit planning --------------------------------
    send_dst_core: Dict[int, int] = {}
    send_meta: List[Tuple[SendEdge, Instr]] = []
    for e in part.sends:
        ins = Instr(Op.SEND, 0, (e.nxt_vreg,),
                    send_dst_proc=e.dst_proc, send_dst_vreg=e.cur_vreg)
        proc_instrs[e.src_proc].append(ins)
        send_dst_core[id(ins)] = core_of_proc[e.dst_proc]
        send_meta.append((e, ins))

    war_edges: List[List[Tuple[int, int]]] = [[] for _ in range(nproc)]
    order_edges: List[List[Tuple[int, int]]] = [[] for _ in range(nproc)]
    share: List[Dict[int, int]] = [dict() for _ in range(nproc)]
    # cur vreg -> index of its committing instr (shared def or commit MOV):
    # the pipeliner derives commit-visibility slots from this
    commit_def: List[Dict[int, int]] = [dict() for _ in range(nproc)]
    commit_movs = 0
    shared_commits = 0
    # incremental dependence graph per process (RAW + accepted WAR edges):
    # a share is legal only if adding reader->def edges keeps it acyclic,
    # i.e. no reader of cur is reachable from def(nxt). Mutually-swapping
    # registers (r0'=r1; r1'=r0) would otherwise deadlock the scheduler.
    proc_adj: List[Optional[Dict[int, List[int]]]] = [None] * nproc
    for (p, nxt, cur) in part.local_commits:
        instrs = proc_instrs[p]
        if proc_adj[p] is None:
            proc_adj[p] = _raw_adjacency(instrs)
        adj = proc_adj[p]
        def_idx = next(i for i, ins in enumerate(instrs)
                       if ins.writes() == nxt)
        readers = [i for i, ins in enumerate(instrs)
                   if cur in ins.srcs and i != def_idx]
        desc = _reachable(adj, def_idx)
        if ((p, nxt, cur) not in part.remat_commits
                and (p, cur) not in part.remat_reads
                and not any(r in desc for r in readers)):
            # share machine register: next value lands in cur's register,
            # WAR edges force every read of cur to issue first.
            share[p][nxt] = cur
            commit_def[p][cur] = def_idx
            war_edges[p] += [(r, def_idx) for r in readers]
            for r in readers:
                adj.setdefault(r, []).append(def_idx)
            shared_commits += 1
        else:
            mov = Instr(Op.MOV, cur, (nxt,))
            instrs.append(mov)
            mi = len(instrs) - 1
            commit_def[p][cur] = mi
            war_edges[p] += [(r, mi) for r in readers]
            adj.setdefault(def_idx, []).append(mi)
            for r in readers:
                adj.setdefault(r, []).append(mi)
            commit_movs += 1

    # memory-order edges: every LD of a memory before its first ST; STs in
    # program order (full-cycle semantics: reads see pre-cycle state)
    for p, instrs in enumerate(proc_instrs):
        by_mem: Dict[str, Tuple[List[int], List[int]]] = {}
        for i, ins in enumerate(instrs):
            if ins.op in (Op.LD, Op.GLD):
                by_mem.setdefault(ins.mem or "?", ([], []))[0].append(i)
            elif ins.op in (Op.ST, Op.GST):
                by_mem.setdefault(ins.mem or "?", ([], []))[1].append(i)
        for lds, sts in by_mem.values():
            for a, b in zip(sts, sts[1:]):
                order_edges[p].append((a, b))
            if sts:
                order_edges[p] += [(ld, sts[0]) for ld in lds]

    # ---- schedule ---------------------------------------------------------
    t0 = time.perf_counter()
    sched = schedule(proc_instrs, core_of_proc, hw, send_dst_core,
                     war_edges, order_edges, strategy=sched_strategy)
    tm["schedule"] = tm.get("schedule", 0.0) + time.perf_counter() - t0
    if check:
        validate_schedule(sched, proc_instrs, core_of_proc, hw,
                          send_dst_core, war_edges, order_edges)

    return _Arm(name, core_of_proc, part, proc_instrs, proc_tables,
                send_dst_core, send_meta, war_edges, order_edges, share,
                commit_def, commit_movs, shared_commits, remat_stats, sched)


def compile_circuit(circuit: Circuit,
                    hw: Optional[HardwareConfig] = None,
                    strategy: str = "balanced",
                    use_luts: bool = True,
                    optimize: bool = True,
                    sched_strategy: str = "slack",
                    placement: Union[str, Sequence[int]] = "anneal",
                    pipeline: str = "modulo",
                    check: bool = False,
                    timings: Optional[Dict[str, float]] = None) -> Program:
    """Compile ``circuit`` into an executable :class:`Program`.

    ``strategy`` picks the partition merge heuristic (``"balanced"`` /
    ``"lpt"``), ``sched_strategy`` the scheduler (``"slack"`` — the
    slack-driven default with rematerialization — or ``"greedy"``, the
    original scheduler kept frozen for differential testing; see
    ``core.schedule``). ``placement`` picks the process-to-core mapping
    (``core.place``): ``"anneal"`` (default) optimizes slack-weighted hop
    count and ships whichever of {annealed, identity} geometry schedules
    the lower VCPL; ``"identity"`` is the frozen process-p-on-core-p
    mapping; an explicit core list (one core id per process, all distinct)
    is a testing hook. ``pipeline`` enables cross-Vcycle modulo pipelining
    (``"modulo"``, default): boundary retiming + overlap accounting ship a
    steady-state initiation interval II < VCPL when legal, best-of-two
    against the unpipelined schedule (``stats["pipeline_pick"]``);
    ``"off"`` is the frozen unpipelined path. ``check=True`` re-validates
    the schedule against the machine model
    (``core.schedule.validate_schedule``) before emitting the binary."""
    hw = hw or HardwareConfig()
    if pipeline not in PIPELINES:
        raise ValueError(
            f"unknown pipeline mode {pipeline!r}; choose from {PIPELINES}")
    tm: Dict[str, float] = {} if timings is None else timings

    t0 = time.perf_counter()
    low = lower(circuit)
    tm["lower"] = time.perf_counter() - t0

    # ---- optimizing middle-end (PR 3; optimize=False is the bit-identical
    # legacy path: the pass pipeline is skipped entirely) ------------------
    instrs_lowered = len(low.instrs)
    opt_records: List[Dict] = []
    if optimize:
        t0 = time.perf_counter()
        low, opt_records = optimize_lowered(low)
        tm["opt"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    part0 = partition(low, hw.num_cores, strategy)
    tm["partition"] = time.perf_counter() - t0
    nproc = part0.num_procs
    assert nproc <= hw.num_cores, (nproc, hw.num_cores)

    # ---- placement (core.place): candidate process-to-core mappings ------
    t0 = time.perf_counter()
    place_stats: Dict[str, float] = {}
    if isinstance(placement, str):
        placement_name = placement
        pl = place(low, part0, hw, strategy=placement)
        place_stats = dict(pl.stats)
        ident = list(range(nproc))
        if pl.core_of_proc != ident:
            # schedule both geometries, ship the lower VCPL: the weighted
            # hop objective is a proxy — the scheduler is the arbiter
            candidates = [("anneal", pl.core_of_proc), ("identity", ident)]
        else:
            candidates = [(placement, ident)]
    else:
        placement_name = "explicit"
        cop = [int(c) for c in placement]
        if (len(cop) != nproc or len(set(cop)) != nproc
                or any(c < 0 or c >= hw.num_cores for c in cop)):
            raise ValueError(
                f"explicit placement must be {nproc} distinct core ids "
                f"< {hw.num_cores}, got {cop}")
        place_stats = {"total_hops": float(hop_cost(cop, part0.sends, hw)),
                       "weighted_hops": 0.0, "place_moves": 0.0}
        candidates = [("explicit", cop)]
    tm["place"] = time.perf_counter() - t0

    best: Optional[_Arm] = None
    for arm_name, core_of_proc in candidates:
        arm_part = part0.clone() if len(candidates) > 1 else part0
        arm = _compile_arm(arm_name, core_of_proc, low, arm_part, hw,
                           use_luts, sched_strategy, check, tm)
        # <= so identity (scheduled second) wins ties: same VCPL at a more
        # compact core numbering
        if best is None or arm.sched.vcpl <= best.sched.vcpl:
            best = arm
    assert best is not None
    if best.name == "identity" and len(candidates) > 1:
        # the annealed geometry lost at the scheduler: report identity hops
        place_stats["total_hops"] = place_stats.get(
            "identity_hops", place_stats.get("total_hops", 0.0))
        place_stats["weighted_hops"] = place_stats.get(
            "identity_weighted_hops", place_stats.get("weighted_hops", 0.0))
    part, core_of_proc, sched = best.part, best.core_of_proc, best.sched
    proc_instrs, proc_tables = best.proc_instrs, best.proc_tables
    send_meta, send_dst_core = best.send_meta, best.send_dst_core
    share, commit_def = best.share, best.commit_def
    commit_movs, shared_commits = best.commit_movs, best.shared_commits
    remat_stats = best.remat_stats
    used = max(core_of_proc) + 1 if core_of_proc else 1

    # ---- cross-Vcycle modulo pipelining (core.retime + pipeline_schedule):
    # best-of-two ship rule — the pipelined schedule replaces the baseline
    # only when its steady-state initiation interval beats the unpipelined
    # VCPL; "off" (and a losing pipelined arm) leaves the baseline binary
    # untouched bit for bit.
    vcpl0 = sched.vcpl
    crit_lb0 = int(sched.stats.get("crit_path_lb", 0))
    pipe_pick = "off"
    pipe_info: Optional[PipelineInfo] = None
    if pipeline == "modulo":
        t0 = time.perf_counter()
        output_vregs: Set[int] = set()
        for vregs in low.outputs.values():
            output_vregs.update(vregs)
        epi0 = int(sched.stats.get("epilogue", 0))
        budget = max(0, vcpl0 - crit_lb0) + epi0
        # three hoist arms: none (pure overlap accounting — the emitted
        # stream stays the baseline), aggressive retime (committed-register
        # sources visible by the critical-path bound), conservative retime
        # (no committed-register sources at all)
        hoists = [[set() for _ in range(nproc)]]
        if budget > 0:
            for theta in (crit_lb0, -1):
                h = plan_retime(proc_instrs, core_of_proc, hw, sched, share,
                                commit_def, best.war_edges, best.order_edges,
                                output_vregs, theta=theta, budget=budget)
                if h not in hoists:
                    hoists.append(h)
        best_pipe = None
        best_key = None
        for hoist in hoists:
            r = pipeline_schedule(proc_instrs, core_of_proc, hw,
                                  send_dst_core, best.war_edges,
                                  best.order_edges, share, commit_def,
                                  hoist, strategy=sched_strategy,
                                  crit_path_lb=crit_lb0, base=sched)
            if r is None:
                continue
            # ties go to the arm that retimes more work across the commit
            # boundary: same modeled throughput, but the hoisted carries
            # shorten the next iteration's critical head
            key = (r[1].ii, -sum(len(h) for h in hoist))
            if best_key is None or key < best_key:
                best_pipe, best_key = r, key
        tm["pipeline"] = time.perf_counter() - t0
        if best_pipe is not None and best_pipe[1].ii < vcpl0:
            pipe_pick = "modulo"
            sched, pipe_info = best_pipe
            if check:
                validate_schedule(sched, proc_instrs, core_of_proc, hw,
                                  send_dst_core, best.war_edges,
                                  best.order_edges, pipeline=pipe_info)

    # ---- memory placement (resolve relocations) --------------------------
    spad_base: Dict[str, int] = {}
    gmem_base: Dict[str, int] = {}
    core_spad_used = [0] * hw.num_cores
    g_used = 0
    owner_core: Dict[str, int] = {}
    def place_spad(mname: str, c: int) -> None:
        owner_core[mname] = c
        spad_base[mname] = core_spad_used[c]
        core_spad_used[c] += low.mems[mname].depth * low.mems[mname].stride
        if core_spad_used[c] > hw.spad_words:
            raise RuntimeError(
                f"scratchpad overflow on core {c}: {core_spad_used[c]} "
                f"words (memory {mname})")

    for p, mems in enumerate(part.proc_mems):
        for mname in mems:
            place_spad(mname, core_of_proc[p])
    for mname, m in low.mems.items():
        if m.is_global:
            gmem_base[mname] = g_used
            g_used += m.depth * m.stride
        elif mname not in spad_base:
            # every access optimized away (e.g. provably-dead stores): the
            # memory still gets a placement so its init image and any
            # relocatable base stay resolvable
            place_spad(mname, core_of_proc[part.priv_proc])

    def resolve(v: InitVal) -> int:
        if isinstance(v, int):
            return v & WORD_MASK
        m = low.mems[v.mem]
        base = gmem_base[v.mem] if m.is_global else spad_base[v.mem]
        addr = base + v.offset
        return (addr >> 16) & WORD_MASK if v.part == "hi" else addr & WORD_MASK

    # ---- register allocation ---------------------------------------------
    t0 = time.perf_counter()
    pinned: Dict[int, InitVal] = dict(low.vreg_init)
    for r in low.regs:
        for j, cw in enumerate(r.cur):
            pinned[cw] = (r.init >> (16 * j)) & WORD_MASK

    allocs: List[Optional[CoreAlloc]] = [None] * hw.num_cores
    for p in range(nproc):
        c = core_of_proc[p]
        # prologue carries live across the iteration boundary — their
        # machine registers must not be recycled mid-stream
        carries = ({proc_instrs[p][i].writes() for i in pipe_info.hoist[p]}
                   if pipe_info is not None else None)
        allocs[c] = allocate(sched.cores[c].slots, pinned, share[p],
                             hw.num_regs, no_recycle=carries)
    tm["regalloc"] = time.perf_counter() - t0

    # ---- emit binary -------------------------------------------------------
    C, T = hw.num_cores, max(sched.t_compute, 1)
    code = np.zeros((C, T, NUM_FIELDS), dtype=np.int32)
    luts = np.zeros((C, hw.num_luts, 16), dtype=np.uint16)
    reg_init = np.zeros((C, hw.num_regs), dtype=np.uint16)
    spad_init = np.zeros((C, max(max(core_spad_used), 1)), dtype=np.uint16)
    gmem_init = np.zeros((max(g_used, 1),), dtype=np.uint16)

    send_slot_reg: Dict[int, Tuple[int, int]] = {}  # id(ins) -> (core, slot)
    global_ops = 0
    for c in range(C):
        al = allocs[c]
        if al is None:
            continue
        vm = al.vreg_to_mreg
        for mreg, iv in al.init:
            reg_init[c, mreg] = resolve(iv)
        for t, ins in enumerate(sched.cores[c].slots):
            if ins is None:
                continue
            op = ins.op
            if op in (Op.GLD, Op.GST):
                global_ops += 1
            dst = vm.get(ins.dst, 0) if ins.writes() is not None else 0
            if op == Op.MOV and ins.dst in vm:   # commit MOV writes cur
                dst = vm[ins.dst]
            ss = [vm.get(s, 0) for s in ins.srcs] + [0] * (4 - len(ins.srcs))
            imm = ins.imm
            if op == Op.SEND:
                send_slot_reg[id(ins)] = (c, t)
            code[c, t] = (int(op), dst, ss[0], ss[1], ss[2], ss[3], imm)
    for p, tables in enumerate(proc_tables):
        c = core_of_proc[p]
        for k, tt in enumerate(tables):
            luts[c, k] = np.array(tt, dtype=np.uint16)

    # exchange tables
    xs_core, xs_slot, xd_core, xd_reg = [], [], [], []
    for e, ins in send_meta:
        c, t = send_slot_reg[id(ins)]
        dc = core_of_proc[e.dst_proc]
        dal = allocs[dc]
        assert dal is not None
        dreg = dal.vreg_to_mreg.get(e.cur_vreg)
        assert dreg is not None, (
            f"SEND target register v{e.cur_vreg} unallocated in core {dc}")
        xs_core.append(c); xs_slot.append(t)
        xd_core.append(dc); xd_reg.append(dreg)
        imm = (dc << 16) | dreg
        code[c, t, 6] = imm

    # memory images
    for mname, m in low.mems.items():
        w = np.array(m.init_words, dtype=np.uint16)
        if m.is_global:
            b = gmem_base[mname]
            gmem_init[b:b + len(w)] = w
        else:
            c, b = owner_core[mname], spad_base[mname]
            spad_init[c, b:b + len(w)] = w

    # host-visible values
    outputs: Dict[str, Tuple[int, List[int]]] = {}
    priv_core = core_of_proc[part.priv_proc]
    pal = allocs[priv_core]
    for name, vregs in low.outputs.items():
        if pal is not None and all(v in pal.vreg_to_mreg for v in vregs):
            outputs[name] = (priv_core, [pal.vreg_to_mreg[v] for v in vregs])

    # every core holding a copy of a register word (owner + duplicated
    # readers) — read_reg uses the first, elastic migration writes them all
    state_regs: Dict[str, List[List[Tuple[int, int]]]] = {}
    for r in low.regs:
        words: List[List[Tuple[int, int]]] = []
        for cw in r.cur:
            locs = [(c, allocs[c].vreg_to_mreg[cw]) for c in range(C)
                    if allocs[c] is not None and cw in allocs[c].vreg_to_mreg]
            words.append(locs)
        if all(words):
            state_regs[r.name] = words

    # partial-evaluation metadata: per-slot opcode usage + histogram (the
    # engines specialize on this; see core.bsp / kernels.vcycle)
    op_masks = slot_op_masks(code, used)
    opcodes, op_counts = np.unique(code[:used, :, 0], return_counts=True)
    op_histogram = {Op(int(o)).name: int(n)
                    for o, n in zip(opcodes, op_counts) if o}

    stats = dict(sched.stats)
    stats.update(part.stats())
    stats["mem_layout"] = {
        mname: ((0, gmem_base[mname], m.depth * m.stride, True)
                if m.is_global else
                (owner_core[mname], spad_base[mname], m.depth * m.stride,
                 False))
        for mname, m in low.mems.items()}
    crit_lb = sched.stats.get("crit_path_lb", 0)
    ship_vcpl = pipe_info.ii if pipe_info is not None else sched.vcpl
    stats.update({
        "optimize": bool(optimize),
        "sched_strategy": sched_strategy,
        "vcpl_over_lb": round(sched.vcpl / crit_lb, 4) if crit_lb else 0.0,
        "sched_seconds": round(tm.get("schedule", 0.0), 6),
        "pipeline": pipeline,
        "pipeline_pick": pipe_pick,
        "vcpl_ii": ship_vcpl,
        "vcpl_unpipelined": vcpl0,
        "pipe_prologue_len": pipe_info.prologue_len if pipe_info else 0,
        "pipe_hoisted": (pipe_info.stats["hoisted"] if pipe_info else 0),
        "sched_minimal": (ship_vcpl <= crit_lb if pipe_info is not None
                          else sched.stats.get("sched_minimal", False)),
        **remat_stats,
        "instrs_lowered": instrs_lowered,
        "instrs_opt": len(low.instrs),
        "opt_passes": opt_records,
        "commit_movs": commit_movs,
        "shared_commits": shared_commits,
        "global_ops": global_ops,
        "lut_tables": sum(len(t) for t in proc_tables),
        "lut_instrs": int((code[..., 0] == int(Op.LUT)).sum()),
        "op_histogram": op_histogram,
        "used_cores": used,
        "spad_words_max": max(core_spad_used),
        "compile_times": dict(tm),
        "placement": placement_name,
        "place_pick": best.name,
        "place_seconds": round(tm.get("place", 0.0), 6),
        **{k: v for k, v in place_stats.items() if k != "place_seconds"},
    })

    return Program(
        name=circuit.name, hw=hw, code=code, luts=luts, reg_init=reg_init,
        spad_init=spad_init, gmem_init=gmem_init,
        xchg_src_core=np.array(xs_core, dtype=np.int32),
        xchg_src_slot=np.array(xs_slot, dtype=np.int32),
        xchg_dst_core=np.array(xd_core, dtype=np.int32),
        xchg_dst_reg=np.array(xd_reg, dtype=np.int32),
        t_compute=sched.t_compute, vcpl=ship_vcpl, used_cores=used,
        outputs=outputs, state_regs=state_regs, stats=stats,
        slot_op_mask=op_masks,
        pipe_prologue=pipe_info.prologue_len if pipe_info else 0)
