"""Multi-device static BSP execution: cores sharded over a device mesh.

This is the paper's NoC scaled past one chip: a Manticore grid too large for
one accelerator is sharded over a TPU mesh, and the Vcycle-boundary exchange
becomes **one statically-shaped ``all_to_all``** per Vcycle under
``shard_map`` — the BSP superstep's communication phase. Because the compiler
knows every SEND (source core/slot, destination core/register) at compile
time, the per-device-pair message matrix is a *static* numpy table: message
``k`` from device ``s`` to device ``d`` always carries the same SEND value
into the same (core, register) cell. No runtime routing, no dynamic shapes —
the schedule is collision-free by construction, exactly as on the paper's
deflection-free torus.

The slot loop is the same partially-evaluated step the single-device engine
scans (``core.bsp.make_slot_step``): opcode branches specialized to the
program, and SEND values scattered at trace time into a compact per-device
buffer — the ``all_to_all`` payload is gathered straight from that buffer,
never from a [T, C] trace.

Per-device state (register files, scratchpads, flags) lives sharded on the
``cores`` axis; the privileged core's global memory rides along sharded per
device (only its owner mutates it).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.compat import shard_map
from .bsp import MachineState, make_slot_step
from .compile import Program


class ExchangeTables(NamedTuple):
    """Static per-device message tables ([D, D, M] sharded on axis 0)."""
    snd_idx: jax.Array    # index into the local compact SEND buffer
    rcv_core: jax.Array   # local core to write (receive side)
    rcv_reg: jax.Array    # machine register to write
    rcv_valid: jax.Array  # bool


def _build_exchange(program: Program, D: int, cl: int,
                    Cp: int) -> Tuple[np.ndarray, ...]:
    """Group the compile-time SEND table by (src_dev, dst_dev).

    Returns (snd_idx, rcv_core, rcv_reg, rcv_valid, cap, L): each device
    captures its own SENDs into a compact local buffer of ``L + 1`` words
    (``cap`` is the [T, Cp] capture-index table, sacrificial index ``L``),
    and message ``k`` of pair (s, d) reads local buffer slot
    ``snd_idx[s, d, k]``.
    """
    n = program.n_sends
    T = program.code.shape[1]
    loc_li = np.zeros((n,), np.int32)        # global send -> local index
    counts = [0] * D
    for i in range(n):
        sd = int(program.xchg_src_core[i]) // cl
        loc_li[i] = counts[sd]
        counts[sd] += 1
    L = max(counts) if counts else 0

    msgs: Dict[Tuple[int, int], list] = {}
    for i in range(n):
        sc = int(program.xchg_src_core[i]); dc = int(program.xchg_dst_core[i])
        sd, dd = sc // cl, dc // cl
        msgs.setdefault((sd, dd), []).append(
            (int(loc_li[i]), dc % cl, int(program.xchg_dst_reg[i])))
    mmax = max((len(v) for v in msgs.values()), default=0)
    mmax = max(mmax, 1)
    shape = (D, D, mmax)
    snd_idx = np.full(shape, L, np.int32)    # invalid -> sacrificial slot
    rcv_core = np.zeros(shape, np.int32)
    rcv_reg = np.zeros(shape, np.int32)
    rcv_valid = np.zeros(shape, bool)
    for (sd, dd), lst in msgs.items():
        for k, (li, dcore, dreg) in enumerate(lst):
            snd_idx[sd, dd, k] = li
            # receive tables are indexed by the *receiver*: row = src device
            rcv_core[dd, sd, k] = dcore
            rcv_reg[dd, sd, k] = dreg
            rcv_valid[dd, sd, k] = True

    cap = np.full((T, Cp), L, np.int32)
    for i in range(n):
        cap[int(program.xchg_src_slot[i]),
            int(program.xchg_src_core[i])] = loc_li[i]
    return snd_idx, rcv_core, rcv_reg, rcv_valid, cap, L


class GridMachine:
    """Static BSP executor over a device mesh (axis name: 'cores')."""

    AXIS = "cores"

    def __init__(self, program: Program, mesh: Mesh):
        self.p = program
        self.mesh = mesh
        D = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        assert mesh.axis_names == (self.AXIS,), \
            "GridMachine expects a 1-D mesh over axis 'cores'"
        self.D = D
        hw = program.hw
        C = program.used_cores
        cl = max(1, -(-C // D))            # cores per device
        Cp = cl * D
        self.C, self.cl, self.Cp = C, cl, Cp

        code = np.zeros((program.code.shape[1], Cp, 7), np.int32)
        code[:, :C] = program.code[:C].transpose(1, 0, 2)
        luts = np.zeros((Cp,) + program.luts.shape[1:], np.uint32)
        luts[:C] = program.luts[:C]
        regs = np.zeros((Cp, program.reg_init.shape[1]), np.uint32)
        regs[:C] = program.reg_init[:C]
        spads = np.zeros((Cp, program.spad_init.shape[1]), np.uint32)
        spads[:C] = program.spad_init[:C]

        (snd_idx, rcv_core, rcv_reg, rcv_valid, cap,
         L) = _build_exchange(program, D, cl, Cp)
        self.L = L

        sh = lambda *spec: NamedSharding(mesh, P(*spec))
        # code/cap are [T, Cp(, 7)]: shard the core axis
        self.code = jax.device_put(code, sh(None, self.AXIS, None))
        self.cap = jax.device_put(cap, sh(None, self.AXIS))
        self.luts = jax.device_put(luts, sh(self.AXIS))
        self.reg0 = jax.device_put(regs, sh(self.AXIS))
        self.spad0 = jax.device_put(spads, sh(self.AXIS))
        gmem = np.broadcast_to(program.gmem_init.astype(np.uint32),
                               (D,) + program.gmem_init.shape).copy()
        self.gmem0 = jax.device_put(gmem, sh(self.AXIS))

        self.xt = ExchangeTables(*[
            jax.device_put(a, sh(self.AXIS))
            for a in (snd_idx, rcv_core, rcv_reg, rcv_valid)])
        self.cache_lines = hw.cache_words // hw.cache_line_words
        op_set = program.op_set()

        def device_vcycle(code, cap, luts, regs, spads, gmem, flags, tags,
                          counters, xt: ExchangeTables):
            # local shapes: code [T, cl, 7]; gmem [1, G]; tables [1, D, M]
            gmem = gmem[0]
            local_step = make_slot_step(
                luts, max(spads.shape[1], 1), max(gmem.shape[0], 1),
                self.cache_lines, hw.cache_line_words, hw.cache_hit_stall,
                hw.cache_miss_stall, op_set=op_set)
            sbuf = jnp.zeros((L + 1,), jnp.uint32)
            carry = (regs, spads, gmem, flags, tags[0], counters[0], sbuf)
            carry, _ = jax.lax.scan(local_step, carry, (code, cap))
            regs, spads, gmem, flags, tags, counters, sbuf = carry
            # ---- BSP exchange: one all_to_all per Vcycle, payload read
            # straight from the compact SEND buffer ----
            out = sbuf[xt.snd_idx[0]]                  # [D, M]
            inb = jax.lax.all_to_all(out, self.AXIS, 0, 0, tiled=True)
            rcv_core, rcv_reg, rcv_valid = (xt.rcv_core[0], xt.rcv_reg[0],
                                            xt.rcv_valid[0])
            # masked scatter: invalid entries land in a sacrificial register
            # column appended to the register file
            pad = jnp.zeros((regs.shape[0], 1), regs.dtype)
            regs_x = jnp.concatenate([regs, pad], axis=1)
            dst_core = jnp.where(rcv_valid, rcv_core, 0).reshape(-1)
            dst_reg = jnp.where(rcv_valid, rcv_reg,
                                regs.shape[1]).reshape(-1)
            regs_x = regs_x.at[dst_core, dst_reg].set(inb.reshape(-1))
            regs = regs_x[:, :-1]
            counters = counters.at[0].add(jnp.uint32(1))
            return regs, spads, gmem[None], flags, tags[None], counters[None]

        spec_c = P(self.AXIS)
        self._vcycle = shard_map(
            device_vcycle, mesh=mesh,
            in_specs=(P(None, self.AXIS, None), P(None, self.AXIS), spec_c,
                      spec_c, spec_c, spec_c, spec_c, spec_c, spec_c,
                      ExchangeTables(*([spec_c] * 4))),
            out_specs=(spec_c, spec_c, spec_c, spec_c, spec_c, spec_c),
            check_vma=False)

        @functools.partial(jax.jit, static_argnames=("num_cycles",))
        def run(state, num_cycles):
            def cond(c):
                cyc, st = c
                return (cyc < num_cycles) & jnp.all(st[3] == 0)

            def body(c):
                cyc, st = c
                regs, spads, gmem, flags, tags, counters = self._vcycle(
                    self.code, self.cap, self.luts, st[0], st[1], st[2],
                    st[3], st[4], st[5], self.xt)
                return cyc + 1, (regs, spads, gmem, flags, tags, counters)

            _, out = jax.lax.while_loop(cond, body,
                                        (jnp.int32(0), tuple(state)))
            return MachineState(*out)

        self._run = run

    # ------------------------------------------------------------------
    def init_state(self) -> MachineState:
        sh = lambda *spec: NamedSharding(self.mesh, P(*spec))
        D = self.D
        return MachineState(
            regs=self.reg0, spads=self.spad0, gmem=self.gmem0,
            flags=jax.device_put(np.zeros((self.Cp,), np.uint32),
                                 sh(self.AXIS)),
            cache_tags=jax.device_put(
                -np.ones((D, self.cache_lines), np.int32), sh(self.AXIS)),
            counters=jax.device_put(np.zeros((D, 4), np.uint32),
                                    sh(self.AXIS)),
        )

    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        return self._run(state, num_cycles=num_cycles)

    def exceptions(self, state: MachineState) -> Dict[int, int]:
        f = np.asarray(state.flags)[:self.C]
        return {int(c): int(e) for c, e in enumerate(f) if e}

    def read_reg(self, state: MachineState, rtl_name: str) -> int:
        words = self.p.state_regs[rtl_name]
        regs = np.asarray(state.regs)
        out = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            out |= int(regs[c, r]) << (16 * j)
        return out

    def read_output(self, state: MachineState, name: str) -> int:
        core, mregs = self.p.outputs[name]
        regs = np.asarray(state.regs)
        out = 0
        for j, r in enumerate(mregs):
            out |= int(regs[core, r]) << (16 * j)
        return out

    def perf(self, state: MachineState) -> Dict[str, int]:
        cnt = np.asarray(state.counters)[0]
        return {
            "vcycles": int(cnt[0]),
            "ghits": int(cnt[1]),
            "gmisses": int(cnt[2]),
            "stall_cycles": int(cnt[3]),
            "machine_cycles": int(cnt[0]) * self.p.vcpl + int(cnt[3]),
        }
