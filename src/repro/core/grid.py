"""Multi-device static BSP execution: cores sharded over a device mesh.

This is the paper's NoC scaled past one chip: a Manticore grid too large for
one accelerator is sharded over a TPU mesh, and the Vcycle-boundary exchange
becomes **one statically-shaped ``all_to_all``** per Vcycle under
``shard_map`` — the BSP superstep's communication phase. Because the compiler
knows every SEND (source core/slot, destination core/register) at compile
time, the per-device-pair message matrix is a *static* numpy table: message
``k`` from device ``s`` to device ``d`` always carries the same SEND value
into the same (core, register) cell. No runtime routing, no dynamic shapes —
the schedule is collision-free by construction, exactly as on the paper's
deflection-free torus.

The slot loop is the same partially-evaluated step the single-device engine
scans (``core.bsp.make_slot_step``): opcode branches specialized to the
program, and SEND values scattered at trace time into a compact per-device
buffer — the ``all_to_all`` payload is gathered straight from that buffer,
never from a [T, C] trace.

Vcycles are dispatched in **chunks of K** under one ``lax.scan`` (matching
the single-device engine): each Vcycle is predicated on the exception
flags, and the host syncs the flags once per chunk instead of compiling a
``num_cycles``-static ``while_loop``.

``GridMachine(prog, mesh, images=[...])`` runs **B batched stimuli**: every
state leaf gains a leading ``[B]`` axis (still sharded over the cores
axis), the per-device slot scan is ``vmap``-ed over B, and the per-Vcycle
``all_to_all`` moves the whole ``[B, n_sends]`` payload in a single
collective. Exceptions freeze per batch element.

Per-device state (register files, scratchpads, flags) lives sharded on the
``cores`` axis; the privileged core's global memory rides along sharded per
device (only its owner mutates it).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.compat import shard_map
from .bsp import DEFAULT_CHUNK, MachineState, dispatch_chunks, make_slot_step
from .compile import Program


class ExchangeTables(NamedTuple):
    """Static per-device message tables ([D, D, M] sharded on axis 0)."""
    snd_idx: jax.Array    # index into the local compact SEND buffer
    rcv_core: jax.Array   # local core to write (receive side)
    rcv_reg: jax.Array    # machine register to write
    rcv_valid: jax.Array  # bool


def _build_exchange(program: Program, D: int, cl: int,
                    Cp: int) -> Tuple[np.ndarray, ...]:
    """Group the compile-time SEND table by (src_dev, dst_dev).

    Returns (snd_idx, rcv_core, rcv_reg, rcv_valid, cap, L): each device
    captures its own SENDs into a compact local buffer of ``L + 1`` words
    (``cap`` is the [T, Cp] capture-index table, sacrificial index ``L``),
    and message ``k`` of pair (s, d) reads local buffer slot
    ``snd_idx[s, d, k]``.
    """
    n = program.n_sends
    T = program.code.shape[1]
    loc_li = np.zeros((n,), np.int32)        # global send -> local index
    counts = [0] * D
    for i in range(n):
        sd = int(program.xchg_src_core[i]) // cl
        loc_li[i] = counts[sd]
        counts[sd] += 1
    L = max(counts) if counts else 0

    msgs: Dict[Tuple[int, int], list] = {}
    for i in range(n):
        sc = int(program.xchg_src_core[i]); dc = int(program.xchg_dst_core[i])
        sd, dd = sc // cl, dc // cl
        msgs.setdefault((sd, dd), []).append(
            (int(loc_li[i]), dc % cl, int(program.xchg_dst_reg[i])))
    mmax = max((len(v) for v in msgs.values()), default=0)
    mmax = max(mmax, 1)
    shape = (D, D, mmax)
    snd_idx = np.full(shape, L, np.int32)    # invalid -> sacrificial slot
    rcv_core = np.zeros(shape, np.int32)
    rcv_reg = np.zeros(shape, np.int32)
    rcv_valid = np.zeros(shape, bool)
    for (sd, dd), lst in msgs.items():
        for k, (li, dcore, dreg) in enumerate(lst):
            snd_idx[sd, dd, k] = li
            # receive tables are indexed by the *receiver*: row = src device
            rcv_core[dd, sd, k] = dcore
            rcv_reg[dd, sd, k] = dreg
            rcv_valid[dd, sd, k] = True

    cap = np.full((T, Cp), L, np.int32)
    for i in range(n):
        cap[int(program.xchg_src_slot[i]),
            int(program.xchg_src_core[i])] = loc_li[i]
    return snd_idx, rcv_core, rcv_reg, rcv_valid, cap, L


class GridMachine:
    """Static BSP executor over a device mesh (axis name: 'cores').

    ``images=[(reg_init, spad_init, gmem_init), ...]`` selects batched
    mode: B stimuli of the one compiled program run together, each state
    leaf carrying a leading [B] axis.
    """

    AXIS = "cores"

    def __init__(self, program: Program, mesh: Mesh,
                 images=None, chunk: int = DEFAULT_CHUNK):
        self.p = program
        self.mesh = mesh
        self.chunk = max(1, int(chunk))
        D = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        assert mesh.axis_names == (self.AXIS,), \
            "GridMachine expects a 1-D mesh over axis 'cores'"
        self.D = D
        hw = program.hw
        C = program.used_cores
        cl = max(1, -(-C // D))            # cores per device
        Cp = cl * D
        self.C, self.cl, self.Cp = C, cl, Cp
        self.B = len(images) if images is not None else None
        R = program.used_reg_count()       # active-register compaction
        self.R = R

        code = np.zeros((program.code.shape[1], Cp, 7), np.int32)
        code[:, :C] = program.code[:C].transpose(1, 0, 2)
        luts = np.zeros((Cp,) + program.luts.shape[1:], np.uint32)
        luts[:C] = program.luts[:C]

        def pad_cores(a, fill=0):
            out = np.full((Cp,) + a.shape[1:], fill, np.uint32)
            out[:C] = a[:C]
            return out

        if images is None:
            regs = pad_cores(program.reg_init[:, :R])
            spads = pad_cores(program.spad_init)
            gmem = np.broadcast_to(program.gmem_init.astype(np.uint32),
                                   (D,) + program.gmem_init.shape).copy()
        else:
            regs = np.stack([pad_cores(np.asarray(ri)[:, :R])
                             for ri, _, _ in images])
            spads = np.stack([pad_cores(np.asarray(si))
                              for _, si, _ in images])
            gmem = np.stack([
                np.broadcast_to(np.asarray(gi).astype(np.uint32),
                                (D,) + np.asarray(gi).shape)
                for _, _, gi in images]).copy()

        (snd_idx, rcv_core, rcv_reg, rcv_valid, cap,
         L) = _build_exchange(program, D, cl, Cp)
        self.L = L

        sh = lambda *spec: NamedSharding(mesh, P(*spec))
        bsp = (None,) if self.B is not None else ()   # leading batch axis
        # code/cap are [T, Cp(, 7)]: shard the core axis
        self.code = jax.device_put(code, sh(None, self.AXIS, None))
        self.cap = jax.device_put(cap, sh(None, self.AXIS))
        self.luts = jax.device_put(luts, sh(self.AXIS))
        self.reg0 = jax.device_put(regs, sh(*bsp, self.AXIS))
        self.spad0 = jax.device_put(spads, sh(*bsp, self.AXIS))
        self.gmem0 = jax.device_put(gmem, sh(*bsp, self.AXIS))

        self.xt = ExchangeTables(*[
            jax.device_put(a, sh(self.AXIS))
            for a in (snd_idx, rcv_core, rcv_reg, rcv_valid)])
        self.cache_lines = hw.cache_words // hw.cache_line_words
        op_set = program.op_set()

        def local_vcycle(code, cap, luts, regs, spads, gmem, flags, tags,
                         counters):
            """One device's slot scan for one stimulus (local shapes:
            code [T, cl, 7], gmem [G]); returns the 7-tuple carry whose
            last entry is the compact [L + 1] SEND buffer."""
            local_step = make_slot_step(
                luts, max(spads.shape[1], 1), max(gmem.shape[0], 1),
                self.cache_lines, hw.cache_line_words, hw.cache_hit_stall,
                hw.cache_miss_stall, op_set=op_set)
            sbuf = jnp.zeros((L + 1,), jnp.uint32)
            carry = (regs, spads, gmem, flags, tags, counters, sbuf)
            carry, _ = jax.lax.scan(local_step, carry, (code, cap))
            return carry

        def scatter_in(regs, inb, rcv_core, rcv_reg, rcv_valid):
            # masked scatter: invalid entries land in a sacrificial register
            # column appended to the register file
            pad = jnp.zeros((regs.shape[0], 1), regs.dtype)
            regs_x = jnp.concatenate([regs, pad], axis=1)
            dst_core = jnp.where(rcv_valid, rcv_core, 0).reshape(-1)
            dst_reg = jnp.where(rcv_valid, rcv_reg,
                                regs.shape[1]).reshape(-1)
            regs_x = regs_x.at[dst_core, dst_reg].set(inb.reshape(-1))
            return regs_x[:, :-1]

        if self.B is None:
            def device_vcycle(code, cap, luts, regs, spads, gmem, flags,
                              tags, counters, xt: ExchangeTables):
                # local shapes: code [T, cl, 7]; gmem [1, G]; xt [1, D, M]
                carry = local_vcycle(code, cap, luts, regs, spads, gmem[0],
                                     flags, tags[0], counters[0])
                regs, spads, gmem, flags, tags, counters, sbuf = carry
                # ---- BSP exchange: one all_to_all per Vcycle, payload
                # read straight from the compact SEND buffer ----
                out = sbuf[xt.snd_idx[0]]              # [D, M]
                inb = jax.lax.all_to_all(out, self.AXIS, 0, 0, tiled=True)
                regs = scatter_in(regs, inb, xt.rcv_core[0], xt.rcv_reg[0],
                                  xt.rcv_valid[0])
                counters = counters.at[0].add(jnp.uint32(1))
                return (regs, spads, gmem[None], flags, tags[None],
                        counters[None])
        else:
            def device_vcycle(code, cap, luts, regs, spads, gmem, flags,
                              tags, counters, xt: ExchangeTables):
                # local shapes: regs [B, cl, R]; gmem [B, 1, G]
                carry = jax.vmap(
                    lambda r, s, g, f, t, cn: local_vcycle(
                        code, cap, luts, r, s, g[0], f, t[0], cn[0])
                )(regs, spads, gmem, flags, tags, counters)
                regs, spads, gmem, flags, tags, counters, sbuf = carry
                # ---- BSP exchange: the whole [B, n_sends] payload moves
                # in ONE collective per Vcycle ----
                out = sbuf[:, xt.snd_idx[0]]           # [B, D, M]
                inb = jax.lax.all_to_all(out, self.AXIS, 1, 1, tiled=True)
                regs = jax.vmap(
                    lambda r, i: scatter_in(r, i, xt.rcv_core[0],
                                            xt.rcv_reg[0], xt.rcv_valid[0])
                )(regs, inb)
                counters = counters.at[:, 0].add(jnp.uint32(1))
                return (regs, spads, gmem[:, None], flags, tags[:, None],
                        counters[:, None])

        spec_c = P(self.AXIS)
        bspec = lambda *tail: P(*bsp, self.AXIS, *tail)
        state_specs = (bspec(None), bspec(None), bspec(None), bspec(),
                       bspec(None), bspec(None))
        self._vcycle = shard_map(
            device_vcycle, mesh=mesh,
            in_specs=(P(None, self.AXIS, None), P(None, self.AXIS), spec_c)
            + state_specs + (ExchangeTables(*([spec_c] * 4)),),
            out_specs=state_specs,
            check_vma=False)

        def step_state(st):
            out = self._vcycle(self.code, self.cap, self.luts, st[0], st[1],
                               st[2], st[3], st[4], st[5], self.xt)
            return out

        if self.B is None:
            def active_of(cyc, budget, st):
                return (cyc < budget) & jnp.all(st[3] == 0)       # scalar
        else:
            def active_of(cyc, budget, st):
                return (cyc < budget) & ~jnp.any(st[3] != 0, axis=1)  # [B]

        @jax.jit
        def run_chunk(cyc, budget, state):
            def body(c, _):
                cyc, st = c
                act = active_of(cyc, budget, st)
                new = step_state(st)
                sel = lambda n, o: jnp.where(
                    act if act.ndim == 0
                    else act.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
                st = tuple(map(sel, new, st))
                return (cyc + act.astype(jnp.int32), st), None

            (cyc, state), _ = jax.lax.scan(body, (cyc, state), None,
                                           length=self.chunk)
            return cyc, state

        self._run_chunk = run_chunk

    # ------------------------------------------------------------------
    def init_state(self) -> MachineState:
        sh = lambda *spec: NamedSharding(self.mesh, P(*spec))
        D, B = self.D, self.B
        lead = () if B is None else (B,)
        bsp = () if B is None else (None,)
        return MachineState(
            regs=self.reg0, spads=self.spad0, gmem=self.gmem0,
            flags=jax.device_put(np.zeros(lead + (self.Cp,), np.uint32),
                                 sh(*bsp, self.AXIS)),
            cache_tags=jax.device_put(
                -np.ones(lead + (D, self.cache_lines), np.int32),
                sh(*bsp, self.AXIS)),
            counters=jax.device_put(np.zeros(lead + (D, 4), np.uint32),
                                    sh(*bsp, self.AXIS)),
        )

    def run(self, state: MachineState, num_cycles: int) -> MachineState:
        cyc = (jnp.int32(0) if self.B is None
               else jnp.zeros((self.B,), jnp.int32))
        carry = dispatch_chunks(
            self._run_chunk, cyc, tuple(state), self.chunk,
            int(num_cycles), lambda f: (f != 0).any(axis=-1).all())
        return MachineState(*carry)

    def _elem(self, a, b):
        """Strip the batch axis: element ``b`` (default 0) when batched,
        the array itself when not."""
        if self.B is None:
            return a
        return a[0 if b is None else b]

    def exceptions(self, state: MachineState, b: Optional[int] = None):
        """Exceptions as {core: id}; with batched state and ``b=None``,
        one dict per batch element (mirroring BatchedMachine)."""
        if self.B is not None and b is None:
            return [self.exceptions(state, i) for i in range(self.B)]
        f = np.asarray(self._elem(state.flags, b))[:self.C]
        return {int(c): int(e) for c, e in enumerate(f) if e}

    def read_reg(self, state: MachineState, rtl_name: str,
                 b: Optional[int] = None) -> int:
        words = self.p.state_regs[rtl_name]
        regs = np.asarray(self._elem(state.regs, b))
        out = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            out |= int(regs[c, r]) << (16 * j)
        return out

    def read_output(self, state: MachineState, name: str,
                    b: Optional[int] = None) -> int:
        core, mregs = self.p.outputs[name]
        regs = np.asarray(self._elem(state.regs, b))
        out = 0
        for j, r in enumerate(mregs):
            out |= int(regs[core, r]) << (16 * j)
        return out

    def perf(self, state: MachineState,
             b: Optional[int] = None) -> Dict[str, int]:
        """Performance counters (device 0 holds the privileged core). With
        batched state and ``b=None``, aggregates over the batch."""
        if self.B is not None and b is None:
            cnt = np.asarray(state.counters)[:, 0].sum(axis=0)
        else:
            cnt = np.asarray(self._elem(state.counters, b))[0]
        return {
            "vcycles": int(cnt[0]),
            "ghits": int(cnt[1]),
            "gmisses": int(cnt[2]),
            "stall_cycles": int(cnt[3]),
            "machine_cycles": int(cnt[0]) * self.p.vcpl + int(cnt[3]),
        }
