"""Numpy ISA simulator for compiled Programs.

The paper (§6) keeps interpreters for both IRs to validate compiler passes;
this is ours for the *lower* level: a direct, jit-free executor of the
binary + exchange schedule. Used heavily by the hypothesis property tests
(fast per-example, no XLA compile) and as a second, independent oracle
against the jnp/Pallas engines.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .compile import Program
from .isa import Op

M = 0xFFFF


class IsaSim:
    def __init__(self, prog: Program):
        self.p = prog
        C = prog.used_cores
        self.C = C
        self.code = prog.code[:C]          # [C, T, 7]
        self.luts = prog.luts[:C].astype(np.uint32)
        self.regs = prog.reg_init[:C].astype(np.uint32).copy()
        self.spads = prog.spad_init[:C].astype(np.uint32).copy()
        self.gmem = prog.gmem_init.astype(np.uint32).copy()
        self.flags = np.zeros((C,), np.uint32)
        self.cycle = 0

    def _exec_one(self, c: int, w) -> int:
        op, dst, s1, s2, s3, s4, imm = (int(x) for x in w)
        r = self.regs[c]
        v1, v2, v3, v4 = int(r[s1]), int(r[s2]), int(r[s3]), int(r[s4])
        res = 0
        o = Op(op)
        if o == Op.NOP:
            return 0
        elif o == Op.MOV:
            res = v1
        elif o == Op.MOVI:
            res = imm & M
        elif o == Op.ADD:
            res = (v1 + v2) & M
        elif o == Op.ADDC:
            res = (v1 + v2 + v3) & M
        elif o == Op.CARRY:
            res = (v1 + v2 + v3) >> 16
        elif o == Op.SUB:
            res = (v1 - v2) & M
        elif o == Op.SUBB:
            res = (v1 - v2 - v3) & M
        elif o == Op.BORROW:
            res = 1 if v1 - v2 - v3 < 0 else 0
        elif o == Op.MUL:
            res = (v1 * v2) & M
        elif o == Op.MULH:
            res = (v1 * v2) >> 16
        elif o == Op.AND:
            res = v1 & v2
        elif o == Op.OR:
            res = v1 | v2
        elif o == Op.XOR:
            res = v1 ^ v2
        elif o == Op.NOT:
            res = (~v1) & M
        elif o == Op.MUX:
            res = v2 if v1 else v3
        elif o == Op.SEQ:
            res = int(v1 == v2)
        elif o == Op.SNE:
            res = int(v1 != v2)
        elif o == Op.SLTU:
            res = int(v1 < v2)
        elif o == Op.SLL:
            res = (v1 << (imm & 15)) & M
        elif o == Op.SRL:
            res = v1 >> (imm & 15)
        elif o == Op.SRA:
            sv = v1 - 0x10000 if v1 & 0x8000 else v1
            res = (sv >> (imm & 15)) & M
        elif o == Op.SLLV:
            res = (v1 << (v2 & 15)) & M
        elif o == Op.SRLV:
            res = v1 >> (v2 & 15)
        elif o == Op.SLICE:
            res = (v1 >> (imm >> 5)) & ((1 << (imm & 31)) - 1)
        elif o == Op.LUT:
            tt = self.luts[c, min(imm, self.luts.shape[1] - 1)]
            res = 0
            for j in range(16):
                pat = ((v1 >> j) & 1) | (((v2 >> j) & 1) << 1) | \
                    (((v3 >> j) & 1) << 2) | (((v4 >> j) & 1) << 3)
                res |= ((int(tt[pat]) >> j) & 1) << j
        elif o == Op.LD:
            res = int(self.spads[c, v1 % self.spads.shape[1]])
        elif o == Op.ST:
            if v3:
                self.spads[c, v1 % self.spads.shape[1]] = v2
            return 0
        elif o == Op.GLD:
            res = int(self.gmem[((v1 << 16) | v2) % len(self.gmem)])
        elif o == Op.GST:
            if v4:
                self.gmem[((v1 << 16) | v2) % len(self.gmem)] = v3
            return 0
        elif o == Op.SEND:
            return v1            # traced value; no register write
        elif o == Op.EXPECT:
            if v1 != v2 and self.flags[c] == 0:
                self.flags[c] = imm
            return 0
        if dst != 0:
            self.regs[c, dst] = res
        return res

    def step(self) -> None:
        """One Vcycle: slot loop + BSP exchange."""
        T = self.code.shape[1]
        trace = np.zeros((T, self.C), np.uint32)
        for t in range(T):
            for c in range(self.C):
                if self.code[c, t, 0]:
                    trace[t, c] = self._exec_one(c, self.code[c, t])
        p = self.p
        for i in range(p.xchg_src_core.shape[0]):
            sc, ss = int(p.xchg_src_core[i]), int(p.xchg_src_slot[i])
            dc, dr = int(p.xchg_dst_core[i]), int(p.xchg_dst_reg[i])
            self.regs[dc, dr] = trace[ss, sc]
        self.cycle += 1

    def run(self, max_cycles: int) -> int:
        for _ in range(max_cycles):
            if self.flags.any():
                break
            self.step()
        return self.cycle

    def read_reg(self, name: str) -> int:
        out = 0
        for j, locs in enumerate(self.p.state_regs[name]):
            c, r = locs[0]
            out |= int(self.regs[c, r]) << (16 * j)
        return out

    def exceptions(self) -> Dict[int, int]:
        return {c: int(e) for c, e in enumerate(self.flags) if e}
