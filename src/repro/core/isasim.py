"""Numpy ISA simulator for compiled Programs.

The paper (§6) keeps interpreters for both IRs to validate compiler passes;
this is ours for the *lower* level: a direct, jit-free executor of the
binary + exchange schedule. Used heavily by the hypothesis property tests
(fast per-example, no XLA compile) and as a second, independent oracle
against the jnp/Pallas engines.

Like the engines, the simulator is partially evaluated against the static
code stream: at construction every slot is grouped by opcode (the groups
never change — the schedule is static), so a Vcycle is a handful of
vectorized numpy ops over core batches instead of a Python loop over every
(slot, core) pair, and SEND values are captured compactly instead of via a
full [T, C] trace.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .compile import Program
from .isa import Op

M = 0xFFFF


class IsaSim:
    def __init__(self, prog: Program):
        self.p = prog
        C = prog.used_cores
        self.C = C
        self.code = prog.code[:C]          # [C, T, 7]
        self.luts = prog.luts[:C].astype(np.uint32)
        # active-register compaction, mirroring core.bsp.Machine
        self.R = prog.used_reg_count()
        self.regs = prog.reg_init[:C, :self.R].astype(np.uint32).copy()
        self.spads = prog.spad_init[:C].astype(np.uint32).copy()
        self.gmem = prog.gmem_init.astype(np.uint32).copy()
        self.flags = np.zeros((C,), np.uint32)
        self.cycle = 0
        # ---- static partial evaluation of the slot loop ----
        # per slot: one entry per opcode present, with the core batch
        # executing it (see compile.slot_groups)
        from .compile import slot_groups
        slots = slot_groups(prog, C)
        self._n_sends = prog.n_sends
        self._xd_core = prog.xchg_dst_core
        self._xd_reg = prog.xchg_dst_reg
        # Rotated dispatch for modulo-pipelined programs: the first
        # ``pipe_prologue`` slots of the stream are pure recomputations of
        # the next Vcycle's hoisted carries.  They run once up front
        # (iteration 0's prologue) and thereafter as a gated tail after each
        # exchange, so every ``step()`` boundary observes fully committed
        # architectural state.
        self._P = int(prog.pipe_prologue)
        self._pro = slots[:self._P]
        self._body = slots[self._P:]
        if self._P:
            self._run_groups(self._pro)

    # ------------------------------------------------------------------
    def _exec_group(self, op: Op, cores, dst, s1, s2, s3, s4, imm,
                    sbuf, sid) -> None:
        """Execute one (opcode, core-batch) group of a slot, vectorized."""
        r = self.regs
        v1 = r[cores, s1]
        v2 = r[cores, s2]
        if op == Op.ST:
            v3 = r[cores, s3]
            addr = v1 % self.spads.shape[1]
            m = v3 != 0
            self.spads[cores[m], addr[m]] = v2[m]
            return
        if op == Op.GST:
            v3 = r[cores, s3]
            v4 = r[cores, s4]
            addr = ((v1.astype(np.uint64) << 16) | v2) % len(self.gmem)
            m = v4 != 0
            self.gmem[addr[m]] = v3[m]
            return
        if op == Op.EXPECT:
            m = (v1 != v2) & (self.flags[cores] == 0)
            self.flags[cores[m]] = imm[m]
            return

        if op == Op.MOV:
            res = v1
        elif op == Op.MOVI:
            res = imm & M
        elif op == Op.ADD:
            res = (v1 + v2) & M
        elif op == Op.ADDC:
            res = (v1 + v2 + r[cores, s3]) & M
        elif op == Op.CARRY:
            res = (v1 + v2 + r[cores, s3]) >> 16
        elif op == Op.SUB:
            res = (v1 - v2) & M
        elif op == Op.SUBB:
            res = (v1 - v2 - r[cores, s3]) & M
        elif op == Op.BORROW:
            res = (v1 < v2 + r[cores, s3]).astype(np.uint32)
        elif op == Op.MUL:
            res = (v1 * v2) & M
        elif op == Op.MULH:
            res = (v1 * v2) >> 16
        elif op == Op.AND:
            res = v1 & v2
        elif op == Op.OR:
            res = v1 | v2
        elif op == Op.XOR:
            res = v1 ^ v2
        elif op == Op.NOT:
            res = (~v1) & M
        elif op == Op.MUX:
            res = np.where(v1 != 0, v2, r[cores, s3])
        elif op == Op.SEQ:
            res = (v1 == v2).astype(np.uint32)
        elif op == Op.SNE:
            res = (v1 != v2).astype(np.uint32)
        elif op == Op.SLTU:
            res = (v1 < v2).astype(np.uint32)
        elif op == Op.SLL:
            res = (v1 << (imm & 15)) & M
        elif op == Op.SRL:
            res = v1 >> (imm & 15)
        elif op == Op.SRA:
            sv = ((v1 ^ 0x8000).astype(np.uint32) - 0x8000).astype(np.int32)
            res = (sv >> (imm & 15)).astype(np.uint32) & M
        elif op == Op.SLLV:
            res = (v1 << (v2 & 15)) & M
        elif op == Op.SRLV:
            res = v1 >> (v2 & 15)
        elif op == Op.SLICE:
            res = (v1 >> (imm >> 5)) & \
                ((np.uint32(1) << (imm & 31)) - 1)
        elif op == Op.LUT:
            tt = self.luts[cores,
                           np.minimum(imm, self.luts.shape[1] - 1)]  # [n,16]
            v3 = r[cores, s3]
            v4 = r[cores, s4]
            nv = [(~x) & M for x in (v1, v2, v3, v4)]
            res = np.zeros(len(cores), np.uint32)
            for p in range(16):
                pm = (v1 if p & 1 else nv[0]) & (v2 if p & 2 else nv[1]) \
                    & (v3 if p & 4 else nv[2]) & (v4 if p & 8 else nv[3])
                res = res | (pm & tt[:, p])
        elif op == Op.LD:
            res = self.spads[cores, v1 % self.spads.shape[1]]
        elif op == Op.GLD:
            addr = ((v1.astype(np.uint64) << 16) | v2) % len(self.gmem)
            res = self.gmem[addr]
        elif op == Op.SEND:
            sbuf[sid] = v1 & M
            return
        else:  # pragma: no cover — exhaustive over the ISA
            raise ValueError(f"unhandled opcode {op}")

        res = (res & M).astype(np.uint32)
        sbuf[sid] = res
        m = dst != 0
        self.regs[cores[m], dst[m]] = res[m]

    def _run_groups(self, slot_list) -> None:
        """Execute a list of slot groups against a throwaway send buffer."""
        sbuf = np.zeros((self._n_sends + 1,), np.uint32)
        for groups in slot_list:
            for (op, cores, dst, s1, s2, s3, s4, imm, sid) in groups:
                self._exec_group(op, cores, dst, s1, s2, s3, s4, imm,
                                 sbuf, sid)

    def step(self) -> None:
        """One Vcycle: grouped vectorized slot loop + compact BSP exchange."""
        sbuf = np.zeros((self._n_sends + 1,), np.uint32)
        for groups in self._body:
            for (op, cores, dst, s1, s2, s3, s4, imm, sid) in groups:
                self._exec_group(op, cores, dst, s1, s2, s3, s4, imm,
                                 sbuf, sid)
        if self._n_sends:
            self.regs[self._xd_core, self._xd_reg] = sbuf[:self._n_sends]
        self.cycle += 1
        if self._P and not self.flags.any():
            self._run_groups(self._pro)

    def run(self, max_cycles: int) -> int:
        for _ in range(max_cycles):
            if self.flags.any():
                break
            self.step()
        return self.cycle

    def read_reg(self, name: str) -> int:
        out = 0
        for j, locs in enumerate(self.p.state_regs[name]):
            c, r = locs[0]
            out |= int(self.regs[c, r]) << (16 * j)
        return out

    def exceptions(self) -> Dict[int, int]:
        return {c: int(e) for c, e in enumerate(self.flags) if e}
