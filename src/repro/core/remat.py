"""Partition-aware rematerialization: trade SENDs for local recompute.

After partitioning, every remote reader of a register receives its next
value over the NoC — one SEND issue slot at the producer, ``hops`` link
slots in flight, a unique arrival slot, and one epilogue replay slot at
the receiver (paper §5.2). When the next value is a *cheap pure cone*
(a constant, a MOV, a one-or-two-instruction expression) whose state
inputs the receiver already holds, recomputing it locally is strictly
cheaper than shipping it: the SEND disappears from the schedule and the
receiver pays a few compute slots it usually hides under its existing
stream.

The pass runs between :func:`~repro.core.partition.partition` and
:func:`~repro.core.schedule.schedule`, mutating the
:class:`~repro.core.partition.Partition` in place:

  * for each inbound :class:`~repro.core.partition.SendEdge` whose next
    value has a pure backward cone of at most ``max_cone`` instructions
    (``core.opt.pure_backward_cone`` over ``isa.PURE_OPS``), and whose
    current-register inputs are all already *available* on the consumer
    (owned, received over a surviving edge, or themselves rematerialized),
  * accept when the duplicated instruction count does not exceed the
    route cost (``1 + hops * send_latency + 1``: issue + flight + replay)
    and does not push the consumer's load past the pre-pass global
    maximum (rematerialization must never create a new straggler core),
  * on accept: union the cone into the consumer's instruction list,
    delete the edge, and append a local commit so the consumer updates
    its copy of the register every Vcycle — induction keeps
    self-recurrent cones (``nxt`` reading its own ``cur``) correct.

The pass only ever *removes* communication; it never adds a send. It is
run for the ``"slack"`` scheduling strategy only, keeping the
``"greedy"`` differential path bit-identical to the frozen baseline.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .isa import HardwareConfig
from .lower import Lowered
from .partition import Partition, SendEdge
from .opt import pure_backward_cone

DEFAULT_MAX_CONE = 4


def rematerialize(low: Lowered, part: Partition, hw: HardwareConfig,
                  core_of_proc: Optional[List[int]] = None,
                  max_cone: int = DEFAULT_MAX_CONE) -> Dict[str, int]:
    """Delete SendEdges whose payload is cheaper to recompute locally.

    Mutates ``part`` (``procs``, ``sends``, ``local_commits``) in place and
    returns the pass statistics. ``core_of_proc`` defaults to the identity
    placement used by :func:`~repro.core.compile.compile_circuit`.
    """
    if core_of_proc is None:
        core_of_proc = list(range(part.num_procs))
    defs = low.defs()

    owner_of_cur: Dict[int, int] = {}
    for (p, _nxt, cur) in part.local_commits:
        owner_of_cur[cur] = p

    # per-process load (instrs + outbound sends) — the straggler cap
    load = [len(p) for p in part.procs]
    inbound: Dict[int, List[SendEdge]] = {}
    for e in part.sends:
        load[e.src_proc] += 1
        inbound.setdefault(e.dst_proc, []).append(e)
    cap = max(load) if load else 0

    owned: List[Set[int]] = [set() for _ in part.procs]
    for (p, _nxt, cur) in part.local_commits:
        owned[p].add(cur)
    recv_curs: List[Set[int]] = [set() for _ in part.procs]
    for e in part.sends:
        recv_curs[e.dst_proc].add(e.cur_vreg)
    rematted: List[Set[int]] = [set() for _ in part.procs]

    # live receive counts: the epilogue-setting receiver may exceed the
    # load cap by at most the replay slots it has shed (each slot over the
    # cap risks +1 t_compute but is paid for by a guaranteed -1 epilogue)
    recv_now = [len(inbound.get(p, ())) for p in range(part.num_procs)]
    shed = [0] * part.num_procs

    cone_cache: Dict[int, Optional[Tuple[FrozenSet[int], FrozenSet[int]]]] = {}

    def cone_of(nxt: int):
        if nxt not in cone_cache:
            cone_cache[nxt] = pure_backward_cone(low, nxt, max_cone,
                                                 defs=defs)
        return cone_cache[nxt]

    deleted: Set[int] = set()          # id(edge)
    new_commits: List[Tuple[int, int, int]] = []
    sends_deleted = 0
    instrs_added = 0
    procs_touched: Set[int] = set()

    # hottest receivers first: they set the epilogue and gain the most
    order = sorted(inbound, key=lambda d: (-len(inbound[d]), d))
    for d in order:
        proc_set = set(part.procs[d])
        changed = True
        while changed:
            changed = False
            for e in sorted(inbound[d], key=lambda e: (e.cur_vreg,
                                                       e.src_proc)):
                if id(e) in deleted:
                    continue
                cone = cone_of(e.nxt_vreg)
                if cone is None:
                    continue
                cone_idx, state_reads = cone
                new = cone_idx - proc_set
                avail = owned[d] | recv_curs[d] | rematted[d]
                if any(s in owner_of_cur and s not in avail
                       for s in state_reads):
                    continue
                hops = hw.route_hops(core_of_proc[e.src_proc],
                                     core_of_proc[e.dst_proc])
                route_cost = 1 + hops * hw.send_latency + 1
                if len(new) > route_cost:
                    continue
                over = load[d] + len(new) - cap
                if over > 0:
                    other_max = max((recv_now[p]
                                     for p in range(part.num_procs)
                                     if p != d), default=0)
                    if not (recv_now[d] > other_max
                            and over <= shed[d] + 1):
                        continue
                proc_set |= new
                load[d] += len(new)
                load[e.src_proc] -= 1
                recv_now[d] -= 1
                shed[d] += 1
                for s in state_reads:
                    if s in owner_of_cur:
                        part.remat_reads.add((d, s))
                recv_curs[d].discard(e.cur_vreg)
                rematted[d].add(e.cur_vreg)
                deleted.add(id(e))
                new_commits.append((d, e.nxt_vreg, e.cur_vreg))
                sends_deleted += 1
                instrs_added += len(new)
                procs_touched.add(d)
                changed = True
        if d in procs_touched:
            part.procs[d] = sorted(proc_set)

    if deleted:
        part.sends = [e for e in part.sends if id(e) not in deleted]
        part.local_commits.extend(new_commits)
        part.remat_commits.update(new_commits)

    for e in part.sends:
        assert core_of_proc[e.src_proc] != core_of_proc[e.dst_proc], (
            "self-route SEND survived rematerialization: "
            f"{e.src_proc}->{e.dst_proc}")

    return {
        "remat_sends": sends_deleted,
        "remat_instrs": instrs_added,
        "remat_procs": len(procs_touched),
    }
