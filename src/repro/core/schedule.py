"""List scheduling + static NoC routing (paper §6.3).

Performs an abstract cycle-accurate simulation of one Vcycle over a model of
the core pipeline and the uni-directional 2D torus NoC:

  * an instruction issues when its RAW predecessors issued >= ``raw_latency``
    slots earlier (the compiler resolves hazards with NOps — there are no
    interlocks in hardware);
  * memory-order edges keep full-cycle semantics (all loads of a memory issue
    before its stores; stores keep program order);
  * WAR edges protect current-register values until their commit (either an
    explicit MOV or the Wimmer-Franz register-sharing optimization that lands
    the next value directly in the current register);
  * a SEND issues only when every link of its dimension-ordered route is free
    at the corresponding future slot and its arrival slot at the destination
    is unique (the paper's switches drop colliding messages — the schedule
    must be collision-free *by construction*);
  * received messages cost one epilogue slot each at the destination
    (they are replayed from instruction memory, §5.2).

The scheduler reports **VCPL** — machine slots per simulated RTL cycle — the
paper's exact performance model for a deterministic machine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .isa import HardwareConfig, Instr, Op

RAW = 0
ORDER = 1  # issue-order edge (memory order, WAR): latency 1


@dataclass
class CoreProgram:
    """One core's scheduled stream: slot -> instr (None = NOp)."""
    slots: List[Optional[Instr]]
    recv_count: int = 0
    # (slot, dst_core, dst_machine_reg placeholder vreg) for SENDs, filled in
    sends: List[Tuple[int, Instr]] = field(default_factory=list)


@dataclass
class ScheduleResult:
    cores: List[CoreProgram]
    t_compute: int            # executed slots per Vcycle
    vcpl: int                 # full virtual critical path (incl. epilogue)
    stats: Dict[str, float] = field(default_factory=dict)


def _route(hw: HardwareConfig, src: int, dst: int) -> List[Tuple[str, int, int]]:
    """Dimension-ordered route on the uni-directional torus: +x then +y.
    Returns a list of directed links ('x'|'y', x, y) traversed in order."""
    sx, sy = hw.core_xy(src)
    dx, dy = hw.core_xy(dst)
    links: List[Tuple[str, int, int]] = []
    x, y = sx, sy
    while x != dx:
        links.append(("x", x, y))
        x = (x + 1) % hw.grid_width
    while y != dy:
        links.append(("y", x, y))
        y = (y + 1) % hw.grid_height
    if not links:  # self-send (possible after merging); one local hop
        links.append(("x", x, y))
    return links


def schedule(core_instrs: List[List[Instr]],
             core_of_proc: List[int],
             hw: HardwareConfig,
             send_dst_core: Dict[int, int],
             war_edges: List[List[Tuple[int, int]]],
             order_edges: List[List[Tuple[int, int]]]) -> ScheduleResult:
    """Schedule every process's instruction list onto its core.

    ``core_instrs[p]`` is process p's topo-ordered instruction list (SENDs
    included). ``war_edges[p]`` / ``order_edges[p]`` are (src_idx, dst_idx)
    issue-order constraints. ``send_dst_core`` maps id(instr) -> dst core.
    """
    ncores = hw.num_cores
    L = hw.raw_latency

    # per-process dependence structures
    preds: List[List[List[Tuple[int, int]]]] = []   # p -> i -> [(j, kind)]
    succs: List[List[List[Tuple[int, int]]]] = []
    for p, instrs in enumerate(core_instrs):
        defs: Dict[int, int] = {}
        pr: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        su: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        for i, ins in enumerate(instrs):
            for s in ins.srcs:
                d = defs.get(s)
                if d is not None:
                    pr[i].append((d, RAW))
                    su[d].append((i, RAW))
            w = ins.writes()
            if w is not None and w != 0:   # vreg 0 is the constant zero
                defs[w] = i
        for (a, b) in war_edges[p] + order_edges[p]:
            pr[b].append((a, ORDER))
            su[a].append((b, ORDER))
        preds.append(pr)
        succs.append(su)

    # priority = longest latency path to any leaf (critical path first)
    prio: List[List[int]] = []
    for p, instrs in enumerate(core_instrs):
        n = len(instrs)
        pv = [0] * n
        for i in range(n - 1, -1, -1):
            best = 0
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                best = max(best, pv[j] + lat)
            pv[i] = best
        prio.append(pv)

    # lower bound on t_compute: the longest latency path through any
    # process's dependence graph, and each core's instruction load. A
    # schedule hitting this bound is provably minimal *for this partition*
    # (the middle-end's job is to shrink the bound itself — fewer, simpler
    # instructions per cone; see core.opt).
    core_load: Dict[int, int] = {}
    crit_lb = 0
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        core_load[c] = core_load.get(c, 0) + len(instrs)
        if instrs:
            crit_lb = max(crit_lb, max(prio[p]) + 1)
    crit_path_lb = max([crit_lb] + list(core_load.values()))

    # scheduling state
    n_sched: List[int] = [0] * len(core_instrs)
    sched_slot: List[List[int]] = [[-1] * len(ci) for ci in core_instrs]
    npreds_left = [[len(pp) for pp in preds[p]] for p in range(len(preds))]
    ready: List[List[int]] = [[] for _ in core_instrs]   # instr idxs
    ready_time: List[Dict[int, int]] = [dict() for _ in core_instrs]
    for p, instrs in enumerate(core_instrs):
        for i in range(len(instrs)):
            if npreds_left[p][i] == 0:
                ready[p].append(i)
                ready_time[p][i] = 0

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv_count = [0] * ncores
    core_slots: List[List[Optional[Instr]]] = [[] for _ in range(ncores)]
    core_sends: List[List[Tuple[int, Instr]]] = [[] for _ in range(ncores)]
    last_arrival = 0

    total = sum(len(ci) for ci in core_instrs)
    done = 0
    t = 0
    max_slots = 4 * total + 64 + sum(len(ci) == 0 for ci in core_instrs)
    proc_list = list(range(len(core_instrs)))
    while done < total:
        if t > max_slots:
            raise RuntimeError("scheduler failed to converge")
        for p in proc_list:
            c = core_of_proc[p]
            instrs = core_instrs[p]
            # pick highest-priority ready instr that can issue now
            cand = sorted((i for i in ready[p] if ready_time[p][i] <= t),
                          key=lambda i: (-prio[p][i], i))
            issued = None
            for i in cand:
                ins = instrs[i]
                if ins.op == Op.SEND:
                    dst = send_dst_core[id(ins)]
                    links = _route(hw, c, dst)
                    slots_needed = [t + 1 + k * hw.send_latency
                                    for k in range(len(links))]
                    arrive = t + 1 + len(links) * hw.send_latency
                    if any(s in link_busy.get(lk, set())
                           for lk, s in zip(links, slots_needed)):
                        continue
                    if arrive in arrival_busy.get(dst, set()):
                        continue
                    for lk, s in zip(links, slots_needed):
                        link_busy.setdefault(lk, set()).add(s)
                    arrival_busy.setdefault(dst, set()).add(arrive)
                    recv_count[dst] += 1
                    last_arrival = max(last_arrival, arrive)
                    core_sends[c].append((t, ins))
                issued = i
                break
            # pad with NOps up to slot t
            while len(core_slots[c]) < t:
                core_slots[c].append(None)
            if issued is not None:
                ins = instrs[issued]
                core_slots[c].append(ins)
                sched_slot[p][issued] = t
                ready[p].remove(issued)
                done += 1
                for (j, kind) in succs[p][issued]:
                    npreds_left[p][j] -= 1
                    lat = L if kind == RAW else 1
                    rt = max(ready_time[p].get(j, 0), t + lat)
                    ready_time[p][j] = rt
                    if npreds_left[p][j] == 0:
                        ready[p].append(j)
        t += 1

    t_compute = max((len(s) for s in core_slots), default=0)
    t_compute = max(t_compute, last_arrival)
    for s in core_slots:
        while len(s) < t_compute:
            s.append(None)

    epilogue = max(recv_count) if recv_count else 0
    vcpl = t_compute + epilogue

    nops = sum(1 for s in core_slots for x in s if x is None)
    sends_n = sum(len(s) for s in core_sends)
    cores = [CoreProgram(core_slots[c], recv_count[c], core_sends[c])
             for c in range(ncores)]
    res = ScheduleResult(cores, t_compute, vcpl, stats={
        "t_compute": t_compute,
        "epilogue": epilogue,
        "vcpl": vcpl,
        "nops": nops,
        "sends": sends_n,
        "instrs": total,
        "crit_path_lb": crit_path_lb,
        "sched_minimal": t_compute == crit_path_lb,
        "imem_overflow": max(0, vcpl - hw.imem_slots),
    })
    return res
