"""List scheduling + static NoC routing (paper §6.3).

Performs an abstract cycle-accurate simulation of one Vcycle over a model of
the core pipeline and the uni-directional 2D torus NoC:

  * an instruction issues when its RAW predecessors issued >= ``raw_latency``
    slots earlier (the compiler resolves hazards with NOps — there are no
    interlocks in hardware);
  * memory-order edges keep full-cycle semantics (all loads of a memory issue
    before its stores; stores keep program order);
  * WAR edges protect current-register values until their commit (either an
    explicit MOV or the Wimmer-Franz register-sharing optimization that lands
    the next value directly in the current register);
  * a SEND issues only when every link of its dimension-ordered route is free
    at the corresponding future slot and its arrival slot at the destination
    is unique (the paper's switches drop colliding messages — the schedule
    must be collision-free *by construction*);
  * received messages cost one epilogue slot each at the destination
    (they are replayed from instruction memory, §5.2).

Two strategies share this machine model:

  * ``"greedy"`` — the original scheduler, kept bit-identical for
    differential testing: priority is the longest latency path to a leaf,
    computed once; candidates are re-sorted every slot; a SEND that cannot
    claim its route simply retries next cycle.
  * ``"slack"`` (default) — a slack-driven list scheduler: per-instruction
    ASAP/ALAP times give mobility (ALAP - ASAP), the dynamic priority
    (tie-broken by successor fanout), maintained in per-process ready heaps
    so each instruction is examined O(log n) times instead of once per
    slot.  A SEND searches its route for the *earliest* collision-free slot
    and reserves links + arrival ahead of time rather than retrying, and
    its priority is biased by downstream receiver slack so cross-core
    critical paths drain first.  The pass runs under two priority
    functions (mobility-biased and pure critical-path height) and keeps
    whichever schedule lands the lower VCPL.

A SEND whose source and destination core coincide is a *local move*: it
claims no NoC link and no arrival slot and costs no epilogue replay.

The scheduler reports **VCPL** — machine slots per simulated RTL cycle — the
paper's exact performance model for a deterministic machine.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .isa import HardwareConfig, Instr, Op

RAW = 0
ORDER = 1  # issue-order edge (memory order, WAR): latency 1

STRATEGIES = ("greedy", "slack")


@dataclass
class CoreProgram:
    """One core's scheduled stream: slot -> instr (None = NOp)."""
    slots: List[Optional[Instr]]
    recv_count: int = 0
    # (slot, dst_core, dst_machine_reg placeholder vreg) for SENDs, filled in
    sends: List[Tuple[int, Instr]] = field(default_factory=list)


@dataclass
class ScheduleResult:
    cores: List[CoreProgram]
    t_compute: int            # executed slots per Vcycle
    vcpl: int                 # full virtual critical path (incl. epilogue)
    stats: Dict[str, float] = field(default_factory=dict)


def _route(hw: HardwareConfig, src: int, dst: int) -> List[Tuple[str, int, int]]:
    """Dimension-ordered route on the uni-directional torus: +x then +y.
    Returns a list of directed links ('x'|'y', x, y) traversed in order.
    A src == dst route is empty: a self-send is a local move that never
    touches the NoC."""
    sx, sy = hw.core_xy(src)
    dx, dy = hw.core_xy(dst)
    links: List[Tuple[str, int, int]] = []
    x, y = sx, sy
    while x != dx:
        links.append(("x", x, y))
        x = (x + 1) % hw.grid_width
    while y != dy:
        links.append(("y", x, y))
        y = (y + 1) % hw.grid_height
    return links


def _build_deps(core_instrs: List[List[Instr]],
                war_edges: List[List[Tuple[int, int]]],
                order_edges: List[List[Tuple[int, int]]]):
    """Per-process dependence graph: preds[p][i] / succs[p][i] = [(j, kind)]."""
    preds: List[List[List[Tuple[int, int]]]] = []
    succs: List[List[List[Tuple[int, int]]]] = []
    for p, instrs in enumerate(core_instrs):
        defs: Dict[int, int] = {}
        pr: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        su: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        for i, ins in enumerate(instrs):
            for s in ins.srcs:
                d = defs.get(s)
                if d is not None:
                    pr[i].append((d, RAW))
                    su[d].append((i, RAW))
            w = ins.writes()
            if w is not None and w != 0:   # vreg 0 is the constant zero
                defs[w] = i
        for (a, b) in war_edges[p] + order_edges[p]:
            pr[b].append((a, ORDER))
            su[a].append((b, ORDER))
        preds.append(pr)
        succs.append(su)
    return preds, succs


def schedule(core_instrs: List[List[Instr]],
             core_of_proc: List[int],
             hw: HardwareConfig,
             send_dst_core: Dict[int, int],
             war_edges: List[List[Tuple[int, int]]],
             order_edges: List[List[Tuple[int, int]]],
             strategy: str = "slack") -> ScheduleResult:
    """Schedule every process's instruction list onto its core.

    ``core_instrs[p]`` is process p's topo-ordered instruction list (SENDs
    included). ``war_edges[p]`` / ``order_edges[p]`` are (src_idx, dst_idx)
    issue-order constraints. ``send_dst_core`` maps id(instr) -> dst core.
    ``strategy`` selects the scheduling policy (see module docstring).
    """
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown sched strategy {strategy!r}; choose from {STRATEGIES}")
    ncores = hw.num_cores
    L = hw.raw_latency

    preds, succs = _build_deps(core_instrs, war_edges, order_edges)

    # priority = longest latency path to any leaf (critical path first)
    prio: List[List[int]] = []
    for p, instrs in enumerate(core_instrs):
        n = len(instrs)
        pv = [0] * n
        for i in range(n - 1, -1, -1):
            best = 0
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                best = max(best, pv[j] + lat)
            pv[i] = best
        prio.append(pv)

    # lower bound on t_compute: the longest latency path through any
    # process's dependence graph, and each core's instruction load. A
    # schedule hitting this bound is provably minimal *for this partition*
    # (the middle-end's job is to shrink the bound itself — fewer, simpler
    # instructions per cone; see core.opt).
    core_load: Dict[int, int] = {}
    crit_lb = 0
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        core_load[c] = core_load.get(c, 0) + len(instrs)
        if instrs:
            crit_lb = max(crit_lb, max(prio[p]) + 1)
    crit_path_lb = max([crit_lb] + list(core_load.values()))

    sched_prio = None
    if strategy == "greedy":
        passres = _greedy_pass(core_instrs, core_of_proc, hw, send_dst_core,
                               preds, succs, prio, ncores)
    else:
        # Two cheap list-scheduling passes over the same machine model:
        # mobility priority wins on communication-heavy graphs (it drains
        # low-slack cross-core chains first), pure height priority on
        # compute-dense ones. Keep whichever lands the lower VCPL
        # (mobility on ties — it is the primary policy).
        best = None
        for pr in ("mobility", "height"):
            pres = _slack_pass(core_instrs, core_of_proc, hw, send_dst_core,
                               preds, succs, ncores, core_load, pr)
            if best is None or _pass_vcpl(pres) < _pass_vcpl(best[0]):
                best = (pres, pr)
        passres, sched_prio = best
    core_slots, core_sends, recv_count, last_arrival = passres

    total = sum(len(ci) for ci in core_instrs)
    res = _finish(core_slots, core_sends, recv_count, last_arrival, ncores,
                  total, crit_path_lb, hw, strategy)
    if sched_prio is not None:
        res.stats["sched_prio"] = sched_prio
    return res


def _pass_vcpl(passres) -> int:
    """VCPL of a raw scheduling pass result, before padding/stats."""
    core_slots, _sends, recv_count, last_arrival = passres
    t_comp = max([len(s) for s in core_slots] + [last_arrival], default=0)
    return t_comp + (max(recv_count) if recv_count else 0)


# ----------------------------------------------------------------------
# greedy pass — the original scheduler, frozen for differential testing
# ----------------------------------------------------------------------

def _greedy_pass(core_instrs, core_of_proc, hw, send_dst_core,
                 preds, succs, prio, ncores):
    L = hw.raw_latency

    n_sched: List[int] = [0] * len(core_instrs)
    sched_slot: List[List[int]] = [[-1] * len(ci) for ci in core_instrs]
    npreds_left = [[len(pp) for pp in preds[p]] for p in range(len(preds))]
    ready: List[List[int]] = [[] for _ in core_instrs]   # instr idxs
    ready_time: List[Dict[int, int]] = [dict() for _ in core_instrs]
    for p, instrs in enumerate(core_instrs):
        for i in range(len(instrs)):
            if npreds_left[p][i] == 0:
                ready[p].append(i)
                ready_time[p][i] = 0

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv_count = [0] * ncores
    core_slots: List[List[Optional[Instr]]] = [[] for _ in range(ncores)]
    core_sends: List[List[Tuple[int, Instr]]] = [[] for _ in range(ncores)]
    last_arrival = 0

    total = sum(len(ci) for ci in core_instrs)
    done = 0
    t = 0
    max_slots = 4 * total + 64 + sum(len(ci) == 0 for ci in core_instrs)
    proc_list = list(range(len(core_instrs)))
    while done < total:
        if t > max_slots:
            raise RuntimeError("scheduler failed to converge")
        for p in proc_list:
            c = core_of_proc[p]
            instrs = core_instrs[p]
            # pick highest-priority ready instr that can issue now
            cand = sorted((i for i in ready[p] if ready_time[p][i] <= t),
                          key=lambda i: (-prio[p][i], i))
            issued = None
            for i in cand:
                ins = instrs[i]
                if ins.op == Op.SEND:
                    dst = send_dst_core[id(ins)]
                    links = _route(hw, c, dst)
                    if links:
                        slots_needed = [t + 1 + k * hw.send_latency
                                        for k in range(len(links))]
                        arrive = t + 1 + len(links) * hw.send_latency
                        if any(s in link_busy.get(lk, set())
                               for lk, s in zip(links, slots_needed)):
                            continue
                        if arrive in arrival_busy.get(dst, set()):
                            continue
                        for lk, s in zip(links, slots_needed):
                            link_busy.setdefault(lk, set()).add(s)
                        arrival_busy.setdefault(dst, set()).add(arrive)
                        recv_count[dst] += 1
                        last_arrival = max(last_arrival, arrive)
                    else:
                        # self-send: local move, no NoC claims, no epilogue
                        last_arrival = max(last_arrival, t + 1)
                    core_sends[c].append((t, ins))
                issued = i
                break
            # pad with NOps up to slot t
            while len(core_slots[c]) < t:
                core_slots[c].append(None)
            if issued is not None:
                ins = instrs[issued]
                core_slots[c].append(ins)
                sched_slot[p][issued] = t
                ready[p].remove(issued)
                done += 1
                for (j, kind) in succs[p][issued]:
                    npreds_left[p][j] -= 1
                    lat = L if kind == RAW else 1
                    rt = max(ready_time[p].get(j, 0), t + lat)
                    ready_time[p][j] = rt
                    if npreds_left[p][j] == 0:
                        ready[p].append(j)
        t += 1

    return core_slots, core_sends, recv_count, last_arrival


# ----------------------------------------------------------------------
# slack pass — ASAP/ALAP mobility heaps + earliest-slot SEND reservation
# ----------------------------------------------------------------------

def _slack_pass(core_instrs, core_of_proc, hw, send_dst_core,
                preds, succs, ncores, core_load, prio_mode="mobility"):
    L = hw.raw_latency
    nproc = len(core_instrs)

    # Route (and receiver pressure) per SEND, computed once.
    routes: Dict[int, List[Tuple[str, int, int]]] = {}
    route_dst: Dict[int, int] = {}
    inbound = [0] * ncores
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        for ins in instrs:
            if ins.op == Op.SEND:
                dst = send_dst_core[id(ins)]
                routes[id(ins)] = _route(hw, c, dst)
                route_dst[id(ins)] = dst
                if dst != c:
                    inbound[dst] += 1

    # ASAP (earliest data-ready slot) and height (latency-weighted distance
    # to schedule exit, where a SEND's exit includes its route flight time).
    asap_all: List[List[int]] = []
    height_all: List[List[int]] = []
    T_est = max(core_load.values(), default=0)
    for p, instrs in enumerate(core_instrs):
        n = len(instrs)
        asap = [0] * n
        for i in range(n):
            best = 0
            for (j, kind) in preds[p][i]:
                lat = L if kind == RAW else 1
                if asap[j] + lat > best:
                    best = asap[j] + lat
            asap[i] = best
        hgt = [1] * n
        for i in range(n - 1, -1, -1):
            ins = instrs[i]
            best = 1
            if ins.op == Op.SEND:
                best = 1 + len(routes[id(ins)]) * hw.send_latency
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                if lat + hgt[j] > best:
                    best = lat + hgt[j]
            hgt[i] = best
        if n:
            T_est = max(T_est, max(asap[i] + hgt[i] for i in range(n)))
        asap_all.append(asap)
        height_all.append(hgt)

    # "mobility" priority: mobility = ALAP - ASAP = (T_est - height) - ASAP,
    # least-slack first, tie-broken by successor fanout; a SEND's mobility
    # is additionally capped by its receiver's slack (how much room the
    # destination core has before its stream + epilogue reach T_est), so
    # messages into hot receivers drain first. "height" priority: plain
    # critical-path (longest latency-weighted distance to exit) first.
    def _prio_key(p: int, i: int):
        if prio_mode == "height":
            return (-height_all[p][i], -len(succs[p][i]), i)
        ins = core_instrs[p][i]
        mob = (T_est - height_all[p][i]) - asap_all[p][i]
        if ins.op == Op.SEND:
            dst = route_dst[id(ins)]
            recv_slack = T_est - core_load.get(dst, 0) - inbound[dst]
            mob = min(mob, max(0, recv_slack))
        return (mob, -len(succs[p][i]), i)

    npreds_left = [[len(pp) for pp in preds[p]] for p in range(nproc)]
    # pend[p]: (data-ready slot, i) — promoted into ready[p] at that slot;
    # ready[p]: (mobility, -fanout, i) min-heaps.
    pend: List[List[Tuple[int, int]]] = [[] for _ in range(nproc)]
    ready: List[List[Tuple[int, int, int]]] = [[] for _ in range(nproc)]
    for p, instrs in enumerate(core_instrs):
        for i in range(len(instrs)):
            if npreds_left[p][i] == 0:
                heapq.heappush(pend[p], (0, i))

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv_count = [0] * ncores
    core_slots: List[List[Optional[Instr]]] = [[] for _ in range(ncores)]
    core_sends: List[List[Tuple[int, Instr]]] = [[] for _ in range(ncores)]
    # reserved[c][slot] = SEND committed to a future slot on core c
    reserved: List[Dict[int, Instr]] = [dict() for _ in range(ncores)]
    last_arrival = 0

    total = sum(len(ci) for ci in core_instrs)
    max_slots = 4 * total + 64 + sum(len(ci) == 0 for ci in core_instrs)

    def _mark_scheduled(p: int, i: int, slot: int) -> None:
        for (j, kind) in succs[p][i]:
            npreds_left[p][j] -= 1
            lat = L if kind == RAW else 1
            rt = slot + lat
            prev = sched_rt[p].get(j, 0)
            if rt > prev:
                sched_rt[p][j] = rt
            if npreds_left[p][j] == 0:
                heapq.heappush(pend[p], (sched_rt[p].get(j, 0), j))

    sched_rt: List[Dict[int, int]] = [dict() for _ in range(nproc)]

    def _reserve_send(p: int, i: int, ins: Instr, c: int, t: int) -> int:
        """Earliest collision-free slot >= t for this SEND: core slot free,
        every route link free at its flight slot, arrival unique at dst.
        Claims everything immediately and returns the chosen slot."""
        nonlocal last_arrival
        links = routes[id(ins)]
        dst = route_dst[id(ins)]
        nhops = len(links)
        ts = t
        while True:
            if ts > max_slots:
                raise RuntimeError("scheduler failed to converge")
            if ts in reserved[c]:
                ts += 1
                continue
            if not links:          # self-send: local move, always placeable
                last_arrival = max(last_arrival, ts + 1)
                return ts
            slots_needed = [ts + 1 + k * hw.send_latency
                            for k in range(nhops)]
            arrive = ts + 1 + nhops * hw.send_latency
            if (any(s in link_busy.get(lk, set())
                    for lk, s in zip(links, slots_needed))
                    or arrive in arrival_busy.get(dst, set())):
                ts += 1
                continue
            for lk, s in zip(links, slots_needed):
                link_busy.setdefault(lk, set()).add(s)
            arrival_busy.setdefault(dst, set()).add(arrive)
            recv_count[dst] += 1
            last_arrival = max(last_arrival, arrive)
            return ts

    emitted = 0
    t = 0
    proc_list = list(range(nproc))
    while emitted < total:
        if t > max_slots:
            raise RuntimeError("scheduler failed to converge")
        for p in proc_list:
            c = core_of_proc[p]
            instrs = core_instrs[p]
            res = reserved[c].pop(t, None)
            if res is not None:
                while len(core_slots[c]) < t:
                    core_slots[c].append(None)
                core_slots[c].append(res)
                emitted += 1
                continue
            pp, rp = pend[p], ready[p]
            while pp and pp[0][0] <= t:
                _, i = heapq.heappop(pp)
                heapq.heappush(rp, _prio_key(p, i))
            issued: Optional[Instr] = None
            while rp:
                _, _, i = heapq.heappop(rp)
                ins = instrs[i]
                if ins.op == Op.SEND:
                    ts = _reserve_send(p, i, ins, c, t)
                    core_sends[c].append((ts, ins))
                    _mark_scheduled(p, i, ts)
                    if ts == t:
                        issued = ins
                        emitted += 1
                        break
                    reserved[c][ts] = ins
                    continue   # send parked in the future; keep looking
                _mark_scheduled(p, i, t)
                issued = ins
                emitted += 1
                break
            if issued is not None:
                while len(core_slots[c]) < t:
                    core_slots[c].append(None)
                core_slots[c].append(issued)
        t += 1

    return core_slots, core_sends, recv_count, last_arrival


# ----------------------------------------------------------------------
# shared epilogue: padding, VCPL, stats
# ----------------------------------------------------------------------

def _finish(core_slots, core_sends, recv_count, last_arrival, ncores, total,
            crit_path_lb, hw, strategy) -> ScheduleResult:
    t_compute = max((len(s) for s in core_slots), default=0)
    t_compute = max(t_compute, last_arrival)
    for s in core_slots:
        while len(s) < t_compute:
            s.append(None)

    epilogue = max(recv_count) if recv_count else 0
    vcpl = t_compute + epilogue

    nops = sum(1 for s in core_slots for x in s if x is None)
    for sends in core_sends:
        sends.sort(key=lambda e: e[0])
    sends_n = sum(len(s) for s in core_sends)
    cores = [CoreProgram(core_slots[c], recv_count[c], core_sends[c])
             for c in range(ncores)]

    # per-core utilization over *used* cores (any instr or any receive)
    used = [c for c in range(ncores)
            if recv_count[c] or any(x is not None for x in core_slots[c])]
    loads = [sum(x is not None for x in core_slots[c]) for c in used]
    hist = [0] * 10
    if t_compute:
        for ld in loads:
            dens = 1.0 - ld / t_compute
            hist[min(9, int(dens * 10))] += 1

    res = ScheduleResult(cores, t_compute, vcpl, stats={
        "t_compute": t_compute,
        "epilogue": epilogue,
        "vcpl": vcpl,
        "nops": nops,
        "sends": sends_n,
        "instrs": total,
        "crit_path_lb": crit_path_lb,
        "sched_minimal": t_compute == crit_path_lb,
        "imem_overflow": max(0, vcpl - hw.imem_slots),
        "sched_strategy": strategy,
        "cores_used": len(used),
        "core_load_max": max(loads, default=0),
        "core_load_mean": round(sum(loads) / len(loads), 3) if loads else 0.0,
        "nop_density_hist": hist,
        "epilogue_share": round(epilogue / vcpl, 4) if vcpl else 0.0,
    })
    return res


# ----------------------------------------------------------------------
# independent validator
# ----------------------------------------------------------------------

def validate_schedule(res: ScheduleResult,
                      core_instrs: List[List[Instr]],
                      core_of_proc: List[int],
                      hw: HardwareConfig,
                      send_dst_core: Dict[int, int],
                      war_edges: List[List[Tuple[int, int]]],
                      order_edges: List[List[Tuple[int, int]]]) -> Dict[str, int]:
    """Independently re-check a :class:`ScheduleResult` against the machine
    model: every instruction placed exactly once on its process's core, RAW
    def->use distance >= ``hw.raw_latency``, WAR/memory-order edges strictly
    respected, NoC link slots collision-free, arrival slots unique per
    destination and within ``t_compute``, receive counts and VCPL
    consistent. Raises :class:`ValueError` on the first violation; returns
    summary counts when the schedule is valid."""
    L = hw.raw_latency
    # the partitioner duplicates instruction *objects* across processes
    # (cone duplication), so placement is keyed per core, where each object
    # occupies exactly one slot
    placed: List[Dict[int, int]] = [{} for _ in res.cores]
    for c, cp in enumerate(res.cores):
        if len(cp.slots) != res.t_compute:
            raise ValueError(
                f"core {c}: stream length {len(cp.slots)} != t_compute "
                f"{res.t_compute}")
        for s, ins in enumerate(cp.slots):
            if ins is None:
                continue
            if id(ins) in placed[c]:
                raise ValueError(
                    f"instruction placed twice on core {c}: {ins!r}")
            placed[c][id(ins)] = s

    send_ids: Set[int] = set()
    n_placed = sum(len(m) for m in placed)
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        defs: Dict[int, int] = {}
        slots: List[int] = []
        for i, ins in enumerate(instrs):
            slot = placed[c].get(id(ins))
            if slot is None:
                raise ValueError(f"proc {p} instr {i} missing from core {c}")
            slots.append(slot)
            for src in ins.srcs:
                d = defs.get(src)
                if d is not None and slot - slots[d] < L:
                    raise ValueError(
                        f"RAW violation proc {p}: {d}->{i} distance "
                        f"{slot - slots[d]} < {L}")
            w = ins.writes()
            if w is not None and w != 0:
                defs[w] = i
            if ins.op == Op.SEND:
                send_ids.add(id(ins))
        for (a, b) in war_edges[p] + order_edges[p]:
            if slots[b] <= slots[a]:
                raise ValueError(
                    f"order violation proc {p}: {a}(slot {slots[a]}) !< "
                    f"{b}(slot {slots[b]})")

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv = [0] * hw.num_cores
    listed: Set[int] = set()
    for c, cp in enumerate(res.cores):
        for (ts, ins) in cp.sends:
            if placed[c].get(id(ins)) != ts:
                raise ValueError(
                    f"send list slot ({c},{ts}) disagrees with placement "
                    f"{placed[c].get(id(ins))}")
            listed.add(id(ins))
            dst = send_dst_core[id(ins)]
            links = _route(hw, c, dst)
            if not links:
                continue           # local move: no NoC claims, no replay
            for k, lk in enumerate(links):
                sl = ts + 1 + k * hw.send_latency
                if sl in link_busy.setdefault(lk, set()):
                    raise ValueError(f"link collision on {lk} at slot {sl}")
                link_busy[lk].add(sl)
            arrive = ts + 1 + len(links) * hw.send_latency
            if arrive in arrival_busy.setdefault(dst, set()):
                raise ValueError(
                    f"arrival collision at core {dst} slot {arrive}")
            arrival_busy[dst].add(arrive)
            if arrive > res.t_compute:
                raise ValueError(
                    f"arrival {arrive} past t_compute {res.t_compute}")
            recv[dst] += 1
    if listed != send_ids:
        raise ValueError("send lists do not cover exactly the SEND instrs")
    for c, cp in enumerate(res.cores):
        if cp.recv_count != recv[c]:
            raise ValueError(
                f"core {c} recv_count {cp.recv_count} != derived {recv[c]}")
    epilogue = max(recv) if recv else 0
    if res.vcpl != res.t_compute + epilogue:
        raise ValueError(
            f"vcpl {res.vcpl} != t_compute {res.t_compute} + epilogue "
            f"{epilogue}")
    return {"instrs": n_placed, "sends": len(send_ids)}
