"""List scheduling + static NoC routing (paper §6.3).

Performs an abstract cycle-accurate simulation of one Vcycle over a model of
the core pipeline and the uni-directional 2D torus NoC:

  * an instruction issues when its RAW predecessors issued >= ``raw_latency``
    slots earlier (the compiler resolves hazards with NOps — there are no
    interlocks in hardware);
  * memory-order edges keep full-cycle semantics (all loads of a memory issue
    before its stores; stores keep program order);
  * WAR edges protect current-register values until their commit (either an
    explicit MOV or the Wimmer-Franz register-sharing optimization that lands
    the next value directly in the current register);
  * a SEND issues only when every link of its dimension-ordered route is free
    at the corresponding future slot and its arrival slot at the destination
    is unique (the paper's switches drop colliding messages — the schedule
    must be collision-free *by construction*);
  * received messages cost one epilogue slot each at the destination
    (they are replayed from instruction memory, §5.2).

Two strategies share this machine model:

  * ``"greedy"`` — the original scheduler, kept bit-identical for
    differential testing: priority is the longest latency path to a leaf,
    computed once; candidates are re-sorted every slot; a SEND that cannot
    claim its route simply retries next cycle.
  * ``"slack"`` (default) — a slack-driven list scheduler: per-instruction
    ASAP/ALAP times give mobility (ALAP - ASAP), the dynamic priority
    (tie-broken by successor fanout), maintained in per-process ready heaps
    so each instruction is examined O(log n) times instead of once per
    slot.  A SEND searches its route for the *earliest* collision-free slot
    and reserves links + arrival ahead of time rather than retrying, and
    its priority is biased by downstream receiver slack so cross-core
    critical paths drain first.  The pass runs under two priority
    functions (mobility-biased and pure critical-path height) and keeps
    whichever schedule lands the lower VCPL.

A SEND whose source and destination core coincide is a *local move*: it
claims no NoC link and no arrival slot and costs no epilogue replay.

The scheduler reports **VCPL** — machine slots per simulated RTL cycle — the
paper's exact performance model for a deterministic machine.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .isa import HardwareConfig, Instr, Op, PURE_OPS

RAW = 0
ORDER = 1  # issue-order edge (memory order, WAR): latency 1

STRATEGIES = ("greedy", "slack")
PIPELINES = ("modulo", "off")
MEM_OPS = (Op.LD, Op.ST, Op.GLD, Op.GST)


@dataclass
class CoreProgram:
    """One core's scheduled stream: slot -> instr (None = NOp)."""
    slots: List[Optional[Instr]]
    recv_count: int = 0
    # (slot, dst_core, dst_machine_reg placeholder vreg) for SENDs, filled in
    sends: List[Tuple[int, Instr]] = field(default_factory=list)


@dataclass
class ScheduleResult:
    cores: List[CoreProgram]
    t_compute: int            # executed slots per Vcycle
    vcpl: int                 # full virtual critical path (incl. epilogue)
    stats: Dict[str, float] = field(default_factory=dict)


def _route(hw: HardwareConfig, src: int, dst: int) -> List[Tuple[str, int, int]]:
    """Dimension-ordered route on the uni-directional torus: +x then +y.
    Returns a list of directed links ('x'|'y', x, y) traversed in order.
    A src == dst route is empty: a self-send is a local move that never
    touches the NoC."""
    sx, sy = hw.core_xy(src)
    dx, dy = hw.core_xy(dst)
    links: List[Tuple[str, int, int]] = []
    x, y = sx, sy
    while x != dx:
        links.append(("x", x, y))
        x = (x + 1) % hw.grid_width
    while y != dy:
        links.append(("y", x, y))
        y = (y + 1) % hw.grid_height
    return links


def _build_deps(core_instrs: List[List[Instr]],
                war_edges: List[List[Tuple[int, int]]],
                order_edges: List[List[Tuple[int, int]]]):
    """Per-process dependence graph: preds[p][i] / succs[p][i] = [(j, kind)]."""
    preds: List[List[List[Tuple[int, int]]]] = []
    succs: List[List[List[Tuple[int, int]]]] = []
    for p, instrs in enumerate(core_instrs):
        defs: Dict[int, int] = {}
        pr: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        su: List[List[Tuple[int, int]]] = [[] for _ in instrs]
        for i, ins in enumerate(instrs):
            for s in ins.srcs:
                d = defs.get(s)
                if d is not None:
                    pr[i].append((d, RAW))
                    su[d].append((i, RAW))
            w = ins.writes()
            if w is not None and w != 0:   # vreg 0 is the constant zero
                defs[w] = i
        for (a, b) in war_edges[p] + order_edges[p]:
            pr[b].append((a, ORDER))
            su[a].append((b, ORDER))
        preds.append(pr)
        succs.append(su)
    return preds, succs


def schedule(core_instrs: List[List[Instr]],
             core_of_proc: List[int],
             hw: HardwareConfig,
             send_dst_core: Dict[int, int],
             war_edges: List[List[Tuple[int, int]]],
             order_edges: List[List[Tuple[int, int]]],
             strategy: str = "slack",
             min_ready: Optional[List[Dict[int, int]]] = None
             ) -> ScheduleResult:
    """Schedule every process's instruction list onto its core.

    ``core_instrs[p]`` is process p's topo-ordered instruction list (SENDs
    included). ``war_edges[p]`` / ``order_edges[p]`` are (src_idx, dst_idx)
    issue-order constraints. ``send_dst_core`` maps id(instr) -> dst core.
    ``strategy`` selects the scheduling policy (see module docstring).
    ``min_ready[p]`` maps instruction index -> earliest issue slot — the
    modulo pipeliner uses it to keep body consumers of prologue-hoisted
    values ``raw_latency`` slots downstream of their (rotated) producers.
    """
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown sched strategy {strategy!r}; choose from {STRATEGIES}")
    ncores = hw.num_cores
    L = hw.raw_latency

    preds, succs = _build_deps(core_instrs, war_edges, order_edges)

    # priority = longest latency path to any leaf (critical path first)
    prio: List[List[int]] = []
    for p, instrs in enumerate(core_instrs):
        n = len(instrs)
        pv = [0] * n
        for i in range(n - 1, -1, -1):
            best = 0
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                best = max(best, pv[j] + lat)
            pv[i] = best
        prio.append(pv)

    # lower bound on t_compute: the longest latency path through any
    # process's dependence graph, and each core's instruction load. A
    # schedule hitting this bound is provably minimal *for this partition*
    # (the middle-end's job is to shrink the bound itself — fewer, simpler
    # instructions per cone; see core.opt).
    core_load: Dict[int, int] = {}
    crit_lb = 0
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        core_load[c] = core_load.get(c, 0) + len(instrs)
        if instrs:
            crit_lb = max(crit_lb, max(prio[p]) + 1)
    crit_path_lb = max([crit_lb] + list(core_load.values()))

    sched_prio = None
    if strategy == "greedy":
        passres = _greedy_pass(core_instrs, core_of_proc, hw, send_dst_core,
                               preds, succs, prio, ncores,
                               min_ready=min_ready)
    else:
        # Two cheap list-scheduling passes over the same machine model:
        # mobility priority wins on communication-heavy graphs (it drains
        # low-slack cross-core chains first), pure height priority on
        # compute-dense ones. Keep whichever lands the lower VCPL
        # (mobility on ties — it is the primary policy).
        best = None
        for pr in ("mobility", "height"):
            pres = _slack_pass(core_instrs, core_of_proc, hw, send_dst_core,
                               preds, succs, ncores, core_load, pr,
                               min_ready=min_ready)
            if best is None or _pass_vcpl(pres) < _pass_vcpl(best[0]):
                best = (pres, pr)
        passres, sched_prio = best
    core_slots, core_sends, recv_count, last_arrival = passres

    total = sum(len(ci) for ci in core_instrs)
    res = _finish(core_slots, core_sends, recv_count, last_arrival, ncores,
                  total, crit_path_lb, hw, strategy)
    if sched_prio is not None:
        res.stats["sched_prio"] = sched_prio
    return res


def _pass_vcpl(passres) -> int:
    """VCPL of a raw scheduling pass result, before padding/stats."""
    core_slots, _sends, recv_count, last_arrival = passres
    t_comp = max([len(s) for s in core_slots] + [last_arrival], default=0)
    return t_comp + (max(recv_count) if recv_count else 0)


# ----------------------------------------------------------------------
# greedy pass — the original scheduler, frozen for differential testing
# ----------------------------------------------------------------------

def _greedy_pass(core_instrs, core_of_proc, hw, send_dst_core,
                 preds, succs, prio, ncores, min_ready=None):
    L = hw.raw_latency

    n_sched: List[int] = [0] * len(core_instrs)
    sched_slot: List[List[int]] = [[-1] * len(ci) for ci in core_instrs]
    npreds_left = [[len(pp) for pp in preds[p]] for p in range(len(preds))]
    ready: List[List[int]] = [[] for _ in core_instrs]   # instr idxs
    ready_time: List[Dict[int, int]] = [
        dict(min_ready[p]) if min_ready else dict()
        for p in range(len(core_instrs))]
    for p, instrs in enumerate(core_instrs):
        for i in range(len(instrs)):
            if npreds_left[p][i] == 0:
                ready[p].append(i)
                ready_time[p].setdefault(i, 0)

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv_count = [0] * ncores
    core_slots: List[List[Optional[Instr]]] = [[] for _ in range(ncores)]
    core_sends: List[List[Tuple[int, Instr]]] = [[] for _ in range(ncores)]
    last_arrival = 0

    total = sum(len(ci) for ci in core_instrs)
    done = 0
    t = 0
    max_slots = 4 * total + 64 + sum(len(ci) == 0 for ci in core_instrs)
    proc_list = list(range(len(core_instrs)))
    while done < total:
        if t > max_slots:
            raise RuntimeError("scheduler failed to converge")
        for p in proc_list:
            c = core_of_proc[p]
            instrs = core_instrs[p]
            # pick highest-priority ready instr that can issue now
            cand = sorted((i for i in ready[p] if ready_time[p][i] <= t),
                          key=lambda i: (-prio[p][i], i))
            issued = None
            for i in cand:
                ins = instrs[i]
                if ins.op == Op.SEND:
                    dst = send_dst_core[id(ins)]
                    links = _route(hw, c, dst)
                    if links:
                        slots_needed = [t + 1 + k * hw.send_latency
                                        for k in range(len(links))]
                        arrive = t + 1 + len(links) * hw.send_latency
                        if any(s in link_busy.get(lk, set())
                               for lk, s in zip(links, slots_needed)):
                            continue
                        if arrive in arrival_busy.get(dst, set()):
                            continue
                        for lk, s in zip(links, slots_needed):
                            link_busy.setdefault(lk, set()).add(s)
                        arrival_busy.setdefault(dst, set()).add(arrive)
                        recv_count[dst] += 1
                        last_arrival = max(last_arrival, arrive)
                    else:
                        # self-send: local move, no NoC claims, no epilogue
                        last_arrival = max(last_arrival, t + 1)
                    core_sends[c].append((t, ins))
                issued = i
                break
            # pad with NOps up to slot t
            while len(core_slots[c]) < t:
                core_slots[c].append(None)
            if issued is not None:
                ins = instrs[issued]
                core_slots[c].append(ins)
                sched_slot[p][issued] = t
                ready[p].remove(issued)
                done += 1
                for (j, kind) in succs[p][issued]:
                    npreds_left[p][j] -= 1
                    lat = L if kind == RAW else 1
                    rt = max(ready_time[p].get(j, 0), t + lat)
                    ready_time[p][j] = rt
                    if npreds_left[p][j] == 0:
                        ready[p].append(j)
        t += 1

    return core_slots, core_sends, recv_count, last_arrival


# ----------------------------------------------------------------------
# slack pass — ASAP/ALAP mobility heaps + earliest-slot SEND reservation
# ----------------------------------------------------------------------

def _slack_pass(core_instrs, core_of_proc, hw, send_dst_core,
                preds, succs, ncores, core_load, prio_mode="mobility",
                min_ready=None):
    L = hw.raw_latency
    nproc = len(core_instrs)

    # Route (and receiver pressure) per SEND, computed once.
    routes: Dict[int, List[Tuple[str, int, int]]] = {}
    route_dst: Dict[int, int] = {}
    inbound = [0] * ncores
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        for ins in instrs:
            if ins.op == Op.SEND:
                dst = send_dst_core[id(ins)]
                routes[id(ins)] = _route(hw, c, dst)
                route_dst[id(ins)] = dst
                if dst != c:
                    inbound[dst] += 1

    # ASAP (earliest data-ready slot) and height (latency-weighted distance
    # to schedule exit, where a SEND's exit includes its route flight time).
    asap_all: List[List[int]] = []
    height_all: List[List[int]] = []
    T_est = max(core_load.values(), default=0)
    for p, instrs in enumerate(core_instrs):
        n = len(instrs)
        floor = min_ready[p] if min_ready else {}
        asap = [0] * n
        for i in range(n):
            best = floor.get(i, 0)
            for (j, kind) in preds[p][i]:
                lat = L if kind == RAW else 1
                if asap[j] + lat > best:
                    best = asap[j] + lat
            asap[i] = best
        hgt = [1] * n
        for i in range(n - 1, -1, -1):
            ins = instrs[i]
            best = 1
            if ins.op == Op.SEND:
                best = 1 + len(routes[id(ins)]) * hw.send_latency
            for (j, kind) in succs[p][i]:
                lat = L if kind == RAW else 1
                if lat + hgt[j] > best:
                    best = lat + hgt[j]
            hgt[i] = best
        if n:
            T_est = max(T_est, max(asap[i] + hgt[i] for i in range(n)))
        asap_all.append(asap)
        height_all.append(hgt)

    # "mobility" priority: mobility = ALAP - ASAP = (T_est - height) - ASAP,
    # least-slack first, tie-broken by successor fanout; a SEND's mobility
    # is additionally capped by its receiver's slack (how much room the
    # destination core has before its stream + epilogue reach T_est), so
    # messages into hot receivers drain first. "height" priority: plain
    # critical-path (longest latency-weighted distance to exit) first.
    def _prio_key(p: int, i: int):
        if prio_mode == "height":
            return (-height_all[p][i], -len(succs[p][i]), i)
        ins = core_instrs[p][i]
        mob = (T_est - height_all[p][i]) - asap_all[p][i]
        if ins.op == Op.SEND:
            dst = route_dst[id(ins)]
            recv_slack = T_est - core_load.get(dst, 0) - inbound[dst]
            mob = min(mob, max(0, recv_slack))
        return (mob, -len(succs[p][i]), i)

    npreds_left = [[len(pp) for pp in preds[p]] for p in range(nproc)]
    # pend[p]: (data-ready slot, i) — promoted into ready[p] at that slot;
    # ready[p]: (mobility, -fanout, i) min-heaps.
    pend: List[List[Tuple[int, int]]] = [[] for _ in range(nproc)]
    ready: List[List[Tuple[int, int, int]]] = [[] for _ in range(nproc)]
    sched_rt: List[Dict[int, int]] = [
        dict(min_ready[p]) if min_ready else dict() for p in range(nproc)]
    for p, instrs in enumerate(core_instrs):
        for i in range(len(instrs)):
            if npreds_left[p][i] == 0:
                heapq.heappush(pend[p], (sched_rt[p].get(i, 0), i))

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv_count = [0] * ncores
    core_slots: List[List[Optional[Instr]]] = [[] for _ in range(ncores)]
    core_sends: List[List[Tuple[int, Instr]]] = [[] for _ in range(ncores)]
    # reserved[c][slot] = SEND committed to a future slot on core c
    reserved: List[Dict[int, Instr]] = [dict() for _ in range(ncores)]
    last_arrival = 0

    total = sum(len(ci) for ci in core_instrs)
    max_slots = 4 * total + 64 + sum(len(ci) == 0 for ci in core_instrs)

    def _mark_scheduled(p: int, i: int, slot: int) -> None:
        for (j, kind) in succs[p][i]:
            npreds_left[p][j] -= 1
            lat = L if kind == RAW else 1
            rt = slot + lat
            prev = sched_rt[p].get(j, 0)
            if rt > prev:
                sched_rt[p][j] = rt
            if npreds_left[p][j] == 0:
                heapq.heappush(pend[p], (sched_rt[p].get(j, 0), j))

    def _reserve_send(p: int, i: int, ins: Instr, c: int, t: int) -> int:
        """Earliest collision-free slot >= t for this SEND: core slot free,
        every route link free at its flight slot, arrival unique at dst.
        Claims everything immediately and returns the chosen slot."""
        nonlocal last_arrival
        links = routes[id(ins)]
        dst = route_dst[id(ins)]
        nhops = len(links)
        ts = t
        while True:
            if ts > max_slots:
                raise RuntimeError("scheduler failed to converge")
            if ts in reserved[c]:
                ts += 1
                continue
            if not links:          # self-send: local move, always placeable
                last_arrival = max(last_arrival, ts + 1)
                return ts
            slots_needed = [ts + 1 + k * hw.send_latency
                            for k in range(nhops)]
            arrive = ts + 1 + nhops * hw.send_latency
            if (any(s in link_busy.get(lk, set())
                    for lk, s in zip(links, slots_needed))
                    or arrive in arrival_busy.get(dst, set())):
                ts += 1
                continue
            for lk, s in zip(links, slots_needed):
                link_busy.setdefault(lk, set()).add(s)
            arrival_busy.setdefault(dst, set()).add(arrive)
            recv_count[dst] += 1
            last_arrival = max(last_arrival, arrive)
            return ts

    emitted = 0
    t = 0
    proc_list = list(range(nproc))
    while emitted < total:
        if t > max_slots:
            raise RuntimeError("scheduler failed to converge")
        for p in proc_list:
            c = core_of_proc[p]
            instrs = core_instrs[p]
            res = reserved[c].pop(t, None)
            if res is not None:
                while len(core_slots[c]) < t:
                    core_slots[c].append(None)
                core_slots[c].append(res)
                emitted += 1
                continue
            pp, rp = pend[p], ready[p]
            while pp and pp[0][0] <= t:
                _, i = heapq.heappop(pp)
                heapq.heappush(rp, _prio_key(p, i))
            issued: Optional[Instr] = None
            while rp:
                _, _, i = heapq.heappop(rp)
                ins = instrs[i]
                if ins.op == Op.SEND:
                    ts = _reserve_send(p, i, ins, c, t)
                    core_sends[c].append((ts, ins))
                    _mark_scheduled(p, i, ts)
                    if ts == t:
                        issued = ins
                        emitted += 1
                        break
                    reserved[c][ts] = ins
                    continue   # send parked in the future; keep looking
                _mark_scheduled(p, i, t)
                issued = ins
                emitted += 1
                break
            if issued is not None:
                while len(core_slots[c]) < t:
                    core_slots[c].append(None)
                core_slots[c].append(issued)
        t += 1

    return core_slots, core_sends, recv_count, last_arrival


# ----------------------------------------------------------------------
# shared epilogue: padding, VCPL, stats
# ----------------------------------------------------------------------

def _finish(core_slots, core_sends, recv_count, last_arrival, ncores, total,
            crit_path_lb, hw, strategy) -> ScheduleResult:
    t_compute = max((len(s) for s in core_slots), default=0)
    t_compute = max(t_compute, last_arrival)
    for s in core_slots:
        while len(s) < t_compute:
            s.append(None)

    epilogue = max(recv_count) if recv_count else 0
    vcpl = t_compute + epilogue

    nops = sum(1 for s in core_slots for x in s if x is None)
    for sends in core_sends:
        sends.sort(key=lambda e: e[0])
    sends_n = sum(len(s) for s in core_sends)
    cores = [CoreProgram(core_slots[c], recv_count[c], core_sends[c])
             for c in range(ncores)]

    # per-core utilization over *used* cores (any instr or any receive)
    used = [c for c in range(ncores)
            if recv_count[c] or any(x is not None for x in core_slots[c])]
    loads = [sum(x is not None for x in core_slots[c]) for c in used]
    hist = [0] * 10
    if t_compute:
        for ld in loads:
            dens = 1.0 - ld / t_compute
            hist[min(9, int(dens * 10))] += 1

    res = ScheduleResult(cores, t_compute, vcpl, stats={
        "t_compute": t_compute,
        "epilogue": epilogue,
        "vcpl": vcpl,
        "nops": nops,
        "sends": sends_n,
        "instrs": total,
        "crit_path_lb": crit_path_lb,
        "sched_minimal": t_compute == crit_path_lb,
        "imem_overflow": max(0, vcpl - hw.imem_slots),
        "sched_strategy": strategy,
        "cores_used": len(used),
        "core_load_max": max(loads, default=0),
        "core_load_mean": round(sum(loads) / len(loads), 3) if loads else 0.0,
        "nop_density_hist": hist,
        "epilogue_share": round(epilogue / vcpl, 4) if vcpl else 0.0,
    })
    return res


# ----------------------------------------------------------------------
# cross-Vcycle modulo pipelining
# ----------------------------------------------------------------------

@dataclass
class PipelineInfo:
    """Modulo-pipelining overlay for a combined prologue+body schedule.

    The combined stream (``span`` slots: prologue ``[0, P)``, body compute,
    epilogue replays) is launched every ``ii`` slots in steady state.  All
    legality is expressed through per-commit *visibility slots* sigma — the
    slot at which a committed current-register value becomes readable:

      * local commit (shared next-value def or commit MOV) issued at slot
        ``d``: sigma = d + raw_latency (the write traverses the exec
        pipeline);
      * local move (self-send): applied with the exchange, sigma =
        t_compute + 1;
      * NoC message replayed with 1-based epilogue rank ``r``: sigma =
        t_compute + r, occupying destination-core slot t_compute + r - 1.

    A reader of current register ``v`` at slot ``s``: if ``s < sigma`` it
    reads the *previous* iteration's commit, so the next launch must wait
    for visibility — ``ii >= sigma - s``; if ``s >= sigma`` it reads this
    iteration's value, so the *next* commit must not overtake it —
    ``ii >= s - sigma + 1`` (commit-order safety).  Register WAR inside the
    body is assumed away by modulo variable expansion (see docs); only
    architectural state carries constraints: current registers (above),
    prologue carries (``ii >= last_read - def + 1``), and scratchpad
    ordering (iteration n+1's first memory op waits for iteration n's last
    store: ``ii >= max_store_slot - min_mem_slot + 1`` per process/memory).
    Resources repeat modulo ii: core issue slots (incl. replay slots), link
    claims, and arrival slots must each be collision-free mod ii.
    """
    ii: int
    prologue_len: int
    span: int
    hoist: List[Set[int]]                 # per-process hoisted instr idxs
    share: List[Dict[int, int]]           # per-process nxt -> cur shares
    commit_def: List[Dict[int, int]]      # per-process cur -> commit idx
    replay_rank: Dict[int, int]           # id(SEND) -> 1-based replay rank
    stats: Dict[str, float] = field(default_factory=dict)


def _commit_sigma(core_instrs: List[List[Instr]],
                  core_of_proc: List[int],
                  hw: HardwareConfig,
                  send_dst_core: Dict[int, int],
                  commit_def: List[Dict[int, int]],
                  slot_of: List[List[int]],
                  t_comp: int,
                  replay_rank: Optional[Dict[int, int]] = None):
    """Visibility slot per (proc, current vreg); assigns replay ranks.

    When ``replay_rank`` is None, ranks are chosen per destination core by
    ascending earliest-reader slot (unread messages last) — the replay
    order is free (the engine exchange is an atomic scatter), and this
    choice minimizes ``max(sigma - s_min)`` over inbound messages.  When
    given, the supplied ranks are used (validator mode).
    """
    L = hw.raw_latency
    nproc = len(core_instrs)
    big = 1 << 30
    sigma: List[Dict[int, int]] = [{} for _ in range(nproc)]
    for p, cd in enumerate(commit_def):
        for cur, di in cd.items():
            sigma[p][cur] = slot_of[p][di] + L

    # earliest read slot per (proc, vreg) — drives the replay order
    reader_min: Dict[Tuple[int, int], int] = {}
    for q, qinstrs in enumerate(core_instrs):
        for i, ins in enumerate(qinstrs):
            s = slot_of[q][i]
            for src in ins.srcs:
                k = (q, src)
                if s < reader_min.get(k, big):
                    reader_min[k] = s

    inbound: Dict[int, List[Tuple[int, int, int, int, Instr]]] = {}
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        for i, ins in enumerate(instrs):
            if ins.op != Op.SEND:
                continue
            q, v = ins.send_dst_proc, ins.send_dst_vreg
            dst = send_dst_core[id(ins)]
            ts = slot_of[p][i]
            if dst == c:
                if q is not None and v:
                    sigma[q][v] = t_comp + 1
                continue
            smin = reader_min.get((q, v), big) if q is not None else big
            inbound.setdefault(dst, []).append((smin, ts, p, i, ins))

    ranks: Dict[int, int] = {}
    for dst, lst in inbound.items():
        if replay_rank is None:
            lst.sort(key=lambda e: (e[0], e[1], e[2], e[3]))
            order = list(enumerate(lst, start=1))
        else:
            order = []
            for e in lst:
                r = replay_rank.get(id(e[4]))
                if r is None:
                    raise ValueError(
                        f"inbound SEND to core {dst} has no replay rank")
                order.append((r, e))
            if sorted(r for r, _ in order) != list(range(1, len(lst) + 1)):
                raise ValueError(
                    f"replay ranks at core {dst} are not a permutation of "
                    f"1..{len(lst)}")
        for r, (_smin, _ts, _p, _i, ins) in order:
            ranks[id(ins)] = r
            q, v = ins.send_dst_proc, ins.send_dst_vreg
            if q is not None and v:
                sigma[q][v] = t_comp + r
    return sigma, ranks


def _pipeline_floors(core_instrs: List[List[Instr]],
                     hoist: List[Set[int]],
                     sigma: List[Dict[int, int]],
                     slot_of: List[List[int]]) -> int:
    """Largest data-hazard lower bound on the initiation interval."""
    ii = 1
    for p, instrs in enumerate(core_instrs):
        mem_lo: Dict[str, int] = {}
        mem_st: Dict[str, int] = {}
        for i, ins in enumerate(instrs):
            s = slot_of[p][i]
            for src in set(ins.srcs):
                sg = sigma[p].get(src)
                if sg is None:
                    continue
                if s < sg:
                    ii = max(ii, sg - s)          # cross-iteration RAW
                else:
                    ii = max(ii, s - sg + 1)      # commit-order safety
            if ins.op in MEM_OPS:
                m = ins.mem or "?"
                if m not in mem_lo or s < mem_lo[m]:
                    mem_lo[m] = s
                if ins.op in (Op.ST, Op.GST):
                    if m not in mem_st or s > mem_st[m]:
                        mem_st[m] = s
        for m, st in mem_st.items():
            ii = max(ii, st - mem_lo[m] + 1)      # stores drain before reuse
        for i in hoist[p]:
            w = instrs[i].writes()
            d = slot_of[p][i]
            for j, jins in enumerate(instrs):
                if j != i and w in jins.srcs:
                    ii = max(ii, slot_of[p][j] - d + 1)   # carry WAR
    return ii


def _modulo_conflict(ii: int,
                     busy: List[Set[int]],
                     link_busy: Dict[Tuple[str, int, int], Set[int]],
                     arrival_busy: Dict[int, Set[int]]) -> bool:
    for grp in busy:
        if len({s % ii for s in grp}) != len(grp):
            return True
    for grp in link_busy.values():
        if len({s % ii for s in grp}) != len(grp):
            return True
    for grp in arrival_busy.values():
        if len({s % ii for s in grp}) != len(grp):
            return True
    return False


def _repair_modulo(comb: ScheduleResult,
                   core_instrs: List[List[Instr]],
                   core_of_proc: List[int],
                   hw: HardwareConfig,
                   preds, succs,
                   P: int, ii: int,
                   slot_of: List[List[int]]):
    """Try to make every core's issue/replay slots distinct modulo ``ii``
    by relocating instructions into free slots.

    Steady-state collisions are almost always the epilogue replay tail of
    iteration n wrapping onto the stream head of iteration n+1 — and the
    head instructions usually have slack.  A colliding instruction may move
    to any free slot of its core inside its dependence window (RAW
    distance ``raw_latency``, order edges distance 1, body region
    ``[P, t_compute)``) whose residue mod ii is unclaimed.  Replay slots
    and SENDs (whose link/arrival claims are frozen) never move.  Returns
    ``(per-core slot lists, per-proc slot positions)`` or ``None`` when
    some collision is unresolvable at this ii.  The caller re-verifies the
    repaired placement from scratch (moves shift commit visibility and
    reader slots, so the data-hazard floor must be recomputed).
    """
    L = hw.raw_latency
    t_comp = comb.t_compute
    ncores = len(comb.cores)
    slots_c = [list(cp.slots) for cp in comb.cores]
    pos = [list(sl) for sl in slot_of]
    owner: List[Dict[int, Tuple[int, int]]] = [{} for _ in range(ncores)]
    for p in range(len(core_instrs)):
        c = core_of_proc[p]
        for i, s in enumerate(pos[p]):
            owner[c][s] = (p, i)

    for c in range(ncores):
        busy = {s for s, x in enumerate(slots_c[c]) if x is not None}
        busy |= {t_comp + r for r in range(comb.cores[c].recv_count)}
        if len(busy) > ii:
            return None
        res_used: Dict[int, List[int]] = {}
        for s in sorted(busy):
            res_used.setdefault(s % ii, []).append(s)
        for r in sorted(res_used):
            group = res_used[r]
            if len(group) <= 1:
                continue
            movable = [s for s in group
                       if s < t_comp and slots_c[c][s] is not None
                       and slots_c[c][s].op != Op.SEND]
            if len(group) - len(movable) > 1:
                return None        # two immovable occupants share a residue
            need_move = movable if len(movable) < len(group) \
                else movable[1:]   # all movable: keep the earliest
            for s in need_move:
                p, i = owner[c][s]
                # an instruction never crosses the prologue/body boundary
                # during repair: hoisted carries stay in [0, P), body work
                # stays in [P, t_compute)
                lo, hi = (0, P - 1) if s < P else (P, t_comp - 1)
                for (j, kind) in preds[p][i]:
                    lo = max(lo, pos[p][j] + (L if kind == RAW else 1))
                for (j, kind) in succs[p][i]:
                    hi = min(hi, pos[p][j] - (L if kind == RAW else 1))
                s2 = None
                for cand in range(lo, hi + 1):
                    if slots_c[c][cand] is None and cand % ii not in res_used:
                        s2 = cand
                        break
                if s2 is None:
                    return None
                slots_c[c][s2] = slots_c[c][s]
                slots_c[c][s] = None
                del owner[c][s]
                owner[c][s2] = (p, i)
                pos[p][i] = s2
                group.remove(s)
                res_used[s2 % ii] = [s2]
    return slots_c, pos


def _resource_sets(res: ScheduleResult, hw: HardwareConfig,
                   send_dst_core: Dict[int, int]):
    """Core-busy / link / arrival claim sets of a combined schedule."""
    busy: List[Set[int]] = []
    for cp in res.cores:
        b = {s for s, x in enumerate(cp.slots) if x is not None}
        b |= {res.t_compute + r for r in range(cp.recv_count)}
        busy.append(b)
    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    for c, cp in enumerate(res.cores):
        for (ts, ins) in cp.sends:
            dst = send_dst_core[id(ins)]
            links = _route(hw, c, dst)
            for k, lk in enumerate(links):
                link_busy.setdefault(lk, set()).add(
                    ts + 1 + k * hw.send_latency)
            if links:
                arrival_busy.setdefault(dst, set()).add(
                    ts + 1 + len(links) * hw.send_latency)
    return busy, link_busy, arrival_busy


def pipeline_schedule(core_instrs: List[List[Instr]],
                      core_of_proc: List[int],
                      hw: HardwareConfig,
                      send_dst_core: Dict[int, int],
                      war_edges: List[List[Tuple[int, int]]],
                      order_edges: List[List[Tuple[int, int]]],
                      share: List[Dict[int, int]],
                      commit_def: List[Dict[int, int]],
                      hoist: List[Set[int]],
                      strategy: str = "slack",
                      crit_path_lb: int = 0,
                      base: Optional[ScheduleResult] = None
                      ) -> Optional[Tuple[ScheduleResult, PipelineInfo]]:
    """Modulo-pipeline one Vcycle: hoist ``hoist[p]`` into a prologue,
    reschedule the body, and compute the steady-state initiation interval.

    Returns ``(combined, info)`` — the combined prologue+body schedule
    (``info.span == combined.vcpl`` slots) and the pipelining overlay — or
    ``None`` when no II strictly below the combined span is legal (then
    pipelining cannot beat the barrier machine and the caller ships the
    baseline).  With an empty hoist and ``base`` given, the body schedule
    is reused verbatim, so the emitted program is bit-identical to the
    unpipelined one and the pass is pure overlap accounting.
    """
    L = hw.raw_latency
    ncores = hw.num_cores
    nproc = len(core_instrs)
    total = sum(len(ci) for ci in core_instrs)
    empty = all(not h for h in hoist)
    preds_all, succs_all = _build_deps(core_instrs, war_edges, order_edges)

    # ---- prologue placement (once; independent of body rescheduling):
    # hoisted instrs in topo order, earliest slot >= every hoisted RAW
    # predecessor + raw_latency, first free slot on the core (the hoist
    # set is ancestor-closed, so all RAW preds of a hoisted instr are
    # hoisted)
    pro_slot: List[Dict[int, int]] = [{} for _ in range(nproc)]
    occupied: List[Set[int]] = [set() for _ in range(ncores)]
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        for i in sorted(hoist[p]):
            lo = 0
            for (j, kind) in preds_all[p][i]:
                if kind == RAW and j in hoist[p]:
                    lo = max(lo, pro_slot[p][j] + L)
            while lo in occupied[c]:
                lo += 1
            occupied[c].add(lo)
            pro_slot[p][i] = lo
    P = 1 + max((max(o) for o in occupied if o), default=-1)

    # body = everything not hoisted; WAR edges whose reader is hoisted drop
    # (the rotated reader consumes the *committed* value — the sigma
    # constraints take over); memory-order endpoints are never hoistable
    body_instrs: List[List[Instr]] = []
    body_war: List[List[Tuple[int, int]]] = []
    body_order: List[List[Tuple[int, int]]] = []
    raw_floors: List[Dict[int, int]] = []
    for p, instrs in enumerate(core_instrs):
        h = hoist[p]
        newidx: Dict[int, int] = {}
        bl: List[Instr] = []
        for i, ins in enumerate(instrs):
            if i in h:
                continue
            newidx[i] = len(bl)
            bl.append(ins)
        body_instrs.append(bl)
        body_war.append([(newidx[a], newidx[b]) for (a, b) in
                         war_edges[p] if a not in h and b not in h])
        body_order.append([(newidx[a], newidx[b]) for (a, b) in
                           order_edges[p] if a not in h and b not in h])
        fl: Dict[int, int] = {}
        for i in newidx:
            for (j, kind) in preds_all[p][i]:
                if kind == RAW and j in h:
                    lo = max(0, pro_slot[p][j] + L - P)
                    if lo > fl.get(newidx[i], 0):
                        fl[newidx[i]] = lo
        raw_floors.append(fl)

    def _assemble(extra: Optional[List[int]]) -> ScheduleResult:
        """Combined prologue+body schedule; ``extra[p]`` is a head-clearance
        floor (earliest body slot) for every instruction of process p."""
        if empty and extra is None and base is not None:
            return ScheduleResult(
                [CoreProgram(list(cp.slots), cp.recv_count, list(cp.sends))
                 for cp in base.cores],
                base.t_compute, base.vcpl, dict(base.stats))
        mr: Optional[List[Dict[int, int]]] = None
        if extra is not None and any(extra):
            mr = []
            for p, bl in enumerate(body_instrs):
                fl = dict(raw_floors[p])
                if extra[p]:
                    for i in range(len(bl)):
                        if fl.get(i, 0) < extra[p]:
                            fl[i] = extra[p]
                mr.append(fl)
        elif not empty:
            mr = raw_floors
        body = schedule(body_instrs, core_of_proc, hw, send_dst_core,
                        body_war, body_order, strategy, min_ready=mr)
        if empty and P == 0:
            return body
        comb_slots: List[List[Optional[Instr]]] = []
        for c in range(ncores):
            sl: List[Optional[Instr]] = [None] * P
            sl.extend(body.cores[c].slots)
            comb_slots.append(sl)
        for p in range(nproc):
            c = core_of_proc[p]
            for i, s in pro_slot[p].items():
                comb_slots[c][s] = core_instrs[p][i]
        comb_sends = [[(ts + P, ins) for (ts, ins) in body.cores[c].sends]
                      for c in range(ncores)]
        recv = [cp.recv_count for cp in body.cores]
        comb = _finish(comb_slots, comb_sends, recv, 0, ncores, total,
                       crit_path_lb, hw, strategy)
        if "sched_prio" in body.stats:
            comb.stats["sched_prio"] = body.stats["sched_prio"]
        return comb

    def _floor_of(comb: ScheduleResult):
        placed: List[Dict[int, int]] = [{} for _ in comb.cores]
        for c, cp in enumerate(comb.cores):
            for s, ins in enumerate(cp.slots):
                if ins is not None:
                    placed[c][id(ins)] = s
        slot_of = [[placed[core_of_proc[p]][id(ins)] for ins in instrs]
                   for p, instrs in enumerate(core_instrs)]
        sigma, _ = _commit_sigma(core_instrs, core_of_proc, hw,
                                 send_dst_core, commit_def, slot_of,
                                 comb.t_compute)
        floor = _pipeline_floors(core_instrs, hoist, sigma, slot_of)
        busy, _lb, _ab = _resource_sets(comb, hw, send_dst_core)
        return slot_of, max(floor, max((len(b) for b in busy), default=1))

    def _attempt(comb: ScheduleResult, slot_of, floor: int, stop: int):
        """Search II upward from the data/occupancy floor; at each
        candidate repair modulo collisions by relocating slack
        instructions, then re-verify the repaired placement from
        scratch."""
        span = comb.vcpl
        t_comp = comb.t_compute
        for ii in range(floor, min(span, stop)):
            rep = _repair_modulo(comb, core_instrs, core_of_proc, hw,
                                 preds_all, succs_all, P, ii, slot_of)
            if rep is None:
                continue
            slots_c, pos = rep
            cand = ScheduleResult(
                [CoreProgram(slots_c[c], comb.cores[c].recv_count,
                             comb.cores[c].sends)
                 for c in range(ncores)],
                t_comp, span, dict(comb.stats))
            sigma2, ranks2 = _commit_sigma(core_instrs, core_of_proc, hw,
                                           send_dst_core, commit_def, pos,
                                           t_comp)
            if _pipeline_floors(core_instrs, hoist, sigma2, pos) > ii:
                continue
            busy2, lb2, ab2 = _resource_sets(cand, hw, send_dst_core)
            if _modulo_conflict(ii, busy2, lb2, ab2):
                continue
            info = PipelineInfo(
                ii=ii, prologue_len=P, span=span, hoist=hoist, share=share,
                commit_def=commit_def, replay_rank=ranks2,
                stats={"ii": ii, "prologue_len": P, "span": span,
                       "hoisted": sum(len(h) for h in hoist)})
            return cand, info
        return None

    stop = base.vcpl if base is not None else (1 << 30)
    comb = _assemble(None)
    slot_of, floor = _floor_of(comb)
    best = _attempt(comb, slot_of, floor, stop)
    if best is not None:
        stop = best[1].ii

    # head-clearance rounds: the dominant steady-state collision is the
    # epilogue replay tail of iteration n wrapping onto the stream head of
    # iteration n+1 on the receiving cores.  Reschedule with a per-core
    # min_ready floor that keeps each receiving core's head clear of its
    # own wrapped replay residues, then search again; iterate while the
    # data floor keeps moving (the delayed heads also delay replay-fed
    # readers, which lowers the floor's sigma - s demand).
    t_comp, target = comb.t_compute, floor
    recv_of = [cp.recv_count for cp in comb.cores]
    for _round in range(3):
        extra = [max(0, t_comp + recv_of[core_of_proc[p]] - target)
                 if recv_of[core_of_proc[p]] else 0 for p in range(nproc)]
        if not any(extra):
            break
        comb = _assemble(extra)
        slot_of, floor = _floor_of(comb)
        got = _attempt(comb, slot_of, floor, stop)
        if got is not None:
            best, stop = got, got[1].ii
        t_comp, target = comb.t_compute, floor
        recv_of = [cp.recv_count for cp in comb.cores]
    return best


# ----------------------------------------------------------------------
# independent validator
# ----------------------------------------------------------------------

def validate_schedule(res: ScheduleResult,
                      core_instrs: List[List[Instr]],
                      core_of_proc: List[int],
                      hw: HardwareConfig,
                      send_dst_core: Dict[int, int],
                      war_edges: List[List[Tuple[int, int]]],
                      order_edges: List[List[Tuple[int, int]]],
                      pipeline: Optional[PipelineInfo] = None
                      ) -> Dict[str, int]:
    """Independently re-check a :class:`ScheduleResult` against the machine
    model: every instruction placed exactly once on its process's core, RAW
    def->use distance >= ``hw.raw_latency``, WAR/memory-order edges strictly
    respected, NoC link slots collision-free, arrival slots unique per
    destination and within ``t_compute``, receive counts and VCPL
    consistent. Raises :class:`ValueError` on the first violation; returns
    summary counts when the schedule is valid.

    With ``pipeline`` given the modulo overlay is checked too: prologue
    region purity, commit visibility recomputation, cross-iteration RAW
    distances and commit-order safety, prologue-carry WAR, cross-iteration
    memory ordering, and core/link/arrival claims collision-free modulo the
    initiation interval (see :class:`PipelineInfo`)."""
    L = hw.raw_latency
    # the partitioner duplicates instruction *objects* across processes
    # (cone duplication), so placement is keyed per core, where each object
    # occupies exactly one slot
    placed: List[Dict[int, int]] = [{} for _ in res.cores]
    for c, cp in enumerate(res.cores):
        if len(cp.slots) != res.t_compute:
            raise ValueError(
                f"core {c}: stream length {len(cp.slots)} != t_compute "
                f"{res.t_compute}")
        for s, ins in enumerate(cp.slots):
            if ins is None:
                continue
            if id(ins) in placed[c]:
                raise ValueError(
                    f"instruction placed twice on core {c}: {ins!r}")
            placed[c][id(ins)] = s

    send_ids: Set[int] = set()
    n_placed = sum(len(m) for m in placed)
    for p, instrs in enumerate(core_instrs):
        c = core_of_proc[p]
        defs: Dict[int, int] = {}
        slots: List[int] = []
        for i, ins in enumerate(instrs):
            slot = placed[c].get(id(ins))
            if slot is None:
                raise ValueError(f"proc {p} instr {i} missing from core {c}")
            slots.append(slot)
            for src in ins.srcs:
                d = defs.get(src)
                if d is not None and slot - slots[d] < L:
                    raise ValueError(
                        f"RAW violation proc {p}: {d}->{i} distance "
                        f"{slot - slots[d]} < {L}")
            w = ins.writes()
            if w is not None and w != 0:
                defs[w] = i
            if ins.op == Op.SEND:
                send_ids.add(id(ins))
        for (a, b) in war_edges[p] + order_edges[p]:
            if slots[b] <= slots[a]:
                raise ValueError(
                    f"order violation proc {p}: {a}(slot {slots[a]}) !< "
                    f"{b}(slot {slots[b]})")

    link_busy: Dict[Tuple[str, int, int], Set[int]] = {}
    arrival_busy: Dict[int, Set[int]] = {}
    recv = [0] * hw.num_cores
    listed: Set[int] = set()
    for c, cp in enumerate(res.cores):
        for (ts, ins) in cp.sends:
            if placed[c].get(id(ins)) != ts:
                raise ValueError(
                    f"send list slot ({c},{ts}) disagrees with placement "
                    f"{placed[c].get(id(ins))}")
            listed.add(id(ins))
            dst = send_dst_core[id(ins)]
            links = _route(hw, c, dst)
            if not links:
                continue           # local move: no NoC claims, no replay
            for k, lk in enumerate(links):
                sl = ts + 1 + k * hw.send_latency
                if sl in link_busy.setdefault(lk, set()):
                    raise ValueError(f"link collision on {lk} at slot {sl}")
                link_busy[lk].add(sl)
            arrive = ts + 1 + len(links) * hw.send_latency
            if arrive in arrival_busy.setdefault(dst, set()):
                raise ValueError(
                    f"arrival collision at core {dst} slot {arrive}")
            arrival_busy[dst].add(arrive)
            if arrive > res.t_compute:
                raise ValueError(
                    f"arrival {arrive} past t_compute {res.t_compute}")
            recv[dst] += 1
    if listed != send_ids:
        raise ValueError("send lists do not cover exactly the SEND instrs")
    for c, cp in enumerate(res.cores):
        if cp.recv_count != recv[c]:
            raise ValueError(
                f"core {c} recv_count {cp.recv_count} != derived {recv[c]}")
    epilogue = max(recv) if recv else 0
    if res.vcpl != res.t_compute + epilogue:
        raise ValueError(
            f"vcpl {res.vcpl} != t_compute {res.t_compute} + epilogue "
            f"{epilogue}")

    if pipeline is not None:
        _validate_pipeline(res, core_instrs, core_of_proc, hw,
                           send_dst_core, placed, pipeline)
    return {"instrs": n_placed, "sends": len(send_ids)}


def _validate_pipeline(res: ScheduleResult,
                       core_instrs: List[List[Instr]],
                       core_of_proc: List[int],
                       hw: HardwareConfig,
                       send_dst_core: Dict[int, int],
                       placed: List[Dict[int, int]],
                       info: PipelineInfo) -> None:
    """Modulo-overlay legality (see :class:`PipelineInfo` for the model)."""
    L = hw.raw_latency
    ii, P, span = info.ii, info.prologue_len, info.span
    t_comp = res.t_compute
    if span != res.vcpl:
        raise ValueError(f"pipeline span {span} != schedule vcpl {res.vcpl}")
    if not 1 <= ii < span:
        raise ValueError(f"initiation interval {ii} outside [1, {span})")

    slot_of = [[placed[core_of_proc[p]][id(ins)] for ins in instrs]
               for p, instrs in enumerate(core_instrs)]

    # prologue region purity: slots [0, P) hold exactly the hoisted instrs,
    # every hoisted op is a pure register op, and no SEND issues there
    hoistable = PURE_OPS | {Op.LUT}
    hoist_ids: Set[int] = set()
    for p, h in enumerate(info.hoist):
        for i in h:
            ins = core_instrs[p][i]
            hoist_ids.add(id(ins))
            if ins.op not in hoistable or ins.writes() is None:
                raise ValueError(
                    f"hoisted instr proc {p} idx {i} is not a pure "
                    f"register op: {ins!r}")
            if slot_of[p][i] >= P:
                raise ValueError(
                    f"hoisted instr proc {p} idx {i} at slot "
                    f"{slot_of[p][i]} outside prologue [0, {P})")
    for c, cp in enumerate(res.cores):
        for s in range(min(P, len(cp.slots))):
            ins = cp.slots[s]
            if ins is not None and id(ins) not in hoist_ids:
                raise ValueError(
                    f"non-hoisted instr in prologue region: core {c} "
                    f"slot {s}: {ins!r}")
        for (ts, _ins) in cp.sends:
            if ts < P:
                raise ValueError(
                    f"SEND in prologue region: core {c} slot {ts}")

    # recompute commit visibility under the recorded replay ranks (raises
    # if the ranks are not a per-core permutation of the inbound messages)
    for p, cd in enumerate(info.commit_def):
        for cur, di in cd.items():
            ins = core_instrs[p][di]
            w = ins.writes()
            shared = w is not None and info.share[p].get(w) == cur
            moved = ins.op == Op.MOV and ins.dst == cur
            if not (shared or moved):
                raise ValueError(
                    f"commit_def proc {p} vreg {cur}: instr {di} is "
                    f"neither a shared def nor a commit MOV: {ins!r}")
    sigma, _ranks = _commit_sigma(core_instrs, core_of_proc, hw,
                                  send_dst_core, info.commit_def, slot_of,
                                  t_comp, replay_rank=info.replay_rank)

    # cross-iteration RAW / commit-order, carry WAR, memory ordering
    need = _pipeline_floors(core_instrs, info.hoist, sigma, slot_of)
    if ii < need:
        raise ValueError(
            f"initiation interval {ii} below data-hazard floor {need} "
            f"(cross-iteration RAW / commit order / carry WAR / memory)")

    # resource claims must repeat collision-free modulo ii
    busy, link_busy, arrival_busy = _resource_sets(res, hw, send_dst_core)
    for c, grp in enumerate(busy):
        if len({s % ii for s in grp}) != len(grp):
            raise ValueError(
                f"core {c} issue/replay slots collide modulo {ii}")
    for lk, grp in link_busy.items():
        if len({s % ii for s in grp}) != len(grp):
            raise ValueError(f"link {lk} claims collide modulo {ii}")
    for dst, grp in arrival_busy.items():
        if len({s % ii for s in grp}) != len(grp):
            raise ValueError(
                f"arrival slots at core {dst} collide modulo {ii}")
