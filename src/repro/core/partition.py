"""Parallelism extraction: split into per-sink processes, then merge.

Paper §6.1:

  * **Split** — one process per data sink (a next-register word, a store, an
    EXPECT, or a host-visible output); each process is the backward cone of
    its sink, with DAG nodes *duplicated* across processes to maximize
    parallelism. Instructions that access the same memory must colocate, and
    all privileged instructions (GLD/GST/EXPECT, outputs) colocate in the
    privileged process.
  * **Merge** — reduce the process count to the available cores with a
    communication-aware balanced heuristic (algorithm **B**): repeatedly take
    the cheapest process and merge it with the communicating partner that
    minimizes the merged cost, where cost = instructions + Sends. Merging is
    non-linear because duplicated instructions deduplicate (set union) and
    Sends between the pair vanish. A communication-oblivious LPT baseline
    (algorithm **L**) is provided for the Fig. 9 / Table 4 ablation.

Cross-process dataflow is *exclusively* register (state) values: the producer
of a next-register value SENDs it to every remote process that reads the
register's current value, and delivery happens at the Vcycle boundary — the
static-BSP exchange.

Since PR 3 the input is the *optimized* IR (``core.opt`` runs between lower
and partition): cones are smaller, copy-propagation has collapsed MOV chains
(exposing larger fanout-free logic components to ``core.lutsynth``), and the
merge cost model — instructions + Sends — therefore prices the instructions
that will actually be scheduled. The split relies on the IR's liveness
contract: every next-register word keeps a unique defining instruction
(``Lowered.check``), so every register word is a sink here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .isa import Instr, Op
from .lower import Lowered

PRIV = -1  # pseudo-sink id for the privileged group


@dataclass
class SendEdge:
    """next-register value flowing between processes at the Vcycle boundary."""
    src_proc: int
    nxt_vreg: int       # value being sent (defined in src_proc)
    dst_proc: int
    cur_vreg: int       # register (leaf vreg) updated in dst_proc


@dataclass
class Partition:
    lowered: Lowered
    procs: List[List[int]]             # per-process instr indices (topo order)
    priv_proc: int
    proc_mems: List[List[str]]         # local memories owned per process
    sends: List[SendEdge]
    local_commits: List[Tuple[int, int, int]]  # (proc, nxt_vreg, cur_vreg)
    # commits added by core.remat: these always commit via an explicit MOV
    # (never the Wimmer-Franz register share) so the rematerialized compute
    # can float early in the schedule instead of being WAR-serialized
    # behind every local reader of the register
    remat_commits: Set[Tuple[int, int, int]] = field(default_factory=set)
    # (proc, cur_vreg) state leaves read by rematerialized cones: their
    # commits are likewise forced to MOV, otherwise the WAR edge
    # reader-before-def would splice the (low-priority) rematerialized
    # compute into the middle of the proc's critical chain
    remat_reads: Set[Tuple[int, int]] = field(default_factory=set)
    # diagnostics
    split_count: int = 0
    merge_steps: int = 0

    @property
    def num_procs(self) -> int:
        return len(self.procs)

    def clone(self) -> "Partition":
        """Independent copy for a compile arm: ``core.remat`` mutates
        ``procs``/``sends``/commit sets in place, and ``compile_circuit``
        schedules one arm per candidate placement. ``lowered`` is shared
        (read-only past partitioning); ``SendEdge``s are fresh objects
        because remat keys deletions by identity."""
        return Partition(
            self.lowered, [list(p) for p in self.procs], self.priv_proc,
            [list(m) for m in self.proc_mems],
            [SendEdge(e.src_proc, e.nxt_vreg, e.dst_proc, e.cur_vreg)
             for e in self.sends],
            list(self.local_commits),
            remat_commits=set(self.remat_commits),
            remat_reads=set(self.remat_reads),
            split_count=self.split_count, merge_steps=self.merge_steps)

    def stats(self) -> Dict[str, int]:
        sizes = [len(p) for p in self.procs]
        return {
            "procs": len(self.procs),
            "split_procs": self.split_count,
            "sends": len(self.sends),
            "instrs_total": sum(sizes),
            "instrs_max": max(sizes) if sizes else 0,
            "instrs_unique": len({i for p in self.procs for i in p}),
        }


class _Splitter:
    def __init__(self, low: Lowered):
        self.low = low
        self.defs: Dict[int, int] = low.defs()
        # state leaves = current-register vregs
        self.cur_vregs: Set[int] = low.state_vregs()

    def cone(self, sink: int) -> Tuple[FrozenSet[int], FrozenSet[int]]:
        """Backward closure from instr ``sink``. Returns (instr ids, state
        leaves read)."""
        instrs: Set[int] = set()
        reads: Set[int] = set()
        stack = [sink]
        while stack:
            idx = stack.pop()
            if idx in instrs:
                continue
            instrs.add(idx)
            for s in self.low.instrs[idx].reads():
                d = self.defs.get(s)
                if d is not None:
                    if d not in instrs:
                        stack.append(d)
                elif s in self.cur_vregs:
                    reads.add(s)
        return frozenset(instrs), frozenset(reads)


class _UF:
    def __init__(self):
        self.p: Dict[int, int] = {}

    def find(self, x: int) -> int:
        r = x
        while self.p.setdefault(r, r) != r:
            r = self.p[r]
        while self.p[x] != r:
            self.p[x], x = r, self.p[x]
        return r

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra
        return ra


def split(low: Lowered) -> Tuple[List[Set[int]], List[Set[int]],
                                 Dict[int, int], List[Tuple[int, int]], int,
                                 Dict[int, List[str]]]:
    """Split into maximal processes. Returns (instr sets, read sets,
    sink->group, regword (sink,cur) pairs, priv group index, group mems)."""
    sp = _Splitter(low)
    instrs = low.instrs

    # sinks
    next_vregs: Dict[int, int] = {}  # nxt vreg -> cur vreg
    for r in low.regs:
        for cw, nw in zip(r.cur, r.nxt):
            next_vregs[nw] = cw
    out_vregs = {v for vs in low.outputs.values() for v in vs}

    sinks: List[int] = []
    for idx, ins in enumerate(instrs):
        w = ins.writes()
        if ins.op in (Op.ST, Op.GST, Op.EXPECT):
            sinks.append(idx)
        elif w is not None and (w in next_vregs or w in out_vregs):
            sinks.append(idx)

    cones = {s: sp.cone(s) for s in sinks}

    uf = _UF()
    uf.find(PRIV)
    mem_anchor: Dict[str, int] = {}
    for s in sinks:
        cone_instrs, _ = cones[s]
        root = s
        for idx in cone_instrs:
            ins = instrs[idx]
            if ins.is_privileged():
                root = uf.union(PRIV, root)
            if ins.op in (Op.LD, Op.ST) and ins.mem is not None:
                if ins.mem in mem_anchor:
                    root = uf.union(mem_anchor[ins.mem], root)
                else:
                    mem_anchor[ins.mem] = root
        w = instrs[s].writes()
        if w is not None and w in out_vregs:
            uf.union(PRIV, s)

    groups: Dict[int, List[int]] = {}
    for s in sinks:
        groups.setdefault(uf.find(s), []).append(s)
    # guarantee the privileged group exists even if empty
    priv_root = uf.find(PRIV)
    groups.setdefault(priv_root, [])

    roots = sorted(groups, key=lambda r: (r != priv_root, r))
    proc_instrs: List[Set[int]] = []
    proc_reads: List[Set[int]] = []
    sink_group: Dict[int, int] = {}
    group_mems: Dict[int, List[str]] = {}
    for gi, root in enumerate(roots):
        ii: Set[int] = set()
        rr: Set[int] = set()
        for s in groups[root]:
            ci, cr = cones[s]
            ii |= ci
            rr |= cr
            sink_group[s] = gi
        proc_instrs.append(ii)
        proc_reads.append(rr)
        group_mems[gi] = sorted({m for m, anchor in mem_anchor.items()
                                 if uf.find(anchor) == root})
    regwords = [(s, next_vregs[instrs[s].writes()])
                for s in sinks if instrs[s].writes() in next_vregs]
    return proc_instrs, proc_reads, sink_group, regwords, 0, group_mems


class _MergeState:
    """Incremental cost model over groups during merging."""

    def __init__(self, proc_instrs: List[Set[int]], proc_reads: List[Set[int]],
                 sink_group: Dict[int, int],
                 regwords: List[Tuple[int, int]],
                 group_mems: Dict[int, List[str]], priv: int):
        self.instrs = proc_instrs
        self.reads = proc_reads
        self.alive = [True] * len(proc_instrs)
        self.mems = dict(group_mems)
        self.priv = priv
        # regword: owner group + cur vreg
        self.owned: List[List[int]] = [[] for _ in proc_instrs]  # cur vregs
        self.cur_owner: Dict[int, int] = {}
        for s, cur in regwords:
            g = sink_group[s]
            self.owned[g].append(cur)
            self.cur_owner[cur] = g
        self.readers: Dict[int, Set[int]] = {}
        for g, rr in enumerate(proc_reads):
            for cur in rr:
                self.readers.setdefault(cur, set()).add(g)

    def sends(self, g: int) -> int:
        n = 0
        for cur in self.owned[g]:
            n += len(self.readers.get(cur, set()) - {g})
        return n

    def cost(self, g: int) -> int:
        return len(self.instrs[g]) + self.sends(g)

    def merged_cost(self, a: int, b: int) -> int:
        ni = len(self.instrs[a] | self.instrs[b])
        ns = 0
        for g in (a, b):
            for cur in self.owned[g]:
                ns += len(self.readers.get(cur, set()) - {a, b})
        return ni + ns

    def neighbors(self, g: int) -> Set[int]:
        out: Set[int] = set()
        for cur in self.reads[g]:                 # producers of what g reads
            o = self.cur_owner.get(cur)
            if o is not None and o != g and self.alive[o]:
                out.add(o)
        for cur in self.owned[g]:                 # consumers of what g owns
            for r in self.readers.get(cur, set()):
                if r != g and self.alive[r]:
                    out.add(r)
        return out

    def merge(self, a: int, b: int) -> int:
        """Merge b into a (a must not be the one discarded if priv)."""
        if b == self.priv:
            a, b = b, a
        self.instrs[a] |= self.instrs[b]
        self.instrs[b] = set()
        for cur in self.reads[b]:
            rs = self.readers[cur]
            rs.discard(b)
            rs.add(a)
        self.reads[a] |= self.reads[b]
        self.reads[b] = set()
        for cur in self.owned[b]:
            self.cur_owner[cur] = a
        self.owned[a] += self.owned[b]
        self.owned[b] = []
        self.mems[a] = sorted(set(self.mems.get(a, [])) |
                              set(self.mems.get(b, [])))
        self.mems[b] = []
        self.alive[b] = False
        return a


def merge_balanced(state: _MergeState, num_cores: int,
                   extra_rounds: int = 64) -> int:
    """Algorithm B: communication-aware balanced merging."""
    steps = 0
    def alive_groups():
        return [g for g in range(len(state.instrs)) if state.alive[g]]

    while True:
        groups = alive_groups()
        if len(groups) <= 1:
            break
        over = len(groups) > num_cores
        if not over and extra_rounds <= 0:
            break
        costs = {g: state.cost(g) for g in groups}
        p = min(groups, key=lambda g: (costs[g], g))
        cands = state.neighbors(p)
        if not cands:
            cands = {g for g in groups if g != p}
            # fall back to the next-cheapest processes only
            cands = set(sorted(cands, key=lambda g: costs[g])[:8])
        best_q, best_c = None, None
        for q in cands:
            c = state.merged_cost(p, q)
            if best_c is None or c < best_c:
                best_q, best_c = q, c
        if best_q is None:
            break
        if not over:
            # only continue if the merge does not create a new straggler and
            # reduces total cost (fewer Sends / deduplicated instructions)
            max_cost = max(costs.values())
            if best_c >= max_cost or best_c >= costs[p] + costs[best_q]:
                extra_rounds = 0
                continue
            extra_rounds -= 1
        state.merge(p, best_q)
        steps += 1
    return steps


def merge_lpt(state: _MergeState, num_cores: int) -> int:
    """Algorithm L: communication-oblivious longest-processing-time-first."""
    groups = [g for g in range(len(state.instrs)) if state.alive[g]]
    if len(groups) <= num_cores:
        return 0
    groups.sort(key=lambda g: -state.cost(g))
    bins: List[int] = groups[:num_cores]
    loads = {g: state.cost(g) for g in bins}
    steps = 0
    for g in groups[num_cores:]:
        tgt = min(bins, key=lambda b: loads[b])
        kept = state.merge(tgt, g)
        if kept != tgt:  # priv swap
            loads[kept] = loads.pop(tgt)
            bins[bins.index(tgt)] = kept
            tgt = kept
        loads[tgt] = state.cost(tgt)
        steps += 1
    return steps


def partition(low: Lowered, num_cores: int,
              strategy: str = "balanced") -> Partition:
    proc_instrs, proc_reads, sink_group, regwords, priv, group_mems = split(low)
    split_count = sum(1 for s in proc_instrs if s)
    state = _MergeState(proc_instrs, proc_reads, sink_group, regwords,
                        group_mems, priv)
    if strategy == "balanced":
        steps = merge_balanced(state, num_cores)
    elif strategy == "lpt":
        steps = merge_lpt(state, num_cores)
    else:
        raise ValueError(strategy)

    # compact to final processes; keep privileged first
    alive = [g for g in range(len(state.instrs))
             if state.alive[g] and (state.instrs[g] or g == state.priv)]
    alive.sort(key=lambda g: (g != state.priv,))
    remap = {g: i for i, g in enumerate(alive)}

    procs = [sorted(state.instrs[g]) for g in alive]
    proc_mems = [state.mems.get(g, []) for g in alive]

    # communication edges + local commits
    cur_of_nxt: Dict[int, int] = {}
    for r in low.regs:
        for cw, nw in zip(r.cur, r.nxt):
            cur_of_nxt[nw] = cw
    nxt_def_proc: Dict[int, int] = {}
    for s, cur in regwords:
        g = state.cur_owner[cur]   # owner group after merging
        if state.alive[g]:
            nxt_def_proc[low.instrs[s].writes()] = remap[g]

    sends: List[SendEdge] = []
    local_commits: List[Tuple[int, int, int]] = []
    for nxt, cur in cur_of_nxt.items():
        owner = nxt_def_proc.get(nxt)
        if owner is None:
            continue  # dead register (no live reader anywhere, cone empty)
        readers = {remap[g] for g in state.readers.get(cur, set())
                   if state.alive[g]}
        for rproc in sorted(readers):
            if rproc == owner:
                continue
            sends.append(SendEdge(owner, nxt, rproc, cur))
        # the owner always keeps an architecturally-visible copy (hosts read
        # and checkpoint registers from their owner core), even without
        # in-process readers
        local_commits.append((owner, nxt, cur))

    return Partition(low, procs, remap.get(state.priv, 0), proc_mems, sends,
                     local_commits, split_count=split_count,
                     merge_steps=steps)
