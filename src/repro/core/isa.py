"""Manticore lower-assembly ISA (16-bit datapath).

The ISA mirrors the paper (§4.2): word size is 16 bits, every instruction has
fixed unit latency from the scheduler's point of view (data hazards are
resolved by compiler-inserted NOps), branches do not exist (predication only),
and the only cross-core primitive is SEND whose register update is deferred to
the end of the virtual cycle (Vcycle).

Instruction layout (7 int fields, unpacked):

    (op, dst, s1, s2, s3, s4, imm)

``dst``/``s*`` are register indices into the per-core register file
(default 2048 entries, r0 hard-wired to zero). ``imm`` is an opcode-specific
immediate (shift amount, slice spec, LUT table index, exception id, SEND
destination encoding).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

WORD_BITS = 16
WORD_MASK = (1 << WORD_BITS) - 1
NUM_REGS = 2048           # paper: 2048-entry BRAM register file
NUM_LUTS = 32             # paper: 32 programmable custom functions per core
LUT_INPUTS = 4
SPAD_WORDS = 16384        # paper: 128 KiB URAM scratchpad as 16384 x 16-bit
ZERO_REG = 0              # r0 == 0 by convention (reserved by regalloc)


class Op(enum.IntEnum):
    NOP = 0
    MOV = 1       # dst = s1
    MOVI = 2      # dst = imm                      (used by boot/setup only)
    ADD = 3       # dst = (s1 + s2) & mask
    ADDC = 4      # dst = (s1 + s2 + s3) & mask    (s3 is a 0/1 carry reg)
    CARRY = 5     # dst = (s1 + s2 + s3) >> 16     (carry out of wide add)
    SUB = 6       # dst = (s1 - s2) & mask
    SUBB = 7      # dst = (s1 - s2 - s3) & mask    (s3 is a 0/1 borrow reg)
    BORROW = 8    # dst = 1 if s1 - s2 - s3 < 0 else 0
    MUL = 9       # dst = (s1 * s2) & mask
    MULH = 10     # dst = (s1 * s2) >> 16
    AND = 11
    OR = 12
    XOR = 13
    NOT = 14      # dst = ~s1
    MUX = 15      # dst = s2 if s1 != 0 else s3
    SEQ = 16      # dst = (s1 == s2)
    SNE = 17      # dst = (s1 != s2)
    SLTU = 18     # dst = (s1 < s2), unsigned
    SLL = 19      # dst = (s1 << imm) & mask
    SRL = 20      # dst = s1 >> imm
    SRA = 21      # dst = sign-extended s1 >> imm
    SLLV = 22     # dst = (s1 << (s2 & 15)) & mask
    SRLV = 23     # dst = s1 >> (s2 & 15)
    SLICE = 24    # dst = (s1 >> off) & ((1<<width)-1); imm = off*32 + width
    LUT = 25      # dst = CFU[imm](s1, s2, s3, s4)   (per-bit-lane 4-LUT)
    LD = 26       # dst = spad[s1]
    ST = 27       # if s3: spad[s1] = s2             (stores are predicated)
    GLD = 28      # dst = gmem[s1*65536 + s2]        (privileged)
    GST = 29      # if s4: gmem[s1*65536 + s2] = s3  (privileged)
    SEND = 30     # send s1 to core imm>>16, register imm&0xffff (dst mirrors)
    EXPECT = 31   # if s1 != s2: raise exception imm (privileged)


# Opcodes that only the privileged core may execute (paper §4.2).
PRIVILEGED_OPS = frozenset({Op.GLD, Op.GST, Op.EXPECT})
# Bitwise ops eligible for custom-function (LUT) fusion (paper §6.2).
LOGIC_OPS = frozenset({Op.AND, Op.OR, Op.XOR, Op.NOT})
# Ops with no register result.
NO_RESULT_OPS = frozenset({Op.NOP, Op.ST, Op.GST, Op.EXPECT})
# SEND "result" is defined as the forwarded value (the engine traces it).

# Opcodes with observable effects beyond their register result — never
# eliminated, reordered across same-memory ops, or value-numbered.
SIDE_EFFECT_OPS = frozenset({Op.ST, Op.GST, Op.EXPECT, Op.SEND})
# Memory reads: not pure (result depends on memory state), but full-cycle
# semantics order every load of a memory before its first store, so two
# loads of the same (memory, address) within one Vcycle are equivalent.
MEM_READ_OPS = frozenset({Op.LD, Op.GLD})
# Register-to-register opcodes whose result is a pure function of operands
# and imm — foldable, substitutable and value-numberable by core.opt.
PURE_OPS = frozenset({
    Op.MOV, Op.MOVI, Op.ADD, Op.ADDC, Op.CARRY, Op.SUB, Op.SUBB, Op.BORROW,
    Op.MUL, Op.MULH, Op.AND, Op.OR, Op.XOR, Op.NOT, Op.MUX, Op.SEQ, Op.SNE,
    Op.SLTU, Op.SLL, Op.SRL, Op.SRA, Op.SLLV, Op.SRLV, Op.SLICE,
})
# Pure ops where the first two operands commute (canonicalized by GVN).
COMMUTATIVE_OPS = frozenset({
    Op.ADD, Op.ADDC, Op.CARRY, Op.MUL, Op.MULH, Op.AND, Op.OR, Op.XOR,
    Op.SEQ, Op.SNE,
})

NUM_FIELDS = 7  # (op, dst, s1, s2, s3, s4, imm)


@dataclass
class Instr:
    """One lower-assembly instruction over *virtual* registers.

    Virtual register namespace is global (SSA values); regalloc maps them to
    per-core machine registers.
    """
    op: Op
    dst: int = 0
    srcs: Tuple[int, ...] = ()
    imm: int = 0
    # --- metadata used by the compiler (not encoded) ---
    # memory identity for LD/ST (keeps same-memory ops in one process)
    mem: Optional[str] = None
    # SEND routing (filled by partitioner): destination process / vreg
    send_dst_proc: Optional[int] = None
    send_dst_vreg: Optional[int] = None

    def reads(self) -> Tuple[int, ...]:
        return self.srcs

    def writes(self) -> Optional[int]:
        if self.op in NO_RESULT_OPS:
            return None
        return self.dst

    def is_privileged(self) -> bool:
        return self.op in PRIVILEGED_OPS

    def __repr__(self) -> str:  # compact, for debugging
        s = ",".join(f"v{r}" for r in self.srcs)
        return f"{self.op.name} v{self.dst} {s} #{self.imm}"


def encode(op: Op, dst: int, s1: int = 0, s2: int = 0, s3: int = 0,
           s4: int = 0, imm: int = 0) -> Tuple[int, ...]:
    """Encode to the 7-int machine form consumed by the executors."""
    return (int(op), dst, s1, s2, s3, s4, imm)


@dataclass
class HardwareConfig:
    """Machine parameters. Defaults mirror the paper's U200 prototype."""
    grid_width: int = 15
    grid_height: int = 15
    num_regs: int = NUM_REGS
    num_luts: int = NUM_LUTS
    spad_words: int = SPAD_WORDS
    imem_slots: int = 4096          # paper: 4096 x 64b URAM instruction memory
    raw_latency: int = 4            # slots until a result is readable (exec
                                    # stage is pipelined over 4 stages, §5.1)
    send_latency: int = 1           # slots per NoC hop (unidirectional torus)
    gmem_words: int = 1 << 22       # 8 MiB of 16-bit global memory
    cache_words: int = 1 << 16      # 128 KiB direct-mapped cache (§5.3)
    cache_line_words: int = 32      # 64-byte lines
    cache_hit_stall: int = 14       # global stall cycles on a cache hit
    cache_miss_stall: int = 120     # global stall cycles on a miss (DRAM)

    @property
    def num_cores(self) -> int:
        return self.grid_width * self.grid_height

    def core_xy(self, core: int) -> Tuple[int, int]:
        return core % self.grid_width, core // self.grid_width

    def xy_core(self, x: int, y: int) -> int:
        return (y % self.grid_height) * self.grid_width + (x % self.grid_width)

    def route_hops(self, src: int, dst: int) -> int:
        """Hop count of the dimension-ordered (+x then +y) route on the
        uni-directional torus; 0 for a self-send (a local move that never
        touches the NoC)."""
        sx, sy = self.core_xy(src)
        dx, dy = self.core_xy(dst)
        return (dx - sx) % self.grid_width + (dy - sy) % self.grid_height
