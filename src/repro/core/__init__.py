"""Core static-BSP stack: netlist IR, compiler pipeline and executors.

These modules are the implementation layer; the recommended entry point is
the :mod:`repro.sim` facade (``sim.compile(...)`` / ``Simulation``), which
wraps them behind one API. Everything here stays importable directly —
``repro.core.compile.compile_circuit``, ``repro.core.bsp.Machine`` etc.
are stable — and the most common names are re-exported below for
convenience.
"""
from .compile import Program, compile_circuit
from .isa import HardwareConfig, Op
from .netlist import Circuit

__all__ = ["Program", "compile_circuit", "HardwareConfig", "Op", "Circuit"]
