"""Reference netlist interpreter — the oracle for everything downstream.

Full-cycle, cycle-accurate semantics (paper §2.1): each ``step`` evaluates the
combinational DAG from current state, then commits registers and memory
writes atomically. Exceptions (EXPECT) are collected per cycle and surfaced to
the caller, mirroring Manticore's host-serviced exceptions (paper §A.3.2).

This interpreter is intentionally simple Python (exact 64-bit integer
semantics); it is the ground truth against which the compiler, the jnp
lockstep engine, and the Pallas kernel are validated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .netlist import Circuit, NOp, Node


@dataclass
class CycleResult:
    exceptions: List[int] = field(default_factory=list)
    outputs: Dict[str, int] = field(default_factory=dict)


class NetlistSim:
    """Executable model of a :class:`Circuit`."""

    def __init__(self, circuit: Circuit):
        circuit.validate()
        self.c = circuit
        self.order = self._topo_order()
        self.regs: Dict[int, int] = dict(circuit.reg_init)
        self.mems: Dict[str, List[int]] = {
            name: list(m.init) for name, m in circuit.mems.items()}
        self.cycle = 0

    # ------------------------------------------------------------------
    def _topo_order(self) -> List[Node]:
        """Topological order of combinational nodes (REG/INPUT/CONST are
        leaves; MEMRD reads *current* memory state so it is a leaf too,
        except for its address operand)."""
        nodes = self.c.nodes
        order: List[Node] = []
        state = [0] * len(nodes)  # 0=unvisited 1=visiting 2=done
        stack: List[Tuple[int, int]] = []
        for root in range(len(nodes)):
            if state[root]:
                continue
            stack.append((root, 0))
            while stack:
                nid, ai = stack.pop()
                node = nodes[nid]
                if ai == 0:
                    if state[nid] == 2:
                        continue
                    if state[nid] == 1:
                        raise ValueError("combinational loop in netlist")
                    state[nid] = 1
                if ai < len(node.args):
                    stack.append((nid, ai + 1))
                    arg = node.args[ai]
                    if state[arg] == 0:
                        stack.append((arg, 0))
                    elif state[arg] == 1:
                        raise ValueError("combinational loop in netlist")
                else:
                    state[nid] = 2
                    order.append(node)
        return order

    # ------------------------------------------------------------------
    def step(self) -> CycleResult:
        c = self.c
        val: List[int] = [0] * len(c.nodes)
        res = CycleResult()
        mem_writes: List[Tuple[str, int, int]] = []

        for n in self.order:
            a = n.args
            op = n.op
            mask = (1 << n.width) - 1
            if op == NOp.CONST:
                val[n.nid] = n.params["value"]
            elif op == NOp.INPUT:
                val[n.nid] = c.input_values[n.nid]
            elif op == NOp.REG:
                val[n.nid] = self.regs[n.nid]
            elif op == NOp.AND:
                val[n.nid] = val[a[0]] & val[a[1]]
            elif op == NOp.OR:
                val[n.nid] = val[a[0]] | val[a[1]]
            elif op == NOp.XOR:
                val[n.nid] = val[a[0]] ^ val[a[1]]
            elif op == NOp.NOT:
                val[n.nid] = (~val[a[0]]) & mask
            elif op == NOp.ADD:
                val[n.nid] = (val[a[0]] + val[a[1]]) & mask
            elif op == NOp.SUB:
                val[n.nid] = (val[a[0]] - val[a[1]]) & mask
            elif op == NOp.MUL:
                val[n.nid] = (val[a[0]] * val[a[1]]) & mask
            elif op == NOp.EQ:
                val[n.nid] = int(val[a[0]] == val[a[1]])
            elif op == NOp.NE:
                val[n.nid] = int(val[a[0]] != val[a[1]])
            elif op == NOp.LTU:
                val[n.nid] = int(val[a[0]] < val[a[1]])
            elif op == NOp.SHL:
                val[n.nid] = (val[a[0]] << n.params["amount"]) & mask
            elif op == NOp.SHR:
                val[n.nid] = val[a[0]] >> n.params["amount"]
            elif op == NOp.SRA:
                src = c.nodes[a[0]]
                v = val[a[0]]
                sign = v >> (src.width - 1)
                k = min(n.params["amount"], src.width)
                v >>= k
                if sign:
                    v |= mask & ~((1 << max(src.width - k, 0)) - 1)
                val[n.nid] = v & mask
            elif op == NOp.MUX:
                val[n.nid] = val[a[1]] if val[a[0]] else val[a[2]]
            elif op == NOp.SLICE:
                val[n.nid] = (val[a[0]] >> n.params["off"]) & mask
            elif op == NOp.CAT:
                lo = c.nodes[a[1]]
                val[n.nid] = (val[a[0]] << lo.width) | val[a[1]]
            elif op == NOp.MEMRD:
                m = self.mems[n.params["mem"]]
                val[n.nid] = m[val[a[0]] % len(m)]
            elif op == NOp.MEMWR:
                if val[a[2]]:
                    mem_writes.append((n.params["mem"], val[a[0]], val[a[1]]))
            elif op == NOp.EXPECT:
                if val[a[0]] != val[a[1]]:
                    res.exceptions.append(n.params["eid"])
            elif op == NOp.OUTPUT:
                res.outputs[n.params["name"]] = val[a[0]]
            else:  # pragma: no cover
                raise NotImplementedError(op)

        # ---- commit phase (end of Vcycle) ----
        for rid, nxt in c.reg_next.items():
            self.regs[rid] = val[nxt]
        for name, addr, data in mem_writes:
            m = self.mems[name]
            m[addr % len(m)] = data
        self.cycle += 1
        return res

    def run(self, max_cycles: int,
            stop_on_exception: bool = True) -> Tuple[int, List[CycleResult]]:
        """Run until an exception fires or max_cycles elapse. Returns
        (cycles_run, per-cycle results that had exceptions/outputs)."""
        log: List[CycleResult] = []
        for i in range(max_cycles):
            r = self.step()
            if r.exceptions or r.outputs:
                log.append(r)
            if r.exceptions and stop_on_exception:
                return i + 1, log
        return max_cycles, log

    def reg_value(self, name: str) -> int:
        for rid, nm in self.c.reg_names.items():
            if nm == name:
                return self.regs[rid]
        raise KeyError(name)
