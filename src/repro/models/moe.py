"""Mixture-of-Experts with capacity-based static dispatch.

Supports Mixtral-style (8 routed, top-2) and DeepSeek-MoE-style fine-grained
routing (2 shared + 64 routed, top-6, small per-expert d_ff). Dispatch is the
Mesh-TensorFlow one-hot formulation: static shapes, no sorting, so FLOPs are
proportional to *active* experts (capacity-dropped tokens fall back to the
shared/residual path) — this keeps MODEL_FLOPS / HLO_FLOPs honest in the
roofline (no all-experts-for-all-tokens blowup).

Sharding: experts go on the 'model' axis when divisible (expert parallelism);
otherwise each expert's hidden dim is tensor-parallel.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import batch_axes, shard_act
from .config import ModelConfig
from .layers import dense_init


def moe_init(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    f = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
               * scale).astype(dt),
        "wg": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
               * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
               / np.sqrt(f)).astype(dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"wi": dense_init(kk[0], d, fs, dt),
                       "wg": dense_init(kk[1], d, fs, dt),
                       "wo": dense_init(kk[2], fs, d, dt)}
    return p


def _n_groups(B: int) -> int:
    """§Perf hillclimb (REPRO_MOE_GROUPED=1): dispatch per data-parallel
    group instead of globally. Global dispatch routes through a single
    [T, E, C] tensor whose capacity C scales with the *global* token count
    (all-to-all across the whole mesh); per-group dispatch keeps tokens
    resident on their data shard — C drops by the group count and the
    cross-shard traffic becomes expert-only."""
    import os
    if os.environ.get("REPRO_MOE_GROUPED") != "1":
        return 1
    from ..distributed.ctx import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    return g


def moe_fwd(p: Dict, cfg: ModelConfig, x: jax.Array,
            capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    G = _n_groups(B)
    if G > 1 and B % G == 0:
        xg = x.reshape(G, (B // G) * S, d)
        outs = _moe_groups(p, cfg, xg, capacity_factor)
        y, aux = outs
        return y.reshape(B, S, d), aux
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ p["router"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [T, K]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    C = int(np.ceil(capacity_factor * T * K / E))
    C = max(8, min(C, T))

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # [T, K, E]
    pos_in_e = (jnp.cumsum(onehot.reshape(T * K, E), axis=0)
                .reshape(T, K, E) - 1.0)
    keep = (pos_in_e < C) & (onehot > 0)
    slot = jnp.clip(pos_in_e, 0, C - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32) * \
        keep[..., None].astype(jnp.float32)                 # [T, K, E, C]

    dispatch = slot_oh.sum(1)                               # [T, E, C]
    combine = (slot_oh * gate_vals[..., None, None]).sum(1)  # [T, E, C]

    xe = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32),
                    dispatch).astype(x.dtype)               # [E, C, d]
    xe = shard_act(xe, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wi"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])             # [E, C, d]
    ye = shard_act(ye, "model", None, None)
    y = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(xt @ sh["wi"]) * (xt @ sh["wg"])
        y = y + (hs @ sh["wo"]).astype(jnp.float32)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _moe_groups(p: Dict, cfg: ModelConfig, xg: jax.Array,
                capacity_factor: float) -> Tuple[jax.Array, jax.Array]:
    """Group-local dispatch: xg [G, Tg, d]; G rides the data axes."""
    G, Tg, d = xg.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    xg = shard_act(xg, batch_axes(), None, None)

    logits = xg.astype(jnp.float32) @ p["router"]            # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    me = probs.mean(axis=1)                                  # [G, E]
    ce = jnp.zeros((G, E), jnp.float32)
    ce = ce.at[jnp.arange(G)[:, None, None],
               gate_idx].add(1.0 / (Tg * K))
    aux = (E * (me * ce).sum(-1)).mean()

    C = int(np.ceil(capacity_factor * Tg * K / E))
    C = max(8, min(C, Tg))

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # [G, Tg, K, E]
    pos_in_e = (jnp.cumsum(onehot.reshape(G, Tg * K, E), axis=1)
                .reshape(G, Tg, K, E) - 1.0)
    keep = (pos_in_e < C) & (onehot > 0)
    slot = jnp.clip(pos_in_e, 0, C - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32) * \
        keep[..., None].astype(jnp.float32)                  # [G,Tg,K,E,C]
    dispatch = slot_oh.sum(2)                                # [G, Tg, E, C]
    combine = (slot_oh * gate_vals[..., None, None]).sum(2)

    import os
    if os.environ.get("REPRO_MOE_SCATTER") == "1":
        # §Perf hillclimb 2b: the one-hot dispatch *matmul* costs
        # T*E*C*d FLOPs — thousands of times the expert FFNs. Scatter/
        # gather does the same routing in O(T*K*d) bytes and ~0 FLOPs.
        g_ar = jnp.arange(G)[:, None, None]
        # per-(token, k) slot/keep at the *chosen* expert
        keep_tk = jnp.take_along_axis(keep, gate_idx[..., None],
                                      axis=-1)[..., 0]       # [G, Tg, K]
        slot_tk = jnp.take_along_axis(slot, gate_idx[..., None],
                                      axis=-1)[..., 0]       # [G, Tg, K]
        xe = jnp.zeros((G, E, C, d), xg.dtype)
        contrib = jnp.where(keep_tk[..., None],
                            xg[:, :, None, :].astype(xg.dtype), 0)
        xe = xe.at[g_ar, gate_idx, slot_tk].add(contrib)     # [G, E, C, d]
        xe = shard_act(xe, batch_axes(), "model", None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi"])) * \
            jnp.einsum("gecd,edf->gecf", xe, p["wg"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
        ye = shard_act(ye, batch_axes(), "model", None, None)
        yg = ye[g_ar, gate_idx, slot_tk]                     # [G, Tg, K, d]
        w = (gate_vals * keep_tk.astype(jnp.float32))[..., None]
        y = (yg.astype(jnp.float32) * w).sum(2)
    else:
        xe = jnp.einsum("gtd,gtec->gecd", xg.astype(jnp.float32),
                        dispatch).astype(xg.dtype)           # [G, E, C, d]
        xe = shard_act(xe, batch_axes(), "model", None, None)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi"])) * \
            jnp.einsum("gecd,edf->gecf", xe, p["wg"])
        ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
        ye = shard_act(ye, batch_axes(), "model", None, None)
        y = jnp.einsum("gecd,gtec->gtd", ye.astype(jnp.float32), combine)

    if cfg.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(xg @ sh["wi"]) * (xg @ sh["wg"])
        y = y + (hs @ sh["wo"]).astype(jnp.float32)
    return y.astype(xg.dtype), aux
