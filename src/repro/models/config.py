"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None   # default d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    m_rope: bool = False           # qwen2-vl multimodal rotary (3 sections)
    swa_window: Optional[int] = None  # sliding-window attention
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0           # per-expert hidden dim (fine-grained MoE)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    attn_every: int = 0            # hybrid: shared attention block cadence
    slstm_every: int = 0           # xLSTM: sLSTM block cadence
    # encoder-decoder (audio)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500           # stubbed frontend sequence length
    # misc
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which block stack to build
    block: str = "attn"            # attn | mamba2 | xlstm

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for 6*N*D roofline accounting) -------------
    def param_count(self) -> Tuple[int, int]:
        """(total params, active params per token)."""
        d, dh = self.d_model, self.d_head
        qkv = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) \
            + (self.n_heads * dh) * d
        if self.qkv_bias:
            qkv += (self.n_heads + 2 * self.n_kv_heads) * dh
        if self.block == "mamba2":
            d_in = 2 * d
            heads = d_in // self.ssm_headdim
            blk = d * (2 * d_in + 2 * self.ssm_state + heads) + d_in * d
            blk_active = blk
            attn_blk = qkv if self.attn_every else 0
        elif self.block == "xlstm":
            d_in = 2 * d
            blk = 4 * d * d + d_in * d + d * d_in    # qkv+gates+proj approx
            blk_active = blk
            attn_blk = 0
        else:
            blk = qkv
            blk_active = qkv
            attn_blk = 0
        if self.is_moe:
            dff = self.d_ff_expert or self.d_ff
            expert = 3 * d * dff
            mlp = self.n_experts * expert + self.n_shared_experts * expert
            mlp_active = (self.moe_top_k + self.n_shared_experts) * expert
        elif self.d_ff:
            mlp = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
            mlp_active = mlp
        else:
            mlp = mlp_active = 0
        per_layer = blk + mlp + 2 * d
        per_layer_active = blk_active + mlp_active + 2 * d
        n_l = self.n_layers
        total = n_l * per_layer + 2 * d * self.vocab
        active = n_l * per_layer_active + 2 * d * self.vocab
        if self.attn_every:
            total += attn_blk  # one shared block
            active += attn_blk * (n_l // max(self.attn_every, 1))
        if self.enc_dec:
            # decoder cross-attention + its own stack counted via n_layers;
            # encoder layers:
            enc = self.n_enc_layers * (qkv + mlp + 2 * d)
            cross = self.n_layers * qkv
            total += enc + cross
            active += enc + cross
        return int(total), int(active)
