"""Recurrent blocks: Mamba2 (SSD, for zamba2) and xLSTM (mLSTM/sLSTM).

The recurrences are O(1)-state per token, which is what makes the
``long_500k`` decode shape feasible for these families. Training uses a
chunked ``lax.scan`` over the sequence (linear time, constant memory per
chunk); decode carries the state explicitly.

These are TPU-native formulations of the papers' CUDA kernels: the inner
chunk update is a dense einsum (MXU-friendly) and the cross-chunk recurrence
is a short scan — the standard hardware adaptation for SSDs (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------- mamba2 ----
def mamba2_init(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_in = 2 * d
    H = d_in // cfg.ssm_headdim
    N = cfg.ssm_state
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [x, z, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dt),
        "out_proj": dense_init(ks[1], d_in, d, dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dt),
    }


def _mamba2_scan(xh, Bm, Cm, dtv, A, h0):
    """Sequential SSD recurrence.
    xh: [B,S,H,P]; Bm/Cm: [B,S,N]; dtv: [B,S,H]; h0: [B,H,N,P]."""
    dt_ = jax.nn.softplus(dtv)                            # [B,S,H]
    decay = jnp.exp(-jnp.exp(A)[None, None, :] * dt_)     # [B,S,H]

    def step(h, t):
        x_t, b_t, c_t, dc = t                 # [B,H,P],[B,N],[B,N],[B,H,1,1]
        h = h * dc + jnp.einsum("bn,bhp->bhnp", b_t, x_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, h)
        return h, y

    xs = (xh.transpose(1, 0, 2, 3), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), decay.transpose(1, 0, 2)[..., None, None])
    h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.transpose(1, 0, 2, 3)           # [B,S,H,P]


def mamba2_fwd(p: Dict, cfg: ModelConfig, x: jax.Array,
               state: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,d] -> (y, final_state[B,H,N,P])."""
    B, S, d = x.shape
    d_in = 2 * d
    P = cfg.ssm_headdim
    H = d_in // P
    N = cfg.ssm_state
    z, xr, Bm, Cm, dtv = jnp.split(
        x @ p["in_proj"], [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N],
        axis=-1)
    xh = xr.reshape(B, S, H, P).astype(jnp.float32)
    dtv = dtv.astype(jnp.float32) + p["dt_bias"]
    if state is None:
        state = jnp.zeros((B, H, N, P), jnp.float32)
    state, y = _mamba2_scan(xh, Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32), dtv, p["A_log"], state)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["out_proj"], state


# ----------------------------------------------------------------- xlstm ----
def mlstm_init(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt),
        "wi": dense_init(ks[3], d, cfg.n_heads, dt),   # input gate
        "wf": dense_init(ks[4], d, cfg.n_heads, dt),   # forget gate
        "wo": dense_init(ks[5], d, d, dt),
        "norm": rmsnorm_init(d, dt),
    }


def mlstm_fwd(p: Dict, cfg: ModelConfig, x: jax.Array,
              state: Optional[Tuple] = None) -> Tuple[jax.Array, Tuple]:
    """Matrix-memory LSTM. state = (C [B,H,dh,dh], n [B,H,dh])."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q = (x @ p["wq"]).reshape(B, S, H, dh).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(B, S, H, dh).astype(jnp.float32) / np.sqrt(dh)
    v = (x @ p["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    ig = jnp.exp(-jax.nn.softplus(-(x @ p["wi"]))).astype(jnp.float32)
    fg = jax.nn.sigmoid((x @ p["wf"]).astype(jnp.float32))
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
    else:
        C0, n0 = state

    def step(carry, t):
        C, n = carry
        q_t, k_t, v_t, i_t, f_t = t
        f_ = f_t[..., None, None]
        C = f_ * C + i_t[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        n = f_t[..., None] * n + i_t[..., None] * k_t
        num = jnp.einsum("bhd,bhde->bhe", q_t, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q_t, n)), 1.0)
        return (C, n), num / den[..., None]

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), ig.reshape(B, S, H).transpose(1, 0, 2),
          fg.reshape(B, S, H).transpose(1, 0, 2))
    (C, n), ys = jax.lax.scan(step, (C0, n0), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["wo"], (C, n)


def slstm_init(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "wz": dense_init(ks[0], d, d, dt),
        "wi": dense_init(ks[1], d, d, dt),
        "wf": dense_init(ks[2], d, d, dt),
        "wo": dense_init(ks[3], d, d, dt),
        "proj": dense_init(ks[4], d, d, dt),
        "norm": rmsnorm_init(d, dt),
    }


def slstm_fwd(p: Dict, cfg: ModelConfig, x: jax.Array,
              state: Optional[Tuple] = None) -> Tuple[jax.Array, Tuple]:
    """Scalar-memory LSTM. state = (c [B,d], n [B,d])."""
    B, S, d = x.shape
    z = jnp.tanh((x @ p["wz"]).astype(jnp.float32))
    ig = jnp.exp(-jax.nn.softplus(-(x @ p["wi"]).astype(jnp.float32)))
    fg = jax.nn.sigmoid((x @ p["wf"]).astype(jnp.float32))
    og = jax.nn.sigmoid((x @ p["wo"]).astype(jnp.float32))
    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
    else:
        c0, n0 = state

    def step(carry, t):
        c, n = carry
        z_t, i_t, f_t, o_t = t
        c = f_t * c + i_t * z_t
        n = f_t * n + i_t
        return (c, n), o_t * c / jnp.maximum(n, 1.0)

    xs = tuple(a.transpose(1, 0, 2) for a in (z, ig, fg, og))
    (c, n), ys = jax.lax.scan(step, (c0, n0), xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["proj"], (c, n)
