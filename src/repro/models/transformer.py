"""Model stacks: decoder-only, hybrid (zamba2), xLSTM, encoder-decoder.

Layers are *stacked* ([L, ...] leading axis) and applied with
``jax.lax.scan`` + selective remat — essential to keep HLO size and compile
time bounded for 80-layer configs lowered against 512 devices. Heterogeneous
stacks (zamba2's shared attention block, xLSTM's sLSTM cadence) use a
super-layer: scan over groups, unrolling the small static pattern inside.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import batch_axes, shard_act
from .config import ModelConfig
from . import layers as L
from . import moe as MOE
from . import ssm as SSM

Params = Dict[str, Any]


# --------------------------------------------------------------- helpers ----
# When True, layer scans are fully unrolled. Used by the dry-run calibration:
# XLA's cost analysis counts while-loop bodies once, so per-layer costs are
# measured on small unrolled configs and extrapolated (launch/dryrun.py).
_UNROLL = False


def set_unroll(on: bool) -> None:
    global _UNROLL
    _UNROLL = on


def scan_layers(f, init, xs, length=None):
    if _UNROLL:
        return jax.lax.scan(f, init, xs, length=length, unroll=True)
    return jax.lax.scan(f, init, xs, length=length)


def _stack_init(key, n: int, init_fn):
    ks = jax.random.split(key, n)
    return jax.vmap(init_fn)(ks)


def _remat(f):
    import os
    if os.environ.get("REPRO_REMAT") == "min":
        # §Perf hillclimb: save nothing across the layer boundary --
        # backward recomputes the layer (more FLOPs, far fewer saved bytes)
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(f, policy=jax.checkpoint_policies.dots_saveable)


# ------------------------------------------------------- decoder-only ------
def dense_block_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    if cfg.is_moe:
        p["moe"] = MOE.moe_init(k2, cfg)
    elif cfg.d_ff:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def dense_block_fwd(cfg: ModelConfig, p: Params, x, pos,
                    cache: Optional[Tuple] = None):
    """Returns (x, new_cache, aux)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cache is None:
        a = L.attention_fwd(p["attn"], cfg, h, pos)
        new_cache = None
    else:
        a, ck, cv = L.attention_decode(p["attn"], cfg, h, cache[0], cache[1],
                                       pos)
        new_cache = (ck, cv)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        m, aux = MOE.moe_fwd(p["moe"], cfg, h)
    elif cfg.d_ff:
        m = L.mlp_fwd(p["mlp"], cfg, h)
    else:
        m = jnp.zeros_like(h)
    x = x + m
    return shard_act(x, batch_axes(), None, None), new_cache, aux


def decoder_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": L.embed_init(k1, cfg),
        "layers": _stack_init(k2, cfg.n_layers,
                              lambda k: dense_block_init(k, cfg)),
        "lnf": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


def decoder_fwd(cfg: ModelConfig, params: Params, x, pos,
                caches: Optional[Tuple] = None):
    """Scan over stacked layers. caches: (k [L,B,T,Hk,dh], v) or None."""
    def body(carry, xs):
        h, aux = carry
        if caches is None:
            p = xs
            h, _, a = dense_block_fwd(cfg, p, h, pos)
            return (h, aux + a), None
        p, ck, cv = xs
        h, (ck, cv), a = dense_block_fwd(cfg, p, h, pos, (ck, cv))
        return (h, aux + a), (ck, cv)

    xs = params["layers"] if caches is None else \
        (params["layers"], caches[0], caches[1])
    (x, aux), new_caches = scan_layers(_remat(body),
                                        (x, jnp.zeros((), jnp.float32)), xs)
    x = L.rmsnorm(params["lnf"], x, cfg.norm_eps)
    return x, new_caches, aux


def _ring(kv: jax.Array, S: int, Tw: int) -> jax.Array:
    """Place the last Tw rows of a length-S prompt into ring-buffer slots
    (slot j holds the token with position ≡ j mod Tw)."""
    tail = kv[:, -Tw:]
    return jnp.roll(tail, S % Tw, axis=1)


def decoder_prefill(cfg: ModelConfig, params: Params, x, pos, Tw: int):
    """Forward the prompt once, capturing per-layer K/V ring caches."""
    S = x.shape[1]

    def body(carry, p):
        h, aux = carry
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], cfg, hn, pos)
        mask = L.causal_mask(S, S, cfg.swa_window)
        a = L._sdpa(q, k, v, mask, cfg) @ p["attn"]["wo"]
        h = h + a
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            m, a2 = MOE.moe_fwd(p["moe"], cfg, hn)
            aux = aux + a2
        elif cfg.d_ff:
            m = L.mlp_fwd(p["mlp"], cfg, hn)
        else:
            m = jnp.zeros_like(hn)
        h = shard_act(h + m, batch_axes(), None, None)
        return (h, aux), (_ring(k, S, Tw), _ring(v, S, Tw))

    (x, aux), (ks, vs) = scan_layers(
        _remat(body), (x, jnp.zeros((), jnp.float32)), params["layers"])
    return L.rmsnorm(params["lnf"], x, cfg.norm_eps), ks, vs, aux


def encdec_prefill(cfg: ModelConfig, params: Params, x, pos, enc_out,
                   Tw: int):
    S = x.shape[1]

    def body(h, p):
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        q, k, v = L._qkv(p["attn"], cfg, hn, pos)
        mask = L.causal_mask(S, S, None)
        h = h + L._sdpa(q, k, v, mask, cfg) @ p["attn"]["wo"]
        hn = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        h = h + L.cross_attention_fwd(p["cross"], cfg, hn, enc_out)
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = shard_act(h + L.mlp_fwd(p["mlp"], cfg, hn), batch_axes(),
                      None, None)
        return h, (_ring(k, S, Tw), _ring(v, S, Tw))

    x, (ks, vs) = scan_layers(_remat(body), x, params["dec_layers"])
    return L.rmsnorm(params["lnf"], x, cfg.norm_eps), ks, vs


# ----------------------------------------------------------- zamba2 --------
def zamba2_init(key, cfg: ModelConfig) -> Params:
    inner = cfg.attn_every
    n_super = cfg.n_layers // inner
    tail = cfg.n_layers - n_super * inner
    ks = jax.random.split(key, 5)

    def group_init(k):
        kk = jax.random.split(k, inner)
        return jax.vmap(lambda kx: _mamba_layer_init(kx, cfg))(kk)

    p = {
        "embed": L.embed_init(ks[0], cfg),
        "super": _stack_init(ks[1], n_super, group_init),
        "shared_ln": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "shared_attn": L.attention_init(ks[2], cfg),
        "lnf": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    if tail:
        p["tail"] = _stack_init(ks[3], tail,
                                lambda k: _mamba_layer_init(k, cfg))
    return p


def _mamba_layer_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "mamba": SSM.mamba2_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "mlp": L.mlp_init(k2, cfg),
    }


def _mamba_layer_fwd(cfg, p, x, state):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    m, state = SSM.mamba2_fwd(p["mamba"], cfg, h, state)
    x = x + m
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_fwd(p["mlp"], cfg, h)
    return shard_act(x, batch_axes(), None, None), state


ZAMBA_WINDOW = 4096  # shared-attention sliding window (long-context safety)


def zamba2_fwd(cfg: ModelConfig, params: Params, x, pos,
               state: Optional[Dict] = None, decode: bool = False,
               capture_kv: int = 0):
    """state: {"ssm": [n_super, inner, B,H,N,P], "tail_ssm": [...],
    "ak"/"av": [n_super, B, T, Hkv, dh]} (attention cache, decode only)."""
    inner = cfg.attn_every
    n_super = cfg.n_layers // inner
    B = x.shape[0]
    d_in = 2 * cfg.d_model
    H = d_in // cfg.ssm_headdim
    if state is None:
        state = {}
    ssm0 = state.get("ssm")
    aux = jnp.zeros((), jnp.float32)

    def super_body(carry, xs):
        h = carry
        p, s_in, ak, av = xs
        s_out = []
        for i in range(inner):
            pi = jax.tree.map(lambda a: a[i], p)
            si = None if s_in is None else s_in[i]
            h, so = _mamba_layer_fwd(cfg, pi, h, si)
            s_out.append(so)
        # shared attention block (weights shared across groups)
        hn = L.rmsnorm(params["shared_ln"], h, cfg.norm_eps)
        if not decode:
            if capture_kv:
                S = hn.shape[1]
                q, k, v = L._qkv(params["shared_attn"], cfg, hn, pos)
                mask = L.causal_mask(S, S, ZAMBA_WINDOW)
                a = L._sdpa(q, k, v, mask, cfg) @ \
                    params["shared_attn"]["wo"]
                nak = _ring(k, S, capture_kv)
                nav = _ring(v, S, capture_kv)
            else:
                a = L.attention_fwd(params["shared_attn"], cfg, hn, pos,
                                    window=ZAMBA_WINDOW)
                nak, nav = ak, av
        else:
            a, nak, nav = L.attention_decode(params["shared_attn"], cfg, hn,
                                             ak, av, pos,
                                             window=ZAMBA_WINDOW)
        h = h + a
        return h, (jnp.stack(s_out), nak, nav)

    if decode:
        ak, av = state["ak"], state["av"]
    else:  # unused as inputs in the full-attention branch
        Tw = max(capture_kv, 1)
        ak = jnp.zeros((n_super, B, Tw, cfg.n_kv_heads, cfg.d_head), x.dtype)
        av = jnp.zeros_like(ak)
    if ssm0 is None:
        ssm0 = jnp.zeros((n_super, inner, B, H, cfg.ssm_state,
                          cfg.ssm_headdim), jnp.float32)
    x, (ssm1, ak1, av1) = scan_layers(
        _remat(super_body), x, (params["super"], ssm0, ak, av))

    tail_states = []
    if "tail" in params:
        nt = params["tail"]["ln1"]["scale"].shape[0]
        t0 = state.get("tail_ssm")
        for i in range(nt):
            pi = jax.tree.map(lambda a: a[i], params["tail"])
            si = None if t0 is None else t0[i]
            x, so = _mamba_layer_fwd(cfg, pi, x, si)
            tail_states.append(so)
    x = L.rmsnorm(params["lnf"], x, cfg.norm_eps)
    new_state = {"ssm": ssm1, "ak": ak1, "av": av1}
    if tail_states:
        new_state["tail_ssm"] = jnp.stack(tail_states)
    return x, new_state, aux


# ------------------------------------------------------------ xlstm --------
def xlstm_init(key, cfg: ModelConfig) -> Params:
    inner = cfg.slstm_every - 1          # mLSTM layers per group
    n_super = cfg.n_layers // cfg.slstm_every
    ks = jax.random.split(key, 4)

    def group_init(k):
        k1, k2 = jax.random.split(k)
        kk = jax.random.split(k1, inner)
        return {
            "m": jax.vmap(lambda kx: _xl_layer_init(kx, cfg, "m"))(kk),
            "s": _xl_layer_init(k2, cfg, "s"),
        }

    return {
        "embed": L.embed_init(ks[0], cfg),
        "super": _stack_init(ks[1], n_super, group_init),
        "lnf": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


def _xl_layer_init(key, cfg, kind):
    p = {"ln": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype))}
    p["core"] = SSM.mlstm_init(key, cfg) if kind == "m" else \
        SSM.slstm_init(key, cfg)
    return p


def xlstm_fwd(cfg: ModelConfig, params: Params, x, pos,
              state: Optional[Dict] = None):
    inner = cfg.slstm_every - 1
    n_super = cfg.n_layers // cfg.slstm_every
    B = x.shape[0]
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    d = cfg.d_model
    if state is None:
        state = {
            "mC": jnp.zeros((n_super, inner, B, H, dh, dh), jnp.float32),
            "mn": jnp.zeros((n_super, inner, B, H, dh), jnp.float32),
            "sc": jnp.zeros((n_super, B, d), jnp.float32),
            "sn": jnp.ones((n_super, B, d), jnp.float32),
        }

    def super_body(h, xs):
        p, mC, mn, sc, sn = xs
        mCo, mno = [], []
        for i in range(inner):
            pi = jax.tree.map(lambda a: a[i], p["m"])
            hn = L.rmsnorm(pi["ln"], h, cfg.norm_eps)
            y, (C1, n1) = SSM.mlstm_fwd(pi["core"], cfg, hn, (mC[i], mn[i]))
            h = h + y
            mCo.append(C1); mno.append(n1)
        hn = L.rmsnorm(p["s"]["ln"], h, cfg.norm_eps)
        y, (sc1, sn1) = SSM.slstm_fwd(p["s"]["core"], cfg, hn, (sc, sn))
        h = h + y
        return h, (jnp.stack(mCo), jnp.stack(mno), sc1, sn1)

    x, (mC, mn, sc, sn) = scan_layers(
        _remat(super_body), x,
        (params["super"], state["mC"], state["mn"], state["sc"],
         state["sn"]))
    x = L.rmsnorm(params["lnf"], x, cfg.norm_eps)
    return x, {"mC": mC, "mn": mn, "sc": sc, "sn": sn}, \
        jnp.zeros((), jnp.float32)


# ----------------------------------------------------- encoder-decoder -----
def encdec_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": L.attention_init(k1, cfg),
            "ln2": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": L.mlp_init(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "attn": L.attention_init(k1, cfg),
            "lnx": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "cross": L.attention_init(k2, cfg),
            "ln2": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
            "mlp": L.mlp_init(k3, cfg),
        }

    return {
        "embed": L.embed_init(ks[0], cfg),
        "enc_layers": _stack_init(ks[1], cfg.n_enc_layers, enc_layer),
        "enc_lnf": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "dec_layers": _stack_init(ks[2], cfg.n_layers, dec_layer),
        "lnf": L.rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


def encoder_fwd(cfg: ModelConfig, params: Params, frames: jax.Array):
    """frames: [B, F, d] (stubbed conv frontend output)."""
    def body(h, p):
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        h = h + L.attention_fwd(p["attn"], cfg, hn, None, causal=False)
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp_fwd(p["mlp"], cfg, hn)
        return shard_act(h, batch_axes(), None, None), None

    x, _ = scan_layers(_remat(body), frames, params["enc_layers"])
    return L.rmsnorm(params["enc_lnf"], x, cfg.norm_eps)


def encdec_fwd(cfg: ModelConfig, params: Params, x, pos, enc_out,
               caches: Optional[Tuple] = None):
    def body(carry, xs):
        h = carry
        if caches is None:
            p = xs
            cache = None
        else:
            p, ck, cv = xs
            cache = (ck, cv)
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        if cache is None:
            a = L.attention_fwd(p["attn"], cfg, hn, pos)
            nc = (jnp.zeros((0,)), jnp.zeros((0,)))
        else:
            a, ck, cv = L.attention_decode(p["attn"], cfg, hn, cache[0],
                                           cache[1], pos)
            nc = (ck, cv)
        h = h + a
        hn = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        h = h + L.cross_attention_fwd(p["cross"], cfg, hn, enc_out)
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp_fwd(p["mlp"], cfg, hn)
        h = shard_act(h, batch_axes(), None, None)
        return h, (None if caches is None else nc)

    xs = params["dec_layers"] if caches is None else \
        (params["dec_layers"], caches[0], caches[1])
    x, new_caches = scan_layers(_remat(body), x, xs)
    return L.rmsnorm(params["lnf"], x, cfg.norm_eps), new_caches
