"""Unified model API: build(cfg) -> Model with init / loss / prefill /
decode_step / make_cache / input_specs.

The same entry points serve CPU smoke tests (tiny real arrays), the
production dry-run (ShapeDtypeStruct params, 512 fake devices), training and
serving drivers.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import batch_axes, shard_act
from .config import ModelConfig
from . import layers as L
from . import transformer as T

Params = Dict[str, Any]


def _positions(B: int, S: int, offset=0, m_rope: bool = False):
    pos = jnp.arange(S)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if m_rope:
        return jnp.stack([pos, pos, pos], 0)  # text-only: 3 equal sections
    return pos


def _decode_pos(B: int, pos_scalar, m_rope: bool = False):
    pos = jnp.broadcast_to(jnp.asarray(pos_scalar)[None, None], (B, 1))
    if m_rope:
        return jnp.stack([pos, pos, pos], 0)
    return pos


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- init ----
    def init(self, rng) -> Params:
        cfg = self.cfg
        if cfg.block == "mamba2":
            return T.zamba2_init(rng, cfg)
        if cfg.block == "xlstm":
            return T.xlstm_init(rng, cfg)
        if cfg.enc_dec:
            return T.encdec_init(rng, cfg)
        return T.decoder_init(rng, cfg)

    def abstract_params(self) -> Params:
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        return shapes

    # ---------------------------------------------------------- forward ----
    def _trunk(self, params: Params, x, pos, state=None, decode=False,
               enc_out=None):
        cfg = self.cfg
        if cfg.block == "mamba2":
            return T.zamba2_fwd(cfg, params, x, pos, state, decode=decode)
        if cfg.block == "xlstm":
            return T.xlstm_fwd(cfg, params, x, pos, state)
        if cfg.enc_dec:
            h, caches = T.encdec_fwd(cfg, params, x, pos, enc_out, state)
            return h, caches, jnp.zeros((), jnp.float32)
        return T.decoder_fwd(cfg, params, x, pos, state)

    def _embed_inputs(self, params: Params, batch: Dict) -> Tuple:
        """Returns (x, pos, enc_out, label_offset)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed(params["embed"], tokens)
        enc_out = None
        offset = 0
        if cfg.family == "vlm" and "patches" in batch:
            # stubbed vision frontend: precomputed patch embeddings prefix
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], 1)
            offset = batch["patches"].shape[1]
        if cfg.enc_dec:
            frames = batch["frames"].astype(x.dtype)
            pe = _sinusoid(frames.shape[1], cfg.d_model, x.dtype)
            enc_out = T.encoder_fwd(cfg, params, frames + pe)
        pos = _positions(B, x.shape[1], m_rope=cfg.m_rope)
        x = shard_act(x, batch_axes(), None, None)
        return x, pos, enc_out, offset

    # ------------------------------------------------------------- loss ----
    def loss(self, params: Params, batch: Dict) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        x, pos, enc_out, offset = self._embed_inputs(params, batch)
        h, _, aux = self._trunk(params, x, pos, enc_out=enc_out)
        if offset:
            h = h[:, offset:]
        logits = L.unembed(params["embed"], cfg, h).astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        nll = (logz - gold).mean()
        zloss = 1e-4 * jnp.square(logz).mean()
        total = nll + zloss + 1e-2 * aux
        return total, {"nll": nll, "aux": aux, "zloss": zloss}

    # ---------------------------------------------------------- serving ----
    def make_cache(self, B: int, ctx: int) -> Any:
        """Decode-state pytree sized for a context of ``ctx`` tokens."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.block == "mamba2":
            inner = cfg.attn_every
            n_super = cfg.n_layers // inner
            tail = cfg.n_layers - n_super * inner
            H = 2 * cfg.d_model // cfg.ssm_headdim
            Tw = min(ctx, T.ZAMBA_WINDOW)
            st = {
                "ssm": jnp.zeros((n_super, inner, B, H, cfg.ssm_state,
                                  cfg.ssm_headdim), jnp.float32),
                "ak": jnp.zeros((n_super, B, Tw, cfg.n_kv_heads, cfg.d_head),
                                dt),
                "av": jnp.zeros((n_super, B, Tw, cfg.n_kv_heads, cfg.d_head),
                                dt),
            }
            if tail:
                st["tail_ssm"] = jnp.zeros(
                    (tail, B, H, cfg.ssm_state, cfg.ssm_headdim), jnp.float32)
            return st
        if cfg.block == "xlstm":
            inner = cfg.slstm_every - 1
            n_super = cfg.n_layers // cfg.slstm_every
            H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
            return {
                "mC": jnp.zeros((n_super, inner, B, H, dh, dh), jnp.float32),
                "mn": jnp.zeros((n_super, inner, B, H, dh), jnp.float32),
                "sc": jnp.zeros((n_super, B, cfg.d_model), jnp.float32),
                "sn": jnp.ones((n_super, B, cfg.d_model), jnp.float32),
            }
        Tw = min(ctx, cfg.swa_window) if cfg.swa_window else ctx
        Lc = cfg.n_layers
        k = jnp.zeros((Lc, B, Tw, cfg.n_kv_heads, cfg.d_head), dt)
        v = jnp.zeros_like(k)
        if cfg.enc_dec:
            return {"k": k, "v": v,
                    "enc_out": jnp.zeros((B, cfg.n_frames, cfg.d_model), dt)}
        return {"k": k, "v": v}

    def prefill(self, params: Params, batch: Dict, cache: Any
                ) -> Tuple[jax.Array, Any]:
        """Run the full prompt, return (last-token logits, primed cache).

        Attention families capture per-layer K/V ring caches in the same
        pass (no attention recompute); recurrent families carry their state
        out of the sequence scan directly."""
        cfg = self.cfg
        x, pos, enc_out, offset = self._embed_inputs(params, batch)
        if cfg.block == "mamba2":
            Tw = cache["ak"].shape[2]
            h, st, _ = T.zamba2_fwd(cfg, params, x, pos, capture_kv=Tw)
            logits = L.unembed(params["embed"], cfg,
                               h[:, -1:]).astype(jnp.float32)
            return logits, st
        if cfg.block == "xlstm":
            h, st, _ = self._trunk(params, x, pos)
            logits = L.unembed(params["embed"], cfg,
                               h[:, -1:]).astype(jnp.float32)
            return logits, st
        Tw = cache["k"].shape[2]
        if cfg.enc_dec:
            h, ks, vs = T.encdec_prefill(cfg, params, x, pos, enc_out, Tw)
            logits = L.unembed(params["embed"], cfg,
                               h[:, -1:]).astype(jnp.float32)
            return logits, {"k": ks, "v": vs, "enc_out": enc_out}
        h, ks, vs, _ = T.decoder_prefill(cfg, params, x, pos, Tw)
        logits = L.unembed(params["embed"], cfg,
                           h[:, -1:]).astype(jnp.float32)
        return logits, {"k": ks, "v": vs}

    def decode_step(self, params: Params, tokens: jax.Array, cache: Any,
                    pos_scalar) -> Tuple[jax.Array, Any]:
        """tokens: [B, 1] -> (logits [B,1,V], new cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = L.embed(params["embed"], tokens)
        pos = _decode_pos(B, pos_scalar, cfg.m_rope)
        if cfg.block == "mamba2":
            h, st, _ = self._trunk(params, x, pos, state=cache, decode=True)
            return L.unembed(params["embed"], cfg, h).astype(jnp.float32), st
        if cfg.block == "xlstm":
            h, st, _ = self._trunk(params, x, pos, state=cache)
            return L.unembed(params["embed"], cfg, h).astype(jnp.float32), st
        caches = (cache["k"], cache["v"])
        enc_out = cache.get("enc_out") if cfg.enc_dec else None
        h, (ck, cv), _ = self._trunk(params, x, pos, state=caches,
                                     decode=True, enc_out=enc_out)
        out = {"k": ck, "v": cv}
        if cfg.enc_dec:
            out["enc_out"] = cache["enc_out"]
        return L.unembed(params["embed"], cfg, h).astype(jnp.float32), out

    # ------------------------------------------------------ input specs ----
    def input_specs(self, seq_len: int, global_batch: int,
                    mode: str = "train") -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        cfg = self.cfg
        B, S = global_batch, seq_len
        dt = jnp.dtype(cfg.dtype)
        sd = jax.ShapeDtypeStruct
        toks = sd((B, S), jnp.int32)
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if mode == "train":
            specs = {"tokens": toks, "labels": sd((B, S), jnp.int32)}
        elif mode == "prefill":
            specs = {"tokens": toks}
        elif mode == "decode":
            specs = {"tokens": sd((B, 1), jnp.int32)}
        if cfg.family == "vlm" and mode in ("train", "prefill"):
            specs["patches"] = sd((B, 256, cfg.d_model), dt)
        if cfg.enc_dec and mode in ("train", "prefill"):
            specs["frames"] = sd((B, cfg.n_frames, cfg.d_model), dt)
        return specs


def _sinusoid(S: int, d: int, dtype) -> jax.Array:
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    pe = np.concatenate([np.sin(ang), np.cos(ang)], -1)
    return jnp.asarray(pe[None], dtype)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
