"""Core neural layers as pure functions over explicit parameter pytrees.

Everything is written against abstract shapes (dry-run lowers with
ShapeDtypeStruct params), supports GQA (+qk_norm, QKV bias), RoPE and M-RoPE,
sliding-window masks, and single-token decode against a KV cache.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.ctx import batch_axes, shard_act
from .config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----
def rmsnorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Dict, x: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----
def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2) / d_head))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float,
               m_rope: bool = False) -> jax.Array:
    """x: [B, S, H, dh]; pos: [B, S] (or [3, B, S] for M-RoPE sections)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)  # [dh/2]
    if m_rope:
        # M-RoPE (Qwen2-VL): the rotary dims are split into 3 sections
        # (temporal / height / width), each rotated by its own position id.
        if pos.ndim == 2:
            pos = jnp.stack([pos, pos, pos], axis=0)
        n = freqs.shape[0]
        s1, s2 = n - 2 * (n // 3), n // 3
        sec = jnp.concatenate([
            jnp.zeros((s1,), jnp.int32),
            jnp.ones((s2,), jnp.int32),
            jnp.full((n - s1 - s2,), 2, jnp.int32)])
        pos_sec = pos.transpose(1, 2, 0)[..., sec]       # [B, S, dh/2]
        ang = pos_sec.astype(jnp.float32) * freqs        # [B, S, dh/2]
    else:
        ang = pos.astype(jnp.float32)[..., None] * freqs  # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    dt = _dtype(cfg)
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dt),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), dt)
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(dh, dt)
        p["knorm"] = rmsnorm_init(dh, dt)
    return p


def _qkv(p: Dict, cfg: ModelConfig, x: jax.Array,
         pos: Optional[jax.Array], rope: bool = True):
    B, S, _ = x.shape
    dh = cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, S, cfg.n_kv_heads, dh)
    v = v.reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    if rope and pos is not None:
        q = apply_rope(q, pos, cfg.rope_theta, cfg.m_rope)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.m_rope)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Grouped-query attention core. q: [B,S,H,dh]; k,v: [B,T,Hkv,dh]."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    G = H // k.shape[2]
    q = q.reshape(B, S, k.shape[2], G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(dh)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H * dh)


def causal_mask(S: int, T: int, window: Optional[int],
                offset: int = 0) -> jax.Array:
    """[1,1,1,S,T] mask; query i attends key j iff j <= i+offset and, with a
    sliding window, j > i+offset-window."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > (qi - window)
    return m[None, None, None]


def _sdpa_chunked(q, k, v, cfg: ModelConfig, window, chunk: int,
                  causal: bool = True):
    """Query-chunked attention (§Perf hillclimb): identical math, but the
    [S, S] score matrix only ever exists [chunk, S] at a time — peak
    activation memory drops by S/chunk. (The Pallas flash-attention kernel
    is the TPU-target version of the same idea; this is its XLA-level
    formulation used by the dry-run.)"""
    B, S, H, dh = q.shape
    nq = S // chunk

    def one(i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        mask = causal_mask(chunk, S, window, offset=i * chunk) \
            if causal else None
        return _sdpa(qb, k, v, mask, cfg)

    out = jax.lax.map(one, jnp.arange(nq))          # [nq, B, chunk, H*dh]
    return out.transpose(1, 0, 2, 3).reshape(B, S, H * dh)


def attention_fwd(p: Dict, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                  window: Optional[int] = None,
                  causal: bool = True) -> jax.Array:
    """Full self-attention (training / prefill)."""
    q, k, v = _qkv(p, cfg, x, pos)
    q = shard_act(q, batch_axes(), None, "model", None)
    k = shard_act(k, batch_axes(), None, None, None)
    w = window if window else cfg.swa_window
    chunk = int(os.environ.get("REPRO_ATTN_CHUNK", "0"))
    if chunk and x.shape[1] > chunk and x.shape[1] % chunk == 0:
        out = _sdpa_chunked(q, k, v, cfg, w, chunk, causal)
    else:
        mask = causal_mask(x.shape[1], x.shape[1], w) if causal else None
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"]


def cross_attention_fwd(p: Dict, cfg: ModelConfig, x: jax.Array,
                        kv_src: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no rope)."""
    B, S, _ = x.shape
    dh = cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, dh)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, dh)
    out = _sdpa(q, k, v, None, cfg)
    return out @ p["wo"]


def attention_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array,
                     window: Optional[int] = None) -> Tuple[jax.Array, ...]:
    """One-token decode. x: [B,1,d]; cache_[kv]: [B,T,Hkv,dh]; pos: [B,1].
    Returns (out, new_cache_k, new_cache_v)."""
    q, k, v = _qkv(p, cfg, x, pos)
    # M-RoPE positions are [3, B, 1]; the temporal section indexes the cache
    pos_t = pos[0] if pos.ndim == 3 else pos
    T = cache_k.shape[1]
    slot = pos_t[0, 0] % T  # ring buffer for windowed caches
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    kj = jnp.arange(T)[None, :]
    w = window if window else cfg.swa_window
    if w is not None and T <= w:
        # ring buffer: once pos >= T every slot is a valid in-window entry
        valid = (kj <= pos_t[:, :1]) | (pos_t[:, :1] >= T)
    else:
        valid = kj <= pos_t[:, :1]
    mask = valid[:, None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, mask, cfg)
    return out @ p["wo"], cache_k, cache_v


# ------------------------------------------------------------------ mlp ----
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    dt = _dtype(cfg)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {"wi": dense_init(ks[0], d, f, dt),
                "wg": dense_init(ks[1], d, f, dt),
                "wo": dense_init(ks[2], f, d, dt)}
    return {"wi": dense_init(ks[0], d, f, dt),
            "wo": dense_init(ks[2], f, d, dt)}


def mlp_fwd(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = shard_act(h, batch_axes(), None, "model")
    return h @ p["wo"]


# ------------------------------------------------------------ embedding ----
def embed_init(key, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(k2, cfg.d_model, cfg.vocab, dt)
    return p


def embed(p: Dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["out"]
    logits = x @ w
    return shard_act(logits, batch_axes(), None, "model")
