"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, sliding-window 4096."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    n_experts=8, moe_top_k=2, d_ff_expert=14336, swa_window=4096,
    rope_theta=1e6)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, d_ff_expert=128, vocab=512,
                      swa_window=64)
