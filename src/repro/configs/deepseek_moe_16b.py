"""DeepSeek-MoE-16B [arXiv:2401.06066]: 2 shared + 64 routed experts,
top-6, fine-grained (d_ff_expert=1408)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, moe_top_k=6, d_ff_expert=1408,
    rope_theta=1e4)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_head=16, d_ff=96, d_ff_expert=96, n_experts=8,
                      moe_top_k=2, vocab=512)
