"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone + one *shared* attention
block applied every 6 layers (81 = 13x6 + 3 tail). The shared attention
uses a 4096 sliding window so long_500k decode stays O(1) state."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", block="mamba2", n_layers=81,
    d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_headdim=64, attn_every=6)

SMOKE = CONFIG.scaled(n_layers=7, attn_every=3, d_model=64, n_heads=4,
                      n_kv_heads=4, d_head=16, d_ff=128, vocab=512,
                      ssm_state=8, ssm_headdim=16)
