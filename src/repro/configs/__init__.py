"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from ..models.config import ModelConfig

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-0.6b": "qwen3_0_6b",
    "zamba2-7b": "zamba2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-medium": "whisper_medium",
    "xlstm-125m": "xlstm_125m",
}

ARCHS: Dict[str, ModelConfig] = {}
SMOKE: Dict[str, ModelConfig] = {}
for name, mod in _MODULES.items():
    m = import_module(f"repro.configs.{mod}")
    ARCHS[name] = m.CONFIG
    SMOKE[name] = m.SMOKE


def get(name: str, smoke: bool = False) -> ModelConfig:
    return (SMOKE if smoke else ARCHS)[name]
