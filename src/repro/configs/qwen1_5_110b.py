"""Qwen1.5-110B [hf:Qwen family]: QKV bias, GQA kv=8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab=152064,
    qkv_bias=True, rope_theta=1e6)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=192, vocab=512)
