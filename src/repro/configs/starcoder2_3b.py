"""StarCoder2-3B [arXiv:2402.19173]: GQA kv=2, RoPE, GELU MLP."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab=49152,
    act="gelu", rope_theta=1e5, qkv_bias=True)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512)
