"""xLSTM-125M [arXiv:2405.04517]: mLSTM blocks with an sLSTM every 4th
layer (12 = 3 x (3 mLSTM + 1 sLSTM)). d_ff=0: no separate MLP."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", block="xlstm", n_layers=12,
    d_model=768, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    slstm_every=4)

SMOKE = CONFIG.scaled(n_layers=4, slstm_every=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_head=16, vocab=512)
