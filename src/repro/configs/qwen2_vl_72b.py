"""Qwen2-VL-72B backbone [arXiv:2409.12191]. Vision frontend is a stub:
input_specs() supplies precomputed patch embeddings; M-RoPE implemented."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
    qkv_bias=True, m_rope=True, rope_theta=1e6)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=512)
