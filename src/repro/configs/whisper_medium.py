"""Whisper-medium [arXiv:2212.04356]: 24-layer encoder + 24-layer decoder
with cross attention. Conv frontend is a stub (input_specs() provides
precomputed frame embeddings); learned positions are replaced by a
sinusoid (encoder) / RoPE (decoder) stub — noted in DESIGN.md."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio", enc_dec=True,
    n_layers=24, n_enc_layers=24, n_frames=1500, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    act="gelu", rope_theta=1e4)

SMOKE = CONFIG.scaled(n_layers=2, n_enc_layers=2, n_frames=16, d_model=64,
                      n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                      vocab=512)
