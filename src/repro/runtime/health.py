"""Host-side health monitoring: heartbeats, straggler detection, restart
policy.

At 1000+ nodes the failure model is: a host stops heartbeating (hardware
loss) or its step time drifts (straggler — thermal throttle, flaky ICI
link). Both stacks here are *statically balanced* (equal shards / equal
VCPL), so any persistent per-host step-time skew is a hardware signal, not
load imbalance — which makes a simple robust-z-score detector reliable.

The monitor is pure host code (no device state); the coordinator reads
`decide()` each step and triggers checkpoint-restart (runtime/checkpoint)
with elastic resharding (runtime/elastic) when a host is evicted.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple


@dataclass
class HostHealth:
    last_beat: float
    step_times: Deque[float] = field(default_factory=lambda: deque(maxlen=64))


class HealthMonitor:
    def __init__(self, n_hosts: int, heartbeat_timeout_s: float = 60.0,
                 straggler_factor: float = 1.5, min_samples: int = 8):
        self.timeout = heartbeat_timeout_s
        self.factor = straggler_factor
        self.min_samples = min_samples
        now = time.monotonic()
        self.hosts: Dict[int, HostHealth] = {
            h: HostHealth(last_beat=now) for h in range(n_hosts)}

    def heartbeat(self, host: int, step_time_s: Optional[float] = None,
                  now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        h = self.hosts[host]
        h.last_beat = now
        if step_time_s is not None:
            h.step_times.append(step_time_s)

    # ------------------------------------------------------------------
    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout]

    def stragglers(self) -> List[Tuple[int, float]]:
        """Hosts whose median step time exceeds factor x fleet median."""
        meds = {}
        for h, st in self.hosts.items():
            if len(st.step_times) >= self.min_samples:
                s = sorted(st.step_times)
                meds[h] = s[len(s) // 2]
        if len(meds) < 2:
            return []
        fleet = sorted(meds.values())[len(meds) // 2]
        return [(h, m / fleet) for h, m in sorted(meds.items())
                if m > self.factor * fleet]

    def decide(self, now: Optional[float] = None) -> Dict:
        """Coordinator policy: evict dead hosts immediately; flag stragglers
        for drain-at-next-checkpoint (cheaper than an instant restart)."""
        dead = self.dead_hosts(now)
        strag = self.stragglers()
        return {
            "evict_now": dead,
            "drain_at_checkpoint": [h for h, _ in strag],
            "action": ("restart_elastic" if dead else
                       "drain" if strag else "healthy"),
        }
