"""Elastic scaling + failure handling.

Two mechanisms:

1. **LM side** — checkpoints are mesh-agnostic (full logical arrays,
   reassembled on restore). ``reshard`` places a restored tree onto a new
   mesh's shardings, so a job that lost a pod restarts on (N-1) pods with
   only a spec rebuild: the divisibility guard in ``distributed.sharding``
   re-derives legal specs for the new topology.

2. **RTL-sim side** — ``repartition_state`` migrates a Manticore machine
   state between two *compilations* of the same circuit (different core
   counts / meshes): architectural state is addressed by RTL register name
   and memory name, not by core, so the new partitioning is free to place
   it anywhere (the paper's static schedule is rebuilt by the compiler; the
   state transfer is exact).

Straggler mitigation is *structural* in both stacks: static balanced
partitions (paper §6.1) and equal-shard pjit steps mean no dynamic work
imbalance; the remaining source (slow host / failing chip) is handled by
the heartbeat hook in ``runtime/health.py`` + checkpoint-restart.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from ..core.bsp import Machine, MachineState
from ..core.compile import Program


def reshard(tree: Any, shardings: Any) -> Any:
    """device_put every leaf against new-mesh shardings."""
    return jax.tree.map(jax.device_put, tree, shardings)


# ----------------------------------------------------------- RTL engine ----
def extract_state(prog: Program, state: MachineState) -> Dict[str, int]:
    """Architectural state by name: registers + memories + cycle count."""
    regs = np.asarray(state.regs)
    out: Dict[str, Any] = {"__regs__": {}, "__mems__": {},
                           "__counters__": np.asarray(state.counters)[0:1]}
    for name, words in prog.state_regs.items():
        v = 0
        for j, locs in enumerate(words):
            c, r = locs[0]
            v |= int(regs[c, r]) << (16 * j)
        out["__regs__"][name] = v
    # memories: read back from spads/gmem via the program's layout
    spads = np.asarray(state.spads)
    gmem = np.asarray(state.gmem)
    for mname, (core, base, words, is_global) in prog.stats.get(
            "mem_layout", {}).items():
        if is_global:
            out["__mems__"][mname] = gmem[base:base + words].copy()
        else:
            out["__mems__"][mname] = spads[core, base:base + words].copy()
    return out


def inject_state(prog: Program, machine: Machine,
                 saved: Dict[str, Any]) -> MachineState:
    """Build an initial MachineState for a *new* compilation carrying over
    the architectural state captured by ``extract_state``."""
    st = machine.init_state()
    regs = np.asarray(st.regs).copy()
    for name, value in saved["__regs__"].items():
        words = prog.state_regs.get(name)
        if not words:
            continue
        for j, locs in enumerate(words):
            for (c, r) in locs:          # every duplicated copy
                if c < regs.shape[0]:
                    regs[c, r] = (value >> (16 * j)) & 0xFFFF
    spads = np.asarray(st.spads).copy()
    gmem = np.asarray(st.gmem).copy()
    for mname, data in saved.get("__mems__", {}).items():
        layout = prog.stats.get("mem_layout", {}).get(mname)
        if layout is None:
            continue
        core, base, words, is_global = layout
        if is_global:
            gmem[base:base + len(data)] = data
        elif core < spads.shape[0]:
            spads[core, base:base + len(data)] = data
    import jax.numpy as jnp
    return MachineState(
        regs=jnp.asarray(regs), spads=jnp.asarray(spads),
        gmem=jnp.asarray(gmem), flags=st.flags,
        cache_tags=st.cache_tags, counters=st.counters)


def migrate(old_prog: Program, old_state: MachineState,
            new_prog: Program, new_machine: Machine) -> MachineState:
    """Elastic re-scale of a running RTL simulation: old grid -> new grid."""
    return inject_state(new_prog, new_machine,
                        extract_state(old_prog, old_state))
