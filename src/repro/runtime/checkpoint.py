"""Sharded, atomic, async checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, shapes, dtypes, step
            host<k>.npz          — this host's param/opt shards (flat keys)
         <dir>/step_<N>.COMMIT   — written last; a checkpoint without the
                                   commit marker is ignored (atomicity)

Design points for 1000+ nodes:
  * every host writes only the shards it owns (addressable devices) — no
    gather through host 0;
  * the writer runs on a background thread off the training critical path
    (async), double-buffered so at most one save is in flight;
  * restore is *elastic*: arrays are reassembled from the manifest and
    re-device_put against whatever mesh the restart runs on
    (runtime/elastic.py), so a failed pod can be replaced by a different
    topology;
  * the data pipeline needs no state files — it is counter-based
    (data/pipeline.py); restoring `step` resumes the stream exactly.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:  # bf16 round-trips through npz as a uint16 view
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None


def _encode(arr: np.ndarray):
    if _BF16 is not None and arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16" and _BF16 is not None:
        return arr.view(_BF16)
    return arr


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        flat = _flatten(tree)
        host_arrays: Dict[str, np.ndarray] = {}
        manifest = {"step": int(step), "leaves": {}}
        for key, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            enc, dtype = _encode(arr)
            host_arrays[key] = enc
            manifest["leaves"][key] = {
                "shape": list(arr.shape), "dtype": dtype}
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(int(step), host_arrays, manifest),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, arrays: Dict[str, np.ndarray],
               manifest: Dict) -> None:
        d = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{self.host_id}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"host{self.host_id}.npz", **arrays)
        if self.host_id == 0:
            (tmp / "manifest.json").write_text(json.dumps(manifest))
        # single-host commit protocol (multi-host: host0 commits after a
        # barrier; here n_hosts==1 in-process)
        if d.exists():
            shutil.rmtree(d)
        os.replace(tmp, d)
        (self.dir / f"step_{step:08d}.COMMIT").touch()
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        commits = sorted(self.dir.glob("step_*.COMMIT"))
        for c in commits[:-self.keep]:
            step_dir = self.dir / c.name.replace(".COMMIT", "")
            c.unlink(missing_ok=True)
            if step_dir.exists():
                shutil.rmtree(step_dir)

    # -------------------------------------------------------- restore ----
    def latest_step(self) -> Optional[int]:
        commits = sorted(self.dir.glob("step_*.COMMIT"))
        if not commits:
            return None
        return int(commits[-1].name[len("step_"):-len(".COMMIT")])

    def restore(self, step: Optional[int] = None) -> Tuple[int, Dict[str,
                                                                     np.ndarray]]:
        """Returns (step, flat {path: np.ndarray})."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = {}
        mf = d / "manifest.json"
        if mf.exists():
            manifest = json.loads(mf.read_text()).get("leaves", {})
        arrays: Dict[str, np.ndarray] = {}
        for f in sorted(d.glob("host*.npz")):
            with np.load(f) as z:
                for k in z.files:
                    arrays[k] = _decode(
                        z[k], manifest.get(k, {}).get("dtype", ""))
        return step, arrays

    def restore_tree(self, template: Any, step: Optional[int] = None,
                     shardings: Any = None) -> Tuple[int, Any]:
        """Rebuild a pytree shaped like ``template``; optionally device_put
        each leaf with the (possibly different-mesh) shardings — this is the
        elastic-restart path."""
        step, arrays = self.restore(step)
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        sh_flat = None
        if shardings is not None:
            sh_flat = [s for _, s in _flatten(shardings)]
        leaves = []
        for i, (path, leaf) in enumerate(flat_t):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = arrays[key]
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
