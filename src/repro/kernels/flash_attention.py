"""Pallas TPU kernel: fused (flash) causal attention.

The §Perf hillclimb on qwen3-0.6b/train_4k showed the memory term is
dominated by materialized [S, S] attention scores, and that XLA-level
chunking cannot remove the operand traffic — only a *fused* kernel can
(scores never leave VMEM). This kernel is that artifact: the online-softmax
formulation with per-query-block running (max, sum, acc) state, streaming
K/V blocks HBM->VMEM via the BlockSpec pipeline.

Block shapes: q block [bq=256, dh<=128] (~128 KiB), one K/V block
[bk=512, dh] (~128 KiB x2) resident at a time, fp32 accumulators
[bq, dh] + [bq] stats — comfortably inside VMEM with double buffering, and
the matmul dims (bq x dh x bk) are MXU-aligned multiples of 128.

Validated bit-close (fp32) / allclose (bf16) against ``ref.flash_ref`` in
interpret mode — tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 256
DEFAULT_BK = 512
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  scale: float):
    """One (batch*head, q-block) grid cell.
    q_ref/o_ref: [1, bq, dh]; k_ref/v_ref: [1, S, dh]."""
    q = q_ref[0].astype(jnp.float32) * scale           # [bq, dh]
    bq, dh = q.shape
    S = k_ref.shape[1]
    iq = pl.program_id(1)
    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)      # global query rows

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * block_k, block_k,
                                         axis=0).astype(jnp.float32)
        s = q @ k.T                                    # [bq, bk]
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), _NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    n_blocks = S // block_k
    if causal:
        # only blocks that intersect the causal triangle of this q block
        n_blocks_live = jnp.minimum(
            (iq + 1) * bq + block_k - 1, S) // block_k
    else:
        n_blocks_live = n_blocks
    acc, m, l = jax.lax.fori_loop(0, n_blocks_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: [BH, S, dh] -> [BH, S, dh]. S % block_q == S % block_k == 0.
    (GQA callers fold batch x heads into BH and repeat K/V per group.)"""
    BH, S, dh = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (BH, S // block_q)
    kernel = functools.partial(_flash_kernel, block_k=block_k,
                               causal=causal, scale=1.0 / np.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
