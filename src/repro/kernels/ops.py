"""Jitted wrappers around the Pallas kernels.

``make_vcycle`` binds a compiled :class:`~repro.core.compile.Program` to the
per-Vcycle tiled Pallas kernel (seed path, kept as the ``specialize=False``
baseline); ``make_vcycle_chunk`` binds it to the chunked K-Vcycle kernel —
the specialized fast path with VMEM-resident state, in-kernel compact-SEND
exchange and per-Vcycle exception predication. Both adapt the
(regs, spads, gmem, flags, tags, counters) carry used by ``core.bsp.Machine``.
Programs with privileged off-chip traffic (GLD/GST) fall back to the jnp
engine — the privileged core is special in the paper too (§5.3).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .vcycle import (DEFAULT_TILE, vcycle_chunk_pallas,
                     vcycle_chunk_pallas_batched, vcycle_pallas)


def make_vcycle(program, C: int, interpret: bool = True,
                tile: int = DEFAULT_TILE) -> Callable:
    """Returns vcycle(carry) -> (carry, trace) on the Pallas path."""
    if program.has_global:
        raise ValueError(
            "Pallas path does not execute privileged GLD/GST programs; "
            "use backend='jnp' (the paper's privileged core is also special)")
    tile = min(tile, max(1, C))
    Cp = ((C + tile - 1) // tile) * tile
    code = np.zeros((program.code.shape[1], Cp, 7), dtype=np.int32)
    code[:, :C] = program.code[:C].transpose(1, 0, 2)
    code_j = jnp.asarray(code)
    luts_j = jnp.asarray(
        np.pad(program.luts[:C], ((0, Cp - C), (0, 0), (0, 0))),
        dtype=jnp.uint32)

    pad_c = Cp - C

    @jax.jit
    def vcycle(carry):
        regs, spads, gmem, flags, tags, counters = carry
        regs_p = jnp.pad(regs, ((0, pad_c), (0, 0))) if pad_c else regs
        spads_p = jnp.pad(spads, ((0, pad_c), (0, 0))) if pad_c else spads
        flags_p = jnp.pad(flags, ((0, pad_c),)) if pad_c else flags
        regs_o, spads_o, flags_o, trace = vcycle_pallas(
            code_j, luts_j, regs_p, spads_p, flags_p,
            tile=tile, interpret=interpret)
        carry = (regs_o[:C], spads_o[:C], gmem, flags_o[:C], tags, counters)
        return carry, trace[:, :C]

    return vcycle


def make_vcycle_chunk(program, C: int, K: int, interpret: bool = True,
                      batch: int = None) -> Callable:
    """Bind ``program`` to the chunked K-Vcycle kernel.

    Returns ``chunk(cyc, budget, carry) -> (cyc, carry)`` compatible with
    ``Machine._run_chunk``: one call advances the machine by up to K
    Vcycles (bounded by ``budget`` and frozen by exceptions), with the BSP
    exchange performed in-kernel via the compact SEND buffer.

    ``batch=B`` binds the batched-stimulus kernel instead: the carry
    leaves have a leading [B] axis, ``cyc`` is ``[B]`` and the kernel runs
    one grid step per batch element (each element's state VMEM-resident
    for the whole chunk, exceptions frozen per element).

    The batched binding composes with the device mesh: under
    ``ShardedBatchedMachine(backend="pallas")`` this factory is called
    with the **device-local** batch ``B/D`` and the returned ``bchunk``
    runs inside ``shard_map`` — the kernel's grid axis then covers one
    shard, per-element freezing stays device-local (the per-element
    ``cyc``/flags predicate needs no cross-device state), and the shared
    program blocks (code/cap/luts/exchange tables) are closed-over
    constants replicated to every device.
    """
    if program.has_global:
        raise ValueError(
            "Pallas path does not execute privileged GLD/GST programs; "
            "use backend='jnp' (the paper's privileged core is also special)")
    # pad the core axis to the VPU-friendly tile multiple; padded lanes are
    # all-NOP and never write
    Cp = ((C + DEFAULT_TILE - 1) // DEFAULT_TILE) * DEFAULT_TILE
    code = np.zeros((program.code.shape[1], Cp, 7), dtype=np.int32)
    code[:, :C] = program.code[:C].transpose(1, 0, 2)
    code_j = jnp.asarray(code)
    luts_j = jnp.asarray(
        np.pad(program.luts[:C], ((0, Cp - C), (0, 0), (0, 0))),
        dtype=jnp.uint32)
    cap_j = jnp.asarray(program.send_capture(Cp))
    n_sends = program.n_sends
    dcore_j = jnp.asarray(np.pad(program.xchg_dst_core, (0, 1 - n_sends))
                          if n_sends == 0 else program.xchg_dst_core)
    dreg_j = jnp.asarray(np.pad(program.xchg_dst_reg, (0, 1 - n_sends))
                         if n_sends == 0 else program.xchg_dst_reg)
    op_set = program.op_set()
    num_pro = int(getattr(program, "pipe_prologue", 0))
    pad_c = Cp - C

    if batch is not None:
        def bchunk(cyc, budget, carry):
            regs, spads, gmem, flags, tags, counters = carry
            pad2 = ((0, 0), (0, pad_c), (0, 0))
            regs_p = jnp.pad(regs, pad2) if pad_c else regs
            spads_p = jnp.pad(spads, pad2) if pad_c else spads
            flags_p = (jnp.pad(flags, ((0, 0), (0, pad_c)))
                       if pad_c else flags)
            budget_a = jnp.full((1,), budget, jnp.int32)
            regs_o, spads_o, flags_o, nexec = vcycle_chunk_pallas_batched(
                code_j, cap_j, luts_j, dcore_j, dreg_j, regs_p, spads_p,
                flags_p, cyc.astype(jnp.int32), budget_a, K=K,
                n_sends=n_sends, op_set=op_set, num_pro=num_pro,
                interpret=interpret)
            counters = counters.at[:, 0].add(nexec.astype(jnp.uint32))
            carry = (regs_o[:, :C], spads_o[:, :C], gmem,
                     flags_o[:, :C], tags, counters)
            return cyc + nexec, carry

        return bchunk

    def chunk(cyc, budget, carry):
        regs, spads, gmem, flags, tags, counters = carry
        regs_p = jnp.pad(regs, ((0, pad_c), (0, 0))) if pad_c else regs
        spads_p = jnp.pad(spads, ((0, pad_c), (0, 0))) if pad_c else spads
        flags_p = jnp.pad(flags, ((0, pad_c),)) if pad_c else flags
        cyc_a = jnp.full((1,), cyc, jnp.int32)
        budget_a = jnp.full((1,), budget, jnp.int32)
        regs_o, spads_o, flags_o, nexec = vcycle_chunk_pallas(
            code_j, cap_j, luts_j, dcore_j, dreg_j, regs_p, spads_p,
            flags_p, cyc_a, budget_a, K=K, n_sends=n_sends, op_set=op_set,
            num_pro=num_pro, interpret=interpret)
        counters = counters.at[0].add(nexec[0].astype(jnp.uint32))
        carry = (regs_o[:C], spads_o[:C], gmem, flags_o[:C], tags, counters)
        return cyc + nexec[0], carry

    return chunk
