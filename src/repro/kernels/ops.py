"""Jitted wrappers around the Pallas kernels.

``make_vcycle`` binds a compiled :class:`~repro.core.compile.Program` to the
Pallas Vcycle kernel with core-count padding to the tile size, and adapts the
(regs, spads, gmem, flags, tags, counters) carry used by ``core.bsp.Machine``.
Programs with privileged off-chip traffic (GLD/GST) fall back to the jnp
engine — the privileged core is special in the paper too (§5.3).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .vcycle import DEFAULT_TILE, vcycle_pallas


def make_vcycle(program, C: int, interpret: bool = True,
                tile: int = DEFAULT_TILE) -> Callable:
    """Returns vcycle(carry) -> (carry, trace) on the Pallas path."""
    if program.has_global:
        raise ValueError(
            "Pallas path does not execute privileged GLD/GST programs; "
            "use backend='jnp' (the paper's privileged core is also special)")
    tile = min(tile, max(1, C))
    Cp = ((C + tile - 1) // tile) * tile
    code = np.zeros((program.code.shape[1], Cp, 7), dtype=np.int32)
    code[:, :C] = program.code[:C].transpose(1, 0, 2)
    code_j = jnp.asarray(code)
    luts_j = jnp.asarray(
        np.pad(program.luts[:C], ((0, Cp - C), (0, 0), (0, 0))),
        dtype=jnp.uint32)

    pad_c = Cp - C

    @jax.jit
    def vcycle(carry):
        regs, spads, gmem, flags, tags, counters = carry
        regs_p = jnp.pad(regs, ((0, pad_c), (0, 0))) if pad_c else regs
        spads_p = jnp.pad(spads, ((0, pad_c), (0, 0))) if pad_c else spads
        flags_p = jnp.pad(flags, ((0, pad_c),)) if pad_c else flags
        regs_o, spads_o, flags_o, trace = vcycle_pallas(
            code_j, luts_j, regs_p, spads_p, flags_p,
            tile=tile, interpret=interpret)
        carry = (regs_o[:C], spads_o[:C], gmem, flags_o[:C], tags, counters)
        return carry, trace[:, :C]

    return vcycle
