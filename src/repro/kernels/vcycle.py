"""Pallas TPU kernel: the Vcycle slot loop for a tile of cores.

This is the compute hot-spot of the whole system — the inner interpreter that
executes ``t_compute`` slots for every core, every simulated RTL cycle. The
TPU mapping (DESIGN.md §2):

  * a *tile* of cores lives in one grid step; the tile's register files
    ([tile, R] uint32) and scratchpads ([tile, S]) are VMEM-resident for the
    entire Vcycle — the analogue of Manticore keeping the register file in
    BRAMs next to the ALU;
  * the instruction stream tile ([T, tile, 7]) streams HBM->VMEM through the
    BlockSpec pipeline — the analogue of the URAM instruction memory;
  * every slot executes all opcodes on the whole tile and selects by opcode
    (VPU-friendly compute-all-select; a NOp lane is a masked lane);
  * the per-slot result trace ([T, tile]) is written back so the BSP exchange
    (done by the caller — ``core.bsp``/``core.grid``) can route SEND values.

Block shapes are chosen so the working set fits VMEM with MXU/VPU-aligned
lanes: tile=8 cores x 2048 regs x 4B = 64 KiB registers, 16384-word
scratchpads = 512 KiB, and a T<=4096 instruction block = 896 KiB — ~1.5 MiB
per grid step, leaving headroom for double buffering.

Validated in ``interpret=True`` mode against ``ref.vcycle_ref`` (bit-exact)
— this container has no TPU; the kernel is the TPU *target*.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.bsp import make_slot_step
from ..core.isa import Op

U32 = jnp.uint32
MASK = jnp.uint32(0xFFFF)

DEFAULT_TILE = 8


def _vcycle_kernel(code_ref, luts_ref, regs_in_ref, spads_in_ref,
                   flags_in_ref, regs_out_ref, spads_out_ref, flags_out_ref,
                   trace_ref, *, num_slots: int):
    """Kernel body. Shapes (per tile):
    code [T, tile, 7] i32 | luts [tile, L, 16] u32 | regs [tile, R] u32 |
    spads [tile, S] u32 | flags [tile] u32 | trace [T, tile] u32.
    """
    luts = luts_ref[...]
    tile = regs_in_ref.shape[0]
    S = spads_in_ref.shape[1]
    L = luts.shape[1]
    ar = jnp.arange(tile)

    def body(t, carry):
        regs, spads, flags = carry
        instr = code_ref[t]                       # [tile, 7] int32
        op = instr[:, 0]
        dst = instr[:, 1]
        imm = instr[:, 6].astype(U32)
        v1 = regs[ar, instr[:, 2]]
        v2 = regs[ar, instr[:, 3]]
        v3 = regs[ar, instr[:, 4]]
        v4 = regs[ar, instr[:, 5]]

        add3 = v1 + v2 + v3
        sub3 = v1 - v2 - v3
        prod = v1 * v2
        shamt = imm & 15
        sgn = ((v1 ^ 0x8000) - 0x8000).astype(jnp.int32)

        tt = luts[ar, jnp.minimum(imm, L - 1)]    # [tile, 16]
        nv1, nv2 = (~v1) & 0xFFFF, (~v2) & 0xFFFF
        nv3, nv4 = (~v3) & 0xFFFF, (~v4) & 0xFFFF
        lut_out = jnp.zeros((tile,), U32)
        for p in range(16):
            m = (v1 if p & 1 else nv1) & (v2 if p & 2 else nv2) \
                & (v3 if p & 4 else nv3) & (v4 if p & 8 else nv4)
            lut_out = lut_out | (m & tt[:, p])

        ld_addr = v1 % S
        ld_val = spads[ar, ld_addr]

        branches = [
            (Op.MOV, v1),
            (Op.MOVI, imm & 0xFFFF),
            (Op.ADD, (v1 + v2) & 0xFFFF),
            (Op.ADDC, add3 & 0xFFFF),
            (Op.CARRY, (add3 >> 16) & 0xFFFF),
            (Op.SUB, (v1 - v2) & 0xFFFF),
            (Op.SUBB, sub3 & 0xFFFF),
            (Op.BORROW, (v1 < v2 + v3).astype(U32)),
            (Op.MUL, prod & 0xFFFF),
            (Op.MULH, (prod >> 16) & 0xFFFF),
            (Op.AND, v1 & v2),
            (Op.OR, v1 | v2),
            (Op.XOR, v1 ^ v2),
            (Op.NOT, (~v1) & 0xFFFF),
            (Op.MUX, jnp.where(v1 != 0, v2, v3)),
            (Op.SEQ, (v1 == v2).astype(U32)),
            (Op.SNE, (v1 != v2).astype(U32)),
            (Op.SLTU, (v1 < v2).astype(U32)),
            (Op.SLL, (v1 << shamt) & 0xFFFF),
            (Op.SRL, v1 >> shamt),
            (Op.SRA, (sgn >> shamt).astype(U32) & 0xFFFF),
            (Op.SLLV, (v1 << (v2 & 15)) & 0xFFFF),
            (Op.SRLV, v1 >> (v2 & 15)),
            (Op.SLICE, (v1 >> (imm >> 5)) & ((1 << (imm & 31)) - 1)),
            (Op.LUT, lut_out),
            (Op.LD, ld_val),
            (Op.SEND, v1),
        ]
        result = jnp.zeros((tile,), U32)
        for code_op, val in branches:
            result = jnp.where(op == int(code_op), val, result)
        result = result & 0xFFFF

        no_write = ((op == int(Op.NOP)) | (op == int(Op.ST)) |
                    (op == int(Op.GST)) | (op == int(Op.EXPECT)) |
                    (op == int(Op.SEND)) | (dst == 0))
        wdst = jnp.where(no_write, 0, dst)
        regs = regs.at[ar, wdst].set(jnp.where(no_write, regs[ar, 0], result))

        st_mask = (op == int(Op.ST)) & (v3 != 0)
        st_addr = v1 % S
        spads = spads.at[ar, st_addr].set(
            jnp.where(st_mask, v2, spads[ar, st_addr]))

        exc = (op == int(Op.EXPECT)) & (v1 != v2)
        flags = jnp.where((flags == 0) & exc, imm, flags)

        trace_ref[t] = result
        return regs, spads, flags

    regs, spads, flags = jax.lax.fori_loop(
        0, num_slots, body,
        (regs_in_ref[...], spads_in_ref[...], flags_in_ref[...]))
    regs_out_ref[...] = regs
    spads_out_ref[...] = spads
    flags_out_ref[...] = flags


def vcycle_pallas(code: jax.Array, luts: jax.Array, regs: jax.Array,
                  spads: jax.Array, flags: jax.Array,
                  tile: int = DEFAULT_TILE, interpret: bool = True,
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One Vcycle over all cores. code: [T, C, 7] int32 (C % tile == 0).
    Returns (regs, spads, flags, trace[T, C])."""
    T, C, _ = code.shape
    assert C % tile == 0, (C, tile)
    R = regs.shape[1]
    S = spads.shape[1]
    L = luts.shape[1]
    grid = (C // tile,)

    kernel = functools.partial(_vcycle_kernel, num_slots=T)
    out_shapes = (
        jax.ShapeDtypeStruct((C, R), regs.dtype),
        jax.ShapeDtypeStruct((C, S), spads.dtype),
        jax.ShapeDtypeStruct((C,), flags.dtype),
        jax.ShapeDtypeStruct((T, C), regs.dtype),
    )
    regs_o, spads_o, flags_o, trace = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, tile, 7), lambda i: (0, i, 0)),
            pl.BlockSpec((tile, L, 16), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, R), lambda i: (i, 0)),
            pl.BlockSpec((tile, S), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, R), lambda i: (i, 0)),
            pl.BlockSpec((tile, S), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((T, tile), lambda i: (0, i)),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(code, luts, regs, spads, flags)
    return regs_o, spads_o, flags_o, trace


# ======================================================================
# Chunked K-Vcycle kernel (specialized fast path)
#
# One launch simulates up to K RTL cycles for the *whole* machine: the
# register files and scratchpads stay VMEM-resident across all K Vcycles,
# the BSP exchange happens in-kernel through the compact SEND buffer
# (``trace_ref`` is gone — [n_sends + 1] words instead of [T, C]), and each
# Vcycle is predicated on the exception flags so a program that raises
# mid-chunk freezes at the raising cycle, not at the chunk boundary.
# ======================================================================

def _chunk_kernel(cyc_ref, budget_ref, code_ref, cap_ref, luts_ref,
                  dcore_ref, dreg_ref, regs_in_ref, spads_in_ref,
                  flags_in_ref, regs_out_ref, spads_out_ref, flags_out_ref,
                  nexec_ref, *, num_slots: int, K: int, n_sends: int,
                  op_set, spad_words: int, num_pro: int = 0):
    """Shapes: code [T, C, 7] i32 | cap [T, C] i32 | luts [C, L, 16] u32 |
    dcore/dreg [max(n_sends,1)] i32 | regs [C, R] u32 | spads [C, S] u32 |
    flags [C] u32 | cyc/budget/nexec (1,) i32 scalars (SMEM).

    ``num_pro > 0`` marks a modulo-pipelined program: code rows
    ``[0, num_pro)`` are the *next* Vcycle's hoisted pure ops. Each Vcycle
    runs the steady-state body (rows ``[num_pro, T)``), the exchange, then
    the prologue on the post-exchange state — committed (register carries
    only) iff the cycle raised no exception, so a raising cycle never
    commits cycle k+1's in-flight prologue. Iteration 0's prologue is
    applied by ``Machine.init_state``."""
    luts = luts_ref[...]
    # the slot executor is the same partially-evaluated step the jnp engine
    # scans over; the privileged gmem/cache path never appears here
    # (``make_vcycle_chunk`` rejects has_global programs), so the extra
    # carry entries are inert dummies.
    step = make_slot_step(luts, spad_words, 1, 1, 1, 0, 0, op_set=op_set)
    dummy_gmem = jnp.zeros((1,), U32)
    dummy_tags = jnp.zeros((1,), jnp.int32)
    dummy_cnt = jnp.zeros((4,), U32)
    base = cyc_ref[0]
    budget = budget_ref[0]

    def vcycle(k, carry):
        regs, spads, flags, nexec = carry
        active = (base + nexec < budget) & jnp.all(flags == 0)

        def slot(t, sc):
            return step(sc, (code_ref[t], cap_ref[t]))[0]

        sbuf0 = jnp.zeros((n_sends + 1,), U32)
        regs2, spads2, _, flags2, _, _, sbuf = jax.lax.fori_loop(
            num_pro, num_slots, slot,
            (regs, spads, dummy_gmem, flags, dummy_tags, dummy_cnt, sbuf0))
        if n_sends:
            regs2 = regs2.at[dcore_ref[...], dreg_ref[...]].set(
                sbuf[:n_sends])
        if num_pro:
            regs3 = jax.lax.fori_loop(
                0, num_pro, slot,
                (regs2, spads2, dummy_gmem, flags2, dummy_tags, dummy_cnt,
                 sbuf0))[0]
            regs2 = jnp.where(jnp.all(flags2 == 0), regs3, regs2)
        regs = jnp.where(active, regs2, regs)
        spads = jnp.where(active, spads2, spads)
        flags = jnp.where(active, flags2, flags)
        return regs, spads, flags, nexec + active.astype(jnp.int32)

    regs, spads, flags, nexec = jax.lax.fori_loop(
        0, K, vcycle,
        (regs_in_ref[...], spads_in_ref[...], flags_in_ref[...],
         jnp.int32(0)))
    regs_out_ref[...] = regs
    spads_out_ref[...] = spads
    flags_out_ref[...] = flags
    nexec_ref[0] = nexec


def _chunk_kernel_batched(cyc_ref, budget_ref, code_ref, cap_ref, luts_ref,
                          dcore_ref, dreg_ref, regs_in_ref, spads_in_ref,
                          flags_in_ref, regs_out_ref, spads_out_ref,
                          flags_out_ref, nexec_ref, *, num_slots: int, K: int,
                          n_sends: int, op_set, spad_words: int,
                          num_pro: int = 0):
    """Batched-stimulus variant of ``_chunk_kernel``: one grid step per
    batch element. The shared program (code/cap/luts/exchange tables) is the
    same block for every step; the per-element state blocks are
    [1, C, R]/[1, C, S]/[1, C] so each element's registers and scratchpads
    stay VMEM-resident across all K Vcycles of its chunk. Exceptions are
    per element: this element's flags predicate only this element's
    Vcycles (including its own in-flight prologue when ``num_pro > 0`` —
    see ``_chunk_kernel``)."""
    luts = luts_ref[...]
    step = make_slot_step(luts, spad_words, 1, 1, 1, 0, 0, op_set=op_set)
    dummy_gmem = jnp.zeros((1,), U32)
    dummy_tags = jnp.zeros((1,), jnp.int32)
    dummy_cnt = jnp.zeros((4,), U32)
    base = cyc_ref[0]
    budget = budget_ref[0]

    def vcycle(k, carry):
        regs, spads, flags, nexec = carry
        active = (base + nexec < budget) & jnp.all(flags == 0)

        def slot(t, sc):
            return step(sc, (code_ref[t], cap_ref[t]))[0]

        sbuf0 = jnp.zeros((n_sends + 1,), U32)
        regs2, spads2, _, flags2, _, _, sbuf = jax.lax.fori_loop(
            num_pro, num_slots, slot,
            (regs, spads, dummy_gmem, flags, dummy_tags, dummy_cnt, sbuf0))
        if n_sends:
            regs2 = regs2.at[dcore_ref[...], dreg_ref[...]].set(
                sbuf[:n_sends])
        if num_pro:
            regs3 = jax.lax.fori_loop(
                0, num_pro, slot,
                (regs2, spads2, dummy_gmem, flags2, dummy_tags, dummy_cnt,
                 sbuf0))[0]
            regs2 = jnp.where(jnp.all(flags2 == 0), regs3, regs2)
        regs = jnp.where(active, regs2, regs)
        spads = jnp.where(active, spads2, spads)
        flags = jnp.where(active, flags2, flags)
        return regs, spads, flags, nexec + active.astype(jnp.int32)

    regs, spads, flags, nexec = jax.lax.fori_loop(
        0, K, vcycle,
        (regs_in_ref[0], spads_in_ref[0], flags_in_ref[0], jnp.int32(0)))
    regs_out_ref[0] = regs
    spads_out_ref[0] = spads
    flags_out_ref[0] = flags
    nexec_ref[0] = nexec


def vcycle_chunk_pallas_batched(code: jax.Array, cap: jax.Array,
                                luts: jax.Array, dcore: jax.Array,
                                dreg: jax.Array, regs: jax.Array,
                                spads: jax.Array, flags: jax.Array,
                                cyc: jax.Array, budget: jax.Array, *,
                                K: int, n_sends: int, op_set=None,
                                num_pro: int = 0, interpret: bool = True,
                                ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                           jax.Array]:
    """Up to K Vcycles for B whole machines in one launch (grid over B).
    regs [B, C, R] | spads [B, C, S] | flags [B, C] | cyc [B] | budget [1].
    Returns (regs, spads, flags, n_executed[B]).

    ``B`` is whatever batch the caller holds — the whole stimulus batch on
    one device, or a ``B/D`` shard when the call is traced inside
    ``shard_map`` (``core.bsp.ShardedBatchedMachine``). Nothing in the
    kernel is global-batch-aware: the grid, the block specs and the
    per-element freeze predicate all derive from the local leading axis,
    which is exactly what lets the device mesh carry the batch axis."""
    T, C, _ = code.shape
    B, _, R = regs.shape
    S = spads.shape[2]
    L = luts.shape[1]
    M = dcore.shape[0]

    kernel = functools.partial(
        _chunk_kernel_batched, num_slots=T, K=K, n_sends=n_sends,
        op_set=op_set, spad_words=max(S, 1), num_pro=num_pro)
    smem = lambda shp, im: pl.BlockSpec(shp, im,
                                        memory_space=pltpu.SMEM)
    out_shapes = (
        jax.ShapeDtypeStruct((B, C, R), regs.dtype),
        jax.ShapeDtypeStruct((B, C, S), spads.dtype),
        jax.ShapeDtypeStruct((B, C), flags.dtype),
        jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            smem((1,), lambda b: (b,)),                  # cyc
            smem((1,), lambda b: (0,)),                  # budget
            pl.BlockSpec((T, C, 7), lambda b: (0, 0, 0)),
            pl.BlockSpec((T, C), lambda b: (0, 0)),
            pl.BlockSpec((C, L, 16), lambda b: (0, 0, 0)),
            pl.BlockSpec((M,), lambda b: (0,)),
            pl.BlockSpec((M,), lambda b: (0,)),
            pl.BlockSpec((1, C, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, S), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, R), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, S), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C), lambda b: (b, 0)),
            smem((1,), lambda b: (b,)),                  # nexec
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(cyc, budget, code, cap, luts, dcore, dreg, regs, spads, flags)


def vcycle_chunk_pallas(code: jax.Array, cap: jax.Array, luts: jax.Array,
                        dcore: jax.Array, dreg: jax.Array, regs: jax.Array,
                        spads: jax.Array, flags: jax.Array, cyc: jax.Array,
                        budget: jax.Array, *, K: int, n_sends: int,
                        op_set=None, num_pro: int = 0,
                        interpret: bool = True,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array]:
    """Up to K Vcycles for the whole machine in one launch (exchange
    in-kernel). Returns (regs, spads, flags, n_executed[1])."""
    T, C, _ = code.shape
    R = regs.shape[1]
    S = spads.shape[1]

    kernel = functools.partial(
        _chunk_kernel, num_slots=T, K=K, n_sends=n_sends, op_set=op_set,
        spad_words=max(S, 1), num_pro=num_pro)
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vmem = pl.BlockSpec(memory_space=pltpu.VMEM)
    out_shapes = (
        jax.ShapeDtypeStruct((C, R), regs.dtype),
        jax.ShapeDtypeStruct((C, S), spads.dtype),
        jax.ShapeDtypeStruct((C,), flags.dtype),
        jax.ShapeDtypeStruct((1,), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        in_specs=[smem, smem, vmem, vmem, vmem, vmem, vmem, vmem, vmem,
                  vmem],
        out_specs=[vmem, vmem, vmem, smem],
        out_shape=out_shapes,
        interpret=interpret,
    )(cyc, budget, code, cap, luts, dcore, dreg, regs, spads, flags)
