"""Pure-jnp oracles for the Pallas kernels.

``vcycle_ref`` executes one Vcycle (the slot loop, *without* the BSP
exchange) for a tile of cores — the reference the Pallas kernel in
``vcycle.py`` must match bit-exactly for every shape/dtype sweep in
``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.isa import Op

U32 = jnp.uint32
MASK = jnp.uint32(0xFFFF)


def slot_ref(code_t: jax.Array, luts: jax.Array, regs: jax.Array,
             spads: jax.Array, flags: jax.Array,
             ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Execute one slot for all lanes (no global memory — the privileged
    off-chip path stays in the jnp engine).

    code_t: [C, 7] int32; luts: [C, L, 16] uint32; regs: [C, R] uint32;
    spads: [C, S] uint32; flags: [C] uint32.
    Returns (regs, spads, flags, result).
    """
    C = regs.shape[0]
    S = spads.shape[1]
    ar = jnp.arange(C)
    op = code_t[:, 0]
    dst = code_t[:, 1]
    imm = code_t[:, 6].astype(U32)
    v1 = regs[ar, code_t[:, 2]]
    v2 = regs[ar, code_t[:, 3]]
    v3 = regs[ar, code_t[:, 4]]
    v4 = regs[ar, code_t[:, 5]]

    add3 = v1 + v2 + v3
    sub3 = v1 - v2 - v3
    prod = v1 * v2
    shamt = imm & 15
    sgn = ((v1 ^ 0x8000) - 0x8000).astype(jnp.int32)

    tt = luts[ar, jnp.minimum(imm, luts.shape[1] - 1)]
    nv1, nv2, nv3, nv4 = (~v1) & MASK, (~v2) & MASK, (~v3) & MASK, (~v4) & MASK
    lut_out = jnp.zeros((C,), U32)
    for p in range(16):
        m = (v1 if p & 1 else nv1) & (v2 if p & 2 else nv2) \
            & (v3 if p & 4 else nv3) & (v4 if p & 8 else nv4)
        lut_out = lut_out | (m & tt[:, p])

    ld_addr = v1 % S
    ld_val = spads[ar, ld_addr]

    branches = [
        (Op.MOV, v1),
        (Op.MOVI, imm & MASK),
        (Op.ADD, (v1 + v2) & MASK),
        (Op.ADDC, add3 & MASK),
        (Op.CARRY, (add3 >> 16) & MASK),
        (Op.SUB, (v1 - v2) & MASK),
        (Op.SUBB, sub3 & MASK),
        (Op.BORROW, (v1 < v2 + v3).astype(U32)),
        (Op.MUL, prod & MASK),
        (Op.MULH, (prod >> 16) & MASK),
        (Op.AND, v1 & v2),
        (Op.OR, v1 | v2),
        (Op.XOR, v1 ^ v2),
        (Op.NOT, (~v1) & MASK),
        (Op.MUX, jnp.where(v1 != 0, v2, v3)),
        (Op.SEQ, (v1 == v2).astype(U32)),
        (Op.SNE, (v1 != v2).astype(U32)),
        (Op.SLTU, (v1 < v2).astype(U32)),
        (Op.SLL, (v1 << shamt) & MASK),
        (Op.SRL, v1 >> shamt),
        (Op.SRA, (sgn >> shamt).astype(U32) & MASK),
        (Op.SLLV, (v1 << (v2 & 15)) & MASK),
        (Op.SRLV, v1 >> (v2 & 15)),
        (Op.SLICE, (v1 >> (imm >> 5)) & ((U32(1) << (imm & 31)) - 1)),
        (Op.LUT, lut_out),
        (Op.LD, ld_val),
        (Op.SEND, v1),
    ]
    result = jnp.zeros((C,), U32)
    for code_op, val in branches:
        result = jnp.where(op == int(code_op), val, result)

    no_write = ((op == int(Op.NOP)) | (op == int(Op.ST)) |
                (op == int(Op.GST)) | (op == int(Op.EXPECT)) |
                (op == int(Op.SEND)) | (dst == 0))
    wdst = jnp.where(no_write, 0, dst)
    regs = regs.at[ar, wdst].set(jnp.where(no_write, regs[ar, 0], result))

    st_mask = (op == int(Op.ST)) & (v3 != 0)
    st_addr = v1 % S
    spads = spads.at[ar, st_addr].set(
        jnp.where(st_mask, v2, spads[ar, st_addr]))

    exc = (op == int(Op.EXPECT)) & (v1 != v2)
    flags = jnp.where((flags == 0) & exc, imm, flags)
    return regs, spads, flags, result & MASK


def vcycle_ref(code: jax.Array, luts: jax.Array, regs: jax.Array,
               spads: jax.Array, flags: jax.Array,
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One full Vcycle. code: [T, C, 7]. Returns (regs, spads, flags,
    trace[T, C])."""
    def step(carry, code_t):
        regs, spads, flags = carry
        regs, spads, flags, res = slot_ref(code_t, luts, regs, spads, flags)
        return (regs, spads, flags), res

    (regs, spads, flags), trace = jax.lax.scan(step, (regs, spads, flags),
                                               code)
    return regs, spads, flags, trace


def flash_ref(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """Oracle for kernels/flash_attention.py: plain softmax attention.
    q, k, v: [BH, S, dh]."""
    import numpy as np
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
