"""Static-BSP trainer: manual, bucketed gradient collectives under shard_map.

pjit/GSPMD places gradient all-reduces automatically; at 1000+ nodes you
want them *explicitly scheduled* — the paper's static-BSP discipline. This
trainer computes grads per data shard with no auto-partitioning, then emits
one `psum` per fixed-size bucket in a compiler-known order (large buckets
first, so the scheduler can overlap the tail of backward with the head of
the reduction — XLA overlaps independent collectives with compute when the
dependence graph allows, which the bucket ordering guarantees).

Data-parallel only (params replicated per shard); compose with in-layer TP
by nesting meshes. Used by tests/test_overlap.py and available to
launch/train.py via --manual-dp.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map


def make_buckets(params: Any, bucket_bytes: int = 32 << 20) -> List[List[int]]:
    """Greedy fixed-size bucketing of flattened gradient leaves,
    largest-first (reduction order = reverse autodiff completion order)."""
    leaves = jax.tree_util.tree_leaves(params)
    order = sorted(range(len(leaves)),
                   key=lambda i: -leaves[i].size * leaves[i].dtype.itemsize)
    buckets: List[List[int]] = []
    cur: List[int] = []
    cur_b = 0
    for i in order:
        b = leaves[i].size * leaves[i].dtype.itemsize
        if cur and cur_b + b > bucket_bytes:
            buckets.append(cur)
            cur, cur_b = [], 0
        cur.append(i)
        cur_b += b
    if cur:
        buckets.append(cur)
    return buckets


def bucketed_psum(grads: Any, axis: str, buckets: List[List[int]]) -> Any:
    """psum gradients bucket-by-bucket in a fixed, compiler-visible order."""
    leaves, tree = jax.tree_util.tree_flatten(grads)
    out = list(leaves)
    for bucket in buckets:
        reduced = jax.lax.psum(tuple(out[i] for i in bucket), axis)
        for i, g in zip(bucket, reduced):
            out[i] = g
    return jax.tree_util.tree_unflatten(tree, out)


def make_manual_dp_step(loss_fn: Callable, optimizer_apply: Callable,
                        mesh: Mesh, axis: str = "data",
                        bucket_bytes: int = 32 << 20):
    """Returns step(params, opt, batch) with replicated params and manually
    scheduled (bucketed) gradient reduction."""

    def step(params, opt, batch):
        def shard_body(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            buckets = make_buckets(grads, bucket_bytes)
            grads = bucketed_psum(grads, axis, buckets)
            n = jax.lax.psum(jnp.ones(()), axis)
            grads = jax.tree.map(lambda g: g / n, grads)
            params, opt, gnorm = optimizer_apply(params, grads, opt)
            loss = jax.lax.pmean(loss, axis)
            return params, opt, dict(metrics, loss=loss, gnorm=gnorm)

        return shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(), P(), P(axis)),
            out_specs=(P(), P(), P()),
            check_vma=False)(params, opt, batch)

    return step
