"""Mesh context + activation-sharding helper.

The model code is mesh-agnostic: ``shard_act`` becomes a no-op outside a mesh
context (CPU smoke tests) and a GSPMD sharding constraint inside one (dry-run
/ production). Axis names: ("pod",) "data", "model" — see launch/mesh.py.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def _resolve(*names):
    """Drop mesh axes that do not exist (single-pod vs multi-pod meshes)."""
    mesh = current_mesh()
    out = []
    for n in names:
        if n is None or isinstance(n, (list, tuple)):
            out.append(n)
        elif mesh is not None and n not in mesh.axis_names:
            out.append(None)
        else:
            out.append(n)
    return tuple(out)


def batch_axes():
    """The data-parallel axes present in the current mesh."""
    mesh = current_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"


def shard_act(x: jax.Array, *spec) -> jax.Array:
    """Constrain activation sharding (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*_resolve(*spec))))
