"""Parameter / input / cache sharding rules.

Baseline scheme (DESIGN.md §5): tensor parallelism on the ``model`` axis
(megatron column->row for MLPs and attention heads; vocab-sharded
embeddings; expert- or ffn-parallel MoE), batch on ``pod`` x ``data``.
Rules are *name + trailing-shape* driven over the parameter pytree, with a
divisibility guard: an axis only shards when the dimension divides evenly —
the guard is what lets one rule set serve every architecture and mesh.
"""
from __future__ import annotations

import os

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

Params = Any


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guard(mesh: Mesh, shape, spec) -> P:
    """Drop shard axes that do not divide the dimension."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None or dim % _axis_size(mesh, ax) != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


# trailing-dims rules, matched by parameter name (innermost dict key)
_COL = (None, "model")     # shard outputs  (column parallel)
_ROW = ("model", None)     # shard inputs   (row parallel)

_NAME_RULES: Dict[str, Tuple] = {
    "wq": _COL, "wk": _COL, "wv": _COL, "wg": _COL, "wz": _COL,
    "in_proj": _COL,
    "wo": _ROW, "out_proj": _ROW, "proj": _ROW,
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "tok": ("model", None),     # vocab-sharded embedding
    "out": (None, "model"),     # vocab-sharded unembedding
}


def spec_for(cfg: ModelConfig, mesh: Mesh, path: Tuple[str, ...],
             leaf) -> P:
    name = path[-1]
    shape = leaf.shape
    in_moe = any(p in ("moe",) for p in path) and "shared" not in path
    if in_moe:
        if name == "router":
            return P()
        m = _axis_size(mesh, "model")
        E = cfg.n_experts
        ep = E % m == 0
        # leading stack dims (layers) -> None
        lead = (None,) * (len(shape) - 3)
        if name in ("wi", "wg"):
            rule = ("model", None, None) if ep else (None, None, "model")
        elif name == "wo":
            rule = ("model", None, None) if ep else (None, "model", None)
        else:
            return P()
        return _guard(mesh, shape, lead + rule)
    # xLSTM gate exceptions: tiny trailing dims stay replicated via guard
    rule = _NAME_RULES.get(name)
    if name == "wi" and len(shape) >= 2 and shape[-1] >= 512:
        rule = _COL                       # MLP wi (large) vs mLSTM gate wi
    elif name == "wi":
        rule = None
    if name == "wf":
        rule = _COL if shape[-1] >= 512 else None
    if rule is None:
        return P()
    lead = (None,) * (len(shape) - len(rule))
    return _guard(mesh, shape, lead + tuple(rule))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Params):
    """PartitionSpec pytree matching ``params_shape`` (a shape pytree)."""
    flat, tree = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        keys = tuple(getattr(k, "key", str(k)) for k in path)
        specs.append(spec_for(cfg, mesh, keys, leaf))
    return jax.tree_util.tree_unflatten(tree, specs)


def batch_spec(mesh: Mesh) -> Tuple:
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def input_specs_sharding(cfg: ModelConfig, mesh: Mesh, specs: Dict):
    """Shardings for the model input dict (batch on pod x data)."""
    b = batch_spec(mesh)
    out = {}
    for k, v in specs.items():
        spec = (b,) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, _guard(mesh, v.shape, spec))
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Any:
    """Decode-state sharding: batch on data axes, kv-heads/heads on model
    when divisible (the guard demotes otherwise)."""
    b = batch_spec(mesh)

    def one(path, leaf):
        keys = tuple(getattr(k, "key", str(k)) for k in path)
        shape = leaf.shape
        name = keys[-1]
        if name == "enc_out":
            return _guard(mesh, shape, (b, None, "model"))
        if name in ("k", "v"):        # [L, B, T, Hkv, dh]
            if os.environ.get("REPRO_KV_SHARD") == "seq":
                # §Perf hillclimb: kv-head count rarely divides the model
                # axis; sharding the *sequence* instead keeps the cache
                # distributed (memory) and turns the decode all-gather into
                # a partial-softmax reduction (collective).
                return _guard(mesh, shape, (None, b, "model", None, None))
            return _guard(mesh, shape, (None, b, None, "model", None))
        if name in ("ak", "av"):      # [n_super, B, T, Hkv, dh]
            return _guard(mesh, shape, (None, b, None, "model", None))
        if name == "ssm":             # [n_super, inner, B, H, N, P]
            return _guard(mesh, shape, (None, None, b, "model", None, None))
        if name == "tail_ssm":
            return _guard(mesh, shape, (None, b, "model", None, None))
        if name in ("mC", "mn"):      # [ns, inner, B, H, ...]
            return _guard(mesh, shape,
                          (None, None, b, "model") + (None,) * (len(shape) - 4))
        if name in ("sc", "sn"):      # [ns, B, d]
            return _guard(mesh, shape, (None, b, "model"))
        return P()

    flat, tree = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        tree, [one(p, l) for p, l in flat])


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
