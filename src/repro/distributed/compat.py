"""Version compatibility shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``).
This repo targets whichever the installed JAX provides; all internal users
(``core.grid``, ``distributed.overlap``) import from here.
"""
from __future__ import annotations

import jax

try:                                       # jax >= 0.5: top-level API
    _shard_map = jax.shard_map
    _VMA_KWARG = True
except AttributeError:                     # jax 0.4.x: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map
    _VMA_KWARG = False


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """Uniform shard_map front-end over old/new JAX APIs."""
    if _VMA_KWARG:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
