"""Assigned input-shape sets and arch x shape cell enumeration."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    mode: str      # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention / O(1) state:
#   zamba2-7b  — Mamba2 state + 4096-window shared attention
#   mixtral-8x7b — SWA window 4096 bounds the KV cache
#   xlstm-125m — recurrent state
# Pure full-attention archs skip it (DESIGN.md §Arch-applicability).
LONG_OK = {"zamba2-7b", "mixtral-8x7b", "xlstm-125m"}

ALL_ARCHS = [
    "qwen2-vl-72b", "qwen3-1.7b", "qwen1.5-110b", "starcoder2-3b",
    "qwen3-0.6b", "zamba2-7b", "mixtral-8x7b", "deepseek-moe-16b",
    "whisper-medium", "xlstm-125m",
]


def cells() -> List[Tuple[str, Shape]]:
    out = []
    for arch in ALL_ARCHS:
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and arch not in LONG_OK:
                continue
            out.append((arch, shape))
    return out
