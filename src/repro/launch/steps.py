"""Step builders: train_step / prefill_step / decode_step as pjit-able pure
functions with full input/output shardings.

Every step is a *statically scheduled superstep* in the paper's sense: all
collectives are fixed at trace time by the sharding specs — there is no
dynamic synchronization anywhere (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as SH
from ..distributed.ctx import mesh_context
from ..models.config import ModelConfig
from ..models.model import Model, build
from ..optim import adamw


def make_train_step(cfg: ModelConfig, mesh: Mesh, compress_grads: bool = False):
    """Returns (step_fn, in_shardings, out_shardings, abstract args).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    model = build(cfg)
    p_shapes = model.abstract_params()
    p_specs = SH.param_specs(cfg, mesh, p_shapes)

    def train_step(params, opt, batch):
        with mesh_context(mesh):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            if compress_grads and opt.ef is not None:
                q, s, ef = adamw.compress_grads(grads, opt.ef)
                grads = jax.tree.map(adamw.dequantize_int8, q, s)
                opt = opt._replace(ef=ef)
            params, opt, gnorm = adamw.apply(params, grads, opt)
            metrics = dict(metrics, loss=loss, gnorm=gnorm)
            return params, opt, metrics

    opt_shapes = jax.eval_shape(
        lambda p: adamw.init(p, compress=compress_grads), p_shapes)
    # optimizer state mirrors the param specs leaf-wise; step is replicated
    o_specs = adamw.AdamWState(
        step=P(),
        m=p_specs, v=p_specs,
        ef=p_specs if compress_grads else None)

    return model, train_step, p_shapes, p_specs, opt_shapes, o_specs


def lower_train(cfg: ModelConfig, mesh: Mesh, seq_len: int,
                global_batch: int, compress: bool = False):
    model, step, p_shapes, p_specs, opt_shapes, o_specs = \
        make_train_step(cfg, mesh, compress)
    batch_specs = model.input_specs(seq_len, global_batch, "train")
    batch_sh = SH.input_specs_sharding(cfg, mesh, batch_specs)
    p_sh = SH.to_named(mesh, p_specs)
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                        is_leaf=lambda s: isinstance(s, P))
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1))
    with mesh_context(mesh):
        lowered = jitted.lower(p_shapes, opt_shapes, batch_specs)
    return lowered, model


def make_serve_steps(cfg: ModelConfig, mesh: Mesh):
    model = build(cfg)
    p_shapes = model.abstract_params()
    p_specs = SH.param_specs(cfg, mesh, p_shapes)

    def prefill_step(params, batch, cache):
        with mesh_context(mesh):
            return model.prefill(params, batch, cache)

    def decode_step(params, tokens, cache, pos):
        with mesh_context(mesh):
            logits, cache = model.decode_step(params, tokens, cache, pos)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache

    return model, prefill_step, decode_step, p_shapes, p_specs


def lower_serve(cfg: ModelConfig, mesh: Mesh, seq_len: int,
                global_batch: int, mode: str):
    """mode: 'prefill' (full prompt) or 'decode' (1 token vs seq_len KV)."""
    model, prefill_step, decode_step, p_shapes, p_specs = \
        make_serve_steps(cfg, mesh)
    p_sh = SH.to_named(mesh, p_specs)
    cache_shapes = jax.eval_shape(
        functools.partial(model.make_cache, global_batch, seq_len))
    c_specs = SH.cache_specs(cfg, mesh, cache_shapes)
    c_sh = SH.to_named(mesh, c_specs)

    if mode == "prefill":
        batch_specs = model.input_specs(seq_len, global_batch, "prefill")
        batch_sh = SH.input_specs_sharding(cfg, mesh, batch_specs)
        jitted = jax.jit(prefill_step,
                         in_shardings=(p_sh, batch_sh, c_sh),
                         out_shardings=(None, c_sh))
        with mesh_context(mesh):
            lowered = jitted.lower(p_shapes, batch_specs, cache_shapes)
    else:
        tok_specs = model.input_specs(seq_len, global_batch, "decode")
        tok_sh = SH.input_specs_sharding(cfg, mesh, tok_specs)
        jitted = jax.jit(decode_step,
                         in_shardings=(p_sh, tok_sh["tokens"], c_sh, None),
                         out_shardings=(tok_sh["tokens"], c_sh),
                         donate_argnums=(2,))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh_context(mesh):
            lowered = jitted.lower(p_shapes, tok_specs["tokens"],
                                   cache_shapes, pos)
    return lowered, model
