"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \\
      --steps 200 --seq 128 --batch 8

Runs on whatever devices exist (CPU smoke / real TPU pod); checkpointing,
deterministic resume and (optionally) int8-compressed gradient sync are on.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SMOKE
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..distributed import sharding as SH
from ..launch.steps import make_train_step
from ..optim import adamw
from ..runtime.checkpoint import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    n_dev = len(jax.devices())
    mp = args.model_parallel
    mesh = jax.make_mesh((n_dev // mp, mp), ("data", "model"))
    print(f"arch={cfg.name} devices={n_dev} mesh=({n_dev // mp},{mp})")

    model, step, p_shapes, p_specs, opt_shapes, o_specs = \
        make_train_step(cfg, mesh, compress_grads=args.compress_grads)
    params = jax.device_put(model.init(jax.random.key(0)),
                            SH.to_named(mesh, p_specs))
    opt = jax.device_put(
        adamw.init(params, compress=args.compress_grads),
        jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs,
                     is_leaf=lambda s: isinstance(s, P)))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"params: {n_params / 1e6:.1f}M")

    mgr = CheckpointManager(args.ckpt_dir)
    start = 0
    if mgr.latest_step() is not None:
        start, restored = mgr.restore_tree({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    pipe = TokenPipeline(PipelineConfig(cfg.vocab, args.seq, args.batch))
    jstep = jax.jit(step, donate_argnums=(0, 1))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, metrics = jstep(params, opt, batch)
        if (i + 1) % args.log_every == 0:
            loss = float(metrics["loss"])
            dt = (time.time() - t0) / args.log_every
            tok_s = args.seq * args.batch / dt
            print(f"step {i + 1:5d} loss {loss:.4f} "
                  f"{dt * 1e3:.0f} ms/step {tok_s:.0f} tok/s", flush=True)
            t0 = time.time()
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt})
    mgr.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
