"""Batched serving driver: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \\
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SMOKE
from ..distributed import sharding as SH
from ..launch.steps import make_serve_steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    n_dev = len(jax.devices())
    mp = args.model_parallel
    mesh = jax.make_mesh((n_dev // mp, mp), ("data", "model"))
    model, prefill, decode, p_shapes, p_specs = make_serve_steps(cfg, mesh)
    params = jax.device_put(model.init(jax.random.key(0)),
                            SH.to_named(mesh, p_specs))

    B, S = args.batch, args.prompt_len
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, 16, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frames, cfg.d_model)) * 0.02,
            jnp.bfloat16)

    ctx = S + args.gen
    cache = model.make_cache(B, ctx)
    cache = jax.device_put(cache, SH.to_named(
        mesh, SH.cache_specs(cfg, mesh, jax.eval_shape(lambda: cache))))

    t0 = time.time()
    logits, cache = jax.jit(prefill)(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    jdecode = jax.jit(decode, donate_argnums=(2,))
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, cache = jdecode(params, tok, cache, S + i)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({B * S / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/tok "
          f"({B * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample tokens:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
