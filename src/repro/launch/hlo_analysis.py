"""Roofline-term extraction from a compiled dry-run artifact.

``compiled.cost_analysis()`` supplies HLO FLOPs and bytes accessed;
collective traffic is NOT in cost_analysis, so we parse the optimized HLO
text and sum operand sizes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute.

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

# e.g.  f32[16,128]{1,0}   bf16[2,4096,8192]
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape sizes of collective ops over the whole module.

    HLO line form:  %name = TYPE all-reduce(...), channel_id=...
    We count the *result* shape (for all-gather that is the gathered size,
    for reduce-scatter the scattered size; a reasonable per-op wire proxy).
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        for cname in _COLLECTIVES:
            # match the op name right after the result type annotation
            if re.search(rf"\)?\s{cname}(-start|-done)?\(", rhs) or \
                    rhs.startswith(cname):
                if f"{cname}-done" in rhs:
                    break  # counted at -start
                tm = _SHAPE_RE.search(rhs)
                type_end = rhs.find(f" {cname}")
                type_str = rhs[:type_end] if type_end > 0 else rhs
                b = _shape_bytes(type_str)
                st.counts[cname] = st.counts.get(cname, 0) + 1
                st.bytes_[cname] = st.bytes_.get(cname, 0) + b
                break
    return st


@dataclass
class Roofline:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    n_chips: int
    model_flops: float = 0.0
    collectives: Optional[CollectiveStats] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # collective bytes are module-level totals; each chip drives its
        # shard through ~one link in a ring schedule
        return self.bytes_collective / (self.n_chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the bound (MFU-at-bound)."""
        if self.step_time == 0:
            return 0.0
        return (self.model_flops / (self.n_chips * PEAK_FLOPS)) / \
            self.step_time

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_collective": self.bytes_collective,
            "n_chips": self.n_chips, "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "collective_counts": dict(self.collectives.counts)
            if self.collectives else {},
            "collective_bytes": dict(self.collectives.bytes_)
            if self.collectives else {},
        }


def raw_costs(compiled) -> Tuple[float, float, float, CollectiveStats]:
    """Per-device (SPMD-partitioned module) raw costs.

    NOTE (verified on this backend): ``cost_analysis`` reports *per-device*
    numbers, and while-loop (lax.scan) bodies are counted **once**, not
    multiplied by trip count. The dry-run therefore calibrates scanned-layer
    stacks with a two-point (1-unit / 2-unit) extrapolation — see
    launch/dryrun.py."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_hbm = float(ca.get("bytes accessed", 0.0))
    st = collective_bytes(compiled.as_text())
    return flops, bytes_hbm, float(st.total_bytes), st


def analyze_from_raw(flops_dev: float, bytes_dev: float, coll_dev: float,
                     n_chips: int, model_flops: float,
                     collectives: Optional[CollectiveStats] = None
                     ) -> Roofline:
    """Raw per-device costs -> global roofline terms (x n_chips)."""
    return Roofline(flops=flops_dev * n_chips, bytes_hbm=bytes_dev * n_chips,
                    bytes_collective=coll_dev * n_chips,
                    n_chips=n_chips, model_flops=model_flops,
                    collectives=collectives)


def analyze(compiled, n_chips: int, model_flops: float) -> Roofline:
    flops, bytes_hbm, coll, st = raw_costs(compiled)
    return analyze_from_raw(flops, bytes_hbm, coll, n_chips, model_flops, st)
