import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count on first init). This module is the ONLY place the 512 fake devices
# are requested — tests and benches see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (shardings
propagate, collectives legalize, memory fits) and extracts the roofline
terms (launch/hlo_analysis.py) from the compiled artifact. Results land in
results/dryrun/<arch>__<shape>__<mesh>.json and feed EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCHS
from ..launch import hlo_analysis as HLO
from ..launch.mesh import make_production_mesh
from ..launch.shapes import SHAPES, cells
from ..launch.steps import lower_serve, lower_train

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N_active*D (inference) useful-FLOP accounting."""
    total, active = cfg.param_count()
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * active * tokens
    if shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch     # decode: one token per seq


def _unit_scaled(cfg, k: int):
    """A k-unit variant of cfg for scan-body cost calibration, plus the
    number of units in the full config."""
    if cfg.block == "mamba2":
        u = cfg.attn_every
        return cfg.scaled(n_layers=k * u), cfg.n_layers / u
    if cfg.block == "xlstm":
        u = cfg.slstm_every
        return cfg.scaled(n_layers=k * u), cfg.n_layers / u
    if cfg.enc_dec:
        return cfg.scaled(n_layers=k, n_enc_layers=k), float(cfg.n_layers)
    return cfg.scaled(n_layers=k), float(cfg.n_layers)


def _lower_one(cfg, mesh, shape, compress):
    if shape.mode == "train":
        lowered, _ = lower_train(cfg, mesh, shape.seq_len,
                                 shape.global_batch, compress=compress)
    else:
        lowered, _ = lower_serve(cfg, mesh, shape.seq_len,
                                 shape.global_batch, shape.mode)
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             compress: bool = False, calibrate: bool = True) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = _lower_one(cfg, mesh, shape, compress)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mf = model_flops(cfg, shape)
    raw = HLO.raw_costs(compiled)

    calib = None
    if calibrate:
        # XLA cost analysis counts lax.scan bodies once (verified on this
        # backend): measure real per-layer-unit costs on small *unrolled*
        # configs and extrapolate: cost(U) = fixed + U * per_unit.
        from ..models import transformer as TR
        cfg1, units = _unit_scaled(cfg, 1)
        cfg2, _ = _unit_scaled(cfg, 2)
        TR.set_unroll(True)
        try:
            r1 = HLO.raw_costs(
                _lower_one(cfg1, mesh, shape, compress).compile())
            r2 = HLO.raw_costs(
                _lower_one(cfg2, mesh, shape, compress).compile())
        finally:
            TR.set_unroll(False)
        # per-unit deltas clamped at 0: CSE across unrolled layers can
        # make the 2-unit compile cheaper per-op than the 1-unit one
        corr = tuple(a + max(b - a, 0.0) * (units - 1.0)
                     for a, b in zip(r1[:3], r2[:3]))
        calib = {"units": units,
                 "unit1": r1[:3], "unit2": r2[:3], "corrected": corr}
        roof = HLO.analyze_from_raw(corr[0], corr[1], corr[2], n_chips, mf,
                                    raw[3])
    else:
        roof = HLO.analyze_from_raw(raw[0], raw[1], raw[2], n_chips, mf,
                                    raw[3])

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "mode": shape.mode,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "roofline": roof.as_dict(),
        "roofline_raw_per_device": {"flops": raw[0], "bytes_hbm": raw[1],
                                    "bytes_collective": raw[2]},
        "calibration": calib,
        "status": "ok",
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    todo = []
    if args.all:
        todo = [(a, s.name) for a, s in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            out = RESULTS / (f"{arch}__{shape}__{mesh_tag}"
                             f"{args.tag}.json")
            try:
                rec = run_cell(arch, shape, mp, compress=args.compress_grads,
                               calibrate=not args.no_calibrate)
                r = rec["roofline"]
                print(f"[OK] {arch:18s} {shape:12s} {mesh_tag:8s} "
                      f"lower={rec['t_lower_s']:.0f}s "
                      f"compile={rec['t_compile_s']:.0f}s "
                      f"bottleneck={r['bottleneck']:10s} "
                      f"tc={r['t_compute']:.3e} tm={r['t_memory']:.3e} "
                      f"tx={r['t_collective']:.3e}", flush=True)
            except Exception as e:  # noqa
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                       "status": "fail", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {arch} {shape} {mesh_tag}: "
                      f"{type(e).__name__}: {str(e)[:200]}", flush=True)
            out.write_text(json.dumps(rec, indent=1))
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
