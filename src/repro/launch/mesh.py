"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is pure data parallelism whose gradient all-reduce crosses the
inter-pod links (optionally int8-compressed, see optim/adamw.py).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CPU tests / small runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
