"""Cross-Vcycle pipelined execution: rotated prologue dispatch end to end.

Full-scale bc on the 5x5 grid ships a modulo-pipelined schedule with a
non-empty retimed prologue (``Program.pipe_prologue > 0``), which makes it
the vehicle for everything the engines must get right under pipelining:

  * rotated dispatch (body -> exchange -> gated prologue) stays bit-exact
    against the netlist oracle and the full-stream seed engine;
  * a mid-chunk exception must freeze the machine *without* committing the
    next iteration's in-flight prologue (the gated tail);
  * batched and sharded engines apply the iteration-0 prologue once and
    gate the tail per element;
  * the ``pipeline`` knob is a compile-cache key dimension, and artifacts
    round-trip the prologue length.
"""
import tempfile

import numpy as np
import pytest

import repro.sim as sim
from repro.circuits import FINISH, build
from repro.core.bsp import BatchedMachine, Machine, ShardedBatchedMachine
from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim
from repro.sim.cache import cache_key

HW = HardwareConfig(grid_width=5, grid_height=5)
SEEDS = [3, 11, 42]


@pytest.fixture(scope="module")
def bc_full():
    """Full-scale bc: the circuit whose shipped 5x5 schedule carries a
    retimed prologue (P > 0) — asserted so coverage cannot silently rot."""
    b = build("bc", "full")
    prog = compile_circuit(b.circuit, HW, check=True)
    assert prog.pipe_prologue > 0, \
        "bc/full no longer ships a retimed prologue — pick a new vehicle"
    assert prog.stats["pipeline_pick"] == "modulo"
    assert prog.vcpl < prog.stats["vcpl_unpipelined"]
    ref = NetlistSim(b.circuit)
    ref.run(b.n_cycles + 10)
    return b, prog, ref


@pytest.fixture(scope="module")
def bc_batch():
    b = build("bc", "full", seeds=SEEDS)
    prog = compile_circuit(b.circuit, HW)
    assert prog.pipe_prologue > 0
    return b, prog


def test_rotated_dispatch_matches_oracle(bc_full):
    """jnp engine and numpy ISA sim (both rotated) vs the netlist oracle:
    same finish cycle, same exceptions, identical architectural state —
    and identical raw register planes to each other (same convention)."""
    b, prog, ref = bc_full
    m = Machine(prog)
    st = m.run(m.init_state(), b.n_cycles + 10)
    s = IsaSim(prog)
    assert s.run(b.n_cycles + 10) == b.n_cycles
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}
    assert m.exceptions(st) == s.exceptions()
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname
        assert s.read_reg(rname) == ref.reg_value(rname), rname
    np.testing.assert_array_equal(np.asarray(st.regs)[:s.C], s.regs)


def test_seed_engine_agrees_at_frozen_end(bc_full):
    """The unspecialized seed engine executes the combined stream head
    first (full-stream convention); after the raising Vcycle both
    conventions hold the same committed state, so the frozen end states
    coincide bit for bit."""
    b, prog, _ref = bc_full
    m_rot = Machine(prog)
    m_full = Machine(prog, specialize=False)
    st_r = m_rot.run(m_rot.init_state(), b.n_cycles + 10)
    st_f = m_full.run(m_full.init_state(), b.n_cycles + 10)
    assert m_rot.exceptions(st_r) == m_full.exceptions(st_f)
    np.testing.assert_array_equal(np.asarray(st_r.regs),
                                  np.asarray(st_f.regs))
    np.testing.assert_array_equal(np.asarray(st_r.flags),
                                  np.asarray(st_f.flags))


@pytest.mark.parametrize("backend,chunk", [("jnp", 8), ("jnp", 32),
                                           ("pallas", 8)])
def test_midchunk_freeze_discards_inflight_prologue(backend, chunk, bc_full):
    """bc raises FINISH mid-chunk. By then the raising iteration's gated
    prologue tail — cycle k+1's carries — is in flight; the freeze must
    not commit it. The rotated numpy sim implements the same gate
    independently, so the full frozen register planes must coincide."""
    b, prog, ref = bc_full
    if backend == "pallas" and prog.has_global:
        pytest.skip("privileged off-chip programs use the jnp engine")
    assert b.n_cycles % chunk != 0
    m = Machine(prog, backend=backend, chunk=chunk,
                interpret=(backend == "pallas"))
    st = m.run(m.init_state(), 1000)       # budget far past the exception
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}
    s = IsaSim(prog)
    s.run(b.n_cycles + 10)
    np.testing.assert_array_equal(np.asarray(st.regs)[:s.C], s.regs)
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname


def test_batched_pipelined_matches_single(bc_batch):
    """BatchedMachine under a P > 0 program: the iteration-0 prologue is
    applied per element at init, the tail gated per element; every batch
    element bit-exact against an independent single-stimulus rotated run."""
    b, prog = bc_batch
    images = b.images(prog)
    bm = BatchedMachine(prog, images=images)
    st = bm.run(bm.init_state(), b.n_cycles + 10)
    for i in range(len(SEEDS)):
        m = Machine(prog)
        s1 = m.run(m.init_state(images=images[i]), b.n_cycles + 10)
        assert set(bm.exceptions(st, i).values()) == {FINISH}
        assert bm.exceptions(st, i) == m.exceptions(s1)
        np.testing.assert_array_equal(np.asarray(st.regs[i]),
                                      np.asarray(s1.regs))
        np.testing.assert_array_equal(np.asarray(st.flags[i]),
                                      np.asarray(s1.flags))


def test_sharded_pipelined_matches_batched(bc_batch):
    """The mesh-sharded engine (degenerate D=1 mesh on the test runner,
    real mesh on the 8-device CI job) reproduces the vmapped engine under
    a P > 0 program — prologue-applied init images shard correctly."""
    import jax
    b, prog = bc_batch
    sm = ShardedBatchedMachine(prog, images=b.images_batch(prog))
    bm = BatchedMachine(prog, images=b.images(prog))
    st = sm.run(sm.init_state(), b.n_cycles + 10)
    sb = bm.run(bm.init_state(), b.n_cycles + 10)
    for ls, lb in zip(st, sb):
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lb))


def test_pipeline_knob_in_cache_key(bc_full):
    """pipeline= is a cache-key dimension: off/modulo requests never alias,
    and a facade round-trip through one cache dir keeps both artifacts."""
    b, _prog, _ref = bc_full
    k_mod = cache_key(b.circuit, HW, pipeline="modulo")
    k_off = cache_key(b.circuit, HW, pipeline="off")
    assert k_mod != k_off
    assert k_mod == cache_key(b.circuit, HW)      # modulo is the default
    with tempfile.TemporaryDirectory(prefix="repro-pipe-cache-") as td:
        s_off = sim.compile(b, HW, pipeline="off", cache=td)
        s_mod = sim.compile(b, HW, cache=td)
        assert not s_off.cache_hit and not s_mod.cache_hit   # no aliasing
        assert s_off.program.pipe_prologue == 0
        assert s_mod.program.pipe_prologue > 0
        again = sim.compile(b, HW, cache=td)
        assert again.cache_hit
        assert again.program.pipe_prologue == s_mod.program.pipe_prologue
        assert again.program.vcpl == s_mod.program.vcpl


def test_artifact_roundtrip_preserves_prologue(bc_full, tmp_path):
    """save/load keeps pipe_prologue, and the loaded Program's rotated
    IsaSim run equals the original's."""
    b, prog, _ref = bc_full
    p = tmp_path / "bc_pipe.npz"
    prog.save(p)
    loaded = sim.load(p).program
    assert loaded.pipe_prologue == prog.pipe_prologue
    assert loaded.vcpl == prog.vcpl
    s0, s1 = IsaSim(prog), IsaSim(loaded)
    assert s0.run(b.n_cycles + 10) == s1.run(b.n_cycles + 10)
    np.testing.assert_array_equal(s0.regs, s1.regs)
    assert s0.exceptions() == s1.exceptions()
