"""Hypothesis property tests: the compiler preserves netlist semantics.

Random circuits are generated over the full DSL op set; the invariant is
that the *netlist oracle* (interpreter.NetlistSim), and the *compiled binary
on the numpy ISA simulator* (core.isasim) agree on every register, every
cycle — under both partitioning strategies, with and without LUT fusion, on
several grid sizes.
"""
from __future__ import annotations

import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")

import hypothesis.strategies as st
from hypothesis import given, settings, HealthCheck

from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim
from repro.core.netlist import Circuit


@st.composite
def random_circuit(draw):
    """A random single-clock netlist with registers, logic and a memory."""
    rnd = draw(st.randoms(use_true_random=False))
    n_regs = draw(st.integers(2, 5))
    widths = [draw(st.sampled_from([1, 4, 8, 16, 17, 24, 32, 48]))
              for _ in range(n_regs)]
    c = Circuit("rand")
    regs = [c.reg(w, init=rnd.getrandbits(w), name=f"r{i}")
            for i, w in enumerate(widths)]
    pool = list(regs)

    def pick(width=None):
        cands = [s for s in pool if width is None or s.width == width]
        if not cands:
            s = rnd.choice(pool)
            if width is None:
                return s
            if s.width > width:
                return s[width - 1:0]
            return s.zext(width)
        return rnd.choice(cands)

    n_ops = draw(st.integers(3, 18))
    for _ in range(n_ops):
        kind = rnd.choice(["and", "or", "xor", "not", "add", "sub", "mul",
                           "mux", "eq", "ltu", "shl", "shr", "slice", "cat"])
        a = pick()
        if kind in ("and", "or", "xor", "add", "sub", "mul"):
            b = pick(a.width)
            s = {"and": a & b, "or": a | b, "xor": a ^ b, "add": a + b,
                 "sub": a - b, "mul": a * b}[kind]
        elif kind == "not":
            s = ~a
        elif kind == "mux":
            sel = pick(1) if any(x.width == 1 for x in pool) else a.eq(a)
            b = pick(a.width)
            s = c.mux(sel if sel.width == 1 else sel[0], a, b)
        elif kind == "eq":
            s = a.eq(pick(a.width))
        elif kind == "ltu":
            s = a.ltu(pick(a.width))
        elif kind == "shl":
            s = a << rnd.randrange(0, a.width)
        elif kind == "shr":
            s = a >> rnd.randrange(0, a.width)
        elif kind == "slice":
            hi = rnd.randrange(0, a.width)
            s = a[a.width - 1:hi] if hi < a.width else a
        else:  # cat
            b = pick()
            if a.width + b.width <= 64:
                s = a.cat(b)
            else:
                s = a
        pool.append(s)

    # drive register next-values from the pool (width-adapted)
    for r in regs:
        s = pick()
        s = s.trunc(r.width) if s.width >= r.width else s.zext(r.width)
        c.set_next(r, s)

    def fit16(s):
        return s.trunc(16) if s.width >= 16 else s.zext(16)

    # a small memory exercised by one reader/writer
    use_mem = draw(st.booleans())
    if use_mem:
        m = c.mem("m0", 8, 16, init=[rnd.getrandbits(16) for _ in range(8)])
        addr = fit16(pick())
        c.mem_write(m, addr, fit16(pick()), c.const(1, 1))
        rd = c.mem_read(m, addr)
        extra = c.reg(16, init=0, name="rm")
        c.set_next(extra, rd)
    return c


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(random_circuit(),
       st.sampled_from([(2, 2), (3, 3), (5, 5)]),
       st.sampled_from(["balanced", "lpt"]),
       st.booleans())
def test_compiled_program_matches_oracle(circuit, grid, strategy, use_luts):
    hw = HardwareConfig(grid_width=grid[0], grid_height=grid[1])
    prog = compile_circuit(circuit, hw, strategy=strategy, use_luts=use_luts)
    oracle = NetlistSim(circuit)
    sim = IsaSim(prog)
    for cyc in range(6):
        oracle.step()
        sim.step()
        for name in circuit.reg_names.values():
            if name in prog.state_regs:
                assert sim.read_reg(name) == oracle.reg_value(name), (
                    f"cycle {cyc}, reg {name}, strategy={strategy}, "
                    f"luts={use_luts}")


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(random_circuit(), st.booleans())
def test_optimized_compile_matches_legacy_and_oracle(circuit, use_luts):
    """PR 3 property: the optimizing middle-end preserves semantics. A
    random circuit compiled with ``optimize=True`` and ``optimize=False``
    stays bit-exact against the netlist oracle *and* against itself on
    every register over a multi-Vcycle run, and optimization never adds
    instructions or loses a state register."""
    hw = HardwareConfig(grid_width=3, grid_height=3)
    po = compile_circuit(circuit, hw, use_luts=use_luts, optimize=True)
    pf = compile_circuit(circuit, hw, use_luts=use_luts, optimize=False)
    assert set(po.state_regs) == set(pf.state_regs)
    assert po.stats["instrs_opt"] <= po.stats["instrs_lowered"]
    oracle = NetlistSim(circuit)
    so, sf = IsaSim(po), IsaSim(pf)
    for cyc in range(8):
        oracle.step()
        so.step()
        sf.step()
        for name in circuit.reg_names.values():
            if name in po.state_regs:
                want = oracle.reg_value(name)
                assert so.read_reg(name) == want, (cyc, name, "opt")
                assert sf.read_reg(name) == want, (cyc, name, "legacy")


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_circuit())
def test_opt_pipeline_ir_invariants(circuit):
    """The pass pipeline keeps the IR well-formed (``Lowered.check``) and
    respects the liveness contract on every random circuit."""
    from repro.core.lower import lower
    from repro.core.opt import optimize_lowered

    low = lower(circuit)
    n_regs = len(low.regs)
    low, records = optimize_lowered(low)   # runs check() before and after
    assert len(low.regs) == n_regs         # state registers never eliminated
    assert records
    assert all(r["instrs_after"] <= r["instrs_before"] for r in records), \
        "no pass may add instructions"


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_circuit())
def test_partition_invariants(circuit):
    """Structural invariants of the split/merge pass."""
    from repro.core.lower import lower
    from repro.core.partition import partition

    low = lower(circuit)
    part = partition(low, num_cores=9)
    assert part.num_procs <= 9
    # privileged instructions only in the privileged process
    for pi, proc in enumerate(part.procs):
        for idx in proc:
            if low.instrs[idx].is_privileged():
                assert pi == part.priv_proc
    # memories owned by exactly one process
    owners = {}
    for pi, mems in enumerate(part.proc_mems):
        for m in mems:
            assert m not in owners
            owners[m] = pi
    # every instruction of the monolithic program with a live sink is covered
    covered = {i for proc in part.procs for i in proc}
    # (dead code may be dropped, but every EXPECT/ST must be present)
    from repro.core.isa import Op
    for i, ins in enumerate(low.instrs):
        if ins.op in (Op.EXPECT, Op.ST, Op.GST):
            assert i in covered


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_circuit())
def test_schedule_hazard_invariants(circuit):
    """RAW hazards respected: def->use distance >= raw_latency per core."""
    hw = HardwareConfig(grid_width=3, grid_height=3, raw_latency=4)
    prog = compile_circuit(circuit, hw)
    from repro.core.isa import Op
    for c in range(prog.used_cores):
        last_def = {}
        for t in range(prog.t_compute):
            op, dst, s1, s2, s3, s4, imm = prog.code[c, t]
            if op == 0:
                continue
            for s in (s1, s2, s3, s4):
                if s in last_def:
                    assert t - last_def[s] >= hw.raw_latency, \
                        f"core {c} slot {t} reads r{s} too early"
            writes = Op(op) not in (Op.NOP, Op.ST, Op.GST, Op.EXPECT,
                                    Op.SEND)
            if writes and dst != 0:
                last_def[dst] = t
