"""Differential tests for the nine paper benchmarks: netlist oracle vs the
numpy ISA sim vs the jnp lockstep engine vs the Pallas kernel path."""
import numpy as np
import pytest

from repro.circuits import CIRCUITS, FINISH, build
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim

NAMES = sorted(CIRCUITS)
HW = HardwareConfig(grid_width=5, grid_height=5)


@pytest.fixture(scope="module")
def compiled():
    out = {}
    for nm in NAMES:
        b = build(nm, "small")
        out[nm] = (b, compile_circuit(b.circuit, HW))
    return out


@pytest.mark.parametrize("name", NAMES)
def test_oracle_self_check(name, compiled):
    b, _ = compiled[name]
    sim = NetlistSim(b.circuit)
    ncyc, log = sim.run(b.n_cycles + 10)
    assert ncyc == b.n_cycles
    assert log[-1].exceptions == [FINISH]


@pytest.mark.parametrize("name", NAMES)
def test_isasim_matches(name, compiled):
    b, prog = compiled[name]
    sim = IsaSim(prog)
    ncyc = sim.run(b.n_cycles + 10)
    assert ncyc == b.n_cycles
    assert set(sim.exceptions().values()) == {FINISH}


@pytest.mark.parametrize("name", NAMES)
def test_jnp_engine_matches(name, compiled):
    b, prog = compiled[name]
    m = Machine(prog)
    st = m.run(m.init_state(), b.n_cycles + 10)
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}


@pytest.mark.parametrize("name", NAMES)
def test_pallas_engine_matches(name, compiled):
    b, prog = compiled[name]
    if prog.has_global:
        pytest.skip("privileged off-chip programs use the jnp engine")
    m = Machine(prog, backend="pallas", interpret=True)
    st = m.run(m.init_state(), b.n_cycles + 10)
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}


@pytest.mark.parametrize("name", ["mc", "rv32r"])
def test_register_trace_matches_oracle(name, compiled):
    """Cycle-by-cycle register equivalence on two benches."""
    b, prog = compiled[name]
    oracle = NetlistSim(b.circuit)
    m = Machine(prog)
    st = m.init_state()
    regs = [n for n in prog.state_regs][:6]
    for _ in range(10):
        oracle.step()
        st = m.run(st, 1)
        for r in regs:
            assert m.read_reg(st, r) == oracle.reg_value(r), r


def test_lpt_vs_balanced_both_correct(compiled):
    b, _ = compiled["mc"]
    for strat in ("balanced", "lpt"):
        prog = compile_circuit(b.circuit, HW, strategy=strat)
        sim = IsaSim(prog)
        assert sim.run(b.n_cycles + 10) == b.n_cycles


def test_balanced_fewer_sends_than_lpt():
    """Table 4 property: communication-aware merging reduces Sends."""
    b = build("mc", "full")
    hw = HardwareConfig(grid_width=15, grid_height=15)
    pb = compile_circuit(b.circuit, hw, strategy="balanced")
    pl = compile_circuit(b.circuit, hw, strategy="lpt")
    assert pb.stats["sends"] <= pl.stats["sends"]


def test_luts_reduce_instructions():
    """Fig 10 property: custom functions reduce non-NOp instructions."""
    b = build("bc", "small")
    with_l = compile_circuit(b.circuit, HW, use_luts=True)
    without = compile_circuit(b.circuit, HW, use_luts=False)
    assert with_l.stats["instrs"] <= without.stats["instrs"]
    assert with_l.stats["lut_instrs"] > 0


def test_global_stall_counters():
    """Fig 8 machinery: global memories hit the cache/stall model."""
    from repro.core.netlist import Circuit
    c = Circuit("gmem")
    m = c.mem("big", 1 << 12, 16, is_global=True)
    ctr = c.reg(16, init=0, name="ctr")
    c.set_next(ctr, ctr + 1)
    rd = c.mem_read(m, ctr)
    acc = c.reg(16, init=0, name="acc")
    c.set_next(acc, acc + rd)
    c.mem_write(m, ctr, acc, c.const(1, 1))
    c.finish_when(ctr.eq(64), eid=FINISH)
    prog = compile_circuit(c, HW)
    assert prog.has_global
    mach = Machine(prog)
    st = mach.run(mach.init_state(), 100)
    perf = mach.perf(st)
    assert perf["ghits"] + perf["gmisses"] > 0
    assert perf["stall_cycles"] > 0
    assert perf["machine_cycles"] > perf["vcycles"] * prog.vcpl
