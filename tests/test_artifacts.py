"""Persistent Program artifacts: hypothesis round-trip properties.

``Program.save``/``Program.load`` must be *bit-exact*: every dense array
(code, LUTs, init images, exchange tables, slot-op mask) identical in
value, shape and dtype; the ``outputs``/``state_regs`` maps and ``stats``
structurally equal; and — the property that actually matters — a loaded
Program produces identical ``RunResult``s to the one that was saved.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

import repro.sim as sim
from repro.circuits import build
from repro.core import HardwareConfig
from repro.sim.artifact import _ARRAY_FIELDS

HW = HardwareConfig(grid_width=4, grid_height=4)
ARRAYS = _ARRAY_FIELDS + ("slot_op_mask",)


def _assert_bit_exact(orig, loaded):
    for f in ARRAYS:
        a, b = getattr(orig, f), getattr(loaded, f)
        assert a.dtype == b.dtype, f
        assert a.shape == b.shape, f
        np.testing.assert_array_equal(a, b, err_msg=f)
    assert loaded.name == orig.name
    assert loaded.hw == orig.hw
    assert loaded.t_compute == orig.t_compute
    assert loaded.vcpl == orig.vcpl
    assert loaded.used_cores == orig.used_cores
    assert loaded.pipe_prologue == orig.pipe_prologue
    assert loaded.outputs == orig.outputs
    assert loaded.state_regs == orig.state_regs
    assert loaded.stats == orig.stats


@settings(max_examples=5, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1),
       n_walkers=st.sampled_from([2, 4]),
       n_cycles=st.sampled_from([12, 24, 32]),
       optimize=st.booleans())
def test_program_roundtrip_bit_exact(tmp_path_factory, seed, n_walkers,
                                     n_cycles, optimize):
    """mc small-scale, varied shape/seed/pipeline: save → load preserves
    every array bit and every metadata map, and the loaded Program's
    RunResult equals the original's on two independent engines."""
    td = tmp_path_factory.mktemp("artifacts")
    bench = build("mc", "small", seed=seed, n_walkers=n_walkers,
                  n_cycles=n_cycles)
    s = sim.compile(bench, HW, optimize=optimize)
    path = td / f"mc_{seed}_{n_walkers}_{n_cycles}_{optimize}.npz"
    s.save(path)
    loaded = sim.load(path)
    _assert_bit_exact(s.program, loaded.program)

    n = bench.n_cycles + sim.CYCLE_SLACK
    # the jit-free numpy engine keeps the property loop fast
    r0 = s.engine("isa").run(n)
    r1 = loaded.engine("isa").run(n)
    assert r1 == r0
    assert r1.registers == r0.registers
    assert r1.exceptions == r0.exceptions
    assert r1.cycles == r0.cycles
    assert r0.finished


def test_loaded_program_identical_runresult_jnp(tmp_path):
    """The headline acceptance check on the real engine: compile mc
    small, save, load, run both through the specialized jnp engine —
    identical RunResults (registers, outputs, exceptions, perf)."""
    s = sim.compile("mc", HW, scale="small")
    s.save(tmp_path / "mc.npz")
    loaded = sim.load(tmp_path / "mc.npz")
    n = s.default_cycles()
    r0 = s.run(n)
    r1 = loaded.run(n)
    assert r1 == r0
    assert r1.finished


def test_format_version_gate(tmp_path):
    """An artifact from an incompatible schema is refused, not mis-read."""
    import io
    import json

    s = sim.compile("mc", HW, scale="small")
    p = tmp_path / "mc.npz"
    s.save(p)
    with np.load(p) as z:
        payload = {k: z[k] for k in z.files}
    meta = json.loads(bytes(payload["__meta__"]).decode())
    meta["format_version"] = 999
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **payload)
    p.write_bytes(buf.getvalue())
    with pytest.raises(ValueError, match="format"):
        sim.load(p)


def test_save_never_leaves_torn_artifact(tmp_path):
    """save() writes via a temp file + atomic rename: the destination is
    either absent or a complete artifact, and re-saving overwrites."""
    s = sim.compile("mc", HW, scale="small")
    p = tmp_path / "mc.npz"
    s.save(p)
    s.save(p)                       # overwrite in place
    assert not list(tmp_path.glob("*.tmp")) \
        and not list(tmp_path.glob(".*.tmp"))
    _assert_bit_exact(s.program, sim.load(p).program)
