import os

# tests must see the real (single) CPU device — only launch/dryrun.py asks
# for 512 fake devices
os.environ.setdefault("JAX_PLATFORMS", "cpu")
