"""Multi-device execution tests (not just lowering): run in a subprocess
with 8 host devices so the main test process keeps its single-device view.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_subprocess(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_grid_machine_8dev_matches_oracle():
    out = run_subprocess("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.circuits import build, FINISH
        from repro.core.interpreter import NetlistSim
        from repro.core.isa import HardwareConfig
        from repro.core.compile import compile_circuit
        from repro.core.grid import GridMachine

        b = build("rv32r", "small")
        sim = NetlistSim(b.circuit)
        sim.run(b.n_cycles + 10)
        prog = compile_circuit(b.circuit,
                               HardwareConfig(grid_width=4, grid_height=4))
        mesh = Mesh(np.array(jax.devices()), ("cores",))
        gm = GridMachine(prog, mesh)
        st = gm.run(gm.init_state(), b.n_cycles + 10)
        assert gm.perf(st)["vcycles"] == b.n_cycles, gm.perf(st)
        assert set(gm.exceptions(st).values()) == {FINISH}
        for name in prog.state_regs:
            assert gm.read_reg(st, name) == sim.reg_value(name), name
        print("GRID8-OK")
    """)
    assert "GRID8-OK" in out


def test_sharded_train_step_executes():
    """A real sharded train step (mesh 4x2, TP=2) runs end-to-end and the
    loss decreases — collectives execute, not just lower."""
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import SMOKE
        from repro.launch.steps import make_train_step
        from repro.distributed import sharding as SH
        from repro.data.pipeline import PipelineConfig, TokenPipeline
        from repro.optim import adamw
        from jax.sharding import NamedSharding

        cfg = SMOKE["qwen3-0.6b"]
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        model, step, p_shapes, p_specs, opt_shapes, o_specs = \\
            make_train_step(cfg, mesh)
        params = model.init(jax.random.key(0))
        params = jax.device_put(params, SH.to_named(mesh, p_specs))
        opt = adamw.init(params)
        opt = jax.device_put(opt, jax.tree.map(
            lambda s: NamedSharding(mesh, s), o_specs,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)))
        pipe = TokenPipeline(PipelineConfig(cfg.vocab, 32, 8))
        jstep = jax.jit(step, donate_argnums=(0, 1))
        losses = []
        for i in range(6):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, opt, metrics = jstep(params, opt, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("TRAIN8-OK", losses[0], losses[-1])
    """)
    assert "TRAIN8-OK" in out


def test_sharded_decode_executes():
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import SMOKE
        from repro.launch.steps import make_serve_steps
        from repro.distributed import sharding as SH

        cfg = SMOKE["mixtral-8x7b"]
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        model, prefill, decode, p_shapes, p_specs = \\
            make_serve_steps(cfg, mesh)
        params = model.init(jax.random.key(0))
        params = jax.device_put(params, SH.to_named(mesh, p_specs))
        B, S = 4, 16
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
        cache = model.make_cache(B, 64)
        cache = jax.device_put(cache, SH.to_named(
            mesh, SH.cache_specs(cfg, mesh, jax.eval_shape(lambda: cache))))
        logits, cache = jax.jit(prefill)(params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        for i in range(4):
            tok, cache = jax.jit(decode)(params, tok, cache, S + i)
        assert tok.shape == (B, 1)
        print("DECODE8-OK")
    """)
    assert "DECODE8-OK" in out


def test_multipod_mesh_spec_resolution():
    """pod axis resolves in specs; gradient sync spans pods (2x2x2 mesh)."""
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import SMOKE
        from repro.launch.steps import lower_train

        cfg = SMOKE["qwen3-1.7b"]
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        lowered, model = lower_train(cfg, mesh, seq_len=32, global_batch=8)
        compiled = lowered.compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt or "reduce-scatter" in txt
        print("MULTIPOD-OK")
    """)
    assert "MULTIPOD-OK" in out
