"""The partially-evaluated fast path (PR 1): specialized jnp engine,
chunked Pallas kernel and vectorized numpy ISA sim, each cross-checked
against the NetlistSim oracle on every benchmark circuit — plus the two
behaviours the specialization must not break: SENDs crossing core
boundaries and exceptions freezing the machine *within* a chunk.
"""
import numpy as np
import pytest

from repro.circuits import CIRCUITS, FINISH, build
from repro.core.bsp import DEFAULT_CHUNK, Machine
from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim

NAMES = sorted(CIRCUITS)
HW = HardwareConfig(grid_width=5, grid_height=5)


@pytest.fixture(scope="module")
def compiled():
    out = {}
    for nm in NAMES:
        b = build(nm, "small")
        prog = compile_circuit(b.circuit, HW)
        ref = NetlistSim(b.circuit)
        ref.run(b.n_cycles + 10)
        out[nm] = (b, prog, ref)
    return out


@pytest.mark.parametrize("name", NAMES)
def test_specialized_jnp_matches_oracle(name, compiled):
    b, prog, ref = compiled[name]
    m = Machine(prog)                       # specialize=True is the default
    st = m.run(m.init_state(), b.n_cycles + 10)
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname


@pytest.mark.parametrize("name", NAMES)
def test_chunked_pallas_matches_oracle(name, compiled):
    b, prog, ref = compiled[name]
    if prog.has_global:
        pytest.skip("privileged off-chip programs use the jnp engine")
    m = Machine(prog, backend="pallas", interpret=True)
    st = m.run(m.init_state(), b.n_cycles + 10)
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname
    # and bit-exact against the jnp fast path, registers included
    mj = Machine(prog)
    stj = mj.run(mj.init_state(), b.n_cycles + 10)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(stj.regs))
    np.testing.assert_array_equal(np.asarray(st.spads),
                                  np.asarray(stj.spads))


@pytest.mark.parametrize("name", NAMES)
def test_vectorized_isasim_matches_oracle(name, compiled):
    b, prog, ref = compiled[name]
    sim = IsaSim(prog)
    assert sim.run(b.n_cycles + 10) == b.n_cycles
    assert set(sim.exceptions().values()) == {FINISH}
    for rname in prog.state_regs:
        assert sim.read_reg(rname) == ref.reg_value(rname), rname


def test_cross_core_sends_route_through_compact_buffer(compiled):
    """The compact SEND capture must carry values across core boundaries —
    pick a circuit whose exchange table actually crosses cores and check
    per-cycle bit-exactness of the whole register file."""
    b, prog, _ = compiled["noc"]
    cross = prog.xchg_src_core != prog.xchg_dst_core
    assert cross.any(), "noc must exercise cross-core SENDs"
    m = Machine(prog)
    sim = IsaSim(prog)
    carry = tuple(m.init_state())
    for cyc in range(8):
        carry = m._vcycle(carry)
        sim.step()
        np.testing.assert_array_equal(np.asarray(carry[0]), sim.regs,
                                      err_msg=f"cycle {cyc}")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("chunk", [8, DEFAULT_CHUNK])
def test_exception_freezes_within_chunk(backend, chunk, compiled):
    """mm raises FINISH at cycle 18 — with chunk sizes 8 and 32 that is
    mid-chunk both times. The machine must stop exactly there (not at the
    chunk boundary), with the frozen architectural state intact."""
    b, prog, ref = compiled["mm"]
    assert b.n_cycles % chunk != 0
    m = Machine(prog, backend=backend, chunk=chunk)
    st = m.run(m.init_state(), 1000)       # budget far past the exception
    assert m.perf(st)["vcycles"] == b.n_cycles
    assert set(m.exceptions(st).values()) == {FINISH}
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname


def test_no_full_trace_materialized(compiled):
    """The Vcycle graph must not contain a [T, C] trace intermediate —
    the exchange reads the compact [n_sends + 1] buffer instead."""
    import jax
    _, prog, _ = compiled["noc"]
    m = Machine(prog)
    T, C = prog.t_compute, m.C
    carry = tuple(m.init_state())
    jaxpr = jax.make_jaxpr(m._vcycle)(carry)
    shapes = [tuple(v.aval.shape) for eqn in jaxpr.eqns
              for v in eqn.outvars]
    assert (T, C) not in shapes
    assert m.n_sends + 1 < T * C           # the compact buffer is compact


def test_scan_fallback_matches_unrolled(compiled, monkeypatch):
    """Deep schedules (> UNROLL_SLOTS) fall back to a lax.scan over
    specialized windows — same semantics as the unrolled graph."""
    import repro.core.bsp as B
    b, prog, ref = compiled["noc"]
    monkeypatch.setattr(B, "UNROLL_SLOTS", 0)
    m = B.Machine(prog)
    assert not m._unrolled
    st = m.run(m.init_state(), b.n_cycles + 10)
    assert m.perf(st)["vcycles"] == b.n_cycles
    for rname in prog.state_regs:
        assert m.read_reg(st, rname) == ref.reg_value(rname), rname


def test_seed_baseline_still_available(compiled):
    """specialize=False keeps the seed engine alive as the benchmark
    baseline, bit-identical to the fast path."""
    b, prog, _ = compiled["cgra"]
    m_new = Machine(prog)
    m_old = Machine(prog, specialize=False)
    st_new = m_new.run(m_new.init_state(), b.n_cycles + 10)
    st_old = m_old.run(m_old.init_state(), b.n_cycles + 10)
    np.testing.assert_array_equal(np.asarray(st_new.regs),
                                  np.asarray(st_old.regs))
    np.testing.assert_array_equal(np.asarray(st_new.flags),
                                  np.asarray(st_old.flags))
