"""Batched-stimulus execution (PR 2): one compiled Program, B testbenches
per launch. Every batch element must be bit-exact against an independent
single-stimulus run of the same seed on the seed engine
(``Machine(specialize=False)``), exceptions must freeze per element at the
raising Vcycle, the batched Pallas kernel must match the batched jnp graph,
and deep (> UNROLL_SLOTS) schedules must run through the segmented
specialized-scan fallback.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import repro.core.bsp as B
from repro.circuits import FINISH, build
from repro.circuits.common import Planes, make_counter
from repro.core.bsp import BatchedMachine, Machine
from repro.core.compile import Program, compile_circuit
from repro.core.isa import HardwareConfig, Op
from repro.core.netlist import Circuit

ROOT = Path(__file__).resolve().parents[1]
HW = HardwareConfig(grid_width=5, grid_height=5)
SEEDS = [3, 11, 42]
NAMES = ["bc", "mc", "cgra", "vta", "rv32r"]


@pytest.fixture(scope="module")
def compiled():
    out = {}
    for nm in NAMES:
        b = build(nm, "small", seeds=SEEDS)
        prog = compile_circuit(b.circuit, HW)
        out[nm] = (b, prog, b.images(prog))
    return out


@pytest.mark.parametrize("name", NAMES)
def test_batched_matches_independent_seed_runs(name, compiled):
    """Each batch element bit-exact against an independent seed-engine run
    of the same stimulus — registers, scratchpads, flags and counters."""
    b, prog, images = compiled[name]
    bm = BatchedMachine(prog, images=images)
    st = bm.run(bm.init_state(), b.n_cycles + 10)
    for i in range(len(SEEDS)):
        m = Machine(prog, specialize=False)
        s1 = m.run(m.init_state(images=images[i]), b.n_cycles + 10)
        assert set(m.exceptions(s1).values()) == {FINISH}
        assert set(bm.exceptions(st, i).values()) == {FINISH}
        np.testing.assert_array_equal(np.asarray(st.regs[i]),
                                      np.asarray(s1.regs))
        np.testing.assert_array_equal(np.asarray(st.spads[i]),
                                      np.asarray(s1.spads))
        np.testing.assert_array_equal(np.asarray(st.flags[i]),
                                      np.asarray(s1.flags))
        np.testing.assert_array_equal(np.asarray(st.counters[i]),
                                      np.asarray(s1.counters))
        assert bm.perf(st, i)["vcycles"] == b.n_cycles


def test_batched_seeds_share_code(compiled):
    """The whole point of init planes: stimuli differ only in init state,
    never in the compiled code/luts."""
    b0 = build("mc", "small", seeds=[SEEDS[0]])
    p0 = compile_circuit(b0.circuit, HW)
    _, prog, images = compiled["mc"]
    np.testing.assert_array_equal(p0.code, prog.code)
    np.testing.assert_array_equal(p0.luts, prog.luts)
    # and the per-seed images genuinely differ
    assert not np.array_equal(images[0][0], images[1][0])


def _freeze_bench(stops):
    """A circuit whose FINISH cycle is *per-stimulus* (held in the init
    plane), so batch elements freeze at different Vcycles."""
    c = Circuit("freeze")
    planes = Planes(c, len(stops), live=True)
    ctr = make_counter(c, 16)
    stop = planes.hold(stops, 16, "stopc")
    acc = planes.reg(32, [0x1000 * (i + 1) for i in range(len(stops))],
                     "acc")
    c.set_next(acc, acc + (acc >> 3) + 1)
    c.finish_when(ctr.eq(stop), FINISH)
    return c, planes


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_batched_exception_freeze_per_element(backend):
    """Element b freezes exactly at its own raising Vcycle (mid-chunk)
    while the other elements run on to theirs."""
    stops = [5, 17, 29]
    c, planes = _freeze_bench(stops)
    prog = compile_circuit(c, HW)
    images = [prog.init_images(r, m)
              for r, m in zip(planes.regs, planes.mems)]
    bm = BatchedMachine(prog, images=images, backend=backend, chunk=8)
    st = bm.run(bm.init_state(), 100)       # budget far past every stop
    for i, stop in enumerate(stops):
        assert set(bm.exceptions(st, i).values()) == {FINISH}
        assert bm.perf(st, i)["vcycles"] == stop + 1
        m = Machine(prog, specialize=False)
        s1 = m.run(m.init_state(images=images[i]), 100)
        np.testing.assert_array_equal(np.asarray(st.regs[i]),
                                      np.asarray(s1.regs))
        np.testing.assert_array_equal(np.asarray(st.flags[i]),
                                      np.asarray(s1.flags))


def test_batched_pallas_matches_jnp(compiled):
    b, prog, images = compiled["mc"]
    bj = BatchedMachine(prog, images=images)
    bp = BatchedMachine(prog, images=images, backend="pallas",
                        interpret=True)
    stj = bj.run(bj.init_state(), b.n_cycles + 10)
    stp = bp.run(bp.init_state(), b.n_cycles + 10)
    for leaf_j, leaf_p in zip(stj, stp):
        np.testing.assert_array_equal(np.asarray(leaf_j),
                                      np.asarray(leaf_p))


def test_batched_scan_fallback_matches_unrolled(compiled, monkeypatch):
    b, prog, images = compiled["bc"]
    bu = BatchedMachine(prog, images=images)
    assert bu._unrolled
    monkeypatch.setattr(B, "UNROLL_SLOTS", 0)
    bf = BatchedMachine(prog, images=images)
    assert not bf._unrolled
    stu = bu.run(bu.init_state(), b.n_cycles + 10)
    stf = bf.run(bf.init_state(), b.n_cycles + 10)
    np.testing.assert_array_equal(np.asarray(stu.regs),
                                  np.asarray(stf.regs))
    np.testing.assert_array_equal(np.asarray(stu.flags),
                                  np.asarray(stf.flags))


# ----------------------------------------------------------------------
# deep schedules: > UNROLL_SLOTS slots exercise the segmented scan
# fallback for real (no monkeypatching)
# ----------------------------------------------------------------------

def _deep_program(T=4400, C=3):
    """Hand-built Program with T > UNROLL_SLOTS slots and two opcode
    phases (ADD/XOR then MUL/SUB/SRL), instructions spaced 8 slots apart
    (>= raw_latency), plus one cross-core SEND."""
    assert T > B.UNROLL_SLOTS
    hw = HardwareConfig(grid_width=2, grid_height=2)
    NC = hw.num_cores
    rng = np.random.default_rng(7)
    code = np.zeros((NC, T, 7), np.int32)
    reg_init = np.zeros((NC, hw.num_regs), np.uint16)
    reg_init[:, 1:9] = rng.integers(1, 1 << 16, (NC, 8))

    def put(core, t, op, dst, s1=0, s2=0, imm=0):
        code[core, t] = (int(op), dst, s1, s2, 0, 0, imm)

    half = T // 2
    for t in range(8, half, 8):
        put(0, t, Op.ADD, 2, 1, 2)
        put(1, t, Op.XOR, 3, 3, 1)
        put(2, t, Op.ADD, 2, 2, 1)
    for t in range(half + 8, T - 16, 8):
        put(0, t, Op.MUL, 4, 2, 1)
        put(1, t, Op.SUB, 2, 2, 1)
        put(2, t, Op.SRL, 5, 2, 0, 3)
    # one cross-core SEND near the end of the schedule
    ts = T - 8
    put(1, ts, Op.SEND, 0, 2)
    return Program(
        name="deep", hw=hw, code=code,
        luts=np.zeros((NC, hw.num_luts, 16), np.uint16),
        reg_init=reg_init,
        spad_init=np.zeros((NC, 1), np.uint16),
        gmem_init=np.zeros((1,), np.uint16),
        xchg_src_core=np.array([1], np.int32),
        xchg_src_slot=np.array([ts], np.int32),
        xchg_dst_core=np.array([0], np.int32),
        xchg_dst_reg=np.array([9], np.int32),
        t_compute=T, vcpl=T, used_cores=C, outputs={}, state_regs={})


def test_deep_schedule_uses_segmented_fallback():
    """A real > UNROLL_SLOTS schedule: the specialized engine must pick
    the segmented scan fallback (one specialized body per opcode-set run,
    all-NOP windows dropped) and stay bit-exact against the seed engine."""
    prog = _deep_program()
    m = Machine(prog)
    assert not m._unrolled
    assert 2 <= len(m._segments) <= B.MAX_SCAN_SEGMENTS
    # windows actually executed are far fewer than T/W: NOP gaps dropped
    n_windows = sum(wc.shape[0] for _, wc, _ in m._segments)
    assert n_windows < prog.t_compute // m.W // 2
    # the two phases got *different* specialized bodies: no single segment
    # covers the program's whole opcode set
    assert len(set(m._segment_ops)) >= 2
    assert all(ops < m.op_set for ops in m._segment_ops)
    st = m.run(m.init_state(), 5)
    seed = Machine(prog, specialize=False)
    ss = seed.run(seed.init_state(), 5)
    np.testing.assert_array_equal(np.asarray(st.regs), np.asarray(ss.regs))
    assert m.perf(st)["vcycles"] == 5


def test_deep_schedule_batched():
    prog = _deep_program()
    base = prog.reg_init
    images = []
    for k in range(2):
        ri = base.copy()
        ri[:, 1:9] = np.random.default_rng(100 + k).integers(
            1, 1 << 16, ri[:, 1:9].shape)
        images.append((ri, prog.spad_init, prog.gmem_init))
    bm = BatchedMachine(prog, images=images)
    st = bm.run(bm.init_state(), 4)
    for i in range(2):
        seed = Machine(prog, specialize=False)
        s1 = seed.run(seed.init_state(images=images[i]), 4)
        np.testing.assert_array_equal(np.asarray(st.regs[i]),
                                      np.asarray(s1.regs))


# ----------------------------------------------------------------------
# batched multi-device exchange (8 host devices, subprocess)
# ----------------------------------------------------------------------

def test_batched_grid_machine_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src")
    body = """
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.circuits import build, FINISH
        from repro.core.isa import HardwareConfig
        from repro.core.compile import compile_circuit
        from repro.core.grid import GridMachine
        from repro.core.bsp import BatchedMachine

        b = build("rv32r", "small", seeds=[5, 6, 7])
        prog = compile_circuit(b.circuit,
                               HardwareConfig(grid_width=4, grid_height=4))
        images = b.images(prog)
        mesh = Mesh(np.array(jax.devices()), ("cores",))
        gm = GridMachine(prog, mesh, images=images)
        # the exchange must actually cross devices for this to mean much
        cl = gm.cl
        cross = (prog.xchg_src_core // cl) != (prog.xchg_dst_core // cl)
        assert cross.any(), "rv32r must exercise cross-device SENDs"
        st = gm.run(gm.init_state(), b.n_cycles + 10)
        bm = BatchedMachine(prog, images=images)
        sm = bm.run(bm.init_state(), b.n_cycles + 10)
        C = prog.used_cores
        np.testing.assert_array_equal(np.asarray(st.regs)[:, :C],
                                      np.asarray(sm.regs))
        np.testing.assert_array_equal(np.asarray(st.flags)[:, :C],
                                      np.asarray(sm.flags))
        for i in range(3):
            assert set(gm.exceptions(st, i).values()) == {FINISH}
            assert gm.perf(st, i)["vcycles"] == b.n_cycles
        # b=None accessors on batched state: per-element list / aggregate
        assert len(gm.exceptions(st)) == 3
        assert gm.perf(st)["vcycles"] == 3 * b.n_cycles
        assert gm.read_reg(st, "acc0") == gm.read_reg(st, "acc0", 0)
        print("GRIDBATCH-OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "GRIDBATCH-OK" in r.stdout
