"""Manual-collective (static-BSP) data-parallel trainer."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_bucketing_properties():
    import jax.numpy as jnp
    from repro.distributed.overlap import make_buckets
    params = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((16,)),
              "c": jnp.zeros((512, 512)), "d": jnp.zeros((8, 8))}
    buckets = make_buckets(params, bucket_bytes=1 << 20)
    flat_n = len([1 for b in buckets for _ in b])
    assert flat_n == 4                     # every leaf exactly once
    assert sorted(i for b in buckets for i in b) == [0, 1, 2, 3]
    # largest leaf first
    import jax
    leaves = jax.tree_util.tree_leaves(params)
    assert leaves[buckets[0][0]].size == 1024 * 1024


def test_manual_dp_matches_pjit_loss():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, functools
        from repro.configs import SMOKE
        from repro.models.model import build
        from repro.optim import adamw
        from repro.distributed.overlap import make_manual_dp_step
        from repro.data.pipeline import PipelineConfig, TokenPipeline

        cfg = SMOKE["qwen3-0.6b"]
        model = build(cfg)
        params = model.init(jax.random.key(0))
        opt = adamw.init(params)
        mesh = jax.make_mesh((4,), ("data",))
        step = make_manual_dp_step(model.loss, adamw.apply, mesh)
        pipe = TokenPipeline(PipelineConfig(cfg.vocab, 32, 8))
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        p2, o2, m2 = jax.jit(step)(params, opt, batch)
        # reference: single-process full-batch step
        (l_ref, _), g = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        assert abs(float(m2["loss"]) - float(l_ref)) < 5e-2, \\
            (float(m2["loss"]), float(l_ref))
        print("OVERLAP-OK")
    """)], capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OVERLAP-OK" in r.stdout
