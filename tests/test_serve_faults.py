"""Fault tolerance of the serving stack (``repro.serve`` + ``faults``).

Contracts under test:

- the fault-injection harness is deterministic (same seed → same fire
  sequence), zero-armed by default, and honours its ``times`` caps;
- poison isolation: a batched launch containing a poisoned stimulus is
  bisected so every healthy rider still gets its bit-exact ``OK`` and
  only the culprit gets ``ERROR``/``POISONED``;
- transient launch faults are retried with backoff and never surface to
  riders; the per-batch launch budget bounds a pathological batch;
- the session circuit breaker opens after consecutive compile failures
  (fast-fail ``UNAVAILABLE`` + ``retry_after_s``, no compile attempted),
  half-opens after the cooldown, and closes on a successful probe;
- launch-failure convoys open the breaker too, and one healthy rider in
  a poisoned batch keeps it closed;
- ``close(drain=True)`` answers every queued rider before shutdown and
  admission during/after drain is answered ``DRAINING``; abrupt
  ``close()`` still terminates queued riders (no abandoned futures);
- a client disconnect mid-batch resolves all server-side futures and
  leaves the daemon healthy; per-connection in-flight is capped;
- the timeout-vs-launch race resolves every future exactly once;
- protocol v2 error codes round-trip the wire and legacy (v1) messages
  still decode.
"""
import asyncio
import time

import pytest

from repro.serve import (BatchPolicy, Batcher, CircuitBreaker, DRAINING,
                         ERR_COMPILE_FAILED, ERR_DRAINING, ERR_POISONED,
                         ERR_TIMEOUT, ERR_UNAVAILABLE, ERROR, FaultPlan,
                         FaultSpec, InjectedFault, OK, Pending,
                         RetryPolicy, SessionManager, SimRequest,
                         SimResponse, SimServer, TIMEOUT, UNAVAILABLE,
                         decode_response, encode_request, encode_response)
from repro.serve import faults as faultlib
from repro.serve.__main__ import chaos_drill

HWD = {"grid_width": 5, "grid_height": 5}


@pytest.fixture(scope="module")
def cache_dir(tmp_path_factory):
    """One on-disk compile cache for the module: canonical designs
    compile once, later tests warm-start."""
    return str(tmp_path_factory.mktemp("serve_faults_cache"))


def _req(name, seed, **kw):
    return SimRequest(name, scale="small", seed=seed, hw=HWD, **kw)


def _server(cache_dir, *, faults=None, policy=None, sessions_kw=None,
            retry=None):
    sm = SessionManager(cache=cache_dir, faults=faults,
                        **(sessions_kw or {}))
    return SimServer(
        sessions=sm,
        policy=policy or BatchPolicy(max_batch=8, max_wait_s=0.25),
        faults=faults,
        retry=retry or RetryPolicy(backoff_base_s=0.005))


# ----------------------------------------------------------------------
# the harness itself (no jax, no asyncio)
# ----------------------------------------------------------------------

def test_faultplan_deterministic_and_capped():
    def fires(seed, n=200, p=0.3, times=None):
        plan = FaultPlan(seed, launch=FaultSpec(p=p, times=times,
                                                transient=True))
        out = []
        for i in range(n):
            try:
                plan.check(faultlib.LAUNCH, seeds=[i])
                out.append(0)
            except InjectedFault as f:
                assert f.transient and f.site == faultlib.LAUNCH
                out.append(1)
        return out, plan

    a, plan_a = fires(7)
    b, _ = fires(7)
    c, _ = fires(8)
    assert a == b                       # same seed → same schedule
    assert a != c                       # (with overwhelming probability)
    assert plan_a.fired()["launch"] == sum(a)
    assert plan_a.checked()["launch"] == 200

    capped, plan_cap = fires(7, times=3)
    assert sum(capped) == 3             # times cap: storms dry up
    assert plan_cap.stats()["fired"]["launch"] == 3

    # disabled plan never fires and never draws
    quiet = FaultPlan(7)
    for i in range(50):
        quiet.check(faultlib.COMPILE)
        quiet.check(faultlib.LAUNCH, seeds=[i])
    assert sum(quiet.fired().values()) == 0


def test_faultplan_poison_is_stateless_and_deterministic():
    plan = FaultPlan(0, launch=FaultSpec(poison_seeds=frozenset({13})))
    for _ in range(3):
        with pytest.raises(InjectedFault) as ei:
            plan.check(faultlib.LAUNCH, seeds=[11, 13, 15])
        assert ei.value.poisoned == (13,)
        assert not ei.value.transient
    plan.check(faultlib.LAUNCH, seeds=[11, 15])     # poison-free: quiet
    assert plan.fired()["launch"] == 3


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(threshold=2, cooldown_s=0.05)
    assert br.allow() == (True, 0.0)
    br.record_failure()
    assert br.state == br.CLOSED and br.allow()[0]
    br.record_failure()                             # threshold hit
    assert br.state == br.OPEN
    ok, retry_after = br.allow()
    assert not ok and retry_after > 0.0
    time.sleep(0.06)
    ok, _ = br.allow()                              # half-open probe
    assert ok and br.state == br.HALF_OPEN
    assert not br.allow()[0]                        # only one probe
    br.record_failure()                             # probe failed
    assert br.state == br.OPEN
    assert br.snapshot()["opens"] == 2
    time.sleep(0.15)                                # doubled cooldown
    assert br.allow()[0]
    br.record_success()
    assert br.state == br.CLOSED and br.failures == 0
    assert br.snapshot() == {"state": "closed", "failures": 0, "opens": 0,
                             "retry_after_s": 0.0}


# ----------------------------------------------------------------------
# poison isolation + retries (full daemon, small circuits)
# ----------------------------------------------------------------------

def test_bisection_isolates_exactly_the_poison_seed(cache_dir):
    """Five coalesced riders, seed 13 poisoned: the four healthy riders
    get OK results bit-exact vs a fault-free server; only 13 errors, and
    the session breaker stays closed (the build is healthy)."""
    seeds = [11, 12, 13, 14, 15]
    plan = FaultPlan(0, launch=FaultSpec(poison_seeds=frozenset({13})))

    async def go(faults):
        server = _server(cache_dir, faults=faults)
        try:
            resps = await asyncio.gather(
                *(server.submit(_req("mc", s)) for s in seeds))
            return resps, server.stats()
        finally:
            await server.close()

    poisoned, stats = asyncio.run(go(plan))
    clean, _ = asyncio.run(go(None))
    assert all(r.ok for r in clean)
    by_seed = dict(zip(seeds, poisoned))
    assert by_seed[13].status == ERROR
    assert by_seed[13].error_code == ERR_POISONED
    for s, ref in zip(seeds, clean):
        if s == 13:
            continue
        got = by_seed[s]
        assert got.ok, (s, got.error)
        assert got.result.cycles == ref.result.cycles
        assert got.result.registers == ref.result.registers
        assert got.result.outputs == ref.result.outputs
    assert stats["launch"]["bisections"] >= 1
    assert stats["launch"]["poisoned"] == 1
    # healthy riders succeeded → the identity is not quarantined
    assert stats["sessions"]["breakers"]["mc/small"]["state"] == "closed"


def test_transient_launch_fault_retried_invisibly(cache_dir):
    """times-capped transient launch faults: riders never see them."""
    plan = FaultPlan(0, launch=FaultSpec(p=1.0, times=2, transient=True))

    async def go():
        server = _server(cache_dir, faults=plan)
        try:
            return (await asyncio.gather(
                *(server.submit(_req("mc", 30 + i)) for i in range(3))),
                dict(server.launch_stats))
        finally:
            await server.close()

    resps, launch_stats = asyncio.run(go())
    assert all(r.ok and r.result.finished for r in resps), \
        [r.error for r in resps]
    assert plan.fired()["launch"] == 2
    assert launch_stats["retries"] == 2
    assert launch_stats["bisections"] == 0


def test_launch_budget_bounds_pathological_batch(cache_dir):
    """Every stimulus poisoned: bisection cannot save anyone, the launch
    budget caps device occupancy, and all riders get terminal ERRORs."""
    seeds = list(range(60, 68))
    plan = FaultPlan(0, launch=FaultSpec(poison_seeds=frozenset(seeds)))

    async def go():
        server = _server(cache_dir, faults=plan,
                         retry=RetryPolicy(max_extra_launches=4,
                                           backoff_base_s=0.001))
        try:
            resps = await asyncio.gather(
                *(server.submit(_req("mc", s)) for s in seeds))
            return resps, dict(server.launch_stats)
        finally:
            await server.close()

    resps, launch_stats = asyncio.run(go())
    assert all(r.status == ERROR for r in resps)
    assert all(r.error_code in (ERR_POISONED, "LAUNCH_FAILED")
               for r in resps)
    assert launch_stats["attempts"] <= 5          # 1 + max_extra_launches
    assert launch_stats["budget_exhausted"] >= 1


# ----------------------------------------------------------------------
# circuit breaker through the daemon
# ----------------------------------------------------------------------

def test_breaker_quarantines_failing_compile_and_recovers(cache_dir):
    """3 persistent compile faults: two requests pay a compile attempt
    (ERROR/COMPILE_FAILED), the third fast-fails UNAVAILABLE with a
    retry-after, the half-open probe re-fails and re-opens, and once the
    fault dries up the next probe compiles and the breaker closes."""
    plan = FaultPlan(0, compile=FaultSpec(p=1.0, times=3))

    async def go():
        server = _server(
            cache_dir, faults=plan,
            sessions_kw=dict(breaker_threshold=2, breaker_cooldown_s=0.1,
                             compile_retries=0))
        sm = server.sessions
        out = {}
        try:
            out["r1"] = await server.submit(_req("bc", 1))
            out["r2"] = await server.submit(_req("bc", 2))
            lookups_before = sm.counters["lookups"]
            fails_before = sm.counters["compile_failures"]
            t0 = time.monotonic()
            out["r3"] = await server.submit(_req("bc", 3))
            out["r3_elapsed"] = time.monotonic() - t0
            # no compile was attempted for the fast-fail
            assert sm.counters["compile_failures"] == fails_before
            assert sm.counters["lookups"] == lookups_before + 1
            out["open_snap"] = sm.stats()["breakers"]["bc/small"]
            await asyncio.sleep(0.12)              # past cooldown
            out["r4"] = await server.submit(_req("bc", 4))   # probe: fault 3
            out["reopen_snap"] = sm.stats()["breakers"]["bc/small"]
            await asyncio.sleep(0.25)              # doubled cooldown
            out["r5"] = await server.submit(_req("bc", 5))   # probe: healthy
            out["closed_snap"] = sm.stats()["breakers"]["bc/small"]
            return out
        finally:
            await server.close()

    out = asyncio.run(go())
    for k in ("r1", "r2", "r4"):
        assert out[k].status == ERROR and \
            out[k].error_code == ERR_COMPILE_FAILED, (k, out[k])
    assert out["r3"].status == UNAVAILABLE
    assert out["r3"].error_code == ERR_UNAVAILABLE
    assert out["r3"].retry_after_s > 0.0
    assert out["r3_elapsed"] < 0.05                # fast-fail, no compile
    assert out["open_snap"]["state"] == "open"
    assert out["reopen_snap"]["state"] == "open"
    assert out["reopen_snap"]["opens"] == 2
    assert out["r5"].ok and out["r5"].result.finished
    assert out["closed_snap"]["state"] == "closed"
    assert plan.fired()["compile"] == 3


def test_breaker_opens_on_launch_convoy(cache_dir):
    """Consecutive all-fail launches quarantine a resident session too:
    the broken build stops convoying the daemon."""
    plan = FaultPlan(0, launch=FaultSpec(p=1.0))    # every launch dies

    async def go():
        server = _server(
            cache_dir, faults=plan,
            policy=BatchPolicy(max_batch=2, max_wait_s=0.02),
            sessions_kw=dict(breaker_threshold=2, breaker_cooldown_s=5.0),
            retry=RetryPolicy(max_attempts=1, max_extra_launches=2,
                              backoff_base_s=0.001))
        try:
            r1 = await server.submit(_req("mc", 70))
            r2 = await server.submit(_req("mc", 71))
            r3 = await server.submit(_req("mc", 72))
            return r1, r2, r3, server.sessions.stats()
        finally:
            await server.close()

    r1, r2, r3, sess_stats = asyncio.run(go())
    assert r1.status == ERROR and r2.status == ERROR
    assert r3.status == UNAVAILABLE and r3.retry_after_s > 0.0
    assert sess_stats["breakers"]["mc/small"]["state"] == "open"
    assert sess_stats["counters"]["unavailable"] == 1


# ----------------------------------------------------------------------
# drain / shutdown
# ----------------------------------------------------------------------

def test_drained_close_answers_queued_riders(cache_dir):
    """Riders queued inside an open admission window are flushed and
    answered OK by close(drain=True); admission during and after the
    drain answers DRAINING."""
    async def go():
        server = _server(cache_dir,
                         policy=BatchPolicy(max_batch=8, max_wait_s=0.3))
        riders = [asyncio.ensure_future(server.submit(_req("mc", 80 + i)))
                  for i in range(3)]
        await asyncio.sleep(0.05)       # admitted, window still open
        assert not any(r.done() for r in riders)
        await server.close(drain=True)
        assert server.state == "closed"
        resps = await asyncio.gather(*riders)
        late = await server.submit(_req("mc", 99))
        return resps, late

    resps, late = asyncio.run(go())
    assert all(r.ok and r.result.finished for r in resps), \
        [r.error for r in resps]
    assert all(r.batch == 3 for r in resps)        # flushed as one batch
    assert late.status == DRAINING
    assert late.error_code == ERR_DRAINING


def test_abrupt_close_still_terminates_queued_riders(cache_dir):
    """close() without drain: queued riders get a DRAINING response
    instead of a forever-pending future."""
    async def go():
        server = _server(cache_dir,
                         policy=BatchPolicy(max_batch=8, max_wait_s=5.0))
        # ensure the session is hot so riders reach the queue instantly
        first = await asyncio.wait_for(
            asyncio.ensure_future(server.submit(_req("bc", 90))), 60)
        assert first.ok
        riders = [asyncio.ensure_future(server.submit(_req("bc", 91 + i)))
                  for i in range(3)]
        await asyncio.sleep(0.05)       # inside the 5s admission window
        await server.close()            # abrupt
        return await asyncio.wait_for(asyncio.gather(*riders), 10)

    resps = asyncio.run(go())
    assert [r.status for r in resps] == [DRAINING] * 3
    assert all(r.error_code == ERR_DRAINING for r in resps)


def test_timeout_vs_launch_race_single_resolution():
    """A rider whose deadline expires while its batch is mid-launch is
    resolved exactly once (no InvalidStateError, no double-resolve) —
    pure-batcher test with a slow launch."""
    async def go():
        resolved = []

        async def launch(key, batch):
            await asyncio.sleep(0.1)    # deadline of p2 passes in here
            for p in batch:
                if not p.future.done():
                    p.future.set_result(("ok", p.req.seed))

        def on_timeout(key, expired):
            for p in expired:
                if not p.future.done():
                    p.future.set_result(("timeout", p.req.seed))

        b = Batcher(BatchPolicy(max_batch=4, max_wait_s=0.02),
                    launch, on_timeout)
        loop = asyncio.get_running_loop()
        pend = []
        for i, deadline in enumerate([None, 0.05, None]):
            p = Pending(req=SimRequest("x", seed=i),
                        future=loop.create_future(),
                        deadline=(time.monotonic() + deadline
                                  if deadline else None))
            p.future.add_done_callback(
                lambda f: resolved.append(f.result()))
            pend.append(p)
            b.submit("k", p)
        out = await asyncio.gather(*(p.future for p in pend))
        # a second resolution attempt would raise InvalidStateError and
        # surface through the drain task / gather
        await asyncio.sleep(0.15)
        await b.close()
        return out, resolved, b.outstanding

    out, resolved, outstanding = asyncio.run(go())
    assert sorted(resolved) == sorted(out)
    assert len(resolved) == 3                      # exactly once each
    assert [s for s, _ in out] == ["ok", "ok", "ok"] or \
        ("timeout", 1) in out                      # p2 raced; either side
    assert outstanding == 0


# ----------------------------------------------------------------------
# TCP hardening
# ----------------------------------------------------------------------

def test_tcp_disconnect_mid_batch_resolves_all(cache_dir):
    """A client that pipelines requests and vanishes mid-batch must not
    kill the handler or leak outstanding work; the daemon stays healthy
    for the next client."""
    async def go():
        server = _server(cache_dir,
                         policy=BatchPolicy(max_batch=8, max_wait_s=0.2))
        try:
            tcp = await server.serve_tcp("127.0.0.1", 0)
            port = tcp.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)
            for i in range(4):
                writer.write(encode_request(_req("mc", 300 + i)))
            await writer.drain()
            writer.close()              # vanish before any response
            try:
                await writer.wait_closed()
            except Exception:
                pass
            # wait for the orphaned batch to finish server-side
            for _ in range(400):
                if server.batcher.outstanding == 0:
                    break
                await asyncio.sleep(0.05)
            assert server.batcher.outstanding == 0
            # the daemon is still healthy for the next client
            r2, w2 = await asyncio.open_connection("127.0.0.1", port)
            w2.write(encode_request(_req("mc", 310)))
            await w2.drain()
            resp = decode_response(
                await asyncio.wait_for(r2.readline(), 60))
            w2.close()
            return resp
        finally:
            await server.close()

    resp = asyncio.run(go())
    assert resp.ok and resp.result.finished


def test_tcp_write_fault_isolated_to_connection(cache_dir):
    """An injected TCP write fault (broken pipe) kills that connection's
    writes only — the server and other connections are unaffected."""
    plan = FaultPlan(0, tcp_write=FaultSpec(p=1.0, times=1))

    async def go():
        server = _server(cache_dir, faults=plan,
                         policy=BatchPolicy(max_batch=4, max_wait_s=0.05))
        try:
            tcp = await server.serve_tcp("127.0.0.1", 0)
            port = tcp.sockets[0].getsockname()[1]
            r1, w1 = await asyncio.open_connection("127.0.0.1", port)
            w1.write(encode_request(_req("bc", 400)))
            await w1.drain()
            # the write fault eats the response: readline sees EOF/hangs,
            # bounded by the connection staying open → use a timeout
            try:
                line = await asyncio.wait_for(r1.readline(), 2.0)
            except asyncio.TimeoutError:
                line = b""
            w1.close()
            # fresh connection works (times=1 exhausted the fault)
            r2, w2 = await asyncio.open_connection("127.0.0.1", port)
            w2.write(encode_request(_req("bc", 401)))
            await w2.drain()
            resp = decode_response(
                await asyncio.wait_for(r2.readline(), 60))
            w2.close()
            return line, resp
        finally:
            await server.close()

    line, resp = asyncio.run(go())
    assert line == b""                  # first response was eaten
    assert resp.ok and resp.result.finished
    assert plan.fired()["tcp_write"] == 1


def test_tcp_inflight_cap_still_answers_everything(cache_dir):
    """A pipelined burst far above the per-connection in-flight cap is
    served completely — the cap converts task-set growth into read
    backpressure, not loss."""
    async def go():
        server = _server(cache_dir,
                         policy=BatchPolicy(max_batch=8, max_wait_s=0.05))
        server.max_inflight_per_conn = 4
        try:
            tcp = await server.serve_tcp("127.0.0.1", 0)
            port = tcp.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)
            n = 12
            for i in range(n):
                writer.write(encode_request(_req("mc", 500 + i)))
            await writer.drain()
            resps = []
            for _ in range(n):
                resps.append(decode_response(
                    await asyncio.wait_for(reader.readline(), 120)))
            writer.close()
            return resps
        finally:
            await server.close()

    resps = asyncio.run(go())
    assert len(resps) == 12
    assert all(r.ok and r.result.finished for r in resps)


# ----------------------------------------------------------------------
# protocol v2
# ----------------------------------------------------------------------

def test_error_code_wire_roundtrip_and_legacy_decode():
    resp = SimResponse("r1", UNAVAILABLE, error="quarantined",
                       error_code=ERR_UNAVAILABLE, retry_after_s=1.5)
    line = encode_response(resp)
    back = decode_response(line)
    assert back.status == UNAVAILABLE
    assert back.error_code == ERR_UNAVAILABLE
    assert back.retry_after_s == 1.5

    # OK responses do not put the v2 failure fields on the wire at all
    ok_line = encode_response(SimResponse("r2", OK))
    assert b"error_code" not in ok_line and b"retry_after_s" not in ok_line

    # a legacy v1 message (no error_code) decodes with the fields absent
    legacy = b'{"v": 1, "rid": "r3", "status": "error", "error": "boom"}\n'
    old = decode_response(legacy)
    assert old.status == ERROR and old.error == "boom"
    assert old.error_code is None and old.retry_after_s is None

    with pytest.raises(ValueError):
        decode_response(b'{"v": 3, "rid": "r4", "status": "ok"}\n')

    # timeouts carry their code end-to-end too
    t = decode_response(encode_response(
        SimResponse("r5", TIMEOUT, error_code=ERR_TIMEOUT)))
    assert t.error_code == ERR_TIMEOUT


# ----------------------------------------------------------------------
# mini chaos drill (the CI gate runs the big one via __main__)
# ----------------------------------------------------------------------

def test_chaos_mini_drill(cache_dir):
    """40 requests under the aggressive plan: exactly one terminal
    response each, poison isolated, healthy traffic never ERRORs, then a
    drained close."""
    plan = FaultPlan.chaos(seed=1, p=0.15, poison_seeds={666, 667})

    async def go():
        server = _server(
            cache_dir, faults=plan,
            policy=BatchPolicy(max_batch=16, max_wait_s=0.05),
            sessions_kw=dict(breaker_cooldown_s=0.2, compile_retries=6),
            retry=RetryPolicy(max_attempts=8, backoff_base_s=0.005,
                              max_extra_launches=32))
        rc = await chaos_drill(server, ["mc", "bc"], "small", 40, plan)
        await server.close(drain=True)
        return rc, server.state

    rc, state = asyncio.run(go())
    assert rc == 0
    assert state == "closed"
