"""The unified ``repro.sim`` front-end: Engine-protocol parity across all
five executors, facade auto-selection, the on-disk compile cache, and the
circuits.build error surface.

Extends the ``test_engine_fastpath`` patterns one level up: instead of
hand-driving each engine class with its own calling convention, every
engine is driven *through the protocol* and must produce the identical
uniform ``RunResult``.
"""
import numpy as np
import pytest

import repro.sim as sim
from repro.circuits import CIRCUITS, SCALES, build
from repro.core import Circuit, HardwareConfig

HW = HardwareConfig(grid_width=5, grid_height=5)
# three circuits spanning the schedule space: dense compute (mm), sparse
# walkers (mc), cross-core network traffic (noc)
PARITY_NAMES = ["mm", "mc", "noc"]


@pytest.fixture(scope="module")
def sims():
    return {nm: sim.compile(nm, HW, scale="small") for nm in PARITY_NAMES}


def _single_device_mesh():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("cores",))


def _engines(s):
    """Every conforming engine over one compiled Program (the five
    executor classes; pallas rides the Machine adapter)."""
    engines = {
        "machine": s.engine("machine"),
        "seed": s.engine("seed"),
        "isa": s.engine("isa"),
        "batched": s.engine("batched", batch=2),
        "grid": s.engine("grid", mesh=_single_device_mesh()),
    }
    if not s.program.has_global:
        engines["pallas"] = s.engine("pallas")
    return engines


@pytest.mark.parametrize("name", PARITY_NAMES)
def test_engine_adapter_parity(name, sims):
    """The same compiled Program through every engine via the protocol:
    identical registers, outputs, exceptions and finish cycle."""
    s = sims[name]
    n = s.default_cycles()
    results = {}
    for kind, eng in _engines(s).items():
        assert isinstance(eng, sim.Engine)
        results[kind] = eng.run(n)
    ref = results["machine"]
    assert ref.finished, ref.exceptions
    for kind, r in results.items():
        assert r.cycles == ref.cycles, kind
        assert r.exceptions == ref.exceptions, kind
        assert r.registers == ref.registers, kind
        assert r.outputs == ref.outputs, kind
    # ...and the netlist oracle agrees on every probe it shares
    oracle = s.engine("oracle").run(n)
    assert oracle.cycles == ref.cycles
    assert oracle.exception_ids == ref.exception_ids
    assert oracle.registers == ref.registers


@pytest.mark.parametrize("name", PARITY_NAMES)
def test_batched_elements_match_singles(name, sims):
    """run_batch's per-element results equal independent single runs."""
    s = sims[name]
    n = s.default_cycles()
    batched = s.engine("batched", batch=3).run_batch(n)
    single = s.engine("machine").run(n)
    for b, r in enumerate(batched):
        assert r.batch_index == b
        assert r.registers == single.registers
        assert r.exceptions == single.exceptions


def test_outputs_probed_uniformly():
    """Host-visible outputs land in RunResult.outputs on every engine
    (the benches are EXPECT-only, so build a circuit with an output)."""
    c = Circuit("outs")
    cnt = c.reg(16, init=0, name="cnt")
    c.set_next(cnt, cnt + 3)
    c.output("triple", cnt)
    c.finish_when(cnt.eq(30), eid=1)
    s = sim.compile(c, HW)
    for kind in ("machine", "isa", "oracle"):
        r = s.run(64, engine=kind)
        assert r.finished
        assert r.outputs["triple"] == 30, kind


def test_facade_auto_selection():
    s1 = sim.compile("mc", HW, scale="small")
    assert isinstance(s1.engine(), sim.MachineEngine)
    sb = sim.compile("mc", HW, scale="small", seeds=[5, 6])
    assert sb.batch == 2
    eng = sb.engine()
    assert isinstance(eng, sim.BatchedEngine) and eng.batch == 2
    res = sb.run()
    assert isinstance(res, list) and len(res) == 2
    assert all(r.finished for r in res)
    assert isinstance(s1.run(), sim.RunResult)
    assert isinstance(
        sb.engine(mesh=_single_device_mesh()), sim.GridEngine)


def test_seeded_stimuli_differ_but_share_code():
    """seeds= hides the init-plane plumbing: per-seed registers differ at
    stop time while code/luts are the one compiled binary."""
    sb = sim.compile("mc", HW, scale="small", seeds=[5, 6])
    res = sb.run()
    assert res[0].registers != res[1].registers  # price walks differ
    imgs = sb.images()
    assert len(imgs) == 2
    assert not np.array_equal(imgs[0][0], imgs[1][0])


def test_compile_cache_hits_skip_middle_end(tmp_path, monkeypatch):
    """Warm sim.compile must not invoke the compiler at all: the Program
    comes off disk with the cache_hit stats flag set, bit-identically."""
    import repro.sim.facade as facade
    calls = []
    real = facade.compile_circuit
    monkeypatch.setattr(facade, "compile_circuit",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    cold = sim.compile("mc", HW, scale="small", cache=tmp_path)
    assert calls == [1] and not cold.cache_hit
    warm = sim.compile("mc", HW, scale="small", cache=tmp_path)
    assert calls == [1], "cache hit must skip compile_circuit entirely"
    assert warm.cache_hit and warm.program.stats["cache_hit"]
    np.testing.assert_array_equal(warm.program.code, cold.program.code)
    r_cold = cold.run()
    r_warm = warm.run()
    assert r_warm.registers == r_cold.registers
    assert r_warm.exceptions == r_cold.exceptions


def test_cache_key_sensitivity(tmp_path):
    """Different hardware or compiler options never share a cache entry;
    an identical rebuild of the same design does."""
    b1 = build("mc", "small")
    b2 = build("mc", "small")     # independent build, same structure
    k = sim.cache_key(b1.circuit, HW)
    assert sim.cache_key(b2.circuit, HW) == k
    assert sim.cache_key(
        b1.circuit, HardwareConfig(grid_width=4, grid_height=4)) != k
    assert sim.cache_key(b1.circuit, HW, optimize=False) != k
    assert sim.cache_key(b1.circuit, HW, use_luts=False) != k
    b3 = build("mc", "small", n_walkers=2)
    assert sim.cache_key(b3.circuit, HW) != k


def test_corrupt_cache_entry_is_a_miss(tmp_path):
    cold = sim.compile("mc", HW, scale="small", cache=tmp_path)
    entry = sim.CompileCache(tmp_path).path(cold.meta["cache_key"])
    entry.write_bytes(b"not an npz")
    again = sim.compile("mc", HW, scale="small", cache=tmp_path)
    assert not again.cache_hit
    assert again.run().finished


def test_build_unknown_name_lists_available():
    with pytest.raises(KeyError) as e:
        build("warp_drive")
    msg = str(e.value)
    for nm in CIRCUITS:
        assert nm in msg
    for sc in SCALES:
        assert sc in msg


def test_build_unknown_scale_lists_valid():
    with pytest.raises(KeyError, match="full"):
        build("mc", scale="enormous")


def test_bench_compile_entry_point():
    s = build("mc", "small").compile(HW)
    assert s.n_cycles == s.bench.n_cycles
    assert s.run().finished


def test_loaded_simulation_needs_cycles_and_has_no_oracle(tmp_path):
    s = sim.compile("mc", HW, scale="small")
    p = tmp_path / "mc.npz"
    s.save(p)
    s2 = sim.load(p)
    with pytest.raises(ValueError, match="cycles"):
        s2.run()
    with pytest.raises(ValueError, match="oracle"):
        s2.engine("oracle")
    assert s2.run(s.default_cycles()).registers == s.run().registers


def test_unknown_engine_kind_rejected():
    s = sim.compile("mc", HW, scale="small")
    with pytest.raises(ValueError, match="unknown engine kind"):
        s.engine("verilator")
