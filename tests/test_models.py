"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finiteness, decode/prefill consistency, and
family-specific features (M-RoPE, qk_norm, MoE dispatch, SSM state)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SMOKE
from repro.models.model import build
from repro.optim import adamw

ARCH_NAMES = sorted(SMOKE)


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, 4, cfg.d_model)) * 0.02, jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frames, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_finite(name):
    cfg = SMOKE[name]
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), name
    opt = adamw.init(params)
    new_params, opt, gnorm = adamw.apply(params, grads, opt)
    assert np.isfinite(float(gnorm))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_loss_decreases(name):
    cfg = SMOKE[name]
    model = build(cfg)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg)
    opt = adamw.init(params)
    step = jax.jit(lambda p, o, b: _one_step(model, p, o, b))
    first = None
    for _ in range(8):
        params, opt, loss = step(params, opt, batch)
        if first is None:
            first = float(loss)
    assert float(loss) < first, name


def _one_step(model, params, opt, batch):
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    params, opt, _ = adamw.apply(params, grads, opt, lr=1e-2)
    return params, opt, loss


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """Greedy continuation computed by prefill+decode must equal the
    teacher-forced argmax of a full forward pass (positional + cache
    correctness)."""
    cfg = SMOKE[name]
    model = build(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S, seed=3)
    del batch["labels"]

    cache = model.make_cache(B, 32)
    logits_p, cache = jax.jit(model.prefill)(params, batch, cache)
    # full-forward logits at the last position must match prefill's output
    full = {**batch, "labels": jnp.zeros_like(batch["tokens"])}
    x, pos, enc_out, off = model._embed_inputs(params, full)
    h, _, _ = model._trunk(params, x, pos, enc_out=enc_out)
    from repro.models import layers as L
    logits_f = L.unembed(params["embed"], cfg, h[:, -1:]).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_f),
                               rtol=2e-2, atol=2e-2)

    # one decode step must be finite and shaped [B, 1, vocab]
    tok = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)[:, None]
    pos0 = S + (4 if cfg.family == "vlm" else 0)
    logits_d, cache = jax.jit(model.decode_step)(params, tok, cache, pos0)
    assert logits_d.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits_d, np.float32)).all()


def test_param_counts_sane():
    for name, cfg in ARCHS.items():
        total, active = cfg.param_count()
        # "active" counts FLOPs-relevant params per token; a *shared* block
        # applied k times (zamba2) legitimately exceeds the unique count
        if not cfg.attn_every:
            assert active <= total, name
        assert total > 1e8, name  # full configs are all >100M params
    # spot-check two well-known sizes (order of magnitude)
    t, a = ARCHS["mixtral-8x7b"].param_count()
    assert 40e9 < t < 60e9 and 10e9 < a < 16e9
    t, a = ARCHS["qwen1.5-110b"].param_count()
    assert 90e9 < t < 130e9


def test_moe_dispatch_capacity():
    """Dispatch/combine tensors route <= capacity tokens per expert and the
    combine weights are the top-k router probabilities."""
    from repro.models.moe import moe_fwd, moe_init
    cfg = SMOKE["mixtral-8x7b"]
    key = jax.random.key(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    y, aux = moe_fwd(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0


def test_swa_mask_window():
    from repro.models.layers import causal_mask
    m = np.asarray(causal_mask(8, 8, window=3))[0, 0, 0]
    assert m[5, 5] and m[5, 3] and not m[5, 2] and not m[3, 5]


def test_mrope_sections_differ():
    from repro.models.layers import apply_rope
    x = jnp.ones((1, 4, 2, 32), jnp.float32)
    pos = jnp.stack([jnp.arange(4)[None] * k for k in (1, 2, 3)], 0)
    a = apply_rope(x, pos, 1e4, m_rope=True)
    b = apply_rope(x, jnp.arange(4)[None], 1e4, m_rope=False)
    assert not np.allclose(np.asarray(a), np.asarray(b))
