"""The scheduler (PR 6): both strategies validate against the machine
model on every bench circuit, the slack scheduler's utilization stats are
consistent, self-sends are local moves, and random dependence graphs
schedule correctly under both policies (hypothesis property when
available, a seeded sweep always).
"""
from __future__ import annotations

import random

import pytest

from repro.circuits import CIRCUITS, build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig, Instr, Op
from repro.core.schedule import (PipelineInfo, STRATEGIES, pipeline_schedule,
                                 schedule, validate_schedule, _route)

HW = HardwareConfig(grid_width=5, grid_height=5)


# ----------------------------------------------------------------------
# all nine circuits x both strategies, validated against the machine model
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def programs():
    """Every bench circuit compiled under both strategies with the
    independent schedule validator enabled (check=True re-verifies RAW
    distances, order edges, link/arrival collision freedom and VCPL)."""
    out = {}
    for name in sorted(CIRCUITS):
        c = build(name).circuit
        for strat in STRATEGIES:
            out[name, strat] = compile_circuit(
                c, HW, sched_strategy=strat, check=True)
    return out


@pytest.mark.parametrize("name", sorted(CIRCUITS))
@pytest.mark.parametrize("strat", STRATEGIES)
def test_circuit_schedule_validates(programs, name, strat):
    prog = programs[name, strat]
    st = prog.stats
    assert st["sched_strategy"] == strat
    assert st["vcpl"] == st["t_compute"] + st["epilogue"]
    assert st["t_compute"] >= st["crit_path_lb"]
    assert st["vcpl_over_lb"] >= 1.0
    if strat == "slack":
        assert st["sched_prio"] in ("mobility", "height")
        assert st["remat_sends"] >= 0
    else:
        # greedy path is the frozen baseline: no rematerialization
        assert st["remat_sends"] == 0


@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_slack_never_ships_more_sends(programs, name):
    """Rematerialization only deletes communication, never adds it."""
    assert (programs[name, "slack"].stats["sends"]
            <= programs[name, "greedy"].stats["sends"])


@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_utilization_stats_consistent(programs, name):
    for strat in STRATEGIES:
        st = programs[name, strat].stats
        assert st["cores_used"] >= 1
        assert sum(st["nop_density_hist"]) == st["cores_used"]
        assert st["core_load_max"] <= st["t_compute"]
        assert 0.0 < st["core_load_mean"] <= st["core_load_max"]
        assert 0.0 <= st["epilogue_share"] < 1.0


# ----------------------------------------------------------------------
# self-sends are local moves
# ----------------------------------------------------------------------

def test_route_self_is_empty():
    hw = HardwareConfig(grid_width=3, grid_height=3)
    for c in range(hw.num_cores):
        assert _route(hw, c, c) == []


@pytest.mark.parametrize("strat", STRATEGIES)
def test_self_send_claims_no_noc(strat):
    """A SEND whose src and dst core coincide costs an issue slot but no
    link slots, no arrival slot, and no epilogue replay."""
    hw = HardwareConfig(grid_width=2, grid_height=2)
    a = Instr(Op.ADD, dst=1, srcs=())
    s = Instr(Op.SEND, dst=2, srcs=(1,))
    core_instrs = [[a, s]]
    send_dst_core = {id(s): 0}          # proc 0 lives on core 0
    res = schedule(core_instrs, [0], hw, send_dst_core,
                   [[]], [[]], strategy=strat)
    validate_schedule(res, core_instrs, [0], hw, send_dst_core, [[]], [[]])
    assert res.cores[0].recv_count == 0
    assert res.vcpl == res.t_compute        # no epilogue
    assert len(res.cores[0].sends) == 1


# ----------------------------------------------------------------------
# cross-Vcycle modulo pipelining: validator rejections + II invariants
# ----------------------------------------------------------------------

def test_cross_iteration_raw_violation_rejected():
    """A reader of a current register placed before its commit MOV demands
    ii >= sigma - reader_slot; an II below that floor must be rejected."""
    hw = HardwareConfig(grid_width=2, grid_height=2)
    rd = Instr(Op.ADD, dst=3, srcs=(2,))       # reads cur vreg 2 at the head
    tmp = Instr(Op.ADD, dst=1, srcs=())
    cm = Instr(Op.MOV, dst=2, srcs=(1,))       # commit MOV for cur vreg 2
    core_instrs = [[rd, tmp, cm]]
    war = [[(0, 2)]]                           # read-before-overwrite
    res = schedule(core_instrs, [0], hw, {}, war, [[]], strategy="slack")
    validate_schedule(res, core_instrs, [0], hw, {}, war, [[]])
    # sigma(vreg 2) = commit slot + raw_latency; the head reader at slot 0
    # forces ii >= sigma, which exceeds every legal ii < span here
    info = PipelineInfo(ii=res.vcpl - 1, prologue_len=0, span=res.vcpl,
                        hoist=[set()], share=[{}], commit_def=[{2: 2}],
                        replay_rank={})
    with pytest.raises(ValueError, match="data-hazard floor"):
        validate_schedule(res, core_instrs, [0], hw, {}, war, [[]],
                          pipeline=info)


def test_modulo_link_collision_rejected():
    """Two SENDs sharing a NoC link whose claim slots coincide modulo the
    II must be rejected — steady state replays the claims every ii slots.
    The schedule is hand-built so the shared (1 -> 2) link carries claims
    exactly ii apart: legal as a single Vcycle, a collision in overlap."""
    from repro.core.schedule import CoreProgram, ScheduleResult
    hw = HardwareConfig(grid_width=3, grid_height=1)
    a0 = Instr(Op.ADD, dst=1, srcs=())
    s0 = Instr(Op.SEND, dst=2, srcs=(1,))    # core 0 -> 2: links (0,1),(1,2)
    s1 = Instr(Op.SEND, dst=4, srcs=(9,))    # core 1 -> 2: link (1,2)
    core_instrs = [[a0, s0], [s1]]
    send_dst = {id(s0): 2, id(s1): 2}
    t_comp = 7
    res = ScheduleResult(
        cores=[
            CoreProgram([a0, None, None, None, s0, None, None],
                        0, [(4, s0)]),
            CoreProgram([None, None, s1, None, None, None, None],
                        0, [(2, s1)]),
            CoreProgram([None] * t_comp, 2, []),
        ],
        t_compute=t_comp, vcpl=t_comp + 2)
    validate_schedule(res, core_instrs, [0, 1], hw, send_dst,
                      [[], []], [[], []])
    # (1,2)-link claims: s0 at 4+1+send_latency = 6, s1 at 2+1 = 3 —
    # collision-free per Vcycle, identical residues modulo ii = 3
    info = PipelineInfo(ii=3, prologue_len=0, span=res.vcpl,
                        hoist=[set(), set()], share=[{}, {}],
                        commit_def=[{}, {}], replay_rank=None)
    info.replay_rank = _derive_ranks(res, core_instrs, [0, 1], hw,
                                     send_dst, info)
    with pytest.raises(ValueError, match=r"link .* collide modulo"):
        validate_schedule(res, core_instrs, [0, 1], hw, send_dst,
                          [[], []], [[], []], pipeline=info)


def _derive_ranks(res, core_instrs, core_of_proc, hw, send_dst, info):
    """Replay ranks exactly as the pipeliner assigns them (validator mode
    needs them recorded up front)."""
    from repro.core.schedule import _commit_sigma
    placed = [{id(ins): s for s, ins in enumerate(cp.slots)
               if ins is not None} for cp in res.cores]
    slot_of = [[placed[core_of_proc[p]][id(ins)] for ins in instrs]
               for p, instrs in enumerate(core_instrs)]
    _sigma, ranks = _commit_sigma(core_instrs, core_of_proc, hw, send_dst,
                                  info.commit_def, slot_of, res.t_compute)
    return ranks


@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_shipped_ii_never_exceeds_vcpl(programs, name):
    """Best-of-two ship rule: the shipped machine-cycles-per-Vcycle is the
    II when pipelining won and the barrier VCPL otherwise — never worse."""
    for strat in STRATEGIES:
        prog = programs[name, strat]
        st = prog.stats
        assert st["vcpl_ii"] == prog.vcpl
        assert st["vcpl_ii"] <= st["vcpl_unpipelined"]
        if st["pipeline_pick"] == "modulo":
            assert st["vcpl_ii"] < st["vcpl_unpipelined"]
        else:
            assert st["vcpl_ii"] == st["vcpl_unpipelined"]
            assert prog.pipe_prologue == 0


def test_pipeline_off_knob_is_frozen_path():
    """pipeline="off" must not even account for pipelining: the stats pin
    the unpipelined VCPL and the schedule still validates (check=True)."""
    c = build("bc").circuit
    off = compile_circuit(c, HW, pipeline="off", check=True)
    assert off.stats["pipeline"] == "off"
    assert off.stats["pipeline_pick"] == "off"
    assert off.stats["vcpl_ii"] == off.stats["vcpl_unpipelined"] == off.vcpl
    assert off.pipe_prologue == 0
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        compile_circuit(c, HW, pipeline="bogus")


# ----------------------------------------------------------------------
# random dependence graphs: both strategies produce valid schedules
# ----------------------------------------------------------------------

def _random_problem(rnd: random.Random):
    """A small random multi-process dependence graph: pure ops reading
    earlier defs, SENDs to arbitrary cores (self included), random WAR and
    memory-order edges."""
    hw = HardwareConfig(grid_width=2, grid_height=2)
    nproc = rnd.randint(1, hw.num_cores)
    core_of_proc = list(range(nproc))
    vreg = 1                                  # vreg 0 is the constant zero
    core_instrs, war_edges, order_edges = [], [], []
    send_dst_core = {}
    for _p in range(nproc):
        n = rnd.randint(0, 12)
        instrs, defined = [], []
        for _i in range(n):
            if defined and rnd.random() < 0.3:
                ins = Instr(Op.SEND, dst=vreg, srcs=(rnd.choice(defined),))
                send_dst_core[id(ins)] = rnd.randrange(hw.num_cores)
            else:
                k = rnd.randint(0, min(2, len(defined)))
                ins = Instr(Op.ADD, dst=vreg,
                            srcs=tuple(rnd.sample(defined, k)))
                defined.append(vreg)
            vreg += 1
            instrs.append(ins)
        war, order = [], []
        if n >= 2:
            for _ in range(rnd.randint(0, n)):
                a2 = rnd.randrange(n - 1)
                b2 = rnd.randrange(a2 + 1, n)
                (war if rnd.random() < 0.5 else order).append((a2, b2))
        core_instrs.append(instrs)
        war_edges.append(war)
        order_edges.append(order)
    return hw, core_instrs, core_of_proc, send_dst_core, war_edges, order_edges


def _check_random(seed: int) -> None:
    rnd = random.Random(seed)
    (hw, core_instrs, core_of_proc, send_dst_core,
     war_edges, order_edges) = _random_problem(rnd)
    vcpls = {}
    for strat in STRATEGIES:
        res = schedule(core_instrs, core_of_proc, hw, send_dst_core,
                       war_edges, order_edges, strategy=strat)
        validate_schedule(res, core_instrs, core_of_proc, hw,
                          send_dst_core, war_edges, order_edges)
        assert res.t_compute >= res.stats["crit_path_lb"]
        vcpls[strat] = res.vcpl
        # the modulo pipeliner on the same problem: when it finds an
        # overlay at all, its II is strictly below the barrier VCPL and
        # the combined schedule passes the full pipelined validator
        r = pipeline_schedule(
            core_instrs, core_of_proc, hw, send_dst_core, war_edges,
            order_edges, [dict() for _ in core_instrs],
            [dict() for _ in core_instrs], [set() for _ in core_instrs],
            strategy=strat, crit_path_lb=res.stats["crit_path_lb"],
            base=res)
        if r is not None:
            comb, info = r
            assert 1 <= info.ii < res.vcpl
            assert info.ii < comb.vcpl == info.span
            validate_schedule(comb, core_instrs, core_of_proc, hw,
                              send_dst_core, war_edges, order_edges,
                              pipeline=info)
    # both strategies schedule the same instruction set; neither may
    # blow past the trivial serial bound
    serial = sum(len(ci) for ci in core_instrs)
    lb = res.stats["crit_path_lb"]
    for v in vcpls.values():
        assert v <= 4 * max(serial, lb) + 64


def test_random_dependence_graphs_seeded():
    for seed in range(60):
        _check_random(seed)


try:
    from hypothesis import given, settings, HealthCheck
    import hypothesis.strategies as st_

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st_.integers(0, 2**32 - 1))
    def test_random_dependence_graphs_property(seed):
        _check_random(seed)
except ImportError:  # pragma: no cover - hypothesis optional
    @pytest.mark.skip(reason="hypothesis not installed in this environment")
    def test_random_dependence_graphs_property():
        pass
