"""The scheduler (PR 6): both strategies validate against the machine
model on every bench circuit, the slack scheduler's utilization stats are
consistent, self-sends are local moves, and random dependence graphs
schedule correctly under both policies (hypothesis property when
available, a seeded sweep always).
"""
from __future__ import annotations

import random

import pytest

from repro.circuits import CIRCUITS, build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig, Instr, Op
from repro.core.schedule import (STRATEGIES, schedule, validate_schedule,
                                 _route)

HW = HardwareConfig(grid_width=5, grid_height=5)


# ----------------------------------------------------------------------
# all nine circuits x both strategies, validated against the machine model
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def programs():
    """Every bench circuit compiled under both strategies with the
    independent schedule validator enabled (check=True re-verifies RAW
    distances, order edges, link/arrival collision freedom and VCPL)."""
    out = {}
    for name in sorted(CIRCUITS):
        c = build(name).circuit
        for strat in STRATEGIES:
            out[name, strat] = compile_circuit(
                c, HW, sched_strategy=strat, check=True)
    return out


@pytest.mark.parametrize("name", sorted(CIRCUITS))
@pytest.mark.parametrize("strat", STRATEGIES)
def test_circuit_schedule_validates(programs, name, strat):
    prog = programs[name, strat]
    st = prog.stats
    assert st["sched_strategy"] == strat
    assert st["vcpl"] == st["t_compute"] + st["epilogue"]
    assert st["t_compute"] >= st["crit_path_lb"]
    assert st["vcpl_over_lb"] >= 1.0
    if strat == "slack":
        assert st["sched_prio"] in ("mobility", "height")
        assert st["remat_sends"] >= 0
    else:
        # greedy path is the frozen baseline: no rematerialization
        assert st["remat_sends"] == 0


@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_slack_never_ships_more_sends(programs, name):
    """Rematerialization only deletes communication, never adds it."""
    assert (programs[name, "slack"].stats["sends"]
            <= programs[name, "greedy"].stats["sends"])


@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_utilization_stats_consistent(programs, name):
    for strat in STRATEGIES:
        st = programs[name, strat].stats
        assert st["cores_used"] >= 1
        assert sum(st["nop_density_hist"]) == st["cores_used"]
        assert st["core_load_max"] <= st["t_compute"]
        assert 0.0 < st["core_load_mean"] <= st["core_load_max"]
        assert 0.0 <= st["epilogue_share"] < 1.0


# ----------------------------------------------------------------------
# self-sends are local moves
# ----------------------------------------------------------------------

def test_route_self_is_empty():
    hw = HardwareConfig(grid_width=3, grid_height=3)
    for c in range(hw.num_cores):
        assert _route(hw, c, c) == []


@pytest.mark.parametrize("strat", STRATEGIES)
def test_self_send_claims_no_noc(strat):
    """A SEND whose src and dst core coincide costs an issue slot but no
    link slots, no arrival slot, and no epilogue replay."""
    hw = HardwareConfig(grid_width=2, grid_height=2)
    a = Instr(Op.ADD, dst=1, srcs=())
    s = Instr(Op.SEND, dst=2, srcs=(1,))
    core_instrs = [[a, s]]
    send_dst_core = {id(s): 0}          # proc 0 lives on core 0
    res = schedule(core_instrs, [0], hw, send_dst_core,
                   [[]], [[]], strategy=strat)
    validate_schedule(res, core_instrs, [0], hw, send_dst_core, [[]], [[]])
    assert res.cores[0].recv_count == 0
    assert res.vcpl == res.t_compute        # no epilogue
    assert len(res.cores[0].sends) == 1


# ----------------------------------------------------------------------
# random dependence graphs: both strategies produce valid schedules
# ----------------------------------------------------------------------

def _random_problem(rnd: random.Random):
    """A small random multi-process dependence graph: pure ops reading
    earlier defs, SENDs to arbitrary cores (self included), random WAR and
    memory-order edges."""
    hw = HardwareConfig(grid_width=2, grid_height=2)
    nproc = rnd.randint(1, hw.num_cores)
    core_of_proc = list(range(nproc))
    vreg = 1                                  # vreg 0 is the constant zero
    core_instrs, war_edges, order_edges = [], [], []
    send_dst_core = {}
    for _p in range(nproc):
        n = rnd.randint(0, 12)
        instrs, defined = [], []
        for _i in range(n):
            if defined and rnd.random() < 0.3:
                ins = Instr(Op.SEND, dst=vreg, srcs=(rnd.choice(defined),))
                send_dst_core[id(ins)] = rnd.randrange(hw.num_cores)
            else:
                k = rnd.randint(0, min(2, len(defined)))
                ins = Instr(Op.ADD, dst=vreg,
                            srcs=tuple(rnd.sample(defined, k)))
                defined.append(vreg)
            vreg += 1
            instrs.append(ins)
        war, order = [], []
        if n >= 2:
            for _ in range(rnd.randint(0, n)):
                a2 = rnd.randrange(n - 1)
                b2 = rnd.randrange(a2 + 1, n)
                (war if rnd.random() < 0.5 else order).append((a2, b2))
        core_instrs.append(instrs)
        war_edges.append(war)
        order_edges.append(order)
    return hw, core_instrs, core_of_proc, send_dst_core, war_edges, order_edges


def _check_random(seed: int) -> None:
    rnd = random.Random(seed)
    (hw, core_instrs, core_of_proc, send_dst_core,
     war_edges, order_edges) = _random_problem(rnd)
    vcpls = {}
    for strat in STRATEGIES:
        res = schedule(core_instrs, core_of_proc, hw, send_dst_core,
                       war_edges, order_edges, strategy=strat)
        validate_schedule(res, core_instrs, core_of_proc, hw,
                          send_dst_core, war_edges, order_edges)
        assert res.t_compute >= res.stats["crit_path_lb"]
        vcpls[strat] = res.vcpl
    # both strategies schedule the same instruction set; neither may
    # blow past the trivial serial bound
    serial = sum(len(ci) for ci in core_instrs)
    lb = res.stats["crit_path_lb"]
    for v in vcpls.values():
        assert v <= 4 * max(serial, lb) + 64


def test_random_dependence_graphs_seeded():
    for seed in range(60):
        _check_random(seed)


try:
    from hypothesis import given, settings, HealthCheck
    import hypothesis.strategies as st_

    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st_.integers(0, 2**32 - 1))
    def test_random_dependence_graphs_property(seed):
        _check_random(seed)
except ImportError:  # pragma: no cover - hypothesis optional
    @pytest.mark.skip(reason="hypothesis not installed in this environment")
    def test_random_dependence_graphs_property():
        pass
