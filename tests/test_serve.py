"""Serving layer (``repro.serve``): dynamic batching over hot Simulations.

Contracts under test:

- coalescing is *semantics-free*: a batch of concurrent same-fingerprint
  requests produces per-request results bit-exact against independent
  ``sim.compile(name, seeds=[s]).run()`` runs (mc/bc — builders whose
  structure is seed-invariant);
- mixed-fingerprint traffic lands on separate queues and demuxes
  correctly (mc and bc riders never contaminate each other);
- admission policy: deadline-expired requests get TIMEOUT without
  occupying a batch slot; a full queue refuses admission (REJECTED);
  batches split at ``max_batch``;
- the session LRU evicts under ``max_sessions`` and re-admission
  recompiles *warm* through the on-disk compile cache;
- ``Simulation.fingerprint`` / ``engine_kind`` / ``select_engine_kind``
  are public and survive artifact round-trips;
- the compile cache survives concurrent writers of one entry
  (atomic-rename last-writer-wins: readers see a complete old or new
  artifact, never a torn one);
- ``BatchedEngine.rebind`` swaps stimuli onto a hot engine bit-exactly;
- the TCP front-end round-trips the JSON protocol and still coalesces.
"""
import asyncio
import threading
import time

import numpy as np
import pytest

import repro.sim as sim
from repro.circuits import build
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig
from repro.serve import (BatchPolicy, Batcher, Pending, Rejected,
                         SessionManager, SimRequest, SimServer, TIMEOUT,
                         decode_response, encode_request)
from repro.sim.cache import CompileCache

HWD = {"grid_width": 5, "grid_height": 5}
HW = HardwareConfig(**HWD)


@pytest.fixture(scope="module")
def cache_dir(tmp_path_factory):
    """One on-disk compile cache for the module: canonical designs
    compile once, later tests warm-start."""
    return str(tmp_path_factory.mktemp("serve_cache"))


def _req(name, seed, **kw):
    return SimRequest(name, scale="small", seed=seed, hw=HWD, **kw)


def _assert_same_result(got, ref):
    assert got.cycles == ref.cycles
    assert got.exceptions == ref.exceptions
    assert got.registers == ref.registers
    assert got.outputs == ref.outputs


# ----------------------------------------------------------------------
# coalescing correctness
# ----------------------------------------------------------------------

def test_coalesced_bit_exact_vs_individual(cache_dir):
    """Five concurrent mc requests ride one batched launch, and every
    per-request result is bit-exact vs its own single-stimulus compile."""
    seeds = [11, 12, 13, 14, 15]

    async def go():
        server = SimServer(sessions=SessionManager(cache=cache_dir),
                           policy=BatchPolicy(max_batch=8, max_wait_s=0.3))
        try:
            return await asyncio.gather(
                *(server.submit(_req("mc", s)) for s in seeds))
        finally:
            await server.close()

    resps = asyncio.run(go())
    assert all(r.ok for r in resps), [r.error for r in resps]
    assert len({r.fingerprint for r in resps}) == 1
    assert all(r.batch == len(seeds) for r in resps)     # one launch
    assert all(r.engine_kind == "batched" for r in resps)
    for s, r in zip(seeds, resps):
        ref = sim.compile("mc", HW, scale="small", seeds=[s],
                          cache=cache_dir).run()
        assert r.result.finished and ref.finished
        _assert_same_result(r.result, ref)


def test_mixed_fingerprint_traffic_demuxes(cache_dir):
    """Interleaved mc/bc traffic: two queues, two launches, every rider
    gets its own circuit's (correct) result."""
    async def go():
        server = SimServer(sessions=SessionManager(cache=cache_dir),
                           policy=BatchPolicy(max_batch=8, max_wait_s=0.3))
        try:
            reqs = []
            for i in range(3):
                reqs.append(_req("mc", 21 + i))
                reqs.append(_req("bc", 31 + i))
            return await asyncio.gather(
                *(server.submit(r) for r in reqs))
        finally:
            await server.close()

    resps = asyncio.run(go())
    assert all(r.ok for r in resps), [r.error for r in resps]
    mc_r, bc_r = resps[0::2], resps[1::2]
    assert len({r.fingerprint for r in mc_r}) == 1
    assert len({r.fingerprint for r in bc_r}) == 1
    assert mc_r[0].fingerprint != bc_r[0].fingerprint
    assert all(r.batch == 3 for r in resps)              # per-queue batches
    for kind, group, seed0 in (("mc", mc_r, 21), ("bc", bc_r, 31)):
        for i, r in enumerate(group):
            ref = sim.compile(kind, HW, scale="small", seeds=[seed0 + i],
                              cache=cache_dir).run()
            assert r.result.finished and ref.finished
            _assert_same_result(r.result, ref)


def test_batched_engine_rebind_bit_exact(cache_dir):
    """A hot engine rebound onto new stimulus images matches a freshly
    built engine bit-exactly — the no-retrace residency contract."""
    s = sim.compile("mc", HW, scale="small", seeds=[101, 102, 103],
                    cache=cache_dir)
    eng = s.engine("batched")
    n = s.default_cycles()
    eng.run_batch(n)

    b2 = build("mc", "small", seeds=[201, 202, 203])
    imgs2 = b2.images_batch(s.program)
    fresh = s.engine("batched", images=imgs2).run_batch(n)
    machine_before = eng.m
    eng.rebind(imgs2)
    assert eng.m is machine_before          # no rebuild, no retrace
    rebound = eng.run_batch(n)
    for got, ref in zip(rebound, fresh):
        assert got.finished
        _assert_same_result(got, ref)
    with pytest.raises(ValueError):
        eng.rebind(build("mc", "small", seeds=[1, 2]).images_batch(s.program))


# ----------------------------------------------------------------------
# admission policy
# ----------------------------------------------------------------------

def test_request_timeout(cache_dir):
    """A request whose deadline passes before launch gets TIMEOUT and
    never occupies a batch slot."""
    async def go():
        server = SimServer(sessions=SessionManager(cache=cache_dir),
                           policy=BatchPolicy(max_batch=4, max_wait_s=0.2))
        try:
            ok = await server.submit(_req("mc", 1))
            late = await server.submit(_req("mc", 2, timeout=0.0))
            return ok, late, dict(server.batcher.stats)
        finally:
            await server.close()

    ok, late, stats = asyncio.run(go())
    assert ok.ok and ok.result.finished
    assert late.status == TIMEOUT and late.result is None
    assert late.wait_s >= 0.0
    assert stats["timed_out"] == 1


def test_batcher_backpressure_and_splitting():
    """Pure-batcher unit test (no jax): queue-full admission refusal,
    max_batch splitting, nothing lost."""
    async def go():
        launched = []
        gate = asyncio.Event()

        async def launch(key, batch):
            await gate.wait()
            launched.append([p.req.seed for p in batch])
            for p in batch:
                p.future.set_result(p.req.seed)

        b = Batcher(BatchPolicy(max_batch=3, max_wait_s=0.05, max_queue=4),
                    launch)
        loop = asyncio.get_running_loop()

        def pend(s):
            return Pending(req=SimRequest("x", seed=s),
                           future=loop.create_future())

        first = [pend(i) for i in range(4)]
        for p in first:
            b.submit("k", p)
        # let the drain task pull max_batch=3 into a forming batch (it
        # then blocks on the gate); the queue holds the 4th
        await asyncio.sleep(0.15)
        extra = [pend(10 + i) for i in range(3)]
        for p in extra:
            b.submit("k", p)                       # queue back at 4
        with pytest.raises(Rejected):
            b.submit("k", pend(99))                # admission refused
        gate.set()
        res = await asyncio.gather(*(p.future for p in first + extra))
        await b.close()
        return launched, res, dict(b.stats)

    launched, res, stats = asyncio.run(go())
    assert sorted(res) == [0, 1, 2, 3, 10, 11, 12]
    assert launched[0] == [0, 1, 2]                # split at max_batch
    assert all(len(x) <= 3 for x in launched)
    assert sum(len(x) for x in launched) == 7
    assert stats["rejected"] == 1
    assert stats["launches"] == len(launched)


# ----------------------------------------------------------------------
# session lifecycle
# ----------------------------------------------------------------------

def test_lru_eviction_recompiles_warm(tmp_path):
    """max_sessions=1: admitting bc evicts mc; re-admitting mc compiles
    *warm* from the on-disk cache and still simulates correctly."""
    async def go():
        sm = SessionManager(cache=str(tmp_path), max_sessions=1)
        server = SimServer(sessions=sm,
                           policy=BatchPolicy(max_batch=2, max_wait_s=0.05))
        try:
            r1 = await server.submit(_req("mc", 3))
            assert sm.counters["cache_hits"] == 0  # cold: fresh cache dir
            r2 = await server.submit(_req("bc", 3))
            assert sm.counters["evictions"] >= 1
            assert len(sm.resident()) == 1
            r3 = await server.submit(_req("mc", 4))
            return r1, r2, r3, dict(sm.counters)
        finally:
            await server.close()

    r1, r2, r3, stats = asyncio.run(go())
    for r in (r1, r2, r3):
        assert r.ok and r.result.finished, r.error
    assert r1.fingerprint == r3.fingerprint
    assert stats["compiles"] == 3
    assert stats["cache_hits"] == 1                # mc came back warm


def test_unknown_circuit_and_option_are_errors(cache_dir):
    async def go():
        server = SimServer(sessions=SessionManager(cache=cache_dir),
                           policy=BatchPolicy(max_wait_s=0.01))
        try:
            bad_name = await server.submit(SimRequest("nonesuch"))
            bad_opt = await server.submit(
                _req("mc", 1, options={"frobnicate": True}))
            return bad_name, bad_opt
        finally:
            await server.close()

    bad_name, bad_opt = asyncio.run(go())
    assert bad_name.status == "error" and "nonesuch" in bad_name.error
    assert bad_opt.status == "error" and "frobnicate" in bad_opt.error


# ----------------------------------------------------------------------
# public Simulation attributes (facade)
# ----------------------------------------------------------------------

def test_fingerprint_and_engine_kind_public(tmp_path):
    s = sim.compile("mc", HW, scale="small")
    assert s.fingerprint == s.circuit.fingerprint()
    assert s.engine_kind == "machine"

    s2 = sim.compile("mc", HW, scale="small", seeds=[1, 2])
    assert s2.fingerprint is not None
    assert s2.engine_kind == "batched"
    fake8 = [object()] * 8
    assert s2.select_engine_kind(64, devices=fake8) == "sharded"
    assert s2.select_engine_kind(8, devices=fake8) == "batched"  # B < 2*D
    assert s2.select_engine_kind(1) == "machine"
    assert s2.select_engine_kind(64, devices=fake8,
                                 shard_batch=False) == "batched"
    s3 = sim.compile("mc", HW, scale="small", seeds=[1, 2],
                     shard_batch=True)
    assert s3.select_engine_kind(2, devices=fake8) == "sharded"

    # the fingerprint is recorded in Program.stats, so it survives the
    # artifact round-trip (a loaded Simulation has no circuit to hash)
    p = tmp_path / "mc.npz"
    s.save(p)
    loaded = sim.load(p)
    assert loaded.circuit is None
    assert loaded.fingerprint == s.fingerprint


# ----------------------------------------------------------------------
# compile-cache concurrency (atomic rename, last-writer-wins)
# ----------------------------------------------------------------------

def test_cache_concurrent_writers_last_writer_wins(tmp_path):
    """Writer threads hammer one cache key with two different (complete)
    programs while readers load continuously: every successful load is a
    bit-exact copy of one of the writers' programs — never a torn mix —
    and the final entry is valid."""
    prog_a = compile_circuit(build("mc", "small").circuit, HW)
    prog_b = compile_circuit(build("bc", "small").circuit, HW)
    cc = CompileCache(tmp_path)
    key = "f" * 64
    stop = threading.Event()
    bad = []

    def writer(prog):
        while not stop.is_set():
            cc.store(key, prog)

    def reader():
        while not stop.is_set():
            p = cc.load(key)
            if p is None:          # entry mid-replace reads as a miss
                continue
            ref = {"mc": prog_a, "bc": prog_b}.get(p.name)
            if ref is None:
                bad.append(f"unknown name {p.name!r}")
            elif not (np.array_equal(p.code, ref.code)
                      and np.array_equal(p.reg_init, ref.reg_init)
                      and np.array_equal(p.xchg_src_core,
                                         ref.xchg_src_core)):
                bad.append("torn artifact read")

    threads = [threading.Thread(target=writer, args=(prog_a,)),
               threading.Thread(target=writer, args=(prog_b,)),
               threading.Thread(target=reader),
               threading.Thread(target=reader)]
    for t in threads:
        t.start()
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not bad, bad[:3]
    final = cc.load(key)
    assert final is not None and final.name in ("mc", "bc")
    # no temp-file litter left behind in the cache directory
    assert not [f for f in tmp_path.iterdir() if f.name.endswith(".tmp")]


# ----------------------------------------------------------------------
# TCP front-end
# ----------------------------------------------------------------------

def test_tcp_roundtrip_coalesces(cache_dir):
    async def go():
        server = SimServer(sessions=SessionManager(cache=cache_dir),
                           policy=BatchPolicy(max_batch=4,
                                              max_wait_s=0.25))
        try:
            tcp = await server.serve_tcp("127.0.0.1", 0)
            port = tcp.sockets[0].getsockname()[1]
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", port)
            reqs = [_req("mc", 41 + i) for i in range(2)]
            for r in reqs:
                writer.write(encode_request(r))
            await writer.drain()
            resps = [decode_response(await reader.readline())
                     for _ in range(2)]
            writer.close()
            return reqs, resps
        finally:
            await server.close()

    reqs, resps = asyncio.run(go())
    by_rid = {r.rid: r for r in resps}
    assert set(by_rid) == {r.rid for r in reqs}
    for r in resps:
        assert r.ok and r.result.finished
        assert r.batch == 2                       # coalesced over TCP
        assert r.result.cycles > 0 and r.result.registers
