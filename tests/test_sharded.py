"""Batch-sharded execution (PR 5): B stimuli of one compiled Program split
``[D, B/D]`` over a device mesh (``core.bsp.ShardedBatchedMachine``).

Contracts under test:

- every element of a sharded run is bit-exact against an independent
  single-stimulus specialized run of the same stimulus (mm/mc/bc, 8 forced
  host devices);
- a non-divisible B pads to ``ceil(B/D)*D`` and the padding elements never
  execute, raise, or appear in results/exceptions/perf;
- per-element exception freezing is device-local: an element living on a
  device != 0 freezes at its own raising Vcycle, and the sharded Pallas
  chunk kernel matches the sharded jnp graph;
- facade auto-selection: multi-device mesh + batch picks
  ``ShardedBatchedEngine`` (B >= 2*D), a single device falls back to
  ``BatchedEngine``, `shard_batch=` overrides both ways;
- the B=1 batched fast path skips the vmap wrapper entirely;
- ``Program.init_images_batch`` (host-parallel, stacked) matches the
  sequential per-stimulus ``init_images``.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the pattern of
``test_batched.py::test_batched_grid_machine_8dev``).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.circuits import FINISH, build
from repro.core.bsp import BatchedMachine, Machine, ShardedBatchedMachine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig

ROOT = Path(__file__).resolve().parents[1]
HW = HardwareConfig(grid_width=5, grid_height=5)


def _run_8dev(body: str, ok: str, timeout: int = 900) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    assert ok in r.stdout


# ----------------------------------------------------------------------
# multi-device (8 forced host devices, subprocess)
# ----------------------------------------------------------------------

def test_sharded_bit_exact_and_padding_8dev():
    """B=11 (non-divisible by D=8 -> padded to 16) on mm/mc/bc: every
    element bit-exact vs an independent single-stimulus specialized run;
    padding executes nothing and leaks nowhere."""
    body = """
        import numpy as np, jax
        from repro.circuits import build, FINISH
        from repro.core.isa import HardwareConfig
        from repro.core.compile import compile_circuit
        from repro.core.bsp import Machine, ShardedBatchedMachine

        assert len(jax.devices()) == 8
        HW = HardwareConfig(grid_width=5, grid_height=5)
        B = 11
        for nm in ("mm", "mc", "bc"):
            b = build(nm, "small", seeds=[1000 + i for i in range(B)])
            prog = compile_circuit(b.circuit, HW)
            imgs = b.images_batch(prog)            # stacked, host-parallel
            sm = ShardedBatchedMachine(prog, images=imgs)
            assert (sm.D, sm.B, sm.Bp) == (8, B, 16)
            st = sm.run(sm.init_state(), b.n_cycles + 10)
            m = Machine(prog)
            for i in range(B):
                s1 = m.run(m.init_state(
                    images=(imgs[0][i], imgs[1][i], imgs[2][i])),
                    b.n_cycles + 10)
                np.testing.assert_array_equal(np.asarray(st.regs[i]),
                                              np.asarray(s1.regs))
                np.testing.assert_array_equal(np.asarray(st.spads[i]),
                                              np.asarray(s1.spads))
                np.testing.assert_array_equal(np.asarray(st.flags[i]),
                                              np.asarray(s1.flags))
                np.testing.assert_array_equal(np.asarray(st.counters[i]),
                                              np.asarray(s1.counters))
                assert set(sm.exceptions(st, i).values()) == {FINISH}
            # padding elements never execute, never raise
            assert not np.asarray(st.flags[B:]).any()
            assert not np.asarray(st.counters[B:]).any()
            # ...and never surface: accessors cover the logical batch only
            assert len(sm.exceptions(st)) == B
            p = sm.perf(st)
            assert p["batch"] == B
            assert p["vcycles"] == B * b.n_cycles
        print("SHARDED-EXACT-OK")
    """
    _run_8dev(body, "SHARDED-EXACT-OK")


def test_sharded_freeze_on_nonzero_device_8dev():
    """Per-stimulus FINISH cycles spread over all 8 devices: each element
    (including those on devices != 0) freezes at its own raising Vcycle,
    device-locally; the sharded Pallas chunk kernel matches the sharded
    jnp graph bit-for-bit."""
    body = """
        import numpy as np, jax
        from repro.circuits import FINISH
        from repro.circuits.common import Planes, make_counter
        from repro.core.isa import HardwareConfig
        from repro.core.compile import compile_circuit
        from repro.core.netlist import Circuit
        from repro.core.bsp import Machine, ShardedBatchedMachine

        assert len(jax.devices()) == 8
        HW = HardwareConfig(grid_width=5, grid_height=5)
        stops = [5 + 4 * i for i in range(16)]   # 2 elements per device
        c = Circuit("freeze")
        planes = Planes(c, len(stops), live=True)
        ctr = make_counter(c, 16)
        stop = planes.hold(stops, 16, "stopc")
        acc = planes.reg(32, [0x1000 * (i + 1) for i in range(len(stops))],
                         "acc")
        c.set_next(acc, acc + (acc >> 3) + 1)
        c.finish_when(ctr.eq(stop), FINISH)
        prog = compile_circuit(c, HW)
        images = [prog.init_images(r, m)
                  for r, m in zip(planes.regs, planes.mems)]
        sj = ShardedBatchedMachine(prog, images=images, chunk=8)
        stj = sj.run(sj.init_state(), 100)
        sp = ShardedBatchedMachine(prog, images=images, backend="pallas",
                                   chunk=8, interpret=True)
        stp = sp.run(sp.init_state(), 100)
        for i, s in enumerate(stops):
            # element i lives on device i // 2; all must freeze locally
            assert sj.perf(stj, i)["vcycles"] == s + 1
            assert set(sj.exceptions(stj, i).values()) == {FINISH}
            m = Machine(prog, specialize=False)
            s1 = m.run(m.init_state(images=images[i]), 100)
            np.testing.assert_array_equal(np.asarray(stj.regs[i]),
                                          np.asarray(s1.regs))
            np.testing.assert_array_equal(np.asarray(stj.flags[i]),
                                          np.asarray(s1.flags))
        for lj, lp in zip(stj, stp):
            np.testing.assert_array_equal(np.asarray(lj), np.asarray(lp))
        print("SHARDED-FREEZE-OK")
    """
    _run_8dev(body, "SHARDED-FREEZE-OK")


def test_facade_auto_selection_8dev():
    """mesh + batch picks the sharded engine (B >= 2*D); small batches and
    shard_batch=False stay on the vmapped single-device engine; results
    agree between the two."""
    body = """
        import jax
        import repro.sim as sim
        from repro.sim import BatchedEngine, ShardedBatchedEngine
        from repro.core import HardwareConfig

        assert len(jax.devices()) == 8
        HW = HardwareConfig(grid_width=5, grid_height=5)
        seeds = [100 + i for i in range(16)]
        s = sim.compile("mc", HW, scale="small", seeds=seeds)
        e = s.engine("auto")
        assert isinstance(e, ShardedBatchedEngine), type(e)
        res = s.run()
        assert len(res) == 16 and all(r.finished for r in res)

        sb = sim.compile("mc", HW, scale="small", seeds=seeds,
                         shard_batch=False)
        eb = sb.engine("auto")
        assert isinstance(eb, BatchedEngine)
        assert not isinstance(eb, ShardedBatchedEngine)
        resb = sb.run()
        assert [r.registers for r in resb] == [r.registers for r in res]
        assert [r.exceptions for r in resb] == [r.exceptions for r in res]

        s4 = sim.compile("mc", HW, scale="small", seeds=seeds[:4])
        e4 = s4.engine("auto")       # B=4 < 2*D: stay vmapped
        assert isinstance(e4, BatchedEngine)
        assert not isinstance(e4, ShardedBatchedEngine)
        print("FACADE-AUTO-OK")
    """
    _run_8dev(body, "FACADE-AUTO-OK")


# ----------------------------------------------------------------------
# single-device (in-process)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def mc_small():
    b = build("mc", "small", seeds=[3, 11, 42])
    prog = compile_circuit(b.circuit, HW)
    return b, prog


def test_sharded_single_device_matches_batched(mc_small):
    """D=1 is the degenerate mesh: the sharded engine must reproduce the
    vmapped engine exactly (same chunk body, one shard)."""
    import jax
    b, prog = mc_small
    imgs = b.images_batch(prog)
    sm = ShardedBatchedMachine(prog, images=imgs,
                               devices=jax.devices()[:1])
    assert (sm.D, sm.Bp) == (1, sm.B)
    bm = BatchedMachine(prog, images=b.images(prog))
    st = sm.run(sm.init_state(), b.n_cycles + 10)
    sb = bm.run(bm.init_state(), b.n_cycles + 10)
    for ls, lb in zip(st, sb):
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lb))


def test_batched_b1_skips_vmap(mc_small):
    """A batch of one dispatches the plain specialized graph — no vmap
    wrapper — and stays bit-exact against the single-stimulus engine."""
    b, prog = mc_small
    images = b.images(prog)
    bm = BatchedMachine(prog, images=images[:1])
    assert bm._plain
    assert bm._run_chunk.__wrapped__.__func__ is \
        BatchedMachine._b1chunk_impl
    st = bm.run(bm.init_state(), b.n_cycles + 10)
    m = Machine(prog)
    s1 = m.run(m.init_state(images=images[0]), b.n_cycles + 10)
    np.testing.assert_array_equal(np.asarray(st.regs[0]),
                                  np.asarray(s1.regs))
    np.testing.assert_array_equal(np.asarray(st.flags[0]),
                                  np.asarray(s1.flags))
    np.testing.assert_array_equal(np.asarray(st.counters[0]),
                                  np.asarray(s1.counters))
    # a real batch keeps the vmapped body
    assert not BatchedMachine(prog, images=images)._plain


def test_init_images_batch_matches_sequential(mc_small):
    """The host-parallel stacked generator is a pure layout change: each
    row equals the sequential per-stimulus init_images output, threaded or
    not."""
    b, prog = mc_small
    stacked = prog.init_images_batch(b.reg_planes, b.mem_planes)
    serial = prog.init_images_batch(b.reg_planes, b.mem_planes, workers=1)
    singles = [prog.init_images(r, m)
               for r, m in zip(b.reg_planes, b.mem_planes)]
    for k in range(3):
        np.testing.assert_array_equal(stacked[k], serial[k])
        np.testing.assert_array_equal(
            stacked[k], np.stack([im[k] for im in singles]))


def test_facade_single_device_falls_back(mc_small):
    """On one device, auto stays on the vmapped engine; shard_batch=True
    still runs (degenerate D=1 mesh) with identical results; B=1 avoids
    the batched engine entirely."""
    import repro.sim as sim
    from repro.sim import (BatchedEngine, MachineEngine,
                           ShardedBatchedEngine)
    b, prog = mc_small
    s = sim.compile(b, HW)
    e = s.engine("auto")
    assert isinstance(e, BatchedEngine)
    assert not isinstance(e, ShardedBatchedEngine)
    res = s.run()
    es = s.engine("auto", shard_batch=True)
    assert isinstance(es, ShardedBatchedEngine)
    res_s = es.run_batch(s.default_cycles())
    assert [r.registers for r in res_s] == [r.registers for r in res]
    s1 = sim.compile("mc", HW, scale="small", seeds=[7])
    assert isinstance(s1.engine("auto"), MachineEngine)
