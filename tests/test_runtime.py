"""Runtime: checkpoint/restore, elastic resharding (LM + RTL engine),
deterministic data pipeline, gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.circuits import build, FINISH
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig
from repro.configs import SMOKE
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import build as build_model
from repro.optim import adamw
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime import elastic


def test_checkpoint_roundtrip(tmp_path):
    cfg = SMOKE["qwen3-0.6b"]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw.init(params)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(7, {"params": params, "opt": opt}, blocking=True)
    assert mgr.latest_step() == 7
    step, restored = mgr.restore_tree({"params": params, "opt": opt})
    assert step == 7
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.arange(8)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    steps = sorted(int(p.name[5:-7]) for p in tmp_path.glob("step_*.COMMIT"))
    assert steps == [3, 4]
    # a partial (uncommitted) dir is ignored
    (tmp_path / "step_00000009").mkdir()
    assert mgr.latest_step() == 4


def test_pipeline_deterministic_resume():
    cfg = PipelineConfig(vocab=128, seq_len=32, global_batch=8)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b5 = p1.batch_at(5)
    assert np.array_equal(b5["tokens"], p2.batch_at(5)["tokens"])
    assert not np.array_equal(b5["tokens"], p1.batch_at(6)["tokens"])
    # host sharding partitions the batch deterministically
    h0 = TokenPipeline(PipelineConfig(128, 32, 8, n_hosts=2, host_id=0))
    h1 = TokenPipeline(PipelineConfig(128, 32, 8, n_hosts=2, host_id=1))
    assert h0.batch_at(3)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(3)["tokens"],
                              h1.batch_at(3)["tokens"])


def test_pipeline_config_positional_fields():
    c = PipelineConfig(128, 32, 8, n_hosts=2, host_id=1)
    assert c.vocab == 128 and c.host_id == 1


def test_rtl_elastic_migration():
    """Re-scale a running simulation from a 3x3 grid to a 5x5 grid: the
    migrated machine continues and finishes at the exact same cycle with the
    same architectural state."""
    b = build("mc", "small")
    hw_a = HardwareConfig(grid_width=3, grid_height=3)
    hw_b = HardwareConfig(grid_width=5, grid_height=5)
    prog_a = compile_circuit(b.circuit, hw_a)
    prog_b = compile_circuit(b.circuit, hw_b)
    ma = Machine(prog_a)
    half = b.n_cycles // 2
    st_a = ma.run(ma.init_state(), half)
    assert ma.perf(st_a)["vcycles"] == half

    mb = Machine(prog_b)
    st_b = elastic.migrate(prog_a, st_a, prog_b, mb)
    st_b = mb.run(st_b, b.n_cycles)
    # continues to the exact finish cycle
    total = int(np.asarray(st_b.counters)[0]) + half
    assert total == b.n_cycles
    assert set(mb.exceptions(st_b).values()) == {FINISH}

    # reference: uninterrupted run on grid B
    ref = Machine(prog_b)
    st_r = ref.run(ref.init_state(), b.n_cycles + 10)
    for name in prog_b.state_regs:
        assert mb.read_reg(st_b, name) == ref.read_reg(st_r, name), name


def test_grad_compression_error_feedback():
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 64)), jnp.float32)}
    ef = jax.tree.map(jnp.zeros_like, grads)
    q, s, resid = adamw.compress_grads(grads, ef)
    deq = jax.tree.map(adamw.dequantize_int8, q, s)
    err1 = float(jnp.abs(deq["w"] - grads["w"]).max())
    assert err1 < float(s["w"]) + 1e-6          # bounded by one quantum
    # error feedback: the next round re-injects the residual
    q2, s2, resid2 = adamw.compress_grads(grads, resid)
    deq2 = jax.tree.map(adamw.dequantize_int8, q2, s2)
    two_round = (np.asarray(deq["w"]) + np.asarray(deq2["w"])) / 2
    base = np.asarray(grads["w"])
    assert np.abs(two_round - base).mean() < np.abs(
        np.asarray(deq["w"]) - base).mean()


def test_lm_checkpoint_elastic_reshard(tmp_path):
    """Restore a checkpoint onto a differently-shaped mesh (1-device CPU
    'mesh' here; the spec rebuild path is what is being exercised)."""
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_host_mesh
    cfg = SMOKE["qwen3-1.7b"]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"params": params}, blocking=True)
    mesh = make_host_mesh(model=1)
    specs = SH.param_specs(cfg, mesh, model.abstract_params())
    shardings = SH.to_named(mesh, specs)
    step, restored = mgr.restore_tree({"params": params},
                                      shardings={"params": shardings})
    x = jax.tree.leaves(restored["params"])[0]
    assert x.sharding is not None
    assert step == 1


def test_health_monitor():
    from repro.runtime.health import HealthMonitor
    m = HealthMonitor(n_hosts=4, heartbeat_timeout_s=10.0,
                      straggler_factor=1.5, min_samples=4)
    t0 = 1000.0
    for step in range(8):
        for h in range(4):
            if h == 3 and step >= 2:
                continue  # host 3 dies after step 1
            dt = 1.0 if h != 2 else 2.5  # host 2 straggles
            m.heartbeat(h, step_time_s=dt, now=t0 + step)
    d = m.decide(now=t0 + 12)   # hosts 0-2 beat 5s ago; host 3 beat 11s ago
    assert d["evict_now"] == [3]
    assert 2 in d["drain_at_checkpoint"]
    assert d["action"] == "restart_elastic"
