"""Pallas vcycle kernel: shape sweeps + random-program allclose vs ref.py.

The kernel runs in interpret mode (no TPU in this container); equivalence is
bit-exact (uint16 semantics), checked against both the pure-jnp oracle
(kernels/ref.py) and the numpy ISA simulator on compiled programs.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # hypothesis is optional (offline containers) — only the property
    # test needs it; the deterministic sweeps below always run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.isa import Op
from repro.kernels.ref import vcycle_ref
from repro.kernels.vcycle import vcycle_pallas

RESULT_OPS = [Op.MOV, Op.ADD, Op.ADDC, Op.CARRY, Op.SUB, Op.SUBB, Op.BORROW,
              Op.MUL, Op.MULH, Op.AND, Op.OR, Op.XOR, Op.NOT, Op.MUX,
              Op.SEQ, Op.SNE, Op.SLTU, Op.SLL, Op.SRL, Op.SRA, Op.SLLV,
              Op.SRLV, Op.SLICE, Op.LUT, Op.LD, Op.ST, Op.SEND, Op.EXPECT,
              Op.NOP]


def random_program(rng, C, T, R, S, L=8):
    code = np.zeros((T, C, 7), np.int32)
    for t in range(T):
        for c in range(C):
            op = rng.choice(RESULT_OPS)
            dst = rng.integers(1, R)
            srcs = rng.integers(0, R, 4)
            if op in (Op.SLL, Op.SRL, Op.SRA):
                imm = rng.integers(0, 16)
            elif op == Op.SLICE:
                w = rng.integers(1, 17)
                off = rng.integers(0, 16)
                imm = off * 32 + w
            elif op == Op.LUT:
                imm = rng.integers(0, L)
            else:
                imm = rng.integers(0, 1 << 15)
            code[t, c] = (int(op), dst, *srcs, imm)
    luts = rng.integers(0, 1 << 16, (C, L, 16)).astype(np.uint32)
    regs = rng.integers(0, 1 << 16, (C, R)).astype(np.uint32)
    regs[:, 0] = 0
    spads = rng.integers(0, 1 << 16, (C, S)).astype(np.uint32)
    flags = np.zeros((C,), np.uint32)
    return code, luts, regs, spads, flags


@pytest.mark.parametrize("C,T,R,S,tile", [
    (1, 4, 8, 16, 1),
    (4, 16, 32, 64, 2),
    (8, 32, 64, 32, 8),
    (16, 8, 16, 16, 4),
    (6, 12, 24, 48, 3),
])
def test_kernel_matches_ref_sweep(C, T, R, S, tile):
    rng = np.random.default_rng(C * 1000 + T)
    code, luts, regs, spads, flags = random_program(rng, C, T, R, S)
    args = (jnp.asarray(code), jnp.asarray(luts), jnp.asarray(regs),
            jnp.asarray(spads), jnp.asarray(flags))
    r_ref = vcycle_ref(*args)
    r_pal = vcycle_pallas(*args, tile=tile, interpret=True)
    for a, b, name in zip(r_ref, r_pal, ("regs", "spads", "flags", "trace")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
           st.sampled_from([4, 16, 48]))
    def test_kernel_matches_ref_property(seed, C, T):
        rng = np.random.default_rng(seed)
        code, luts, regs, spads, flags = random_program(rng, C, T, 32, 32)
        args = (jnp.asarray(code), jnp.asarray(luts), jnp.asarray(regs),
                jnp.asarray(spads), jnp.asarray(flags))
        r_ref = vcycle_ref(*args)
        r_pal = vcycle_pallas(*args, tile=2, interpret=True)
        for a, b in zip(r_ref, r_pal):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
else:
    @pytest.mark.skip(reason="hypothesis not installed in this environment")
    def test_kernel_matches_ref_property():
        pass


def test_ref_matches_isasim_on_compiled_program():
    """Triangulate: compiled bench -> one Vcycle on ref.py == numpy IsaSim."""
    from repro.circuits import build
    from repro.core.compile import compile_circuit
    from repro.core.isa import HardwareConfig
    from repro.core.isasim import IsaSim

    b = build("mc", "small")
    prog = compile_circuit(b.circuit, HardwareConfig(grid_width=4,
                                                     grid_height=4))
    C = prog.used_cores
    code = np.ascontiguousarray(prog.code[:C].transpose(1, 0, 2))
    sim = IsaSim(prog)
    regs = sim.regs.copy()
    spads = sim.spads.copy()
    sim.step()
    r, s, f, trace = vcycle_ref(
        jnp.asarray(code), jnp.asarray(prog.luts[:C].astype(np.uint32)),
        jnp.asarray(regs), jnp.asarray(spads),
        jnp.zeros((C,), jnp.uint32))
    # apply the exchange like the engine does
    r = np.asarray(r).copy()
    tr = np.asarray(trace)
    for i in range(prog.xchg_src_core.shape[0]):
        r[prog.xchg_dst_core[i], prog.xchg_dst_reg[i]] = \
            tr[prog.xchg_src_slot[i], prog.xchg_src_core[i]]
    np.testing.assert_array_equal(r, sim.regs)
    np.testing.assert_array_equal(np.asarray(s), sim.spads)


@pytest.mark.parametrize("BH,S,dh,bq,bk,dtype,causal", [
    (2, 256, 64, 64, 64, "float32", True),
    (2, 256, 64, 64, 128, "float32", False),
    (4, 512, 128, 128, 256, "bfloat16", True),
    (1, 128, 32, 128, 64, "float32", True),
    (3, 384, 64, 128, 128, "bfloat16", True),
])
def test_flash_attention_matches_ref(BH, S, dh, bq, bk, dtype, causal):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_ref
    import jax
    rng = jax.random.key(BH * S)
    kq, kk, kv = jax.random.split(rng, 3)
    q = (jax.random.normal(kq, (BH, S, dh), jnp.float32)).astype(dtype)
    k = (jax.random.normal(kk, (BH, S, dh), jnp.float32)).astype(dtype)
    v = (jax.random.normal(kv, (BH, S, dh), jnp.float32)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
